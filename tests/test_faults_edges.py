"""FaultPlan edge cases the main endurance suite does not pin down:
degenerate plan construction (zero events, exact-fit padding,
heterogeneous stacking) and the fault-cursor register at the boundaries
of its domain — events scheduled past the end of the run must cost
nothing and must NOT wrap the cursor, and a fully consumed plan must
stay consumed across continued runs."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import Engine
from repro.core import (FaultPlan, Trace, check_table, pad_plan,
                        seeded_plan, small_platform, stack_plans)
from repro.core import table as table_lib
from repro.core.faults import NEVER


def _write_burst(cfg, n, lo, hi, seed=0):
    rng = np.random.default_rng(seed)
    page = rng.integers(lo, hi, n).astype(np.int32)
    off = (rng.integers(0, cfg.page_size // 64, n) * 64).astype(np.int32)
    return Trace(jnp.asarray(page), jnp.asarray(off),
                 jnp.ones(n, bool), jnp.full(n, 64, jnp.int32))


# ---------------------------------------------------------------------
# plan construction edges
# ---------------------------------------------------------------------
def test_zero_fault_seeded_plan_is_the_empty_plan():
    """n_deaths=0, n_transient=0 must build the exact sentinel plan —
    same arrays, same shape_sig, so it shares the empty plan's compiled
    entry point instead of minting a new one."""
    plan = seeded_plan(123, pages=np.arange(16), n_chunks=50)
    empty = FaultPlan.empty()
    assert plan.shape_sig == empty.shape_sig == (((1, 2), (1, 2)))
    np.testing.assert_array_equal(np.asarray(plan.transient),
                                  np.asarray(empty.transient))
    np.testing.assert_array_equal(np.asarray(plan.deaths),
                                  np.asarray(empty.deaths))
    assert not plan.is_batched


def test_pad_plan_rejects_shrinking():
    plan = FaultPlan.of(deaths=[(1, 2), (3, 4), (5, 6)],
                        transient=[(0, 1), (2, 3)])
    with pytest.raises(ValueError, match="3 events > pad 2"):
        pad_plan(plan, nt=2, nd=2)
    with pytest.raises(ValueError, match="2 events > pad 1"):
        pad_plan(plan, nt=1, nd=3)


def test_pad_plan_exact_fit_is_identity():
    plan = FaultPlan.of(deaths=[(1, 2), (3, 4)], transient=[(0, 1)])
    same = pad_plan(plan, nt=1, nd=2)
    assert same.shape_sig == plan.shape_sig
    np.testing.assert_array_equal(np.asarray(same.transient),
                                  np.asarray(plan.transient))
    np.testing.assert_array_equal(np.asarray(same.deaths),
                                  np.asarray(plan.deaths))
    # padding past the fit appends only never-due sentinels
    grown = pad_plan(plan, nt=3, nd=5)
    assert grown.shape_sig == ((3, 2), (5, 2))
    assert (np.asarray(grown.transient)[1:, 0] == -1).all()
    assert (np.asarray(grown.deaths)[2:, 0] == NEVER).all()


def test_stack_plans_rejects_heterogeneous_shapes():
    a = pad_plan(FaultPlan.of(deaths=[(1, 2)]), nt=2, nd=2)
    b = FaultPlan.empty()  # (1, 2) rows — disagrees with (2, 2)
    with pytest.raises(ValueError, match="disagree on event-array shapes"):
        stack_plans([a, b])
    stacked = stack_plans([a, pad_plan(b, nt=2, nd=2)])
    assert stacked.is_batched
    assert stacked.deaths.shape == (2, 2, 2)


# ---------------------------------------------------------------------
# fault-cursor domain edges
# ---------------------------------------------------------------------
def test_death_past_end_of_run_is_inert_and_cursor_does_not_move():
    """A death stamped beyond the last boundary of the run must (a) leave
    the run bitwise-identical to the empty plan and (b) leave the cursor
    at 0 — not consumed, not wrapped — so a later continued run that DOES
    reach the stamp still fires it exactly once."""
    cfg = small_platform(chunk=8, policy="hotness", decay_every=8)
    engine = Engine(cfg)
    t = _write_burst(cfg, 32, cfg.n_fast_pages, cfg.n_pages)
    # 32 requests / chunk=8 -> boundaries 0..3; stamp far past them
    late = FaultPlan.of(deaths=[(1000, cfg.n_fast_pages + 2)])
    a = engine.run(t, donate=False, faults=FaultPlan.empty())
    b = engine.run(t, donate=False, faults=late)
    for k in a.outs:
        np.testing.assert_array_equal(np.asarray(a.outs[k]),
                                      np.asarray(b.outs[k]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(a.state.table),
                                  np.asarray(b.state.table))
    np.testing.assert_array_equal(np.asarray(a.state.counters),
                                  np.asarray(b.state.counters))
    assert int(b.state.fault_cursor) == 0
    assert int(b.state.counters.frames_retired) == 0
    # continue past the stamp: the plan is keyed on absolute chunk_idx,
    # so the deferred death fires exactly once in the continuation
    state = b.state
    assert int(state.chunk_idx) == 4
    long_t = _write_burst(cfg, 8 * 1000, cfg.n_fast_pages, cfg.n_pages,
                          seed=1)
    state, _ = engine.run(long_t, state=state, faults=late)
    assert int(state.fault_cursor) == 1
    assert int(state.counters.frames_retired) == 1


def test_consumed_plan_does_not_refire_on_continuation():
    """Once every death is consumed the cursor saturates at nd; running
    on — with the SAME plan still attached — must not re-fire events or
    walk the cursor past the end of the array."""
    cfg = small_platform(chunk=8, policy="hotness", decay_every=8)
    engine = Engine(cfg)
    victims = [cfg.n_fast_pages + 2, cfg.n_fast_pages + 5]
    plan = FaultPlan.of(deaths=[(0, victims[0]), (1, victims[1])])
    t = _write_burst(cfg, 64, cfg.n_fast_pages, cfg.n_pages)
    state, _ = engine.run(t, faults=plan)
    assert int(state.fault_cursor) == 2          # nd: fully consumed
    assert int(state.counters.frames_retired) == 2
    check_table(cfg, np.asarray(state.table))
    # two more runs with the consumed plan: nothing new may die
    for seed in (1, 2):
        t2 = _write_burst(cfg, 64, cfg.n_fast_pages, cfg.n_pages,
                          seed=seed)
        state, _ = engine.run(t2, state=state, faults=plan)
        assert int(state.fault_cursor) == 2, "cursor wrapped or re-fired"
        assert int(state.counters.frames_retired) == 2
    flags = np.asarray(state.table)[:, table_lib.FLAGS]
    retired = np.flatnonzero((flags & table_lib.RETIRED) != 0)
    assert len(retired) == 2
