"""Sweep-engine correctness: a vmapped sweep is *bit-identical* to N
independent emulations, a chunk=1 sweep point still matches the sequential
software oracle, and the spec builder rejects static-geometry axes."""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_trace_arrays
from repro import Engine
from repro.core import Trace, pad_trace, small_platform
from repro.core.emulator import entry_cache_count
from repro.sims import trace_sim
from repro.sweep import SweepSpec, build_points, load_rows


def _as_trace(page, off, w, sz):
    return Trace(jnp.asarray(page), jnp.asarray(off), jnp.asarray(w), jnp.asarray(sz))


def _trace(cfg, n, seed=0, **kw):
    arrays = make_trace_arrays(cfg, n, np.random.default_rng(seed), **kw)
    return _as_trace(*arrays)


def _grid_spec(base):
    return SweepSpec(
        base=base,
        technologies=("3dxpoint", "stt-ram"),
        fast_fractions=(0.125, 0.25),
        policies=("static", "hotness"),
        link_lats=(600, 100),
    )


def test_vmapped_sweep_bitwise_matches_independent_runs():
    base = small_platform(chunk=16, hot_threshold=2, decay_every=8)
    points = build_points(_grid_spec(base))
    assert len(points) == 16
    t = _trace(base, 160, hot_fraction=0.5)

    before = entry_cache_count()
    res = Engine(base).sweep(points, t)
    assert entry_cache_count() - before == 1

    for i, pt in enumerate(points):
        padded, valid = pad_trace(pt.cfg, t)
        state, outs = Engine(pt.cfg).run(padded, valid=valid, donate=False)
        for key in ("returns", "device", "latency"):
            got = np.asarray(res.outs[key][i])
            np.testing.assert_array_equal(got, np.asarray(outs[key]))
        assert int(res.states.clock[i]) == int(state.clock)
        assert int(res.states.dma.swaps_done[i]) == int(state.dma.swaps_done)
        for f in ("reads_fast", "writes_fast", "reads_slow", "writes_slow"):
            got = int(getattr(res.states.counters, f)[i])
            assert got == int(getattr(state.counters, f))


def test_chunk1_sweep_points_match_trace_sim_oracle():
    base = small_platform(chunk=1, hot_threshold=2, decay_every=8, write_weight=2)
    spec = SweepSpec(
        base=base,
        technologies=("3dxpoint", "stt-ram"),
        fast_fractions=(0.125, 0.25),
        policies=("static", "hotness"),
    )
    points = build_points(spec)
    assert len(points) == 8
    page, off, w, sz = make_trace_arrays(base, 200, np.random.default_rng(3))
    t = _as_trace(page, off, w, sz)

    res = Engine(base).sweep(points, t)
    for i, pt in enumerate(points):
        oracle = trace_sim.simulate(pt.cfg, page, off, w, sz)
        got_returns = np.asarray(res.outs["returns"][i])
        got_device = np.asarray(res.outs["device"][i])
        np.testing.assert_array_equal(got_returns, oracle.returns)
        np.testing.assert_array_equal(got_device, oracle.device)
        assert int(res.states.clock[i]) == oracle.clock
        assert int(res.states.dma.swaps_done[i]) == oracle.swaps


def test_sweep_results_rows_and_axes():
    base = small_platform(chunk=8)
    spec = SweepSpec(
        base=base,
        technologies=("3dxpoint", "flash"),
        extra_axes=(("hot_threshold", (2, 16)),),
    )
    points = build_points(spec)
    assert len(points) == 4
    res = Engine(base).sweep(points, _trace(base, 64))
    rows = res.rows()
    assert [r["tech"] for r in rows] == ["3dxpoint", "3dxpoint", "flash", "flash"]
    assert {r["hot_threshold"] for r in rows} == {2, 16}
    # flash is orders of magnitude slower than 3dxpoint: AMAT must reflect it
    assert rows[2]["amat_cyc"] > 10 * rows[0]["amat_cyc"]
    assert res.best()["tech"] == "3dxpoint"
    assert "amat_cyc" in res.table()


def test_sweep_compilation_shared_across_runtime_bases():
    """Sweeps whose bases differ only in runtime fields (and whose policy
    sets match) must share one compiled executable."""
    base = small_platform(chunk=4)
    t = _trace(base, 48)
    before = entry_cache_count()
    Engine(base).sweep(build_points(SweepSpec(base=base, link_lats=(600, 100))), t)
    base2 = base.with_(hot_threshold=7, slow=base.fast)
    Engine(base2).sweep(build_points(SweepSpec(base=base2, link_lats=(600, 100))), t)
    assert entry_cache_count() - before == 1


def test_sweep_persistence_roundtrip(tmp_path):
    """to_csv / to_jsonl / load_rows: rows survive a disk round-trip
    (JSONL exactly; CSV up to numeric re-parsing)."""
    base = small_platform(chunk=8)
    spec = SweepSpec(
        base=base,
        technologies=("3dxpoint", "stt-ram"),
        extra_axes=(("hot_threshold", (2, 16)),),
    )
    res = Engine(base).sweep(build_points(spec), _trace(base, 64))
    rows = res.rows()

    jpath = tmp_path / "sweep.jsonl"
    res.to_jsonl(jpath)
    assert load_rows(jpath) == rows

    cpath = tmp_path / "sweep.csv"
    res.to_csv(cpath)
    loaded = load_rows(cpath)
    assert len(loaded) == len(rows)
    for got, want in zip(loaded, rows):
        assert set(got) == set(want)
        for k, v in want.items():
            if isinstance(v, float):
                assert got[k] == pytest.approx(v)
            else:
                assert got[k] == v


def test_sweep_rejects_static_axes():
    base = small_platform()
    with pytest.raises(ValueError, match="not a runtime-sweepable"):
        build_points(SweepSpec(base=base, extra_axes=(("chunk", (8, 16)),)))


def test_donate_without_states_raises():
    """Regression: sweep(donate=True) without states= used to silently
    ignore the donation instead of erroring; run(donate=True) likewise
    needs a state to donate."""
    base = small_platform(chunk=8)
    points = build_points(SweepSpec(base=base, link_lats=(600, 100)))
    engine = Engine(base)
    with pytest.raises(ValueError, match="donate=True requires states="):
        engine.sweep(points, _trace(base, 32), donate=True)
    with pytest.raises(ValueError, match="donate=True requires state="):
        engine.run(_trace(base, 32), donate=True)


def test_write_weight_is_policy_scoped():
    """Regression: write weighting used to be global, making a policy-axis
    sweep of hotness vs write_bias at equal write_weight a no-op. Now only
    write_bias applies the weight: the two policies diverge on a
    write-heavy trace, and hotness is invariant to the knob."""
    base = small_platform(chunk=8, hot_threshold=10, decay_every=2, hotness_decay_shift=1)
    # Per chunk: 3 reads of slow page A, 2 writes of slow page B, 3 reads
    # of rotating cold slow pages. Unweighted, nothing ever crosses the
    # threshold (decay holds heats at ~6); with writes weighted 4x, B
    # crosses every other chunk — so only write_bias migrates.
    n = 512
    a, b = base.n_fast_pages, base.n_fast_pages + 1
    page, wr = [], []
    for c in range(n // 8):
        cold = base.n_fast_pages + 2 + (3 * c) % 40
        page += [a, a, a, b, b, cold, cold + 1, cold + 2]
        wr += [False] * 3 + [True] * 2 + [False] * 3
    page = np.asarray(page, np.int32)
    t = _as_trace(page, np.zeros(n, np.int32), np.asarray(wr), np.full(n, 64, np.int32))

    res = Engine(base).sweep(
        SweepSpec(base=base.with_(write_weight=4), policies=("hotness", "write_bias")), t
    )
    hot, wb = res.rows()
    assert hot["policy"] == "hotness" and wb["policy"] == "write_bias"
    # equal write_weight, same trace — yet only write_bias promotes the
    # write-hot page (the weighting is policy-scoped, not global)
    assert hot["swaps"] == 0
    assert wb["swaps"] > 0

    # hotness must be bitwise invariant to the (now scoped) knob
    r1 = Engine(base).sweep(SweepSpec(base=base.with_(write_weight=1), policies=("hotness",)), t)
    r8 = Engine(base).sweep(SweepSpec(base=base.with_(write_weight=8), policies=("hotness",)), t)
    np.testing.assert_array_equal(np.asarray(r1.outs["returns"]), np.asarray(r8.outs["returns"]))
    np.testing.assert_array_equal(np.asarray(r1.states.table), np.asarray(r8.states.table))


def test_pin_fraction_and_wear_axes_sweepable():
    """pin_fast_fraction and wear_slack ride RuntimeParams: a pin-fraction
    x policy grid is one compiled sweep, pinning shrinks the usable fast
    tier (fewer victims -> fewer swaps), and every point's pinned pages
    stay put."""
    from repro.core import table as table_lib
    from repro.core.config import FAST

    base = small_platform(chunk=8, hot_threshold=2, decay_every=8)
    points = build_points(
        SweepSpec(
            base=base,
            policies=("hotness", "wear_level"),
            extra_axes=(("pin_fast_fraction", (0.0, 0.75)), ("wear_slack", (8, 64))),
        )
    )
    assert len(points) == 8
    t = _trace(base, 256, hot_fraction=0.7)
    res = Engine(base).sweep(points, t)

    nf = base.n_fast_pages
    n_pin = int(0.75 * nf)
    dev = np.asarray(table_lib.device(res.states.table))
    flg = np.asarray(table_lib.flags(res.states.table))
    swaps = np.asarray(res.states.dma.swaps_done)
    for i, pt in enumerate(points):
        frac = dict(pt.coords)["pin_fast_fraction"]
        if frac == 0.0:
            assert not flg[i].any()
        else:
            assert (flg[i][:n_pin] == table_lib.PIN_FAST).all()
            assert (dev[i][:n_pin] == FAST).all()  # pinned pages stayed
    # unpinned points migrate at least as much as heavily pinned ones
    unpinned = [i for i, p in enumerate(points) if dict(p.coords)["pin_fast_fraction"] == 0.0]
    pinned = [i for i, p in enumerate(points) if dict(p.coords)["pin_fast_fraction"] != 0.0]
    assert swaps[unpinned].sum() >= swaps[pinned].sum()
    assert swaps[unpinned].sum() > 0


def test_sweep_sharded_matches_unsharded():
    base = small_platform(chunk=8)
    spec = SweepSpec(base=base, technologies=("3dxpoint", "stt-ram", "mram"))
    points = build_points(spec)
    t = _trace(base, 64)
    engine = Engine(base)
    res = engine.sweep(points, t)
    # mesh of all local devices; point count (3) deliberately not a
    # multiple of any >1 device count, exercising the padding path
    res_sh = engine.sweep(points, t, mesh="auto")
    np.testing.assert_array_equal(
        np.asarray(res.outs["returns"]),
        np.asarray(res_sh.outs["returns"]),
    )
    np.testing.assert_array_equal(
        np.asarray(res.states.clock),
        np.asarray(res_sh.states.clock),
    )
