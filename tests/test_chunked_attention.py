"""chunked_attention (the training/prefill path): forward AND gradients
must match single-shot attention, including GQA, windows, and dk != dv."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.chunked_attention import chunked_attention, naive_attention


def _mk(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@pytest.mark.parametrize("hq,hkv,s,skv,window", [
    (4, 4, 64, 64, None),
    (4, 2, 128, 128, None),      # GQA
    (4, 4, 64, 64, 24),          # window
    (2, 2, 48, 96, None),        # q is tail of kv (prefill continuation)
])
def test_forward_matches_ref(hq, hkv, s, skv, window):
    rng = np.random.default_rng(0)
    q = _mk(rng, (2, hq, s, 32))
    k = _mk(rng, (2, hkv, skv, 32))
    v = _mk(rng, (2, hkv, skv, 32))
    got = chunked_attention(q, k, v, causal=True, window=window, block_q=16)
    want = ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("hkv", [4, 2])
def test_gradients_match_naive(window, hkv):
    rng = np.random.default_rng(1)
    q = _mk(rng, (2, 4, 64, 16))
    k = _mk(rng, (2, hkv, 64, 16))
    v = _mk(rng, (2, hkv, 64, 16))

    def f_chunked(q, k, v):
        return jnp.sum(jnp.sin(
            chunked_attention(q, k, v, causal=True, window=window,
                              block_q=16)))

    def f_naive(q, k, v):
        return jnp.sum(jnp.sin(
            naive_attention(q, k, v, causal=True, window=window)))

    g1 = jax.grad(f_chunked, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_dynamic_window_traced():
    """window may be a traced scalar (per-layer dynamic windows)."""
    rng = np.random.default_rng(2)
    q = _mk(rng, (1, 2, 64, 16))
    k = _mk(rng, (1, 2, 64, 16))
    v = _mk(rng, (1, 2, 64, 16))

    @jax.jit
    def f(w):
        return chunked_attention(q, k, v, causal=True, window=w, block_q=16)

    for w in (8, 32, 2**30):
        got = f(jnp.int32(w))
        want = ref.attention(q, k, v, causal=True, window=int(w))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)


def test_dk_neq_dv():
    """MLA-style: key dim 24, value dim 16."""
    rng = np.random.default_rng(3)
    q = _mk(rng, (2, 2, 32, 24))
    k = _mk(rng, (2, 2, 32, 24))
    v = _mk(rng, (2, 2, 32, 16))
    got = chunked_attention(q, k, v, causal=True, block_q=8,
                            scale=24 ** -0.5)
    want = naive_attention(q, k, v, causal=True, scale=24 ** -0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    g = jax.grad(lambda v: jnp.sum(chunked_attention(
        q, k, v, causal=True, block_q=8, scale=24 ** -0.5)))(v)
    g2 = jax.grad(lambda v: jnp.sum(naive_attention(
        q, k, v, causal=True, scale=24 ** -0.5)))(v)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g2), atol=2e-4)
