"""Elastic restart: a checkpoint written under one mesh layout must resume
under a different layout (different TP width) with identical training
trajectory — the fault-tolerance contract for node loss / cluster
rescale (DESIGN.md §4)."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
from repro.launch import train as train_mod

args = ["--arch", "internlm2-1.8b", "--smoke", "--batch", "4", "--seq", "32",
        "--log-every", "100", "--ckpt-every", "4", "--mesh", "dev",
        "--total-steps", "14"]   # pin the LR schedule across restarts

# run A: 8 steps on (data=2, model=2), checkpointing
d = "/tmp/elastic_ck"
import shutil; shutil.rmtree(d, ignore_errors=True)
train_mod.run(args + ["--steps", "8", "--ckpt-dir", d, "--mesh-model", "2"])
# resume on (data=1, model=4) to step 14
_, loss_elastic = train_mod.run(args + ["--steps", "14", "--ckpt-dir", d,
                                        "--mesh-model", "4"])
# reference: straight 14 steps on (data=2, model=2)
_, loss_ref = train_mod.run(args + ["--steps", "14", "--mesh-model", "2"])
np.testing.assert_allclose(loss_elastic, loss_ref, rtol=1e-4)
print("ELASTIC_OK", loss_elastic, loss_ref)
"""


def test_elastic_restart_different_mesh():
    import os
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=560, env=env, cwd=root)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ELASTIC_OK" in r.stdout
