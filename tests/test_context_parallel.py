"""Context-parallel attention (M2): when head counts don't divide the
model axis, queries shard on the sequence axis. The sharded computation
must be numerically identical to the unsharded reference."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
import repro.configs as C
from repro.models import init_params, loss_fn, ShardCtx
from repro.models.layers import use_context_parallel
from repro.launch.mesh import make_dev_mesh

# 3 heads cannot divide a 2-way model axis -> CP path
cfg = C.get_smoke("musicgen_medium").with_(
    n_heads=3, n_kv_heads=3, d_model=48, head_dim=16, d_ff=64)
params = init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batch = {"inputs": jnp.asarray(rng.standard_normal((4, 16, cfg.frame_dim)),
                               jnp.float32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}

ref, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b, ShardCtx()))(params, batch)

mesh = make_dev_mesh(model=2)
sh = ShardCtx.from_mesh(mesh)
assert use_context_parallel(cfg, sh, 4, 16), "CP must trigger"
with mesh:
    got, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b, sh))(params, batch)
np.testing.assert_allclose(float(ref), float(got), rtol=2e-5)
print("CP_OK", float(ref), float(got))
"""


def test_cp_matches_unsharded():
    import os
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=560, env=env, cwd=root)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "CP_OK" in r.stdout
