"""Seeded ranges violations: an UNGUARDED table gather (the page index
comes straight from an int argument the analyzer must assume spans the
full fixture budget ``[0, 2**20]``, far past ``n_pages``) and an
UNSATURATED scatter-add whose accumulation provably overflows int32
under that same budget. ``python -m repro.analysis --pass ranges
<this file>`` must exit non-zero with findings at the lines below."""


def _bad_step(table, pages, w):
    import jax.numpy as jnp

    hot = table[pages, 2]  # unguarded gather: pages unproven < n_pages
    flat = table.reshape(-1)
    # Unsaturated accumulation: w can be 2**20 per event with no clamp,
    # so repeated chunks blow through int32 — the prover must flag the
    # add as overflow-capable under the budget.
    committed = flat.at[pages * 8 + 2].add(w * w, mode="drop")
    return committed.reshape(table.shape), jnp.sum(hot)


def reprolint_case():
    def make():
        import jax.numpy as jnp

        i32 = jnp.int32
        args = (jnp.zeros((16, 8), i32), jnp.arange(4, dtype=i32),
                jnp.ones(4, i32))
        return _bad_step, args

    return {"kind": "ranges", "make": make}
