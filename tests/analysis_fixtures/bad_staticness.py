"""Seeded traced/static violations: RuntimeParams fields reaching
Python control flow (concretization errors on the first real trace).
``python -m repro.analysis --pass staticness <this file>`` must exit
non-zero with findings at the lines below."""


def promote_if_hot(params, hotness):
    if params.hot_threshold > 0:  # traced field in Python `if`
        return hotness + 1
    return hotness


def spin(params, clock):
    while clock < params.decay_every:  # traced field in `while`
        clock = clock + 1
    return clock


def checked(params, w):
    assert params.write_weight >= 0  # traced field in `assert`
    return w * params.write_weight
