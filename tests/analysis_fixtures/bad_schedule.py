"""Seeded schedule violation: a chunk step that reads the pre-commit
table AFTER the boundary commit and issues a second scatter-add.
``python -m repro.analysis --pass schedule <this file>`` must exit
non-zero with findings at the lines below."""


def _bad_step(table, pages, w):
    import jax.numpy as jnp

    flat = table.reshape(-1)
    committed = flat.at[pages * 8 + 2].add(w, mode="drop")
    committed = committed.reshape(table.shape)
    stale = table[pages, 3]  # stale read of the pre-commit table
    committed = committed.at[pages, 4].add(stale)  # second scatter-add
    return jnp.sum(committed)


def reprolint_case():
    def make():
        import jax.numpy as jnp

        i32 = jnp.int32
        args = (jnp.zeros((16, 8), i32), jnp.arange(4, dtype=i32),
                jnp.ones(4, i32))
        return _bad_step, args

    return {"kind": "schedule", "make": make}
