"""Seeded recompile violation: work under ``assert_compile_flat`` that
compiles a brand-new entry point (a trace length no warmup covered).
``python -m repro.analysis --pass tripwire <this file>`` must exit
non-zero, reporting the RecompileError as a finding."""


def _recompiles_under_tripwire():
    import jax.numpy as jnp

    from repro import Engine
    from repro.analysis import assert_compile_flat
    from repro.core import small_platform
    from repro.core.emulator import Trace

    # a geometry no test shares, so this probe never perturbs
    # compile-count assertions elsewhere
    eng = Engine(small_platform(n_fast_pages=4, n_slow_pages=12, chunk=4))
    i32 = jnp.int32
    trace = Trace(page=jnp.zeros(4, i32), offset=jnp.zeros(4, i32),
                  is_write=jnp.zeros(4, bool), size=jnp.full(4, 64, i32))
    with assert_compile_flat(eng):
        eng.run(trace)  # cold entry -> one compilation -> boom


def reprolint_case():
    return {"kind": "tripwire", "run": _recompiles_under_tripwire,
            "line": 21}
