"""Seeded stale-doc violation, in the style of the pre-PR-7 docstrings:

Run this workload through ``emulate`` (or the run_sweep free function
in sweep/runner.py) to reproduce the figure.

``python -m repro.analysis --pass docrefs <this file>`` must exit
non-zero with findings pointing at the lines above."""
