"""Seeded Pallas-sanitizer violations: a kernel that (a) READS its
output block before any store (uninitialized VMEM), (b) maps BOTH grid
iterations onto the same output block (write-write hazard — iteration
order is undefined), and (c) overflows the fixture's deliberately tiny
VMEM budget. ``python -m repro.analysis --pass pallas_san <this file>``
must exit non-zero with findings anchored at this file."""


def _bad_kernel(x_ref, o_ref):
    acc = o_ref[...]  # read of uninitialized output VMEM
    o_ref[...] = acc + x_ref[...]


def _bad_call(x):
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    return pl.pallas_call(
        _bad_kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec((1, 128), lambda i: (i, 0))],
        # hazard: the index_map ignores the grid index entirely
        out_specs=pl.BlockSpec((1, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((2, 128), jnp.int32),
        interpret=True,
    )(x)


def reprolint_case():
    def make():
        import jax.numpy as jnp

        return _bad_call, (jnp.zeros((2, 128), jnp.int32),)

    # 512 B budget: the two 1x128 int32 blocks (1 KiB) exceed it.
    return {"kind": "pallas_san", "make": make, "budget": 512}
