"""Seeded lane-discipline violations: raw lane constants and bare
integer lane indexing outside core/table.py.
``python -m repro.analysis --pass lanes <this file>`` must exit
non-zero with findings at the lines below."""
from repro.core import table as table_lib
from repro.core.table import HOTNESS


def peek_hotness(table, pages):
    return table[pages, table_lib.HOTNESS]  # raw lane constant


def peek_wear(table, frames):
    return table[frames, 3]  # bare integer lane index


def imported_lane(table):
    return table[:, HOTNESS]  # directly imported lane constant
