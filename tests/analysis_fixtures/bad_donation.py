"""Seeded donation violations: a caller that reads a state after
donating it (AST), and a jitted function whose donated argument cannot
alias into any output, so XLA silently drops the donation (lowering).
``python -m repro.analysis --pass donation <this file>`` must exit
non-zero with findings at the lines below."""


def leaky_caller(engine, trace, state):
    out = engine.run(trace, state=state)
    return out, state.table  # read after donating `state`


def reprolint_case():
    def make():
        import jax
        import jax.numpy as jnp

        # int32 in, float32 out: nothing for the donated buffer to
        # alias — XLA drops the donation without a word.
        fn = jax.jit(lambda x: jnp.float32(1.5) * x.astype(jnp.float32),
                     donate_argnums=(0,))
        return fn, (jnp.zeros((8, 8), jnp.int32),), (0,)

    return {"kind": "donation", "make": make, "line": 21}
