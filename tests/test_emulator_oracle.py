"""The platform's central correctness claim: the vectorized JAX emulation
pipeline at chunk=1 is *bit-identical* to the sequential software
simulators, for every policy aspect (placement, migration, consistency,
DMA conflicts, counters)."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import engine_run, make_trace_arrays
from repro.core import Trace, small_platform
from repro.sims import cycle_sim, trace_sim


def _run_all(cfg, arrays):
    page, off, w, sz = arrays
    t = Trace(jnp.asarray(page), jnp.asarray(off), jnp.asarray(w),
              jnp.asarray(sz))
    state, outs, _ = engine_run(cfg, t)
    r1 = trace_sim.simulate(cfg, page, off, w, sz)
    r2 = cycle_sim.simulate(cfg, page, off, w, sz, refresh=False)
    return state, outs, r1, r2


@pytest.mark.parametrize("policy", ["static", "hotness", "write_bias"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chunk1_matches_oracles(policy, seed):
    cfg = small_platform(chunk=1, policy=policy, hot_threshold=2,
                         decay_every=8, write_weight=2)
    rng = np.random.default_rng(seed)
    arrays = make_trace_arrays(cfg, 300, rng)
    state, outs, r1, r2 = _run_all(cfg, arrays)

    np.testing.assert_array_equal(np.asarray(outs["returns"]), r1.returns)
    np.testing.assert_array_equal(np.asarray(outs["device"]), r1.device)
    np.testing.assert_array_equal(r1.returns, r2.returns)
    assert int(state.dma.swaps_done) == r1.swaps
    # cycle_sim drains in-flight DMA events after the final request; the
    # boundary-committed simulators may trail by the one in-flight swap.
    assert r2.swaps - r1.swaps in (0, 1)
    assert int(state.clock) == r1.clock == r2.clock


def test_migrations_actually_happen():
    cfg = small_platform(chunk=1, policy="hotness", hot_threshold=2,
                         decay_every=16)
    rng = np.random.default_rng(0)
    arrays = make_trace_arrays(cfg, 400, rng, hot_fraction=0.6)
    state, outs, r1, r2 = _run_all(cfg, arrays)
    assert r1.swaps > 0, "test must exercise the DMA path"


def test_counters_match_oracle():
    cfg = small_platform(chunk=1, policy="hotness", hot_threshold=2)
    rng = np.random.default_rng(3)
    arrays = make_trace_arrays(cfg, 250, rng)
    state, outs, r1, _ = _run_all(cfg, arrays)
    c = state.counters
    assert int(c.reads_fast) == r1.counters["reads_fast"]
    assert int(c.writes_fast) == r1.counters["writes_fast"]
    assert int(c.reads_slow) == r1.counters["reads_slow"]
    assert int(c.writes_slow) == r1.counters["writes_slow"]
    assert int(c.reorder_held) == r1.counters["reorder_held"]
    total_bytes = (float(c.bytes_read_fast) + float(c.bytes_read_slow))
    assert total_bytes == r1.counters["bytes_read"]


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_chunked_counts_invariant(chunk):
    """Counts (not timing) are chunk-size invariant for the static policy:
    every request hits the same device regardless of pipeline width."""
    base = small_platform(chunk=1, policy="static")
    rng = np.random.default_rng(1)
    page, off, w, sz = make_trace_arrays(base, 320, rng)
    t = Trace(jnp.asarray(page), jnp.asarray(off), jnp.asarray(w),
              jnp.asarray(sz))
    s1, o1, _ = engine_run(base, t)
    s2, o2, _ = engine_run(base.with_(chunk=chunk), t)
    np.testing.assert_array_equal(np.asarray(o1["device"]),
                                  np.asarray(o2["device"]))
    for f in ("reads_fast", "writes_fast", "reads_slow", "writes_slow"):
        assert int(getattr(s1.counters, f)) == int(getattr(s2.counters, f))


def test_chunked_pipeline_is_faster_in_emulated_time():
    """Pipelining overlaps request latencies: wide chunks must finish the
    same trace in *less emulated time* than the fully blocking chunk=1."""
    cfg1 = small_platform(chunk=1, policy="static")
    cfgN = small_platform(chunk=64, policy="static")
    rng = np.random.default_rng(2)
    page, off, w, sz = make_trace_arrays(cfg1, 320, rng)
    t = Trace(jnp.asarray(page), jnp.asarray(off), jnp.asarray(w),
              jnp.asarray(sz))
    s1, _, _ = engine_run(cfg1, t)
    sN, _, _ = engine_run(cfgN, t)
    assert int(sN.clock) < int(s1.clock)
