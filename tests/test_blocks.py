"""Block-level references: RWKV6 chunked scan vs sequential recurrence,
MoE dispatch invariants, Mamba decode-vs-sequence equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import ShardCtx, init_params
from repro.models.moe import _top_k_dispatch, moe_block
from repro.models.rwkv import rwkv_chunk_scan
from repro.models import mamba as mamba_lib

SH = ShardCtx()


# --------------------------------------------------------------------------- #
# RWKV6: chunked parallel form == sequential recurrence
# --------------------------------------------------------------------------- #

def _rwkv_sequential(r, k, v, logw, u):
    b, h, s, dk = r.shape
    dv = v.shape[-1]
    S = np.zeros((b, h, dk, dv), np.float64)
    out = np.zeros((b, h, s, dv), np.float64)
    rn, kn, vn = (np.asarray(x, np.float64) for x in (r, k, v))
    w = np.exp(np.asarray(logw, np.float64))
    un = np.asarray(u, np.float64)
    for t in range(s):
        kv = kn[:, :, t, :, None] * vn[:, :, t, None, :]
        att = S + un[None, :, :, None] * kv
        out[:, :, t] = np.einsum("bhk,bhkv->bhv", rn[:, :, t], att)
        S = S * w[:, :, t, :, None] + kv
    return out, S


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_rwkv_chunk_scan_matches_sequential(chunk):
    rng = np.random.default_rng(0)
    b, h, s, d = 2, 3, 16, 8
    r = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    logw = jnp.asarray(-np.exp(rng.standard_normal((b, h, s, d)) - 1.5),
                       jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, d)) * 0.3, jnp.float32)

    out, state = rwkv_chunk_scan(r, k, v, logw, u, chunk)
    want_out, want_state = _rwkv_sequential(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(out), want_out, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), want_state, atol=1e-4)


# --------------------------------------------------------------------------- #
# MoE dispatch invariants
# --------------------------------------------------------------------------- #

def test_topk_dispatch_invariants():
    rng = np.random.default_rng(1)
    t, e, k, cap = 64, 8, 2, 12
    probs = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((t, e)), jnp.float32), axis=-1)
    idx, gates, pos, keep = _top_k_dispatch(probs, k, cap)
    idx, gates, pos, keep = (np.asarray(x) for x in (idx, gates, pos, keep))

    # gates normalized over the kept slots' superset
    np.testing.assert_allclose(gates.sum(1), 1.0, atol=1e-5)
    # no expert receives more than `cap` kept tokens, positions unique
    for ei in range(e):
        kept = [(ti, j) for ti in range(t) for j in range(k)
                if idx[ti, j] == ei and keep[ti, j]]
        positions = [pos[ti, j] for ti, j in kept]
        assert len(positions) <= cap
        assert len(set(positions)) == len(positions)
        assert all(0 <= p < cap for p in positions)


def test_moe_block_zero_capacity_drops_gracefully():
    cfg = C.get_smoke("phi35_moe_42b")
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=0.05))
    params = init_params(cfg, jax.random.PRNGKey(0))
    p0 = jax.tree.map(lambda x: x[0], params["layers"]["mlp"])
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 8, cfg.d_model)),
                    jnp.float32)
    out, aux = moe_block(cfg, p0, x, SH)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_moe_aux_loss_balanced_router_is_low():
    """A perfectly uniform router gives aux ~= 1 (the switch-loss floor)."""
    cfg = C.get_smoke("phi35_moe_42b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    p0 = jax.tree.map(lambda x: x[0] * 0.0, params["layers"]["mlp"])  # router=0
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 16, cfg.d_model)),
                    jnp.float32)
    _, aux = moe_block(cfg, p0, x, SH)
    assert 0.9 < float(aux) < 1.1


# --------------------------------------------------------------------------- #
# Mamba: decode chain == full-sequence scan
# --------------------------------------------------------------------------- #

def test_mamba_decode_equals_sequence():
    cfg = C.get_smoke("hymba_1p5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    p0 = jax.tree.map(lambda x: x[0], params["layers"]["attn"]["mamba"])
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 6, cfg.d_model)), jnp.float32)

    y_full, conv_f, ssm_f = mamba_lib.mamba_mix(cfg, p0, x, SH)

    conv = ssm = None
    ys = []
    for t in range(6):
        y, conv, ssm = mamba_lib.mamba_mix(cfg, p0, x[:, t:t + 1], SH,
                                           conv_state=conv, ssm_state=ssm)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(ssm), np.asarray(ssm_f), atol=1e-4)
