"""Property tests for the continuous-batching serving front-end.

The scheduler's contracts, in test form:

* every dispatched batch shape comes from the bucket list, and the
  in-flight dispatch count never exceeds ``max_live_batches``;
* ``Engine.compile_count`` stays flat after ``warmup()`` across a
  mixed-length workload (bucketed shapes + valid-as-argument padding);
* a scheduled run is **bitwise identical** to the same request stream
  replayed serially through ``Engine.run_stream`` — and invariant to
  the async overlap depth;
* pin contracts are stamped at admission, keep the packed table valid
  (``check_table``) mid-run, and are all released by completion;
* eviction under memory pressure never takes a contracted page, and
  evicted window pages are refetched on next use.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro import Engine
from repro.analysis import assert_compile_flat
from repro.core import check_table, small_platform
from repro.core import table as table_lib
from repro.serve import (BucketSpec, ContinuousBatchingScheduler, PagedKVMap,
                         ServeConfig, release_pin_pages, stamp_pin_pages)


def _platform(**kw):
    base = dict(n_fast_pages=64, n_slow_pages=448, chunk=32)
    base.update(kw)
    return small_platform(**base)


def _serve_cfg(**kw):
    base = dict(sorted_batch_sizes=(32, 64, 128), max_live_seqs=100,
                max_admit_per_step=32, max_pages_per_seq=6,
                positions_per_page=8, window_pages=2,
                prefill_writes_per_page=2)
    base.update(kw)
    return ServeConfig(**base)


def _workload(n, seed=0, pmax=4):
    rng = np.random.default_rng(seed)
    return rng.integers(1, pmax, n), rng.integers(1, 16, n)


def _run(engine_cfg, serve_cfg, n_seqs=150, seed=0):
    engine = Engine(engine_cfg)
    sched = ContinuousBatchingScheduler(engine, serve_cfg)
    sched.warmup()
    sched.submit(*_workload(n_seqs, seed))
    sched.run()
    return engine, sched


# ---------------------------------------------------------------------------
# BucketSpec
# ---------------------------------------------------------------------------
def test_bucket_spec_selection():
    b = BucketSpec((32, 64, 256), chunk=32)
    assert b.get_padded_batch_size(1) == 32
    assert b.get_padded_batch_size(33) == 64
    assert b.get_padded_batch_size(256) == 256
    with pytest.raises(ValueError, match="exceed the largest bucket"):
        b.get_padded_batch_size(257)
    assert b.get_dispatch_size(31) is None
    assert b.get_dispatch_size(63) == 32
    assert b.get_dispatch_size(300) == 256


def test_bucket_spec_validation():
    with pytest.raises(ValueError, match="ascending"):
        BucketSpec((64, 32), chunk=32)
    with pytest.raises(ValueError, match="multiple of the pipeline chunk"):
        BucketSpec((48,), chunk=32)
    with pytest.raises(ValueError, match="at least one"):
        BucketSpec((), chunk=32)


# ---------------------------------------------------------------------------
# scheduler properties
# ---------------------------------------------------------------------------
def test_dispatch_shapes_and_admission_cap():
    cfg = _platform()
    engine, sched = _run(cfg, _serve_cfg(max_live_batches=3))
    rep = sched.report()
    assert rep.n_sequences == 150
    sizes = {s for s, _ in sched.dispatch_log}
    assert sizes <= {32, 64, 128}
    assert rep.inflight_high_water <= 3
    assert rep.live_seqs_high_water <= 100


def test_compile_count_flat_after_warmup():
    cfg = _platform()
    engine = Engine(cfg)
    sched = ContinuousBatchingScheduler(engine, _serve_cfg())
    sched.warmup()
    # Mixed lengths: short/long prompts, short/long decodes — every
    # dispatch (steady floor-bucket AND padded drain tail) must hit a
    # warm entry; the valid mask is an argument, not a cache key.
    with assert_compile_flat(engine, msg="serving dispatch after warmup"):
        sched.submit(*_workload(140, seed=3))
        sched.run()
    assert any(n < s for s, n in sched.dispatch_log), \
        "workload never exercised the padded drain path"


def test_scheduled_run_bitwise_equals_run_stream_replay():
    cfg = _platform()
    # pin_pages_per_seq=0: FLAGS ops absent, so the replayed engine sees
    # the identical program stream (smallest bucket == chunk makes the
    # drain padding match run_stream's pad_trace exactly).
    engine, sched = _run(cfg, _serve_cfg(pin_pages_per_seq=0,
                                         record_traces=True), n_seqs=120)
    replay = Engine(cfg).run_stream(iter(sched.trace_log))
    got = {k: np.concatenate([np.asarray(o[k]) for o in sched.outs_log])
           for k in sched.outs_log[0]}
    for k, v in got.items():
        assert np.array_equal(v, np.asarray(replay.outs[k])), k
    for a, b in zip(jax.tree.leaves(sched.carry),
                    jax.tree.leaves(replay.state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_results_invariant_to_overlap_depth():
    cfg = _platform()
    reports = []
    for depth in (1, 3):
        _, sched = _run(cfg, _serve_cfg(max_live_batches=depth), n_seqs=120)
        reports.append(sched.report())
    a, b = reports
    assert a.p50_latency_us == b.p50_latency_us
    assert a.p99_latency_us == b.p99_latency_us
    assert a.pinned_fast_hit_rate == b.pinned_fast_hit_rate
    assert a.n_mem_requests == b.n_mem_requests
    assert b.inflight_high_water == 3 > a.inflight_high_water == 1


def test_pin_contracts_stamped_and_released():
    cfg = _platform()
    engine = Engine(cfg)
    sched = ContinuousBatchingScheduler(engine, _serve_cfg())
    sched.warmup()
    sched.submit(*_workload(60, seed=1))
    # Mid-run: contracts live, table invariants hold (pin agrees with
    # the DEVICE lane — check_table enforces it).
    for _ in range(4):
        sched.step()
    table = np.asarray(sched.carry.table)
    mid_pinned = (table[:, table_lib.FLAGS] & table_lib.PINNED) != 0
    assert mid_pinned.any(), "admission did not stamp any contract"
    check_table(cfg, table)
    sched.run()
    rep = sched.report()
    assert rep.n_sequences == 60 and rep.pinned_accesses > 0
    # Completion released every contract.
    table = np.asarray(sched.carry.table)
    assert ((table[:, table_lib.FLAGS] & table_lib.PINNED) == 0).all()
    check_table(cfg, table)


def test_eviction_under_pressure_spares_pinned_pages():
    # 96 pages total vs ~150 pages of steady demand: the watermark logic
    # must evict cold pages to keep admission alive.
    cfg = _platform(n_fast_pages=32, n_slow_pages=64)
    engine, sched = _run(
        cfg, _serve_cfg(max_live_seqs=40, max_admit_per_step=16,
                        free_low_frac=0.2, free_high_frac=0.3),
        n_seqs=80, seed=2)
    rep = sched.report()
    assert rep.n_sequences == 80
    assert rep.evictions > 0
    # Contracted pages were never victims: every completed sequence
    # released its pin, so none linger in the table...
    table = np.asarray(sched.carry.table)
    assert ((table[:, table_lib.FLAGS] & table_lib.PINNED) == 0).all()


def test_forced_eviction_triggers_refetch():
    cfg = _platform()
    engine = Engine(cfg)
    sched = ContinuousBatchingScheduler(engine, _serve_cfg())
    sched.warmup()
    sched.submit(*_workload(60, seed=4))
    for _ in range(3):
        sched.step()
    # Blow every unpinned page out of the map (a worst-case pressure
    # spike); decode windows now reference evicted pages -> refetch.
    victims = sched.kv.maybe_evict(sched._step_no + 1, extra_needed=1 << 30)
    assert len(victims) and not sched.kv.pinned[victims].any()
    sched.run()
    assert sched.refetches > 0
    assert sched.report().n_sequences == 60


def test_admission_rejects_impossible_prompt():
    cfg = _platform(n_fast_pages=8, n_slow_pages=8)
    engine = Engine(cfg)
    sched = ContinuousBatchingScheduler(
        engine, _serve_cfg(sorted_batch_sizes=(32,), max_pages_per_seq=32))
    with pytest.raises(ValueError, match="max_pages_per_seq"):
        sched.submit([40], [4])
    sched2 = ContinuousBatchingScheduler(
        engine, _serve_cfg(sorted_batch_sizes=(32,), max_pages_per_seq=20))
    sched2.submit([18], [4])
    with pytest.raises(MemoryError, match="never"):
        sched2.run()


# ---------------------------------------------------------------------------
# PagedKVMap
# ---------------------------------------------------------------------------
def test_kv_map_eviction_is_lru_and_skips_pinned():
    cfg = _platform(n_fast_pages=8, n_slow_pages=8)
    kv = PagedKVMap(cfg, max_live_seqs=4, max_pages_per_seq=4,
                    pin_pages_per_seq=1, free_low_frac=0.9,
                    free_high_frac=0.95)
    slots = np.array([0, 0, 1, 1])
    idx = np.array([0, 1, 0, 1])
    pages = kv.alloc(4)
    kv.assign(slots, idx, pages, step=1)
    kv.touch(pages[1:2], 5)               # page idx 1 of slot 0 is hot
    assert kv.pinned[pages[0]] and kv.pinned[pages[2]]
    victims = kv.maybe_evict(step=6, extra_needed=0)
    # Pinned pages (idx 0 of each slot) survive; the cold unpinned page
    # goes first.
    assert pages[3] in victims
    assert not kv.pinned[victims].any()
    assert kv.page_of[1, 1] == -1         # mapping cleared for the victim


def test_kv_map_release_returns_contracted_pages():
    cfg = _platform(n_fast_pages=8, n_slow_pages=8)
    kv = PagedKVMap(cfg, max_live_seqs=2, max_pages_per_seq=4,
                    pin_pages_per_seq=2)
    pages = kv.alloc(3)
    kv.assign(np.array([0, 0, 0]), np.array([0, 1, 2]), pages, step=1)
    free_before = kv.free_total
    released, contracted = kv.release_slots(np.array([0]))
    assert set(released) == set(pages)
    assert set(contracted) == set(pages[:2])
    assert kv.free_total == free_before + 3


# ---------------------------------------------------------------------------
# contracts
# ---------------------------------------------------------------------------
def test_stamp_pads_to_width_and_rejects_overflow():
    cfg = _platform()
    engine = Engine(cfg)
    state = engine.init_state()
    state = stamp_pin_pages(state, [3, 5], width=8)
    table = np.asarray(state.table)
    stamped = np.flatnonzero(table[:, table_lib.FLAGS]
                             & table_lib.PINNED)
    assert set(stamped) == {3, 5}         # sentinel pad lanes dropped
    check_table(cfg, np.asarray(state.table))
    state = release_pin_pages(state, [3, 5], width=8)
    table = np.asarray(state.table)
    assert ((table[:, table_lib.FLAGS] & table_lib.PINNED) == 0).all()
    with pytest.raises(ValueError, match="exceed the pad width"):
        stamp_pin_pages(state, [1, 2, 3], width=2)


# ---------------------------------------------------------------------------
# satellites: memtier regression + serve_mixed + run_stream prefetch
# ---------------------------------------------------------------------------
def test_tiered_report_zero_pinned_accesses_is_zero_not_nan():
    from repro.memtier.tiered_cache import TieredKVAccounting

    cfg = _platform(chunk=16)
    tier = TieredKVAccounting(cfg, n_layers=1, positions_per_page=16,
                              bytes_per_position=64, pin_pages_per_seq=1)
    # A sequence allocates (and pins) but completes before any decode
    # access lands: zero pinned accesses must read as 0.0, not nan.
    tier._page_for(0, 0)
    tier.free_sequence(0)
    rate = tier.report()["pinned_fast_hit_rate"]
    assert rate == 0.0 and not np.isnan(rate)


def test_serve_mixed_generator_bounds_and_determinism():
    from repro.trace import TraceSpec, generate

    spec = TraceSpec(n_requests=2048, footprint_pages=256, pattern="serve_mixed",
                     n_tenants=4, prefill_frac=0.3, decode_window=4, seed=7)
    t1, t2 = generate(spec), generate(spec)
    pages = np.asarray(t1.page)
    assert np.array_equal(pages, np.asarray(t2.page))   # deterministic
    assert pages.min() >= 0 and pages.max() < 256       # in-footprint
    assert 0 < np.asarray(t1.is_write).mean() < 1       # mixed traffic


def test_run_stream_prefetch_is_bitwise_neutral():
    from repro.trace import TraceSpec, generate

    cfg = _platform()
    segs = [generate(TraceSpec(n_requests=n, footprint_pages=256, seed=s))
            for s, n in enumerate((40, 96, 23))]
    base = Engine(cfg).run_stream(iter(segs))
    pre = Engine(cfg).run_stream(iter(segs), prefetch=2)
    for k in base.outs:
        assert np.array_equal(np.asarray(base.outs[k]),
                              np.asarray(pre.outs[k]))
    for a, b in zip(jax.tree.leaves(base.state), jax.tree.leaves(pre.state)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
