"""Cross-pod int8 gradient compression: the compressed exchange inside
shard_map must approximate the exact psum within quantization error."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map
from repro.optim import compressed_psum_spec

import inspect
_kw = {}
_sig = inspect.signature(shard_map).parameters
if "check_vma" in _sig:        # jax >= 0.6 renamed check_rep -> check_vma
    _kw["check_vma"] = False
elif "check_rep" in _sig:
    _kw["check_rep"] = False

mesh = jax.make_mesh((2,), ("pod",))
rng = np.random.default_rng(0)
grads = {"a": jnp.asarray(rng.standard_normal((2, 512)) * 1e-2, jnp.float32),
         "b": jnp.asarray(rng.standard_normal((2, 33, 9)) * 1e-3, jnp.float32)}

def exact(g):
    return jax.tree.map(lambda x: jax.lax.psum(x, "pod"), g)

def compressed(g):
    return compressed_psum_spec(g, "pod", jax.random.PRNGKey(0))

for name, fn in (("exact", exact), ("compressed", compressed)):
    specs = jax.tree.map(lambda _: P("pod"), grads)
    out = shard_map(fn, mesh=mesh, in_specs=(specs,), out_specs=specs,
                    **_kw)(grads)
    if name == "exact":
        ref = out
    else:
        got = out
for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
    scale = np.abs(np.asarray(a)).max() + 1e-12
    err = np.abs(np.asarray(a) - np.asarray(b)).max() / scale
    assert err < 0.02, err   # <2% relative error on the wire-compressed sum
print("COMPRESS_OK")
"""


def test_compressed_psum_close_to_exact():
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=560, env=env, cwd=root)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "COMPRESS_OK" in r.stdout
