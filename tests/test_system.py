"""End-to-end behaviour: training converges, crash/restart resumes exactly,
serving completes with tier accounting, trace suite reproduces Fig-8
ordering."""
import subprocess
import sys

import jax
import numpy as np
import pytest

import repro.configs as C
from conftest import engine_run
from repro.core import paper_platform
from repro.launch import train as train_mod
from repro.memtier import ServeEngine
from repro.memtier.engine import Request
from repro.models import init_params
from repro.trace import WORKLOADS, workload_trace


def test_training_reduces_loss(tmp_path):
    _, loss = train_mod.run([
        "--arch", "internlm2-1.8b", "--smoke", "--steps", "30",
        "--batch", "8", "--seq", "64", "--log-every", "100"])
    assert loss < 4.7      # ln(128) ~ 4.85 at init; structure is learnable


def test_crash_restart_resumes_identically(tmp_path):
    """Train 12 steps with a crash at 8 + resume == train 12 uninterrupted."""
    args = ["--arch", "internlm2-1.8b", "--smoke", "--batch", "4",
            "--seq", "32", "--log-every", "100", "--ckpt-every", "4"]
    d1 = str(tmp_path / "a")
    with pytest.raises(SystemExit):
        train_mod.run(args + ["--steps", "12", "--ckpt-dir", d1,
                              "--simulate-failure-at", "8"])
    _, loss_resumed = train_mod.run(args + ["--steps", "12",
                                            "--ckpt-dir", d1])
    _, loss_straight = train_mod.run(args + ["--steps", "12"])
    np.testing.assert_allclose(loss_resumed, loss_straight, rtol=1e-5)


def test_serving_end_to_end_with_tier_pressure():
    cfg = C.get_smoke("phi3_mini_3p8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    from repro.core import EmulatorConfig
    emu = EmulatorConfig(n_fast_pages=4, n_slow_pages=64, chunk=32,
                         policy="hotness", hot_threshold=3)
    eng = ServeEngine(cfg, params, batch_size=4, smax=128, emu_cfg=emu)
    rng = np.random.default_rng(0)
    for r in range(8):
        # 60 prompt + 30 generated = 2 KV pages/sequence; 4 live sequences
        # = 8 pages against a 4-page fast tier -> guaranteed NVM traffic.
        eng.submit(Request(rid=r,
                           prompt=rng.integers(0, cfg.vocab, 60).astype(np.int32),
                           max_new_tokens=30))
    eng.run()
    rep = eng.report()
    assert rep["requests"] > 0
    # fast tier of 4 pages can't hold all sequences -> slow-tier traffic
    assert rep["reads_slow"] + rep["writes_slow"] > 0


def test_workload_suite_reproduces_fig8_ordering():
    """505.mcf must generate the most traffic; 538.imagick the least
    (paper Fig 8)."""
    vols = {name: w.total_traffic_bytes for name, w in WORKLOADS.items()}
    assert max(vols, key=vols.get) == "505.mcf"
    assert min(vols, key=vols.get) == "538.imagick"
    # the platform's counters agree with the configured volumes
    cfg = paper_platform().with_(chunk=128)
    t, w, n = workload_trace("538.imagick", scale=2e-7)
    state, _, summ = engine_run(cfg, t)
    got = (summ["GB_read"] + summ["GB_written"]) * 1e9
    want = n * 64
    assert abs(got - want) / want < 0.01


def test_dryrun_smoke_subprocess():
    """Tiny end-to-end dry-run check in a subprocess (needs its own
    XLA_FLAGS before jax init): one arch x shape on the 16x16 mesh."""
    import os
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
           "internlm2-1.8b", "--shape", "decode_32k"]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=560,
                       env=env, cwd=root)
    assert r.returncode == 0, r.stdout + r.stderr
    assert '"status": "ok"' in r.stdout
