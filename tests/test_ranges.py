"""Tests for the ``ranges`` abstract interpreter beyond the CLI suite
(test_analysis.py): a Hypothesis soundness property — the abstract
evaluation must OVER-approximate concrete evaluation on every program it
claims to analyze — and the runtime half of the overflow proof: a
long-run endurance check that the WEAR lane saturates at ``WEAR_CAP``
instead of wrapping int32."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need hypothesis; CI installs it via the "test" extra
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro import Engine
from repro.analysis import ranges as ranges_lib
from repro.core import Trace, init_state, small_platform
from repro.core import table as table_lib


# --- soundness: abstract ⊇ concrete ---------------------------------------

#: Small int32 programs covering the transfer functions the prover leans
#: on: arithmetic, lattice ops, clamps, selects, shifts, reductions,
#: scans, and the guarded gather/scatter forms the table proofs use.
_PROGRAMS = (
    lambda x, y: x + y,
    lambda x, y: x - y,
    lambda x, y: x * y,
    lambda x, y: jnp.minimum(x, y),
    lambda x, y: jnp.maximum(x, y) * 2 - x,
    lambda x, y: jnp.clip(x + y, -7, 100),
    lambda x, y: jnp.where(x > y, x, y),
    lambda x, y: jnp.abs(x) + jnp.cumsum(y),
    lambda x, y: (x << 2) + jnp.sum(y),
    lambda x, y: x[jnp.clip(y, 0, x.shape[0] - 1)],
    lambda x, y: jnp.zeros(8, jnp.int32).at[y].add(x, mode="drop"),
    lambda x, y: jnp.sort(x) + jnp.max(y),
)


def _abstract_bounds(fn, iv_x, iv_y, n):
    jaxpr = jax.make_jaxpr(fn)(jnp.zeros(n, jnp.int32),
                               jnp.zeros(n, jnp.int32))
    avals = [ranges_lib.AVal((n,), 'i', 32, iv_x),
             ranges_lib.AVal((n,), 'i', 32, iv_y)]
    interp = ranges_lib.Interp(track_overflow=False)
    return interp.eval_closed(jaxpr, avals)


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(data=st.data())
    def test_abstract_eval_over_approximates_concrete(data):
        """For every program and every input interval, concrete outputs
        on inputs drawn from the interval stay inside the abstract
        output interval (ranges' soundness contract). Magnitudes stay
        small enough that concrete int32 never wraps — wrap-around is
        exactly what the prover exists to rule out."""
        n = 4
        prog = data.draw(st.sampled_from(_PROGRAMS))
        lo_x, hi_x = sorted(data.draw(st.tuples(
            st.integers(-1000, 1000), st.integers(-1000, 1000))))
        lo_y, hi_y = sorted(data.draw(st.tuples(
            st.integers(-1000, 1000), st.integers(-1000, 1000))))
        x = np.array(data.draw(st.lists(
            st.integers(lo_x, hi_x), min_size=n, max_size=n)), np.int32)
        y = np.array(data.draw(st.lists(
            st.integers(lo_y, hi_y), min_size=n, max_size=n)), np.int32)

        outs = _abstract_bounds(prog, (lo_x, hi_x), (lo_y, hi_y), n)
        concrete = prog(jnp.asarray(x), jnp.asarray(y))
        concrete = concrete if isinstance(concrete, tuple) else (concrete,)
        for out, val in zip(outs, concrete):
            got = np.asarray(val)
            lo, hi = out.iv
            assert float(lo) <= got.min() and got.max() <= float(hi), (
                f"abstract {out.iv} does not contain concrete "
                f"[{got.min()}, {got.max()}] for x∈[{lo_x},{hi_x}] "
                f"y∈[{lo_y},{hi_y}]")
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_abstract_eval_over_approximates_concrete():
        pass


def test_abstract_eval_sound_on_known_corners():
    """Deterministic pin of the property above on corners Hypothesis
    may not hit every run (negative-operand bit ops, empty intervals)."""
    n = 4
    cases = (
        (lambda x, y: x | 4, (-9, 5), (0, 0)),
        (lambda x, y: x & -4, (-9, 5), (0, 0)),
        (lambda x, y: (x | 4) & -4, (-130, 120), (0, 0)),
    )
    for fn, iv_x, iv_y in cases:
        outs = _abstract_bounds(fn, iv_x, iv_y, n)
        xs = np.arange(iv_x[0], iv_x[1] + 1, dtype=np.int32)
        for v in xs:
            got = np.asarray(fn(jnp.full(n, v, jnp.int32),
                                jnp.zeros(n, jnp.int32)))
            lo, hi = outs[0].iv
            assert float(lo) <= got.min() and got.max() <= float(hi), (
                f"{fn.__name__ if hasattr(fn, '__name__') else fn}: "
                f"{outs[0].iv} misses {got.min()}..{got.max()} at x={v}")


# --- runtime half: WEAR saturates, never wraps ----------------------------


def test_wear_saturates_at_cap_long_run():
    """Start every page one write below ``WEAR_CAP`` and hammer writes
    for many chunks: the WEAR lane must pin at the cap (saturating add),
    never exceed it, and never wrap negative — the concrete counterpart
    of the prover's HOTNESS/WEAR inductive-lane proof."""
    cfg = small_platform()
    eng = Engine(cfg)
    state = init_state(cfg, eng.params)
    near = table_lib.WEAR_CAP - 1
    state = state._replace(
        table=state.table.at[:, table_lib.WEAR].set(near))

    n = cfg.chunk * 8  # many chunks of pure write traffic, all pages
    i32 = jnp.int32
    pages = jnp.arange(n, dtype=i32) % cfg.n_pages
    trace = Trace(page=pages, offset=jnp.zeros(n, i32),
                  is_write=jnp.ones(n, bool), size=jnp.full(n, 64, i32))
    for _ in range(3):
        state = eng.run(trace, state=state, donate=False).state

    wear = np.asarray(state.table[:, table_lib.WEAR])
    assert wear.min() >= 0, "WEAR wrapped negative"
    assert wear.max() <= table_lib.WEAR_CAP, "WEAR exceeded the cap"
    assert wear.max() == table_lib.WEAR_CAP, \
        "write traffic never reached the cap — the saturation path is untested"
    # the packed-table invariant checker agrees (lane caps included)
    table_lib.check_table(cfg, np.asarray(state.table))
