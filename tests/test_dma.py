"""Property tests for the DMA engine's swap-progress conflict redirection —
the logic the paper says needed the most design/verification care (§III-D)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need hypothesis; CI installs it via the "test" extra
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import dma as dma_lib
from repro.core import table as table_lib
from repro.core import small_platform, init_table, check_table
from repro.core.config import FAST, SLOW

if HAVE_HYPOTHESIS:
    _settings = settings(max_examples=40, deadline=None)
CFG = small_platform()


def _mk_dma(active, a, b, start):
    return dma_lib.DMAState(active=jnp.int32(active), page_a=jnp.int32(a),
                            page_b=jnp.int32(b), start=jnp.int32(start),
                            swaps_done=jnp.int32(0))


if HAVE_HYPOTHESIS:
    @given(st.data())
    @_settings
    def test_redirect_matches_bruteforce(data):
        cfg = CFG
        table0 = init_table(cfg)
        dev0 = table_lib.device(table0)
        frm0 = table_lib.frame(table0)
        a = data.draw(st.integers(cfg.n_fast_pages, cfg.n_pages - 1))  # slow page
        b = data.draw(st.integers(0, cfg.n_fast_pages - 1))            # fast page
        start = data.draw(st.integers(0, 1000))
        t = data.draw(st.integers(0, 20_000))
        page = data.draw(st.sampled_from([a, b, 0, cfg.n_pages - 1]))
        offset = data.draw(st.integers(0, cfg.page_size - 1))

        dma = _mk_dma(1, a, b, start)
        dev, frm = dma_lib.redirect(
            cfg, dma,
            jnp.asarray([page]), jnp.asarray([offset]), jnp.asarray([t]),
            dev0[jnp.asarray([page])], frm0[jnp.asarray([page])],
            table0[a], table0[b])

        # brute force: which sub-blocks have been exchanged by time t?
        exch = dma_lib.exchange_cycles_per_subblock(cfg)
        prog = min(max((t - start) // exch, 0), cfg.subblocks_per_page)
        exp_dev, exp_frm = int(dev0[page]), int(frm0[page])
        if page in (a, b) and offset // cfg.subblock < prog:
            other = b if page == a else a
            exp_dev, exp_frm = int(dev0[other]), int(frm0[other])
        assert int(dev[0]) == exp_dev and int(frm[0]) == exp_frm


    @given(st.data())
    @_settings
    def test_complete_commits_exact_swap_and_keeps_bijection(data):
        cfg = CFG
        table = init_table(cfg)
        a = data.draw(st.integers(cfg.n_fast_pages, cfg.n_pages - 1))
        b = data.draw(st.integers(0, cfg.n_fast_pages - 1))
        start = 100
        dur = dma_lib.swap_duration(cfg)
        dma = _mk_dma(1, a, b, start)

        # not yet done
        d1, t1, done1 = dma_lib.maybe_complete(
            cfg, dma, jnp.int32(start + dur - 1), table)
        assert not bool(done1) and int(d1.active) == 1
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(table))

        # done
        d2, t2, done2 = dma_lib.maybe_complete(
            cfg, dma, jnp.int32(start + dur), table)
        assert bool(done2) and int(d2.active) == 0
        dev2, frm2 = table_lib.device(t2), table_lib.frame(t2)
        frm = table_lib.frame(table)
        assert int(dev2[a]) == FAST and int(dev2[b]) == SLOW
        assert int(frm2[a]) == int(frm[b]) and int(frm2[b]) == int(frm[a])
        # both swap members stamped with the commit cycle
        assert int(table_lib.epoch(t2)[a]) == start + dur
        assert int(table_lib.epoch(t2)[b]) == start + dur
        # still a bijection; OWNER lane is checked by the emulator path
        # (maybe_complete leaves it to the caller), so hand-fix it here.
        t2 = t2.at[frm2[a], table_lib.OWNER].set(a)
        check_table(cfg, np.asarray(t2))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_redirect_matches_bruteforce():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_complete_commits_exact_swap_and_keeps_bijection():
        pass


def test_idle_dma_is_noop():
    cfg = CFG
    table = init_table(cfg)
    dma = dma_lib.DMAState.idle()
    d, t2, done = dma_lib.maybe_complete(cfg, dma, jnp.int32(10**6), table)
    assert not bool(done)
    np.testing.assert_array_equal(np.asarray(t2), np.asarray(table))


def test_progress_clamped():
    cfg = CFG
    dma = _mk_dma(1, 10, 2, 0)
    p = dma_lib.progress_subblocks(cfg, dma, jnp.int32(10**8))
    assert int(p) == cfg.subblocks_per_page
    p0 = dma_lib.progress_subblocks(cfg, dma, jnp.int32(-5))
    assert int(p0) == 0


def test_complete_charges_swap_write_wear():
    """Committing a swap charges the migration's full-page write (in
    line-size units) to the WEAR lane of the slow frame that received the
    demoted page."""
    cfg = CFG
    table = init_table(cfg)
    a = cfg.n_fast_pages + 5          # slow page being promoted
    b = 2                             # fast page being demoted
    frame_a = int(table_lib.frame(table)[a])  # slow frame b lands in
    dma = _mk_dma(1, a, b, 100)
    now = jnp.int32(100 + dma_lib.swap_duration(cfg))
    _, t2, done = dma_lib.maybe_complete(cfg, dma, now, table)
    assert bool(done)
    charge = cfg.page_size // cfg.line_size
    wear = np.asarray(table_lib.wear(t2))
    assert int(wear[frame_a]) == charge
    assert int(wear.sum()) == charge  # nothing else charged (fast is free)
    # an unfinished swap charges nothing
    _, t3, done3 = dma_lib.maybe_complete(cfg, dma, now - 1, table)
    assert not bool(done3)
    assert not np.asarray(table_lib.wear(t3)).any()


def test_maybe_start_returns_started_and_respects_busy():
    dma = dma_lib.DMAState.idle()
    t = jnp.bool_(True)
    d1, started = dma_lib.maybe_start(dma, t, jnp.int32(10), jnp.int32(2),
                                      jnp.int32(50))
    assert bool(started) and int(d1.active) == 1
    # engine busy: the proposal is dropped and started must say so
    d2, started2 = dma_lib.maybe_start(d1, t, jnp.int32(11), jnp.int32(3),
                                       jnp.int32(60))
    assert not bool(started2)
    assert int(d2.page_a) == 10 and int(d2.page_b) == 2


def test_maybe_start_rejects_pinned_members():
    """The engine's own FLAGS guard: a pinned candidate or victim vetoes
    the swap even if the caller's want survived (defense in depth)."""
    cfg = CFG
    a = cfg.n_fast_pages + 4   # slow candidate
    b = 3                      # fast victim
    want = jnp.bool_(True)
    now = jnp.int32(10)
    for page, bit in ((a, table_lib.PIN_SLOW), (b, table_lib.PIN_FAST)):
        table = table_lib.set_flags(init_table(cfg), [page], bit)
        d, started = dma_lib.maybe_start(dma_lib.DMAState.idle(), want,
                                         jnp.int32(a), jnp.int32(b), now,
                                         table)
        assert not bool(started) and int(d.active) == 0
    # unpinned table: same proposal starts
    d, started = dma_lib.maybe_start(dma_lib.DMAState.idle(), want,
                                     jnp.int32(a), jnp.int32(b), now,
                                     init_table(cfg))
    assert bool(started) and int(d.active) == 1
