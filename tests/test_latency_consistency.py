"""Property tests (hypothesis) for the associative-scan timing machinery —
the parts whose parallel formulations must exactly equal the sequential
definitions."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property-test suite needs hypothesis (installed in CI via the "
           "'test' extra)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.consistency import in_order_returns  # noqa: E402
from repro.core.latency import (  # noqa: E402
    _NEG,
    maxplus_scan,
    resolve_bank_queues,
    resolve_bank_queues_segmented,
    segmented_maxplus_scan,
)

_settings = settings(max_examples=25, deadline=None)


@given(st.lists(st.tuples(st.integers(0, 10_000), st.integers(0, 500)),
                min_size=1, max_size=64))
@_settings
def test_maxplus_scan_equals_sequential(pairs):
    arrival = jnp.asarray([p[0] for p in pairs], jnp.int32)
    service = jnp.asarray([p[1] for p in pairs], jnp.int32)
    got = np.asarray(maxplus_scan(arrival, service))
    t = -10**9
    exp = []
    for a, s in pairs:
        t = max(a, t) + s
        exp.append(t)
    np.testing.assert_array_equal(got, np.asarray(exp))


@given(st.data())
@_settings
def test_bank_queues_equal_sequential(data):
    # fixed shape menu bounds jit-compile variants (speed)
    n = data.draw(st.sampled_from([8, 32]))
    n_banks = data.draw(st.sampled_from([2, 8]))
    arrival = np.sort(data.draw(st.lists(
        st.integers(0, 5000), min_size=n, max_size=n)))
    service = data.draw(st.lists(st.integers(1, 300), min_size=n, max_size=n))
    bank = data.draw(st.lists(st.integers(0, n_banks - 1),
                              min_size=n, max_size=n))
    free0 = data.draw(st.lists(st.integers(0, 2000),
                               min_size=n_banks, max_size=n_banks))

    done, new_free = resolve_bank_queues(
        jnp.asarray(arrival, jnp.int32), jnp.asarray(service, jnp.int32),
        jnp.asarray(bank, jnp.int32), n_banks, jnp.asarray(free0, jnp.int32))

    free = list(free0)
    exp = []
    for a, s, b in zip(arrival, service, bank):
        t = max(a, free[b]) + s
        free[b] = t
        exp.append(t)
    np.testing.assert_array_equal(np.asarray(done), np.asarray(exp))
    np.testing.assert_array_equal(np.asarray(new_free), np.asarray(free))


@given(st.data())
@_settings
def test_segmented_resolver_bitwise_equals_dense(data):
    """The sort-based segmented resolver must be BITWISE identical to the
    dense one-hot oracle across random bank maps, chunk sizes and
    pre-seeded bank_free — including zero-service identity elements and
    _NEG sentinel arrivals (the emulator's invalid-lane encoding)."""
    n = data.draw(st.sampled_from([1, 7, 32, 128]))
    n_banks = data.draw(st.sampled_from([1, 2, 16, 48]))
    arrival = np.asarray(data.draw(st.lists(
        st.one_of(st.integers(0, 50_000), st.just(int(_NEG))),
        min_size=n, max_size=n)), np.int64)
    service = data.draw(st.lists(st.integers(0, 300), min_size=n, max_size=n))
    bank = data.draw(st.lists(st.integers(0, n_banks - 1),
                              min_size=n, max_size=n))
    free0 = data.draw(st.lists(st.integers(0, 20_000),
                               min_size=n_banks, max_size=n_banks))

    args = (jnp.asarray(arrival, jnp.int32), jnp.asarray(service, jnp.int32),
            jnp.asarray(bank, jnp.int32), n_banks,
            jnp.asarray(free0, jnp.int32))
    done_d, free_d = resolve_bank_queues(*args)
    done_s, free_s = resolve_bank_queues_segmented(*args)
    np.testing.assert_array_equal(np.asarray(done_s), np.asarray(done_d))
    np.testing.assert_array_equal(np.asarray(free_s), np.asarray(free_d))


@given(st.lists(st.tuples(st.integers(0, 10_000), st.integers(0, 500),
                          st.booleans()), min_size=1, max_size=64))
@_settings
def test_segmented_maxplus_scan_equals_sequential(items):
    """Segment starts reset the recurrence to a fresh queue."""
    arrival = jnp.asarray([i[0] for i in items], jnp.int32)
    service = jnp.asarray([i[1] for i in items], jnp.int32)
    starts = [True] + [i[2] for i in items[1:]]
    got = np.asarray(segmented_maxplus_scan(
        arrival, service, jnp.asarray(starts)))
    exp, t = [], None
    for (a, s, _), reset in zip(items, starts):
        t = a + s if reset else max(a, t) + s
        exp.append(t)
    np.testing.assert_array_equal(got, np.asarray(exp))


@given(st.lists(st.integers(0, 100_000), min_size=1, max_size=64),
       st.integers(0, 100_000))
@_settings
def test_in_order_returns_properties(completions, last):
    c = jnp.asarray(completions, jnp.int32)
    r = np.asarray(in_order_returns(c, jnp.int32(last)))
    # 1. in-order (monotone nondecreasing)
    assert np.all(np.diff(r) >= 0)
    # 2. never before the media completes, nor before the previous chunk
    assert np.all(r >= np.asarray(completions))
    assert np.all(r >= last)
    # 3. exactly the running max (tag matching holds, never delays more)
    np.testing.assert_array_equal(
        r, np.maximum.accumulate(np.maximum(np.asarray(completions), last)))
