"""Endurance-driven frame retirement + fault injection (robustness PR).

Three layers of guarantees:

* **Disabled path is bitwise-frozen**: with ``endurance_budget=0`` and
  no ``FaultPlan``, every chunk_step_kernel x bank_resolver x donation
  combo (and the sharded sweep) reproduces digests captured on the tree
  *before* the retirement subsystem existed — the subsystem is free when
  off.
* **Retirement respects the table contract**: with a budget (or injected
  frame deaths) the packed-table invariants hold at every chunk
  boundary, pinned pages are never on POISONED frames, and RETIRED
  tombstones are permanent.
* **The serving layer degrades gracefully**: dead pages leave the
  ``PagedKVMap`` forever, dead contract pages re-place immediately, and
  stranded contracts renegotiate back onto the fast tier.
"""
import hashlib
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_trace_arrays
from repro import Engine
from repro.core import (FaultPlan, HybridAllocator, Trace, check_table,
                        init_state, pad_plan, pad_trace, seeded_plan,
                        small_platform, stack_plans)
from repro.core import table as table_lib
from repro.core.faults import NEVER
from repro.serve.kv import PagedKVMap
from repro.serve.scheduler import ContinuousBatchingScheduler, ServeConfig
from repro.serve.contracts import stamp_pin_pages
from repro.sweep import SweepSpec

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# Counter fields that existed before this PR — the goldens hash exactly
# these (``frames_retired``/``transient_faults`` were added with the
# subsystem and are structurally new, not a behavior change).
_OLD_FIELDS = ("reads_fast", "writes_fast", "reads_slow", "writes_slow",
               "bytes_read_fast", "bytes_write_fast", "bytes_read_slow",
               "bytes_write_slow", "sum_read_latency", "n_reads",
               "max_latency", "reorder_held", "energy_pj", "poison_faults")

# sha256[:16] digests captured on the pre-endurance tree (same scenario,
# same hash recipe). Within a policy every kernel/resolver/donate combo
# agreed bitwise, so one digest per policy freezes all eight.
_GOLDEN = {
    "hotness": "215ccbe438b786ef",
    "static": "68e0c1d46b0ddd6a",
    "stream": "215ccbe438b786ef",
    "write_bias": "215ccbe438b786ef",
    "hotness_global": "cfc30b7e8553cbe3",
}
_GOLDEN_SWEEP = "22dd7d03165f7c23"
_GOLDEN_SWEEP_CONT = "a2dc85fb841f5986"

_POLICIES = sorted(_GOLDEN)
_DEAD = table_lib.POISONED | table_lib.RETIRED


def _adversarial_state(cfg):
    """Pins, a pre-poisoned observability page, and a mid-flight swap —
    the state the goldens were captured against."""
    state = init_state(cfg, cfg.runtime())
    table = state.table
    table = table_lib.set_flags(table, [0, 1], table_lib.PIN_FAST)
    table = table_lib.set_flags(table, [cfg.n_fast_pages + 1],
                                table_lib.PIN_SLOW)
    table = table_lib.set_flags(table, [cfg.n_fast_pages + 3],
                                table_lib.POISONED)
    state = state._replace(table=table)
    a = jnp.int32(cfg.n_fast_pages + 2)
    b = jnp.int32(cfg.n_fast_pages - 1)
    return state._replace(dma=state.dma._replace(
        active=jnp.int32(1), page_a=a, page_b=b, start=jnp.int32(0)))


def _swap_pair_trace(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    page, off, w, sz = make_trace_arrays(cfg, n, rng, hot_fraction=0.4)
    hit = rng.random(n) < 0.5
    pair = np.where(rng.random(n) < 0.5, cfg.n_fast_pages + 2,
                    cfg.n_fast_pages - 1).astype(np.int32)
    page = np.where(hit, pair, page).astype(np.int32)
    off = (rng.integers(0, cfg.page_size // 64, n) * 64).astype(np.int32)
    return Trace(jnp.asarray(page), jnp.asarray(off), jnp.asarray(w),
                 jnp.asarray(sz))


def _digest_run(res):
    h = hashlib.sha256()
    for k in ("returns", "device", "latency"):
        h.update(np.ascontiguousarray(np.asarray(res.outs[k])).tobytes())
    h.update(np.ascontiguousarray(np.asarray(res.state.table)).tobytes())
    h.update(np.ascontiguousarray(
        np.asarray(res.state.bank_free)).tobytes())
    for f in ("clock", "clock_ptr", "chunk_idx", "link_free_rx",
              "link_free_tx", "last_return"):
        h.update(str(int(getattr(res.state, f))).encode())
    for f in ("active", "page_a", "page_b", "start", "swaps_done"):
        h.update(str(int(getattr(res.state.dma, f))).encode())
    for f in _OLD_FIELDS:
        h.update(f.encode())
        h.update(np.asarray(res.state.counters._asdict()[f]).tobytes())
    return h.hexdigest()[:16]


def _digest_sweep(result):
    h = hashlib.sha256()
    for k in sorted(result.outs):
        h.update(np.ascontiguousarray(np.asarray(result.outs[k])).tobytes())
    h.update(np.ascontiguousarray(
        np.asarray(result.states.table)).tobytes())
    for f in _OLD_FIELDS:
        h.update(f.encode())
        h.update(np.ascontiguousarray(
            np.asarray(result.states.counters._asdict()[f])).tobytes())
    return h.hexdigest()[:16]


def _golden_base(policy):
    return small_platform(chunk=8, hot_threshold=2, decay_every=8,
                          policy=policy)


# ---------------------------------------------------------------------
# disabled path == pre-endurance goldens, bitwise
# ---------------------------------------------------------------------
@pytest.mark.parametrize("policy", _POLICIES)
def test_disabled_path_matches_pre_endurance_goldens(policy):
    """endurance_budget=0 + no FaultPlan reproduces the pre-PR digests on
    every kernel x resolver x donation combo (two-leg run against the
    adversarial state, exactly the capture scenario)."""
    base = _golden_base(policy)
    t = _swap_pair_trace(base, 96)
    for kernel in ("off", "on"):
        for resolver in ("dense", "segmented"):
            cfg = base.with_(chunk_step_kernel=kernel,
                             bank_resolver=resolver)
            padded, valid = pad_trace(cfg, t)
            engine = Engine(cfg)
            for donate in (False, True):
                res = engine.run(padded, valid=valid,
                                 state=_adversarial_state(cfg),
                                 donate=False)
                res = engine.run(padded, valid=valid, state=res.state,
                                 donate=donate)
                key = f"{kernel}/{resolver}/donate={donate}"
                assert _digest_run(res) == _GOLDEN[policy], \
                    f"{policy}/{key} diverged from the pre-endurance golden"


def test_empty_plan_matches_golden_too():
    """An explicit ``FaultPlan.empty()`` is the same disabled path: the
    sentinel rows never fire, bitwise."""
    base = _golden_base("hotness")
    t = _swap_pair_trace(base, 96)
    for kernel in ("off", "on"):
        cfg = base.with_(chunk_step_kernel=kernel)
        padded, valid = pad_trace(cfg, t)
        engine = Engine(cfg)
        res = engine.run(padded, valid=valid, state=_adversarial_state(cfg),
                         donate=False, faults=FaultPlan.empty())
        res = engine.run(padded, valid=valid, state=res.state,
                         faults=FaultPlan.empty())
        assert _digest_run(res) == _GOLDEN["hotness"]


def test_disabled_sweep_matches_golden():
    base = small_platform(chunk=8, hot_threshold=2, decay_every=8)
    spec = SweepSpec(base=base, technologies=("3dxpoint", "stt-ram"),
                     fast_fractions=(0.125,), policies=("hotness", "static"),
                     link_lats=(40,))
    rng = np.random.default_rng(11)
    t = Trace(*(jnp.asarray(x)
                for x in make_trace_arrays(base, 128, rng, hot_fraction=0.3)))
    engine = Engine(base)
    result = engine.sweep(spec, t)
    assert _digest_sweep(result) == _GOLDEN_SWEEP
    cont = engine.continue_sweep(result, t, donate=False)
    assert _digest_sweep(cont) == _GOLDEN_SWEEP_CONT


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import hashlib, sys
import jax.numpy as jnp
import numpy as np
from conftest import make_trace_arrays
from repro import Engine
from repro.core import Trace, small_platform
from repro.sweep import SweepSpec

OLD = ("reads_fast", "writes_fast", "reads_slow", "writes_slow",
       "bytes_read_fast", "bytes_write_fast", "bytes_read_slow",
       "bytes_write_slow", "sum_read_latency", "n_reads", "max_latency",
       "reorder_held", "energy_pj", "poison_faults")

base = small_platform(chunk=8, hot_threshold=2, decay_every=8)
spec = SweepSpec(base=base, technologies=("3dxpoint", "stt-ram"),
                 fast_fractions=(0.125,), policies=("hotness", "static"),
                 link_lats=(40,))
rng = np.random.default_rng(11)
t = Trace(*(jnp.asarray(x)
            for x in make_trace_arrays(base, 128, rng, hot_fraction=0.3)))
result = Engine(base).sweep(spec, t, mesh="auto")
h = hashlib.sha256()
for k in sorted(result.outs):
    h.update(np.ascontiguousarray(np.asarray(result.outs[k])).tobytes())
h.update(np.ascontiguousarray(np.asarray(result.states.table)).tobytes())
for f in OLD:
    h.update(f.encode())
    h.update(np.ascontiguousarray(
        np.asarray(result.states.counters._asdict()[f])).tobytes())
print(h.hexdigest()[:16])
"""


def test_disabled_sweep_sharded_matches_golden():
    """The 2-device sharded sweep reproduces the unsharded golden —
    sharding never changes the numbers, endurance plumbing included."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    here = os.path.dirname(__file__)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src"), here,
         env.get("PYTHONPATH", "")])
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                       capture_output=True, text=True, timeout=560, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert r.stdout.strip().splitlines()[-1] == _GOLDEN_SWEEP


if HAVE_HYPOTHESIS:
    _ENGINES = {}

    def _cached_engine(kernel):
        if kernel not in _ENGINES:
            cfg = _golden_base("hotness").with_(chunk_step_kernel=kernel)
            _ENGINES[kernel] = Engine(cfg)
        return _ENGINES[kernel]

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), kernel=st.sampled_from(["off", "on"]))
    def test_empty_plan_is_bitwise_free(seed, kernel):
        """Property: for arbitrary traces, running with
        ``FaultPlan.empty()`` is bitwise-identical to running with no
        plan at all — outputs, table, counters, and the new registers."""
        engine = _cached_engine(kernel)
        cfg = engine.cfg
        t = _swap_pair_trace(cfg, 64, seed=seed)
        padded, valid = pad_trace(cfg, t)
        a = engine.run(padded, valid=valid, donate=False)
        b = engine.run(padded, valid=valid, donate=False,
                       faults=FaultPlan.empty())
        for k in a.outs:
            np.testing.assert_array_equal(np.asarray(a.outs[k]),
                                          np.asarray(b.outs[k]), err_msg=k)
        for f in a.state._fields:
            np.testing.assert_array_equal(
                np.asarray(jnp.asarray(getattr(a.state, f))
                           if not isinstance(getattr(a.state, f), tuple)
                           else 0),
                np.asarray(jnp.asarray(getattr(b.state, f))
                           if not isinstance(getattr(b.state, f), tuple)
                           else 0), err_msg=f)
        np.testing.assert_array_equal(
            np.asarray(a.state.counters), np.asarray(b.state.counters))
        np.testing.assert_array_equal(
            np.asarray(a.state.dma), np.asarray(b.state.dma))


# ---------------------------------------------------------------------
# retirement semantics
# ---------------------------------------------------------------------
def _write_burst_trace(cfg, n, lo, hi, seed=0):
    """Writes hammering slow pages [lo, hi) — drives WEAR up fast."""
    rng = np.random.default_rng(seed)
    page = rng.integers(lo, hi, n).astype(np.int32)
    off = (rng.integers(0, cfg.page_size // 64, n) * 64).astype(np.int32)
    return Trace(jnp.asarray(page), jnp.asarray(off),
                 jnp.ones(n, bool), jnp.full(n, 64, jnp.int32))


@pytest.mark.parametrize("kernel", ["off", "on"])
def test_budget_retirement_invariants_every_boundary(kernel):
    """With a small endurance budget, frames retire; the packed-table
    invariants (RETIRED => POISONED, never PINNED & POISONED, bijection)
    hold after every chunk boundary, and retirement monotonically
    accumulates permanent tombstones."""
    cfg = small_platform(chunk=8, policy="hotness", decay_every=8,
                         endurance_budget=6,
                         chunk_step_kernel=kernel)
    engine = Engine(cfg)
    state = engine.init_state()
    nf, n = cfg.n_fast_pages, cfg.n_pages
    rng = np.random.default_rng(1)
    seen_retired = set()
    for i in range(40):        # one chunk per run => check every boundary
        t = _write_burst_trace(cfg, cfg.chunk, nf, n, seed=i)
        state, outs = engine.run(t, state=state)
        table = np.asarray(state.table)
        check_table(cfg, table)
        flags = table[:, table_lib.FLAGS]
        assert not (((flags & table_lib.PINNED) != 0)
                    & ((flags & table_lib.POISONED) != 0)).any()
        retired = set(np.flatnonzero((flags & table_lib.RETIRED) != 0)
                      .tolist())
        assert seen_retired <= retired, "a tombstone was resurrected"
        seen_retired = retired
    assert int(state.counters.frames_retired) > 0, \
        "budget=6 under a write hammer never retired a frame"
    assert len(seen_retired) > 0
    # Retired pages are tombstones on dead frames: all POISONED too.
    flags = np.asarray(state.table)[:, table_lib.FLAGS]
    assert ((flags[sorted(seen_retired)] & table_lib.POISONED) != 0).all()


def test_scan_and_kernel_agree_with_retirement_active():
    """The fused kernel and the scan path stay bitwise-identical with
    the retirement machinery firing (budget + injected deaths)."""
    base = small_platform(chunk=8, policy="hotness", decay_every=8,
                          endurance_budget=8)
    t = _write_burst_trace(base, 96, base.n_fast_pages, base.n_pages)
    plan = FaultPlan.of(deaths=[(2, 3), (5, base.n_fast_pages + 7)],
                        transient=[(1, base.n_fast_pages + 2)])
    digests = []
    for kernel in ("off", "on"):
        cfg = base.with_(chunk_step_kernel=kernel)
        engine = Engine(cfg)
        res = engine.run(t, donate=False, faults=plan)
        digests.append(_digest_run(res))
        assert int(res.state.counters.frames_retired) > 0
    assert digests[0] == digests[1]


def test_adversarial_midswap_death_poison_travels():
    """Kill the frame under a page that is a live DMA swap endpoint: the
    rescue rides the in-flight swap — at commit the data lands on the
    healthy frame, the counterpart becomes the tombstone, and the table
    invariants never break."""
    cfg = small_platform(chunk=8, policy="hotness", decay_every=8)
    engine = Engine(cfg)
    state = engine.init_state()
    a = cfg.n_fast_pages + 2            # slow-resident swap member
    b = cfg.n_fast_pages - 1            # fast-resident counterpart
    state = state._replace(dma=state.dma._replace(
        active=jnp.int32(1), page_a=jnp.int32(a), page_b=jnp.int32(b),
        start=jnp.int32(0)))
    plan = FaultPlan.of(deaths=[(0, a)])
    t = _swap_pair_trace(cfg, 64, seed=3)
    state, outs = engine.run(t, state=state, faults=plan)
    table = np.asarray(state.table)
    check_table(cfg, table)
    assert int(state.counters.frames_retired) == 1
    flags = table[:, table_lib.FLAGS]
    dead = np.flatnonzero((flags & table_lib.RETIRED) != 0)
    assert len(dead) == 1
    # The rescued page (the dying swap member) is clean again; its
    # counterpart was sacrificed as the tombstone.
    assert (flags[a] & _DEAD) == 0 or a in dead
    tombs = np.asarray(outs["tombstone"])
    assert (tombs >= 0).any()
    assert int(tombs.max()) == int(dead[0])


def test_min_wear_register_tracks_global_floor():
    """The carried min-wear register re-scrubs on decay boundaries and
    stays a monotone lower bound of the true slow-tier wear floor."""
    cfg = small_platform(chunk=8, policy="wear_level", decay_every=8,
                         wear_slack=2)
    engine = Engine(cfg)
    state = engine.init_state()
    for i in range(6):
        t = _write_burst_trace(cfg, 32, cfg.n_fast_pages, cfg.n_pages,
                               seed=i)
        state, _ = engine.run(t, state=state)
    wear = np.asarray(table_lib.wear(state.table))
    n_slow = cfg.n_pages - cfg.n_fast_pages
    true_floor = int(wear[:n_slow].min())
    assert 0 <= int(state.min_wear) <= true_floor


# ---------------------------------------------------------------------
# FaultPlan mechanics
# ---------------------------------------------------------------------
def test_seeded_plan_deterministic_and_paddable():
    p1 = seeded_plan(7, pages=np.arange(64), n_chunks=100, n_deaths=4,
                     n_transient=6)
    p2 = seeded_plan(7, pages=np.arange(64), n_chunks=100, n_deaths=4,
                     n_transient=6)
    np.testing.assert_array_equal(np.asarray(p1.deaths),
                                  np.asarray(p2.deaths))
    np.testing.assert_array_equal(np.asarray(p1.transient),
                                  np.asarray(p2.transient))
    p3 = seeded_plan(8, pages=np.arange(64), n_chunks=100, n_deaths=4,
                     n_transient=6)
    assert not np.array_equal(np.asarray(p1.deaths), np.asarray(p3.deaths))
    # deaths sorted by chunk; padding preserves events
    d = np.asarray(p1.deaths)
    assert (np.diff(d[:, 0]) >= 0).all()
    padded = pad_plan(p1, 10, 10)
    assert padded.shape_sig == ((10, 2), (10, 2))
    np.testing.assert_array_equal(np.asarray(padded.deaths)[:4], d)
    assert (np.asarray(padded.deaths)[4:, 0] == NEVER).all()


def test_stacked_fault_sweep_design_points():
    """A stacked per-point plan batch sweeps fault scenarios as design
    points in one compiled program: points with deaths retire frames,
    the empty point retires none."""
    base = small_platform(chunk=8, policy="hotness", decay_every=8)
    spec = SweepSpec(base=base, policies=("hotness", "static"))
    plans = [
        pad_plan(FaultPlan.of(deaths=[(1, base.n_fast_pages + 2),
                                      (4, base.n_fast_pages + 5)]), 4, 4),
        pad_plan(FaultPlan.empty(), 4, 4),
    ]
    faults = stack_plans(plans)
    rng = np.random.default_rng(5)
    t = Trace(*(jnp.asarray(x)
                for x in make_trace_arrays(base, 64, rng)))
    result = Engine(base).sweep(spec, t, faults=faults)
    rows = result.rows()
    assert rows[0]["frames_retired"] > 0
    assert rows[1]["frames_retired"] == 0
    for i in range(2):
        check_table(result.points[i].cfg, np.asarray(result.states.table[i]))


# ---------------------------------------------------------------------
# serving-level degradation
# ---------------------------------------------------------------------
def test_allocator_retire_permanent():
    cfg = small_platform()
    alloc = HybridAllocator(cfg)
    h, pages = alloc.alloc(4)
    alloc.retire(pages[:2])
    alloc.free(h)
    free = alloc.free_pages
    total_free = free[0] + free[1]
    assert total_free == cfg.n_pages - 2
    assert alloc.retired_pages == {int(p) for p in pages[:2]}
    # retired pages are never handed out again
    _, fresh = alloc.alloc(cfg.n_pages - 2)
    assert not (set(fresh.tolist()) & alloc.retired_pages)


def test_kv_protected_pages_survive_eviction():
    """Regression (eviction-recency bug): pages named by built-but-
    undispatched requests must not be evicted, however cold."""
    cfg = small_platform()
    kv = PagedKVMap(cfg, max_live_seqs=8, max_pages_per_seq=4,
                    free_low_frac=1.0, free_high_frac=1.0)  # always evict
    pages = kv.alloc(6)
    slots = np.repeat(np.arange(2), 3)
    idx = np.tile(np.arange(1, 4, dtype=np.int32), 2)  # idx 0 would pin
    kv.assign(slots, idx, pages, step=1)
    protected = pages[:3]
    victims = kv.maybe_evict(step=5, extra_needed=0, protected=protected)
    assert not (set(victims.tolist()) & set(protected.tolist()))
    assert set(victims.tolist()) == set(pages[3:].tolist())
    # unprotected call takes them all
    kv2 = PagedKVMap(cfg, max_live_seqs=8, max_pages_per_seq=4,
                     free_low_frac=1.0, free_high_frac=1.0)
    pages2 = kv2.alloc(6)
    kv2.assign(slots, idx, pages2, step=1)
    victims2 = kv2.maybe_evict(step=5)
    assert set(victims2.tolist()) == set(pages2.tolist())


def test_kv_retire_pages_never_return():
    cfg = small_platform()
    kv = PagedKVMap(cfg, max_live_seqs=4, max_pages_per_seq=4)
    pages = kv.alloc(4)
    kv.assign(np.zeros(4, np.int64), np.arange(4, dtype=np.int32),
              pages, step=1)
    free_before = kv.free_total
    live, slots, idxs = kv.retire_pages(pages[:2])
    assert set(live.tolist()) == set(pages[:2].tolist())
    assert (slots == 0).all()
    assert (kv.page_of[0, idxs] == -1).all()
    assert kv.retired == 2
    # dead pages dropped from circulation: freeing them is a no-op, and
    # nothing ever allocates them again
    kv._free(pages[:2])
    assert kv.free_total == free_before
    got = kv.alloc(kv.free_total)
    assert not (set(got.tolist()) & set(pages[:2].tolist()))
    # retiring a free page compacts it out of the stacks
    free_page = got[-1:]
    kv._free(got)
    t0 = kv.free_total
    kv.retire_pages(free_page)
    assert kv.free_total == t0 - 1


def test_stamp_pin_skips_poisoned_pages():
    cfg = small_platform()
    engine = Engine(cfg)
    state = engine.init_state()
    sick = cfg.n_fast_pages + 4
    state = state._replace(table=table_lib.set_flags(
        state.table, [sick], table_lib.POISONED))
    state = stamp_pin_pages(state, np.asarray([sick, 0], np.int32))
    flags = np.asarray(state.table)[:, table_lib.FLAGS]
    assert (flags[sick] & table_lib.PINNED) == 0, \
        "stamped a pin onto a dying frame"
    assert (flags[0] & table_lib.PIN_FAST) != 0
    check_table(cfg, np.asarray(state.table))


def test_serving_recovery_under_faults():
    """End-to-end seeded-fault serving run: frames retire, recovery
    re-places contracts, pinned pages are never on poisoned frames, and
    every sequence still completes."""
    cfg = small_platform(chunk=8, policy="hotness", decay_every=8)
    engine = Engine(cfg)
    plan = seeded_plan(3, pages=np.arange(cfg.n_pages), n_chunks=400,
                       n_deaths=6, n_transient=12)
    sched = ContinuousBatchingScheduler(engine, ServeConfig(
        sorted_batch_sizes=(16, 32, 64), max_live_seqs=32,
        max_pages_per_seq=4, slo_latency_us=1e9, faults=plan))
    sched.warmup()
    warm = engine.compile_count
    rng = np.random.default_rng(0)
    sched.submit(rng.integers(1, 4, 40), rng.integers(2, 8, 40))
    sched.run()
    rep = sched.report()
    assert engine.compile_count == warm, "fault plumbing caused recompiles"
    assert rep.n_sequences == 40
    assert rep.frames_retired > 0
    assert rep.slo_attainment == 1.0
    table = np.asarray(sched.carry.table)
    check_table(cfg, table)
    flags = table[:, table_lib.FLAGS]
    assert not (((flags & table_lib.PINNED) != 0)
                & ((flags & table_lib.POISONED) != 0)).any()
    # dead pages left KV circulation for good
    dead = np.flatnonzero(sched.kv.dead)
    assert len(dead) == rep.frames_retired
    assert (sched.kv.owner[dead] == -1).all()


def test_contract_renegotiation_repins_to_fast():
    """Contracts stranded slow (spilled admission) re-pin onto the fast
    tier as pages free up."""
    cfg = small_platform(chunk=8, policy="static")
    engine = Engine(cfg)
    nf = cfg.n_fast_pages
    sched = ContinuousBatchingScheduler(engine, ServeConfig(
        sorted_batch_sizes=(16, 32), max_live_seqs=64,
        max_pages_per_seq=3, slo_latency_us=1e9))
    sched.warmup()
    # Exhaust the fast stack so admission spills every contract slow.
    hog = sched.kv.alloc(len(sched.kv._stacks[0]), hint=0)
    sched.submit(np.full(8, 2), np.full(8, 4))
    sched.step()
    assert len(sched._reneg) > 0, "no contract spilled despite a full tier"
    # Free the fast pages; the next steps renegotiate.
    sched.kv._free(hog)
    sched.run()
    rep = sched.report()
    assert rep.renegotiations > 0
    assert rep.n_sequences == 8
    check_table(cfg, np.asarray(sched.carry.table))
