"""shard_map expert-parallel MoE (M3): sharded execution must match the
dense path numerically when capacity is not binding."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
import repro.configs as C
from repro.models import init_params, loss_fn, ShardCtx
from repro.launch.mesh import make_dev_mesh

cfg = C.get_smoke("phi35_moe_42b")
# capacity not binding -> no drops -> paths must agree exactly
cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0),
                n_heads=4, n_kv_heads=2)
params = init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batch = {"inputs": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)}

ref, refm = jax.jit(lambda p, b: loss_fn(cfg, p, b, ShardCtx()))(params, batch)

mesh = make_dev_mesh(model=2)
sh = ShardCtx.from_mesh(mesh)
with mesh:
    got, gotm = jax.jit(lambda p, b: loss_fn(cfg, p, b, sh))(params, batch)
np.testing.assert_allclose(float(ref), float(got), rtol=2e-4)
np.testing.assert_allclose(float(refm["aux"]), float(gotm["aux"]), rtol=2e-4)

# gradients must agree too (all-to-all + shard_map autodiff)
g1 = jax.jit(jax.grad(lambda p: loss_fn(cfg, p, batch, ShardCtx())[0]))(params)
with mesh:
    g2 = jax.jit(jax.grad(lambda p: loss_fn(cfg, p, batch, sh)[0]))(params)
for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
print("MOE_SM_OK", float(ref), float(got))
"""


def test_moe_shardmap_matches_dense():
    import os
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=560, env=env, cwd=root)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "MOE_SM_OK" in r.stdout
