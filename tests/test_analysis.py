"""The reprolint suite checks itself: every pass must (a) run clean on
this repo and (b) demonstrably FAIL — non-zero exit with a file:line
finding — on its seeded-violation fixture in tests/analysis_fixtures/.

The CLI contract is tested through real subprocesses (exit codes are the
CI interface); the checker internals get direct unit tests, including
deliberately-broken inputs the fixtures can't express (a doctored
static-key allowlist, an aliasing-free lowering, pragma suppression).
"""
from __future__ import annotations

import pathlib
import re
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = ROOT / "tests" / "analysis_fixtures"
PASSES = ("schedule", "donation", "lanes", "staticness", "tripwire",
          "docrefs", "ranges", "pallas_san")


def _cli(*args):
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}:{env.get('PYTHONPATH', '')}"
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=ROOT, env=env)


@pytest.mark.parametrize("name", PASSES)
def test_pass_fails_on_seeded_fixture(name):
    fixture = FIXTURES / f"bad_{name}.py"
    r = _cli("--pass", name, str(fixture))
    assert r.returncode != 0, \
        f"{name} pass must fail on its fixture\n{r.stdout}\n{r.stderr}"
    assert re.search(rf"bad_{name}\.py:\d+: \[{name}\]", r.stdout), \
        f"no file:line finding in output:\n{r.stdout}"


def test_cli_clean_on_repo():
    """The whole suite exits 0 on the merged tree (the CI gate)."""
    r = _cli("--check")
    assert r.returncode == 0, \
        f"reprolint must run clean on the repo:\n{r.stdout}\n{r.stderr}"
    assert "0 finding(s)" in r.stdout


def test_cli_report_json(tmp_path):
    report = tmp_path / "findings.json"
    r = _cli("--pass", "lanes", "--report", str(report),
             str(FIXTURES / "bad_lanes.py"))
    assert r.returncode != 0
    import json

    data = json.loads(report.read_text())
    assert set(data) == {"findings", "proved_bounds", "stats"}
    rows = data["findings"]
    assert rows and all(
        set(row) == {"path", "line", "pass_name", "message"}
        for row in rows)
    assert data["stats"]["total"] >= data["stats"]["lanes"] >= 0


def test_cli_report_proved_bounds(tmp_path):
    """A repo-mode ranges run ships per-program budget proofs (the
    per-chunk growth G, the horizon, and the proved per-lane bounds)."""
    report = tmp_path / "bounds.json"
    r = _cli("--pass", "ranges", "--report", str(report))
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    import json

    bounds = json.loads(report.read_text())["proved_bounds"]
    labels = {b["label"] for b in bounds}
    assert labels == {"scan-path", "pallas-body", "jnp-ref"}
    for b in bounds:
        assert b["int32_horizon_chunks"] >= b["n_chunks_budget"]
        assert b["table_gathers_proved"] > 0
    lanes = next(b for b in bounds if b["label"] == "jnp-ref")["lanes"]
    assert lanes["HOTNESS"][1] <= 2**29 and lanes["WEAR"][1] <= 2**29


def test_cli_baseline_diff(tmp_path):
    """--baseline makes known findings informational: same fixture twice
    exits 0; adding a second violating fixture exits 1 again."""
    base = tmp_path / "base.json"
    bad = str(FIXTURES / "bad_ranges.py")
    r = _cli("--pass", "ranges", "--report", str(base), bad)
    assert r.returncode != 0
    r = _cli("--pass", "ranges", "--baseline", str(base), bad)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "0 new vs baseline" in r.stdout
    r = _cli("--pass", "ranges", "--pass", "pallas_san",
             "--baseline", str(base), bad,
             str(FIXTURES / "bad_pallas_san.py"))
    assert r.returncode != 0
    assert "new vs baseline" in r.stdout


# --- checker internals ----------------------------------------------------


def test_schedule_checker_clean_on_good_step():
    import jax
    import jax.numpy as jnp

    from repro.analysis import schedule

    def good_step(table, pages, w):
        hot = table[pages, 2]  # gather before the commit
        flat = table.reshape(-1)
        t2 = flat.at[pages * 8 + 2].add(w + hot, mode="drop")
        t2 = t2.reshape(table.shape)
        return t2[pages, 3]  # committed-table read

    i32 = jnp.int32
    jaxpr = jax.make_jaxpr(good_step)(
        jnp.zeros((16, 8), i32), jnp.arange(4, dtype=i32),
        jnp.ones(4, i32))
    assert schedule.check_jaxpr_schedule(jaxpr, 0, label="good") == []


def test_schedule_checker_flags_missing_commit():
    import jax
    import jax.numpy as jnp

    from repro.analysis import schedule

    jaxpr = jax.make_jaxpr(lambda t: t[0, 2])(
        jnp.zeros((16, 8), jnp.int32))
    findings = schedule.check_jaxpr_schedule(jaxpr, 0, label="nocommit")
    assert any("no flattened scatter-add" in f.message for f in findings)


def test_donation_aliasing_parser_sees_alias():
    import jax
    import jax.numpy as jnp

    from repro.analysis import donation

    fn = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    txt = fn.lower(jnp.zeros((8, 8), jnp.int32)).as_text()
    dims, aliased = donation._aliased_args(txt)
    assert dims[0] == "8x8"
    assert 0 in aliased


def test_donation_read_after_donate_rebind_is_clean():
    from repro.analysis import donation

    src = (
        "def ok(engine, trace, state):\n"
        "    state, outs = engine.run(trace, state=state)\n"
        "    return state.table, outs\n"
        "\n"
        "def explicit_no_donate(engine, trace, state):\n"
        "    out = engine.run(trace, state=state, donate=False)\n"
        "    return state.table, out\n")
    import ast

    assert donation._check_read_after_donate(ast.parse(src), "x.py") == []


def test_donation_read_after_donate_flags_leak():
    import ast

    from repro.analysis import donation

    src = (
        "def leak(engine, trace, state):\n"
        "    out = engine.run(trace, state=state)\n"
        "    return out, state.table\n")
    findings = donation._check_read_after_donate(ast.parse(src), "x.py")
    assert len(findings) == 1 and findings[0].line == 3


def test_lanes_pragma_suppresses():
    from repro.analysis import lanes

    src = (
        "from repro.core import table as table_lib\n"
        "\n"
        "def peek(table, pages):\n"
        "    # reprolint: allow[lanes] layout probe for a debug dump\n"
        "    return table[pages, table_lib.HOTNESS]\n")
    assert lanes.check_source(src, "x.py") == []
    # same source without the pragma: flagged
    assert lanes.check_source(src.replace(
        "    # reprolint: allow[lanes] layout probe for a debug dump\n",
        ""), "x.py") != []


def test_staticness_completeness_detects_uncovered_knob(monkeypatch):
    """Un-allowlist the known-inert TechnologyParams subfields: the
    perturbation checker must report them as reaching neither
    static_key nor RuntimeParams."""
    from repro.analysis import common, staticness

    monkeypatch.setattr(staticness, "INERT_SUBFIELDS", set())
    findings = staticness.check_static_key_completeness(common.repo_root())
    assert any("endurance_log10" in f.message and "NEITHER" in f.message
               for f in findings)


def test_staticness_repo_fields_all_perturbable():
    from repro.analysis import common, staticness

    findings = staticness.check_static_key_completeness(common.repo_root())
    assert findings == [], [f.format() for f in findings]


def test_tripwire_passes_when_flat_and_raises_on_compile():
    import jax.numpy as jnp

    from repro import Engine
    from repro.analysis import RecompileError, assert_compile_flat
    from repro.core import small_platform
    from repro.core.emulator import Trace

    # distinct geometry: never collides with other tests' compile counts
    eng = Engine(small_platform(n_fast_pages=4, n_slow_pages=20, chunk=4))
    i32 = jnp.int32
    trace = Trace(page=jnp.zeros(4, i32), offset=jnp.zeros(4, i32),
                  is_write=jnp.zeros(4, bool), size=jnp.full(4, 64, i32))
    with assert_compile_flat(eng, allow=1) as cc:
        eng.run(trace)  # cold: exactly one new entry
    assert cc.count == 1
    with assert_compile_flat(eng):
        eng.run(trace)  # warm: flat
    with pytest.raises(RecompileError, match="new emulation entry"):
        with assert_compile_flat(eng):
            eng.run(Trace(*(jnp.resize(x, 8) for x in trace)))


def test_docrefs_tokens():
    from repro.analysis import docrefs

    findings = docrefs.check_source(
        "# port of the old run_sweep helper\n", "x.py")
    assert findings and findings[0].line == 1
    assert docrefs.check_source(
        "state = engine.run_stream(segments)\n", "x.py") == []
