"""The session API (`repro.Engine`) correctness contract:

* Engine.run is BITWISE invariant across bank_resolver x
  fuse_swap_gather x donate combos, fresh and continued, and
  Engine.sweep matches per-point Engine runs bit-for-bit;
* `run_stream` over K segments — equal-size or ragged — is bitwise
  identical to one concatenated `run`;
* mesh-sharded, donated continued sweeps equal the single long
  unsharded sweep (the ROADMAP states-x-mesh composition item);
* the unified entry-point cache makes same-geometry Engines reuse
  executables (Engine.compile_count — no recompile regression);
* the frozen PolicyRegistry snapshot is immune to later global
  registrations;
* chunk=1 Engine runs match the sequential software oracle.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_trace_arrays
from repro import Engine, PolicyRegistry
from repro.core import Trace, pad_trace, small_platform
from repro.core import policies as policies_lib
from repro.sims import trace_sim
from repro.sweep import SweepSpec, build_points


def _trace(cfg, n, seed=0, **kw):
    arrays = make_trace_arrays(cfg, n, np.random.default_rng(seed), **kw)
    return Trace(*(jnp.asarray(x) for x in arrays))


def _assert_state_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.table), np.asarray(b.table))
    assert int(a.clock) == int(b.clock)
    assert int(a.clock_ptr) == int(b.clock_ptr)
    assert int(a.dma.swaps_done) == int(b.dma.swaps_done)


@pytest.mark.parametrize("knobs", [
    dict(bank_resolver="dense", fuse_swap_gather=False),
    dict(bank_resolver="dense", fuse_swap_gather=True),
    dict(bank_resolver="segmented", fuse_swap_gather=False),
    dict(bank_resolver="segmented", fuse_swap_gather=True),
])
@pytest.mark.parametrize("donate", [False, True])
def test_engine_run_knobs_bitwise_and_donation(knobs, donate):
    """Every resolver/fusion knob combo — fresh and continued, donated or
    not — is bitwise identical to the baseline dense/unfused path, and
    donation consumes the passed-in state (session contract)."""
    base = small_platform(chunk=16, hot_threshold=2, decay_every=8)
    cfg = base.with_(**knobs)
    t = _trace(cfg, 160, hot_fraction=0.5)
    engine = Engine(cfg)

    # fresh-state run
    want_state, want_outs = Engine(base).run(t)
    got_state, got_outs = engine.run(t)
    for k in ("returns", "device", "latency"):
        np.testing.assert_array_equal(np.asarray(got_outs[k]),
                                      np.asarray(want_outs[k]))
    _assert_state_equal(got_state, want_state)

    # continued run, with/without donation
    want2 = Engine(base).run(t, state=want_state, donate=False)
    got2 = engine.run(t, state=got_state, donate=donate)
    np.testing.assert_array_equal(np.asarray(got2.outs["returns"]),
                                  np.asarray(want2.outs["returns"]))
    _assert_state_equal(got2.state, want2.state)
    if donate:  # the passed-in state was consumed (session contract)
        with pytest.raises(RuntimeError):
            np.asarray(got_state.table)


def test_engine_run_donates_passed_state_by_default():
    cfg = small_platform(chunk=16, hot_threshold=2)
    t = _trace(cfg, 96)
    engine = Engine(cfg)
    s0, _ = engine.run(t)
    s1, _ = engine.run(t, state=s0)
    with pytest.raises(RuntimeError):
        np.asarray(s0.table)
    # donate=False keeps the caller's state readable
    s2, _ = engine.run(t, state=s1, donate=False)
    np.asarray(s1.table)
    assert int(s2.clock) > int(s1.clock)
    # explicit donate=True with nothing to donate raises (same guard as
    # the legacy wrappers) instead of being silently dropped
    with pytest.raises(ValueError, match="donate=True requires state="):
        engine.run(t, donate=True)
    with pytest.raises(ValueError, match="donate=True requires state="):
        engine.run_stream([t], donate=True)


@pytest.mark.parametrize("seg_lens", [
    (48, 48, 48),          # equal chunk-multiple segments: one executable
    (40, 25, 31, 48),      # ragged: remainders re-chunked across segments
    (7, 3, 134),           # sub-chunk segments carried forward
])
def test_run_stream_bitwise_matches_concatenated_run(seg_lens):
    cfg = small_platform(chunk=16, hot_threshold=2, decay_every=8)
    t = _trace(cfg, sum(seg_lens), hot_fraction=0.5)
    engine = Engine(cfg)
    want_state, want_outs = engine.run(t)

    segs, at = [], 0
    for ln in seg_lens:
        segs.append(Trace(*(x[at:at + ln] for x in t)))
        at += ln
    got_state, got_outs = engine.run_stream(iter(segs))
    for k in ("returns", "device", "latency"):
        np.testing.assert_array_equal(np.asarray(got_outs[k]),
                                      np.asarray(want_outs[k]))
    _assert_state_equal(got_state, want_state)


def test_run_stream_continues_and_consumes_state():
    cfg = small_platform(chunk=16, hot_threshold=2)
    t = _trace(cfg, 96)
    engine = Engine(cfg)
    t2 = Trace(*(jnp.concatenate([x, x]) for x in t))
    want_state, want_outs = engine.run(t2)

    s0, first_outs = engine.run(t)
    got_state, got_outs = engine.run_stream([t], state=s0)
    np.testing.assert_array_equal(np.asarray(got_outs["returns"]),
                                  np.asarray(want_outs["returns"][96:]))
    _assert_state_equal(got_state, want_state)
    with pytest.raises(RuntimeError):   # donated by default
        np.asarray(s0.table)


def test_engine_sweep_bitwise_matches_per_point_runs():
    base = small_platform(chunk=16, hot_threshold=2, decay_every=8)
    spec = SweepSpec(base=base, technologies=("3dxpoint", "stt-ram"),
                     fast_fractions=(0.125, 0.25),
                     policies=("static", "hotness"), link_lats=(600, 100))
    # trace length 144 (not 160): keeps this grid's entry-cache key
    # distinct from test_sweep's, whose compile-count delta asserts ==1
    t = _trace(base, 144, hot_fraction=0.5)
    engine = Engine(base)
    got = engine.sweep(spec, t)
    points = build_points(spec)
    assert [r["label"] for r in got.rows()] == [pt.label for pt in points]
    for i, pt in enumerate(points):
        want_state, want_outs = Engine(pt.cfg).run(t)
        for k in ("returns", "device", "latency"):
            np.testing.assert_array_equal(np.asarray(got.outs[k][i]),
                                          np.asarray(want_outs[k]))
        np.testing.assert_array_equal(np.asarray(got.states.table[i]),
                                      np.asarray(want_state.table))


def test_engine_sweep_accepts_stacked_params():
    """spec_or_params: a pre-stacked RuntimeParams batch sweeps directly
    (policy_id indexing the engine registry)."""
    import jax

    base = small_platform(chunk=16, hot_threshold=2)
    t = _trace(base, 96)
    engine = Engine(base)
    cfgs = [base.with_(hot_threshold=h) for h in (2, 8)]
    params = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[engine.params._replace(
            hot_threshold=jnp.int32(c.hot_threshold)) for c in cfgs])
    res = engine.sweep(params, t)
    assert len(res) == 2
    for i, c in enumerate(cfgs):
        one = Engine(c).run(t)
        np.testing.assert_array_equal(np.asarray(res.outs["returns"][i]),
                                      np.asarray(one.outs["returns"]))

    # Regression: continuing a stacked-params sweep must replay the
    # RECORDED params (the placeholder points carry only the base cfg —
    # rebuilding from them silently ran every point at default knobs).
    cont = engine.continue_sweep(res, t, donate=False)
    for i, c in enumerate(cfgs):
        e = Engine(c)
        s = e.run(t, donate=False).state
        want = e.run(t, state=s).state
        np.testing.assert_array_equal(np.asarray(cont.states.table[i]),
                                      np.asarray(want.table))
        assert int(cont.states.clock[i]) == int(want.clock)


def test_mesh_sharded_donated_continued_sweep_matches_long_run():
    """The ROADMAP composition item: continued sweeps with donated,
    device-sharded stacked states == the single long unsharded sweep."""
    base = small_platform(chunk=16, hot_threshold=2, decay_every=8)
    points = build_points(SweepSpec(
        base=base, technologies=("3dxpoint", "stt-ram", "mram"),
        policies=("static", "hotness")))
    t = _trace(base, 96, hot_fraction=0.5)
    n = len(t)
    t2 = Trace(*(jnp.concatenate([x, x]) for x in t))
    engine = Engine(base)

    full = engine.sweep(points, t2)
    # point count (6) deliberately not a multiple of any >1 device count,
    # exercising the state/params co-padding path
    first = engine.sweep(points, t, mesh="auto")
    cont = engine.continue_sweep(first, t, mesh="auto")   # donate=True
    np.testing.assert_array_equal(np.asarray(cont.outs["returns"]),
                                  np.asarray(full.outs["returns"][:, n:]))
    np.testing.assert_array_equal(np.asarray(cont.states.table),
                                  np.asarray(full.states.table))
    np.testing.assert_array_equal(np.asarray(cont.states.clock),
                                  np.asarray(full.states.clock))

    # and the unsharded continuation agrees too
    first2 = engine.sweep(points, t)
    cont2 = engine.continue_sweep(first2, t)
    np.testing.assert_array_equal(np.asarray(cont2.states.table),
                                  np.asarray(full.states.table))


def test_same_geometry_engines_share_executables():
    """No-recompile regression: a second Engine over the same static
    geometry (different runtime knobs) must add zero compiled programs,
    and repeated sweeps/runs hit the unified cache."""
    cfg = small_platform(chunk=8, hot_threshold=2)
    t = _trace(cfg, 64)
    e1 = Engine(cfg)
    e1.run(t)
    e1.sweep(SweepSpec(base=cfg, link_lats=(600, 100)), t)
    count = e1.compile_count
    assert count >= 2

    e2 = Engine(cfg.with_(hot_threshold=9, link_lat=100))  # same geometry
    assert e2.compile_count == count
    e2.run(t)
    e2.sweep(SweepSpec(base=cfg.with_(decay_every=4), link_lats=(600, 100)), t)
    assert e2.compile_count == count

    # a different geometry compiles separately and is counted separately
    # (n_banks=3 keeps this geometry unique to the test — the cache is
    # process-global, so assertions stay delta-based)
    e3 = Engine(cfg.with_(n_banks=3))
    c3 = e3.compile_count
    e3.run(t)
    assert e3.compile_count == c3 + 1
    assert e2.compile_count == count


def test_frozen_registry_is_immune_to_late_registration():
    cfg = small_platform(chunk=8, hot_threshold=2)
    t = _trace(cfg, 64)
    engine = Engine(cfg)
    want = engine.run(t, donate=False)
    original = policies_lib.POLICIES.get("hotness")
    try:
        # Re-register the active policy with a do-nothing impostor AFTER
        # the session snapshot: the session must be unaffected...
        @policies_lib.register("hotness")
        def impostor(cfg, params, table, ptr, pages, is_write, valid):
            return policies_lib.static_policy(cfg, params, table, ptr,
                                              pages, is_write, valid)

        assert "hotness" not in [n for n, f in zip(engine.registry.names,
                                                   engine.registry.fns)
                                 if f is impostor]
        again = engine.run(t, donate=False)
        np.testing.assert_array_equal(np.asarray(again.outs["returns"]),
                                      np.asarray(want.outs["returns"]))
        assert int(again.state.dma.swaps_done) == \
            int(want.state.dma.swaps_done) > 0

        # ...while a NEW session snapshots the impostor (never migrates)
        fresh = Engine(cfg)
        assert fresh.registry != engine.registry
        other = fresh.run(t, donate=False)
        assert int(other.state.dma.swaps_done) == 0
    finally:
        policies_lib.POLICIES["hotness"] = original


def test_registry_snapshot_and_subset():
    reg = PolicyRegistry.snapshot()
    assert "hotness" in reg and reg.index("hotness") == \
        policies_lib.policy_id("hotness")
    sub = reg.subset(["hotness", "static"])
    assert sub.names == ("hotness", "static")
    assert sub.fns[0] is policies_lib.POLICIES["hotness"]
    with pytest.raises(KeyError, match="not in this registry"):
        sub.index("stream")
    with pytest.raises(KeyError, match="unknown policy"):
        PolicyRegistry.snapshot(("typo",))


def test_engine_chunk1_matches_trace_sim_oracle():
    cfg = small_platform(chunk=1, hot_threshold=2, decay_every=8)
    arrays = make_trace_arrays(cfg, 200, np.random.default_rng(3))
    t = Trace(*(jnp.asarray(x) for x in arrays))
    state, outs = Engine(cfg).run(t)
    oracle = trace_sim.simulate(cfg, *arrays)
    np.testing.assert_array_equal(np.asarray(outs["returns"]),
                                  oracle.returns)
    np.testing.assert_array_equal(np.asarray(outs["device"]), oracle.device)
    assert int(state.clock) == oracle.clock
    assert int(state.dma.swaps_done) == oracle.swaps


def test_engine_pads_and_trims_unaligned_traces():
    cfg = small_platform(chunk=16, hot_threshold=2)
    t = _trace(cfg, 90)    # not a chunk multiple
    engine = Engine(cfg)
    state, outs = engine.run(t)
    assert outs["returns"].shape == (90,)
    padded, valid = pad_trace(cfg, t)
    want_state, want_outs = engine.run(padded, valid=valid, donate=False)
    np.testing.assert_array_equal(np.asarray(outs["returns"]),
                                  np.asarray(want_outs["returns"][:90]))
    _assert_state_equal(state, want_state)
    with pytest.raises(ValueError, match="chunk-multiple"):
        engine.run(t, valid=jnp.ones(90, bool))


def test_run_channels_matches_per_channel_runs():
    cfg = small_platform(chunk=16, hot_threshold=2)
    params = Engine(cfg).params._replace(slow_read_lat=jnp.int32(9999))
    per = 64
    t = _trace(cfg, 2 * per)
    traces = Trace(*(jnp.stack([x[:per], x[per:]]) for x in t))
    engine = Engine(cfg)
    states, outs = engine.run_channels(traces, params=params)
    for i in range(2):
        one = Trace(*(x[i] for x in traces))
        want_state, want_outs = engine.run(one, params=params)
        np.testing.assert_array_equal(np.asarray(outs["returns"][i]),
                                      np.asarray(want_outs["returns"]))
        assert int(states.clock[i]) == int(want_state.clock)


def test_tiered_cache_pins_and_reports_contract_hit_rate():
    """The §III-G serving contract: latency-critical KV pages allocate
    with pin=True, never migrate, and report() exposes the pinned-page
    fast hit rate."""
    from repro.core import EmulatorConfig, FAST
    from repro.core import table as table_lib
    from repro.memtier.tiered_cache import TieredKVAccounting

    cfg = EmulatorConfig(n_fast_pages=4, n_slow_pages=60, chunk=16,
                         policy="hotness", hot_threshold=2)
    tier = TieredKVAccounting(cfg, n_layers=2, positions_per_page=16,
                              bytes_per_position=64, pin_pages_per_seq=1)
    for step in range(12):
        trace = tier.access_trace([0, 1, 2], [16 * (1 + step % 3) + step] * 3)
        tier.account(trace)
    rep = tier.report()
    assert rep["pinned_pages"] == 3
    assert rep["pinned_accesses"] > 0
    assert 0.0 <= rep["pinned_fast_hit_rate"] <= 1.0
    # contracted pages that landed fast are still fast (pins held)
    table = np.asarray(tier.state.table)
    for page in tier._pinned:
        flags = table[page, table_lib.FLAGS]
        assert flags & table_lib.PINNED
        if flags & table_lib.PIN_FAST:
            assert table[page, table_lib.DEVICE] == FAST
    # releasing a sequence releases its contract
    tier.free_sequence(0)
    assert tier.report()["pinned_pages"] == 2


def test_tiered_cache_pins_recycled_page_to_its_current_tier():
    """Regression: the pin bit must come from the page's current DEVICE
    lane, not its id-boundary tier — a fast-id page that migration
    demoted to NVM gets PIN_SLOW (keeping the table invariant), not a
    PIN_FAST stamp on a slow-resident page."""
    from repro.core import EmulatorConfig, SLOW, check_table
    from repro.core import table as table_lib
    from repro.memtier.tiered_cache import TieredKVAccounting

    cfg = EmulatorConfig(n_fast_pages=4, n_slow_pages=28, chunk=16,
                         policy="static")
    tier = TieredKVAccounting(cfg, n_layers=1, positions_per_page=16,
                              bytes_per_position=64, pin_pages_per_seq=1)
    assert tier._page_for(0, 0) == 0      # seq 0 takes fast page 0
    # Hand-demote fast page 1 (the allocator's next FAST-pool pop): swap
    # its mapping with slow page `s`, as a completed migration would.
    # (Built per instance: the stamp consumes the carried table — the
    # session donation contract — so a table buffer can't be shared.)
    s = cfg.n_fast_pages + 5

    def demote(t):
        fs = int(t[s, table_lib.FRAME])
        t = (t.at[1, table_lib.DEVICE].set(SLOW)
             .at[1, table_lib.FRAME].set(fs))
        t = t.at[s, table_lib.DEVICE].set(0).at[s, table_lib.FRAME].set(1)
        return t.at[1, table_lib.OWNER].set(s)  # fast frame 1 owned by s

    tier.state = tier.state._replace(table=demote(tier.state.table))

    assert tier._page_for(1, 0) == 1      # recycled fast-id page, now SLOW
    table = np.asarray(tier.state.table)
    assert table[1, table_lib.FLAGS] == table_lib.PIN_SLOW
    check_table(cfg, table)               # pin agrees with DEVICE lane

    # Regression: a page that is a member of the DMA's in-flight swap is
    # pinned to the tier the (unconditional) commit will move it to —
    # page 1 is mid-promotion (page_a), so despite DEVICE==SLOW right
    # now it must get PIN_FAST, not a pin that breaks on swap commit.
    tier2 = TieredKVAccounting(cfg, n_layers=1, positions_per_page=16,
                               bytes_per_position=64, pin_pages_per_seq=1)
    assert tier2._page_for(0, 0) == 0
    tier2.state = tier2.state._replace(         # page 1 demoted, as above
        table=demote(tier2.state.table))
    import jax.numpy as _jnp
    tier2.state = tier2.state._replace(dma=tier2.state.dma._replace(
        active=_jnp.int32(1), page_a=_jnp.int32(1),
        page_b=_jnp.int32(s)))
    assert tier2._page_for(1, 0) == 1
    table2 = np.asarray(tier2.state.table)
    assert table2[1, table_lib.FLAGS] == table_lib.PIN_FAST


def test_engine_default_params_require_registry_policy():
    """Regression: a registry restricted past cfg.policy must not fall
    back to the stale global policy_id (which the switch clamps onto a
    different policy) — default params raise; explicit params= work."""
    cfg = small_platform(chunk=16, hot_threshold=2)   # policy "hotness"
    t = _trace(cfg, 64, hot_fraction=0.6)
    engine = Engine(cfg, registry=("static",))
    with pytest.raises(ValueError, match="no default design point"):
        engine.run(t)
    params = Engine(cfg, registry=None).params._replace(
        policy_id=jnp.int32(0))
    state, _ = engine.run(t, params=params)
    assert int(state.dma.swaps_done) == 0   # really ran "static"


def test_internal_callers_raise_no_deprecation_warnings():
    """examples/benchmarks/serving must be migrated: exercising the
    session API end-to-end emits no DeprecationWarning from repro code
    (the pytest config escalates those to errors)."""
    cfg = small_platform(chunk=16, hot_threshold=2)
    t = _trace(cfg, 96)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        engine = Engine(cfg)
        engine.run(t)
        res = engine.sweep(SweepSpec(base=cfg, link_lats=(600, 100)), t)
        engine.continue_sweep(res, t)
        engine.run_stream([t, t])
