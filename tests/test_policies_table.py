"""Policy behaviour + redirection-table/allocator invariants, including
the FLAGS-lane protection subsystem (pinning / poisoning) and the
WEAR-driven wear_level policy."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need hypothesis; CI installs it via the "test" extra
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from conftest import engine_run, make_churn_trace, make_trace_arrays
from repro import Engine
from repro.core import (HybridAllocator, Trace, check_table,
                        init_table, pad_trace, small_platform)
from repro.core import table as table_lib
from repro.core.config import FAST, SLOW


def test_hot_page_gets_promoted():
    cfg = small_platform(chunk=8, policy="hotness", hot_threshold=3,
                         decay_every=64)
    hot_page = cfg.n_fast_pages + 2   # lives in NVM initially
    n = 256
    page = np.full(n, hot_page, np.int32)
    t = Trace(jnp.asarray(page), jnp.zeros(n, jnp.int32),
              jnp.zeros(n, bool), jnp.full(n, 64, jnp.int32))
    state, outs, _ = engine_run(cfg, t)
    assert int(state.dma.swaps_done) >= 1
    assert int(table_lib.device(state.table)[hot_page]) == FAST
    # later accesses hit the fast tier
    dev = np.asarray(outs["device"])
    assert dev[-1] == FAST


def test_static_never_migrates():
    cfg = small_platform(chunk=8, policy="static")
    rng = np.random.default_rng(0)
    page, off, w, sz = make_trace_arrays(cfg, 256, rng, hot_fraction=0.8)
    t = Trace(jnp.asarray(page), jnp.asarray(off), jnp.asarray(w),
              jnp.asarray(sz))
    state, _, _ = engine_run(cfg, t)
    assert int(state.dma.swaps_done) == 0
    table0 = init_table(cfg)
    np.testing.assert_array_equal(
        np.asarray(table_lib.device(state.table)),
        np.asarray(table_lib.device(table0)))


def test_table_bijection_preserved_after_many_swaps():
    cfg = small_platform(chunk=8, policy="hotness", hot_threshold=2,
                         decay_every=32)
    rng = np.random.default_rng(1)
    page, off, w, sz = make_trace_arrays(cfg, 1024, rng, hot_fraction=0.7,
                                         n_hot=6)
    t = Trace(jnp.asarray(page), jnp.asarray(off), jnp.asarray(w),
              jnp.asarray(sz))
    state, _, _ = engine_run(cfg, t)
    assert int(state.dma.swaps_done) >= 2
    # check_table also validates the OWNER-lane inverse map
    check_table(cfg, np.asarray(state.table))
    # migrated pages carry a nonzero EPOCH stamp (2 per committed swap,
    # minus any pages that migrated more than once)
    epoch = np.asarray(table_lib.epoch(state.table))
    assert (epoch > 0).sum() >= 2


def test_stream_policy_prefetches():
    cfg = small_platform(chunk=16, policy="stream", hot_threshold=100)
    # pure sequential walk through NVM pages: stream detector should trigger
    n = 256
    page = (cfg.n_fast_pages + np.arange(n) % 24).astype(np.int32)
    t = Trace(jnp.asarray(page), jnp.zeros(n, jnp.int32),
              jnp.zeros(n, bool), jnp.full(n, 64, jnp.int32))
    state, _, _ = engine_run(cfg, t)
    assert int(state.dma.swaps_done) >= 1


if HAVE_HYPOTHESIS:
    @given(st.data())
    @settings(max_examples=20, deadline=None)
    def test_allocator_roundtrip(data):
        cfg = small_platform()
        alloc = HybridAllocator(cfg)
        total = dict(alloc.free_pages)
        handles = []
        for _ in range(data.draw(st.integers(1, 8))):
            n = data.draw(st.integers(1, 6))
            hint = data.draw(st.sampled_from([FAST, SLOW]))
            h, pages = alloc.alloc(n, hint=hint)
            assert len(set(pages.tolist())) == n
            handles.append(h)
        for h in handles:
            alloc.free(h)
        assert alloc.free_pages == total
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_allocator_roundtrip():
        pass


def test_allocator_hint_honoured_then_spills():
    cfg = small_platform()           # 8 fast pages
    alloc = HybridAllocator(cfg)
    _, p1 = alloc.alloc(8, hint=FAST)
    assert all(p < cfg.n_fast_pages for p in p1)
    _, p2 = alloc.alloc(4, hint=FAST)    # fast exhausted -> spills to slow
    assert all(p >= cfg.n_fast_pages for p in p2)
    with pytest.raises(MemoryError):
        alloc.alloc(cfg.n_pages, hint=SLOW)


def test_write_bias_flattens_nvm_wear():
    """Endurance (paper Table I): the write_bias policy must reduce peak
    NVM frame wear vs static placement on a write-hot working set."""
    import jax.numpy as jnp
    base = small_platform(chunk=8, hot_threshold=2, decay_every=64,
                          n_fast_pages=8, n_slow_pages=56)
    n = 1024
    rng2 = np.random.default_rng(7)
    # write-hot pages resident in NVM
    page = (base.n_fast_pages + rng2.integers(0, 4, n)).astype(np.int32)
    t = Trace(jnp.asarray(page), jnp.zeros(n, jnp.int32),
              jnp.ones(n, bool), jnp.full(n, 64, jnp.int32))

    s_static, _, _ = engine_run(base.with_(policy="static"), t)
    s_wb, _, _ = engine_run(base.with_(policy="write_bias", write_weight=4), t)
    assert int(s_wb.dma.swaps_done) > 0
    assert int(jnp.max(table_lib.wear(s_wb.table))) < \
        int(jnp.max(table_lib.wear(s_static.table)))


def test_wear_level_flattens_wear_at_equal_hit_rate():
    """The wear_level policy must cut peak slow-frame WEAR vs plain
    hotness on a churn-heavy write trace without giving up fast-tier hit
    rate (the endurance/performance trade the policy exists to win)."""
    base = small_platform(n_fast_pages=16, n_slow_pages=112, chunk=32,
                          hot_threshold=4, decay_every=8)
    t = make_churn_trace(base, 8192, hot_w=24, period=512, write_frac=0.7)

    s_hot, o_hot, _ = engine_run(base.with_(policy="hotness"), t)
    s_wl, o_wl, _ = engine_run(base.with_(policy="wear_level"), t)
    assert int(s_wl.dma.swaps_done) > 0

    def peak(s):
        return int(np.asarray(table_lib.wear(s.table))[:base.n_slow_pages].max())

    def hit(o):
        return (np.asarray(o["device"]) == FAST).mean()

    assert peak(s_wl) < peak(s_hot)
    assert hit(o_wl) >= hit(o_hot) - 0.02


def test_clock_ptr_does_not_advance_on_dropped_proposals():
    """Regression (pointer-commit bugfix): while a swap is in flight the
    DMA engine drops every new proposal — the CLOCK pointer must stay
    where it is instead of silently skipping victim frames."""
    # A glacial DMA engine: one swap outlasts the whole trace.
    cfg = small_platform(chunk=8, policy="hotness", hot_threshold=2,
                         decay_every=64, dma_bytes_per_cycle=0.001)
    n = 256
    # hammer several distinct slow pages so every chunk proposes a swap
    page = (cfg.n_fast_pages + (np.arange(n) % 4)).astype(np.int32)
    t = Trace(jnp.asarray(page), jnp.zeros(n, jnp.int32),
              jnp.zeros(n, bool), jnp.full(n, 64, jnp.int32))
    state, _, _ = engine_run(cfg, t)
    assert int(state.dma.active) == 1        # the one swap never finished
    assert int(state.dma.swaps_done) == 0
    # exactly one proposal started -> the pointer advanced exactly once
    assert int(state.clock_ptr) == 1


def test_flags_accessors_and_helpers():
    cfg = small_platform()
    table = init_table(cfg)
    pages = [1, cfg.n_fast_pages + 2]
    table = table_lib.set_flags(table, [pages[0]], table_lib.PIN_FAST)
    table = table_lib.set_flags(table, [pages[1]],
                                table_lib.PIN_SLOW | table_lib.POISONED)
    flg = np.asarray(table_lib.flags(table))
    assert flg[pages[0]] == table_lib.PIN_FAST
    assert flg[pages[1]] == table_lib.PIN_SLOW | table_lib.POISONED
    pinned = np.asarray(table_lib.is_pinned(table))
    poisoned = np.asarray(table_lib.is_poisoned(table))
    assert pinned[pages[0]] and pinned[pages[1]]
    assert not poisoned[pages[0]] and poisoned[pages[1]]
    assert pinned.sum() == 2 and poisoned.sum() == 1
    # row-level accessors work on gathered rows too
    assert bool(table_lib.is_pinned(table[pages[0]]))
    # clearing returns the lane to zero
    table = table_lib.clear_flags(table, pages)
    assert not np.asarray(table_lib.flags(table)).any()
    check_table(cfg, np.asarray(table))


def test_check_table_validates_flags():
    cfg = small_platform()
    table = init_table(cfg)
    with pytest.raises(AssertionError, match="unknown FLAGS"):
        check_table(cfg, np.asarray(
            table.at[3, table_lib.FLAGS].set(1 << 7)))
    with pytest.raises(AssertionError, match="both tiers"):
        check_table(cfg, np.asarray(table_lib.set_flags(
            table, [3], table_lib.PIN_FAST | table_lib.PIN_SLOW)))
    with pytest.raises(AssertionError, match="PIN_FAST"):
        check_table(cfg, np.asarray(table_lib.set_flags(
            table, [cfg.n_fast_pages + 1], table_lib.PIN_FAST)))
    with pytest.raises(AssertionError, match="PIN_SLOW"):
        check_table(cfg, np.asarray(table_lib.set_flags(
            table, [0], table_lib.PIN_SLOW)))
    # valid pins pass
    ok = table_lib.set_flags(table, [0], table_lib.PIN_FAST)
    ok = table_lib.set_flags(ok, [cfg.n_fast_pages], table_lib.PIN_SLOW)
    check_table(cfg, np.asarray(ok))


def test_init_table_pin_fraction():
    cfg = small_platform()                       # 8 fast pages
    pinned = init_table(cfg.with_(pin_fast_fraction=0.5))
    flg = np.asarray(table_lib.flags(pinned))
    np.testing.assert_array_equal(flg[:4], table_lib.PIN_FAST)
    assert not flg[4:].any()
    check_table(cfg, np.asarray(pinned))
    # traced fraction matches the static one bit-for-bit
    traced = init_table(cfg, pin_fast_fraction=jnp.float32(0.5))
    np.testing.assert_array_equal(np.asarray(traced), np.asarray(pinned))
    # fraction 0 leaves the table bitwise identical to the default init
    np.testing.assert_array_equal(
        np.asarray(init_table(cfg.with_(pin_fast_fraction=0.0))),
        np.asarray(init_table(cfg)))


def test_allocator_pin_hints_stamp_flags():
    cfg = small_platform()                       # 8 fast / 56 slow
    alloc = HybridAllocator(cfg)
    h_fast, p_fast = alloc.alloc(4, hint=FAST, pin=True)
    h_slow, p_slow = alloc.alloc(3, hint=SLOW, pin=True)
    _, p_free = alloc.alloc(2, hint=FAST)        # unpinned allocation
    # spilled pinned allocation: fast pool has 2 left -> 4 spill to slow
    h_spill, p_spill = alloc.alloc(6, hint=FAST, pin=True)

    table = alloc.apply_flags(init_table(cfg))
    flg = np.asarray(table_lib.flags(table))
    assert (flg[p_fast] == table_lib.PIN_FAST).all()
    assert (flg[p_slow] == table_lib.PIN_SLOW).all()
    assert not flg[p_free].any()
    # each spilled page pinned to where it actually landed
    for p in p_spill:
        want = table_lib.PIN_FAST if p < cfg.n_fast_pages else table_lib.PIN_SLOW
        assert flg[p] == want
    check_table(cfg, np.asarray(table))

    # freeing releases the pins for subsequent apply_flags calls
    alloc.free(h_fast)
    alloc.free(h_slow)
    alloc.free(h_spill)
    table2 = alloc.apply_flags(init_table(cfg))
    assert not np.asarray(table_lib.flags(table2)).any()


def _run_with_flags(cfg, t, fast_pins=(), slow_pins=(), poison=()):
    from repro.core import init_state
    padded, valid = pad_trace(cfg, t)
    state = init_state(cfg, cfg.runtime())
    table = state.table
    if len(fast_pins):
        table = table_lib.set_flags(table, list(fast_pins), table_lib.PIN_FAST)
    if len(slow_pins):
        table = table_lib.set_flags(table, list(slow_pins), table_lib.PIN_SLOW)
    if len(poison):
        table = table_lib.set_flags(table, list(poison), table_lib.POISONED)
    return Engine(cfg).run(padded, valid=valid,
                           state=state._replace(table=table),
                           donate=False)


def _pin_check(cfg, seed, fast_pins, slow_pins):
    rng = np.random.default_rng(seed)
    page, off, w, sz = make_trace_arrays(cfg, 512, rng, hot_fraction=0.7)
    t = Trace(jnp.asarray(page), jnp.asarray(off), jnp.asarray(w),
              jnp.asarray(sz))
    state, _ = _run_with_flags(cfg, t, fast_pins, slow_pins)
    dev = np.asarray(table_lib.device(state.table))
    assert (dev[list(fast_pins)] == FAST).all(), "pinned page left DRAM"
    assert (dev[list(slow_pins)] == SLOW).all(), "pinned page left NVM"
    check_table(cfg, np.asarray(state.table))
    return int(state.dma.swaps_done)


if HAVE_HYPOTHESIS:
    @given(st.data())
    @settings(max_examples=10, deadline=None)
    def test_pinned_pages_never_migrate(data):
        """Property: no pinned page ever changes DEVICE across a full
        emulation, whatever the policy proposes."""
        cfg = small_platform(chunk=8, hot_threshold=2, decay_every=8,
                             policy=data.draw(st.sampled_from(
                                 ("hotness", "write_bias", "stream",
                                  "wear_level", "hotness_global"))))
        nf = cfg.n_fast_pages
        fast_pins = data.draw(st.sets(st.integers(0, nf - 1), max_size=4))
        slow_pins = data.draw(
            st.sets(st.integers(nf, cfg.n_pages - 1), max_size=6))
        _pin_check(cfg, data.draw(st.integers(0, 100)), fast_pins, slow_pins)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_pinned_pages_never_migrate():
        pass


def test_pinned_pages_never_migrate_fixed():
    """Deterministic variant of the pinning property: pin the pages the
    trace hammers hardest, confirm unpinned traffic still migrates."""
    cfg = small_platform(chunk=8, hot_threshold=2, decay_every=8)
    nf = cfg.n_fast_pages
    # pin half the fast tier and half the hot slow set (make_trace_arrays
    # hammers slow pages nf..nf+3)
    swaps = _pin_check(cfg, seed=5, fast_pins=range(0, nf, 2),
                       slow_pins=(nf, nf + 2))
    assert swaps > 0, "unpinned pages must still migrate"


def test_poisoned_access_faults_counted():
    cfg = small_platform(chunk=8, policy="static")
    bad = cfg.n_fast_pages + 3
    n = 64
    page = np.where(np.arange(n) % 4 == 0, bad, 1).astype(np.int32)
    t = Trace(jnp.asarray(page), jnp.zeros(n, jnp.int32),
              jnp.zeros(n, bool), jnp.full(n, 64, jnp.int32))
    state, outs = _run_with_flags(cfg, t, poison=[bad])
    assert int(state.counters.poison_faults) == n // 4
    # poisoning is observability, not behaviour: the accesses completed
    assert (np.asarray(outs["returns"]) > 0).all()
    # and a clean run counts zero
    clean_state, _ = _run_with_flags(cfg, t)
    assert int(clean_state.counters.poison_faults) == 0


def test_wear_counts_writes_only():
    import jax.numpy as jnp
    cfg = small_platform(chunk=8, policy="static")
    n = 64
    page = np.full(n, cfg.n_fast_pages + 3, np.int32)   # one slow page
    t = Trace(jnp.asarray(page), jnp.zeros(n, jnp.int32),
              jnp.asarray(np.arange(n) % 2 == 0),       # half writes
              jnp.full(n, 64, jnp.int32))
    state, _, _ = engine_run(cfg, t)
    wear = table_lib.wear(state.table)
    assert int(jnp.sum(wear)) == n // 2
    assert int(wear[3]) == n // 2                       # frame 3 of NVM
