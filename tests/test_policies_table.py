"""Policy behaviour + redirection-table/allocator invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need hypothesis; CI installs it via the "test" extra
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from conftest import make_trace_arrays
from repro.core import (HybridAllocator, Trace, check_table, init_table,
                        run_trace, small_platform)
from repro.core import table as table_lib
from repro.core.config import FAST, SLOW


def test_hot_page_gets_promoted():
    cfg = small_platform(chunk=8, policy="hotness", hot_threshold=3,
                         decay_every=64)
    hot_page = cfg.n_fast_pages + 2   # lives in NVM initially
    n = 256
    page = np.full(n, hot_page, np.int32)
    t = Trace(jnp.asarray(page), jnp.zeros(n, jnp.int32),
              jnp.zeros(n, bool), jnp.full(n, 64, jnp.int32))
    state, outs, _ = run_trace(cfg, t)
    assert int(state.dma.swaps_done) >= 1
    assert int(table_lib.device(state.table)[hot_page]) == FAST
    # later accesses hit the fast tier
    dev = np.asarray(outs["device"])
    assert dev[-1] == FAST


def test_static_never_migrates():
    cfg = small_platform(chunk=8, policy="static")
    rng = np.random.default_rng(0)
    page, off, w, sz = make_trace_arrays(cfg, 256, rng, hot_fraction=0.8)
    t = Trace(jnp.asarray(page), jnp.asarray(off), jnp.asarray(w),
              jnp.asarray(sz))
    state, _, _ = run_trace(cfg, t)
    assert int(state.dma.swaps_done) == 0
    table0 = init_table(cfg)
    np.testing.assert_array_equal(
        np.asarray(table_lib.device(state.table)),
        np.asarray(table_lib.device(table0)))


def test_table_bijection_preserved_after_many_swaps():
    cfg = small_platform(chunk=8, policy="hotness", hot_threshold=2,
                         decay_every=32)
    rng = np.random.default_rng(1)
    page, off, w, sz = make_trace_arrays(cfg, 1024, rng, hot_fraction=0.7,
                                         n_hot=6)
    t = Trace(jnp.asarray(page), jnp.asarray(off), jnp.asarray(w),
              jnp.asarray(sz))
    state, _, _ = run_trace(cfg, t)
    assert int(state.dma.swaps_done) >= 2
    # check_table also validates the OWNER-lane inverse map
    check_table(cfg, np.asarray(state.table))
    # migrated pages carry a nonzero EPOCH stamp (2 per committed swap,
    # minus any pages that migrated more than once)
    epoch = np.asarray(table_lib.epoch(state.table))
    assert (epoch > 0).sum() >= 2


def test_stream_policy_prefetches():
    cfg = small_platform(chunk=16, policy="stream", hot_threshold=100)
    # pure sequential walk through NVM pages: stream detector should trigger
    n = 256
    page = (cfg.n_fast_pages + np.arange(n) % 24).astype(np.int32)
    t = Trace(jnp.asarray(page), jnp.zeros(n, jnp.int32),
              jnp.zeros(n, bool), jnp.full(n, 64, jnp.int32))
    state, _, _ = run_trace(cfg, t)
    assert int(state.dma.swaps_done) >= 1


if HAVE_HYPOTHESIS:
    @given(st.data())
    @settings(max_examples=20, deadline=None)
    def test_allocator_roundtrip(data):
        cfg = small_platform()
        alloc = HybridAllocator(cfg)
        total = dict(alloc.free_pages)
        handles = []
        for _ in range(data.draw(st.integers(1, 8))):
            n = data.draw(st.integers(1, 6))
            hint = data.draw(st.sampled_from([FAST, SLOW]))
            h, pages = alloc.alloc(n, hint=hint)
            assert len(set(pages.tolist())) == n
            handles.append(h)
        for h in handles:
            alloc.free(h)
        assert alloc.free_pages == total
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_allocator_roundtrip():
        pass


def test_allocator_hint_honoured_then_spills():
    cfg = small_platform()           # 8 fast pages
    alloc = HybridAllocator(cfg)
    _, p1 = alloc.alloc(8, hint=FAST)
    assert all(p < cfg.n_fast_pages for p in p1)
    _, p2 = alloc.alloc(4, hint=FAST)    # fast exhausted -> spills to slow
    assert all(p >= cfg.n_fast_pages for p in p2)
    with pytest.raises(MemoryError):
        alloc.alloc(cfg.n_pages, hint=SLOW)


def test_write_bias_flattens_nvm_wear():
    """Endurance (paper Table I): the write_bias policy must reduce peak
    NVM frame wear vs static placement on a write-hot working set."""
    import jax.numpy as jnp
    base = small_platform(chunk=8, hot_threshold=2, decay_every=64,
                          n_fast_pages=8, n_slow_pages=56)
    n = 1024
    rng2 = np.random.default_rng(7)
    # write-hot pages resident in NVM
    page = (base.n_fast_pages + rng2.integers(0, 4, n)).astype(np.int32)
    t = Trace(jnp.asarray(page), jnp.zeros(n, jnp.int32),
              jnp.ones(n, bool), jnp.full(n, 64, jnp.int32))

    s_static, _, _ = run_trace(base.with_(policy="static"), t)
    s_wb, _, _ = run_trace(base.with_(policy="write_bias", write_weight=4), t)
    assert int(s_wb.dma.swaps_done) > 0
    assert int(jnp.max(table_lib.wear(s_wb.table))) < \
        int(jnp.max(table_lib.wear(s_static.table)))


def test_wear_counts_writes_only():
    import jax.numpy as jnp
    cfg = small_platform(chunk=8, policy="static")
    n = 64
    page = np.full(n, cfg.n_fast_pages + 3, np.int32)   # one slow page
    t = Trace(jnp.asarray(page), jnp.zeros(n, jnp.int32),
              jnp.asarray(np.arange(n) % 2 == 0),       # half writes
              jnp.full(n, 64, jnp.int32))
    state, _, _ = run_trace(cfg, t)
    wear = table_lib.wear(state.table)
    assert int(jnp.sum(wear)) == n // 2
    assert int(wear[3]) == n // 2                       # frame 3 of NVM
