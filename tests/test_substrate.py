"""Optimizer, data pipeline, gradient compression, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (CheckpointManager, latest_step, load_checkpoint,
                        save_checkpoint)
from repro.data import DataConfig, make_batch_iterator
from repro.optim import (AdamWConfig, adamw_update, clip_by_global_norm,
                         compress_int8, decompress_int8, init_opt_state,
                         warmup_cosine)


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #

def test_adamw_optimizes_quadratic():
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    state = init_opt_state(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, m = adamw_update(cfg, params, g, state)
    assert float(loss(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((9,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                         for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(gn), 10.0 * np.sqrt(13), rtol=1e-5)


def test_warmup_cosine_shape():
    cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(warmup_cosine(cfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9
    assert lrs[100] < 1e-5
    assert all(b >= a for a, b in zip(lrs[:10], lrs[1:11]))  # warmup rises


# --------------------------------------------------------------------------- #
# gradient compression
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("shape", [(100,), (33, 7), (256, 4)])
def test_int8_roundtrip_error_bound(shape):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(shape) * 0.01, jnp.float32)
    q, s, meta = compress_int8(x)
    y = decompress_int8(q, s, meta)
    assert y.shape == x.shape
    # error bounded by half a quantization step per block
    err = np.abs(np.asarray(y - x))
    step = np.asarray(jnp.repeat(s, 256))[:x.size].reshape(shape)
    assert np.all(err <= 0.51 * step + 1e-12)


def test_int8_stochastic_rounding_unbiased():
    x = jnp.full((256,), 0.3e-2, jnp.float32)   # lands between two codes
    keys = jax.random.split(jax.random.PRNGKey(0), 200)
    ys = [float(decompress_int8(*compress_int8(x, k)[:2],
                                compress_int8(x, k)[2]).mean())
          for k in keys[:50]]
    assert abs(np.mean(ys) - 0.3e-2) < 0.02e-2


# --------------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------------- #

def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=4, seed=7)
    it1 = make_batch_iterator(cfg)
    batches = [next(it1) for _ in range(5)]
    it2 = make_batch_iterator(cfg, start_step=3)
    s, b3 = next(it2)
    assert s == 3
    np.testing.assert_array_equal(np.asarray(b3["inputs"]),
                                  np.asarray(batches[3][1]["inputs"]))
    # labels are next-token shifted inputs
    _, b = batches[0]
    np.testing.assert_array_equal(np.asarray(b["inputs"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_data_has_learnable_structure():
    cfg = DataConfig(vocab=64, seq_len=128, global_batch=8)
    _, b = next(make_batch_iterator(cfg))
    x = np.asarray(b["inputs"])
    nxt = np.asarray(b["labels"])
    # the Markov rule makes labels a near-deterministic function of inputs
    pred = (x * 31 + 7) % 64
    agreement = float(np.mean(np.abs(pred - nxt) <= 2))
    assert agreement > 0.9


# --------------------------------------------------------------------------- #
# checkpointing
# --------------------------------------------------------------------------- #

def _tree():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "opt": {"mu": jnp.ones((2, 3), jnp.float32),
                    "step": jnp.int32(4)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, tree, {"cursor": 7})
    assert latest_step(str(tmp_path)) == 7
    restored, manifest = load_checkpoint(str(tmp_path), tree)
    assert manifest["step"] == 7 and manifest["cursor"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir (crashed write) is never picked up."""
    tree = _tree()
    save_checkpoint(str(tmp_path), 5, tree)
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in (10, 20, 30, 40):
        mgr.save(s, tree)
    mgr.close()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [30, 40]


def test_checkpoint_dtype_restored(tmp_path):
    tree = {"p": jnp.ones((3,), jnp.bfloat16)}
    save_checkpoint(str(tmp_path), 1, tree)
    restored, _ = load_checkpoint(str(tmp_path), tree)
    assert restored["p"].dtype == jnp.bfloat16
