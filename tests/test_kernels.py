"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
sweeping shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hmmu_lookup import hmmu_lookup, hmmu_lookup_fused


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,s,d,bq,bk", [
    (1, 2, 2, 128, 32, 64, 64),
    (2, 4, 2, 256, 64, 128, 128),     # GQA 2:1
    (1, 8, 1, 128, 64, 64, 32),       # MQA
    (2, 2, 2, 192, 16, 64, 64),       # ragged-ish seq (192 = 3 blocks)
])
def test_flash_attention_matches_ref(dtype, b, hq, hkv, s, d, bq, bk):
    rng = np.random.default_rng(hash((b, hq, s)) % 2**32)
    q = _rand(rng, (b, hq, s, d), dtype)
    k = _rand(rng, (b, hkv, s, d), dtype)
    v = _rand(rng, (b, hkv, s, d), dtype)
    got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    want = ref.attention(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window", [32, 96])
def test_flash_attention_window(window):
    rng = np.random.default_rng(0)
    q = _rand(rng, (1, 2, 256, 32), jnp.float32)
    k = _rand(rng, (1, 2, 256, 32), jnp.float32)
    v = _rand(rng, (1, 2, 256, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window, block_q=64,
                          block_k=64, interpret=True)
    want = ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(1)
    q = _rand(rng, (1, 2, 128, 32), jnp.float32)
    k = _rand(rng, (1, 2, 128, 32), jnp.float32)
    v = _rand(rng, (1, 2, 128, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    want = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,smax,d,bk", [
    (2, 4, 2, 512, 64, 128),
    (1, 8, 8, 256, 32, 64),
    (3, 4, 1, 384, 128, 128),
])
def test_decode_attention_matches_ref(dtype, b, hq, hkv, smax, d, bk):
    rng = np.random.default_rng(hash((b, hq, smax)) % 2**32)
    q = _rand(rng, (b, hq, d), dtype)
    kc = _rand(rng, (b, hkv, smax, d), dtype)
    vc = _rand(rng, (b, hkv, smax, d), dtype)
    kv_len = jnp.asarray(rng.integers(1, smax + 1, b), jnp.int32)
    got = decode_attention(q, kc, vc, kv_len, block_k=bk, interpret=True)
    want = ref.decode_attention(q, kc, vc, kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_decode_attention_window():
    rng = np.random.default_rng(2)
    q = _rand(rng, (2, 4, 64), jnp.float32)
    kc = _rand(rng, (2, 2, 512, 64), jnp.float32)
    vc = _rand(rng, (2, 2, 512, 64), jnp.float32)
    kv_len = jnp.asarray([200, 512], jnp.int32)
    got = decode_attention(q, kc, vc, kv_len, window=128, block_k=128,
                           interpret=True)
    want = ref.decode_attention(q, kc, vc, kv_len, window=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("n_pages,chunk", [(64, 16), (1000, 128), (37, 5)])
def test_hmmu_lookup_matches_ref(n_pages, chunk):
    rng = np.random.default_rng(3)
    table = jnp.asarray(rng.integers(0, 2**20, (n_pages, 8)), jnp.int32)
    pages = jnp.asarray(rng.integers(0, n_pages, chunk), jnp.int32)
    got = hmmu_lookup(table, pages, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.hmmu_lookup(table, pages)))


def test_hmmu_lookup_row_width_matches_core_layout():
    """The kernel's documented row width is the packed layout of
    repro.core.table — the single source of truth the emulator stores."""
    import importlib

    from repro.core import table as table_lib
    hl_mod = importlib.import_module("repro.kernels.hmmu_lookup")
    assert hl_mod.ROW_W == table_lib.ROW_W


@pytest.mark.parametrize("b,n_pages,chunk", [(3, 64, 16), (5, 37, 7)])
def test_hmmu_lookup_batched_matches_ref(b, n_pages, chunk):
    """Leading batch axis (the sweep's design-point axis): one launch
    gathers every batch member's chunk, bit-identical to per-member ref."""
    rng = np.random.default_rng(4)
    table = jnp.asarray(rng.integers(0, 2**20, (b, n_pages, 8)), jnp.int32)
    pages = jnp.asarray(rng.integers(0, n_pages, (b, chunk)), jnp.int32)
    got = hmmu_lookup(table, pages, interpret=True)
    assert got.shape == (b, chunk, 8)
    for i in range(b):
        np.testing.assert_array_equal(
            np.asarray(got[i]),
            np.asarray(ref.hmmu_lookup(table[i], pages[i])))
    # and the generic ref agrees with itself batched
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.hmmu_lookup(table, pages)))


def test_hmmu_lookup_clamps_out_of_range_pages():
    """Regression: an out-of-range page must fetch the clamped row, not
    whatever the index_map would otherwise produce (mod wraparound / UB)."""
    rng = np.random.default_rng(5)
    n_pages = 32
    table = jnp.asarray(rng.integers(0, 2**20, (n_pages, 8)), jnp.int32)
    pages = jnp.asarray([-1, -100, 0, 31, 32, 1000], jnp.int32)
    want = np.asarray(table)[np.clip(np.asarray(pages), 0, n_pages - 1)]
    got_k = hmmu_lookup(table, pages, interpret=True)
    got_r = ref.hmmu_lookup(table, pages)
    np.testing.assert_array_equal(np.asarray(got_k), want)
    np.testing.assert_array_equal(np.asarray(got_r), want)


@pytest.mark.parametrize("n_pages,chunk,k", [(64, 16, 2), (37, 5, 3)])
def test_hmmu_lookup_fused_matches_per_field_path(n_pages, chunk, k):
    """The fused chunk+k gather (one launch) must equal the unfused path:
    a chunk gather plus separate per-row dynamic-slice reads."""
    rng = np.random.default_rng(7)
    table = jnp.asarray(rng.integers(0, 2**20, (n_pages, 8)), jnp.int32)
    pages = jnp.asarray(rng.integers(0, n_pages, chunk), jnp.int32)
    extra = jnp.asarray(rng.integers(0, n_pages, k), jnp.int32)
    rows_k, extra_k = hmmu_lookup_fused(table, pages, extra, interpret=True)
    rows_r, extra_r = ref.hmmu_lookup_fused(table, pages, extra)
    # vs the unfused formulation the emulator used before the fusion
    np.testing.assert_array_equal(
        np.asarray(rows_k), np.asarray(ref.hmmu_lookup(table, pages)))
    np.testing.assert_array_equal(
        np.asarray(extra_k), np.asarray(table)[np.asarray(extra)])
    np.testing.assert_array_equal(np.asarray(rows_k), np.asarray(rows_r))
    np.testing.assert_array_equal(np.asarray(extra_k), np.asarray(extra_r))


def test_hmmu_lookup_fused_clamps_out_of_range():
    """Regression (PR 2 clamp behavior): out-of-range pages in either the
    chunk or the fused extra tail fetch the clamped row in both paths."""
    rng = np.random.default_rng(8)
    n_pages = 32
    table = jnp.asarray(rng.integers(0, 2**20, (n_pages, 8)), jnp.int32)
    pages = jnp.asarray([-1, 0, 31, 900], jnp.int32)
    extra = jnp.asarray([-5, 32], jnp.int32)
    want_rows = np.asarray(table)[np.clip(np.asarray(pages), 0, n_pages - 1)]
    want_extra = np.asarray(table)[np.clip(np.asarray(extra), 0, n_pages - 1)]
    for rows, extra_rows in (hmmu_lookup_fused(table, pages, extra,
                                               interpret=True),
                             ref.hmmu_lookup_fused(table, pages, extra)):
        np.testing.assert_array_equal(np.asarray(rows), want_rows)
        np.testing.assert_array_equal(np.asarray(extra_rows), want_extra)


def test_hmmu_lookup_fused_vmap_single_launch(monkeypatch):
    """ops.hmmu_lookup_fused under vmap (the sweep executor's shape with
    fused swap-pair prefetch) must batch through the same custom_vmap rule
    and stay bit-identical: table and extra batched, pages shared."""
    import jax

    from repro.kernels import ops
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    rng = np.random.default_rng(9)
    b, n_pages, chunk = 3, 48, 9
    tables = jnp.asarray(rng.integers(0, 2**20, (b, n_pages, 8)), jnp.int32)
    pages = jnp.asarray(rng.integers(0, n_pages, chunk), jnp.int32)
    extras = jnp.asarray(rng.integers(0, n_pages, (b, 2)), jnp.int32)
    rows, extra_rows = jax.vmap(ops.hmmu_lookup_fused,
                                in_axes=(0, None, 0))(tables, pages, extras)
    for i in range(b):
        wr, we = ref.hmmu_lookup_fused(tables[i], pages, extras[i])
        np.testing.assert_array_equal(np.asarray(rows[i]), np.asarray(wr))
        np.testing.assert_array_equal(np.asarray(extra_rows[i]),
                                      np.asarray(we))


def test_hmmu_lookup_vmap_dispatches_to_batched_kernel(monkeypatch):
    """ops.hmmu_lookup under vmap (the sweep executor's shape) must hit
    the batched kernel via its custom_vmap rule and stay bit-identical."""
    import jax

    from repro.kernels import ops
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    rng = np.random.default_rng(6)
    b, n_pages, chunk = 4, 48, 9
    tables = jnp.asarray(rng.integers(0, 2**20, (b, n_pages, 8)), jnp.int32)
    pages = jnp.asarray(rng.integers(0, n_pages, chunk), jnp.int32)
    # table batched, pages shared — exactly Engine.sweep's vmap structure
    got = jax.vmap(ops.hmmu_lookup, in_axes=(0, None))(tables, pages)
    want = np.stack([np.asarray(ref.hmmu_lookup(tables[i], pages))
                     for i in range(b)])
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("chunk", [8, 32])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv_chunk_scan_matches_ref(chunk, dtype):
    from repro.kernels.rwkv_scan import rwkv_chunk_scan as pallas_scan
    from repro.models.rwkv import rwkv_chunk_scan as ref_scan
    rng = np.random.default_rng(5)
    b, h, s, d = 2, 2, 64, 16
    mk = lambda: jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    r, k, v = mk(), mk(), mk()
    logw = jnp.asarray(-np.exp(rng.standard_normal((b, h, s, d)) - 1.5),
                       dtype)
    u = jnp.asarray(rng.standard_normal((h, d)) * 0.3, jnp.float32)
    got = pallas_scan(r, k, v, logw, u, chunk=chunk, interpret=True)
    want, _ = ref_scan(r, k, v, logw, u, chunk)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)
