import numpy as np
import pytest

# NOTE: no xla_force_host_platform_device_count here — unit tests and
# benches must see the real single device; only the dry-run (and the
# subprocess-based integration tests) force 512/4 devices.


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_trace_arrays(cfg, n, rng, hot_fraction=0.4, n_hot=4):
    """Random trace with a hot set in the slow tier (exercises migration)."""
    page = rng.integers(0, cfg.n_pages, n).astype(np.int32)
    hot = rng.random(n) < hot_fraction
    page[hot] = (cfg.n_fast_pages + rng.integers(0, n_hot, hot.sum())
                 ).astype(np.int32)
    offset = (rng.integers(0, cfg.page_size // 64, n) * 64).astype(np.int32)
    is_write = rng.random(n) < 0.35
    size = np.full(n, 64, np.int32)
    return page, offset, is_write, size


def engine_run(cfg, t, params=None, registry=None):
    """Session-API run helper (pad, run at one design point, trim):
    pad, run undonated, return (state, padded outputs, counters summary).
    Shared by the oracle/policy/system tests that predate the Engine."""
    from repro import Engine
    from repro.core import counters as counters_lib, pad_trace

    padded, valid = pad_trace(cfg, t)
    state, outs = Engine(cfg, registry=registry).run(
        padded, valid=valid, params=params, donate=False)
    return state, outs, counters_lib.summary(state.counters)


def make_churn_trace(cfg, n, hot_w, period, write_frac, seed=0):
    """The wear-leveling churn workload (rotating write-hot window wider
    than the fast tier). Single source of truth is ``churn_trace`` in
    examples/wear_leveling.py — loaded from there so the wear_level tests
    assert on exactly the workload the example's CI ``--check`` runs."""
    import importlib.util
    import pathlib

    path = (pathlib.Path(__file__).resolve().parent.parent
            / "examples" / "wear_leveling.py")
    spec = importlib.util.spec_from_file_location("wear_leveling_example",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.churn_trace(cfg, n, hot_w=hot_w, period=period,
                           write_frac=write_frac, seed=seed)
