"""The hot-path perf knobs must never change results: every combination
of bank resolver, gather fusion, scan unroll, one-kernel chunk step and
buffer donation is BITWISE identical to the baseline dense/unfused scan
path — only wall-clock may differ. Plus the channel-parallel
params/registry threading and continued (incremental) sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_trace_arrays
from repro import Engine
from repro.core import (RuntimeParams, Trace, init_state, pad_trace,
                        small_platform)
from repro.core import table as table_lib
from repro.core.latency import pick_bank_resolver
from repro.sweep import SweepSpec, build_points


def _trace(cfg, n, seed=0, **kw):
    arrays = make_trace_arrays(cfg, n, np.random.default_rng(seed), **kw)
    return Trace(*(jnp.asarray(x) for x in arrays))


def _outputs(cfg, t):
    padded, valid = pad_trace(cfg, t)
    state, outs = Engine(cfg).run(padded, valid=valid, donate=False)
    return (np.asarray(outs["returns"]), np.asarray(outs["device"]),
            np.asarray(outs["latency"]), np.asarray(state.table),
            np.asarray(state.bank_free), int(state.clock),
            int(state.dma.swaps_done))


@pytest.mark.parametrize("knobs", [
    dict(bank_resolver="dense", fuse_swap_gather=True),
    dict(bank_resolver="segmented", fuse_swap_gather=False),
    dict(bank_resolver="segmented", fuse_swap_gather=True),
    dict(bank_resolver="auto"),
    dict(bank_resolver="segmented", scan_unroll=4),
    dict(chunk_step_kernel="on"),
    dict(bank_resolver="dense", fuse_swap_gather=True,
         chunk_step_kernel="on"),
])
@pytest.mark.parametrize("chunk", [1, 16])
def test_perf_knobs_bitwise_identical(knobs, chunk):
    base = small_platform(chunk=chunk, hot_threshold=2, decay_every=8,
                          bank_resolver="dense", fuse_swap_gather=False)
    t = _trace(base, 150, hot_fraction=0.5)
    want = _outputs(base, t)
    got = _outputs(base.with_(**knobs), t)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("knobs", [
    dict(bank_resolver="dense", fuse_swap_gather=False),
    dict(bank_resolver="dense", fuse_swap_gather=True),
    dict(bank_resolver="segmented", fuse_swap_gather=False),
    dict(bank_resolver="segmented", fuse_swap_gather=True),
])
def test_zero_flags_reproduces_unflagged_outputs(knobs):
    """The FLAGS machinery must be invisible when every flag is zero:
    under each bank_resolver/fuse_swap_gather combo, a state built with
    pinning enabled and then FLAGS-lane-zeroed is bitwise identical to a
    never-pinned run — while the pinned state itself genuinely diverges
    (the enforcement is not dead code). Note this pins down the FLAGS
    subsystem only; the PR's *intentional* semantic bugfixes (pointer
    commit, migration WEAR charge, scoped write_weight) change outputs
    vs the previous revision by design and are covered by the oracle and
    regression tests instead."""
    base = small_platform(chunk=16, hot_threshold=2, decay_every=8, **knobs)
    t = _trace(base, 160, hot_fraction=0.6)
    padded, valid = pad_trace(base, t)
    want_state, want_outs = Engine(base).run(padded, valid=valid,
                                             donate=False)

    pin_cfg = base.with_(pin_fast_fraction=0.5)
    pin_state, pin_outs = Engine(pin_cfg).run(
        padded, valid=valid, state=init_state(pin_cfg, pin_cfg.runtime()),
        params=pin_cfg.runtime(), donate=False)
    assert not np.array_equal(np.asarray(pin_outs["device"]),
                              np.asarray(want_outs["device"]))
    flg = np.asarray(table_lib.flags(pin_state.table))
    dev = np.asarray(table_lib.device(pin_state.table))
    assert (dev[flg != 0] == 0).all()      # pinned pages never migrated

    zeroed = init_state(pin_cfg, pin_cfg.runtime())
    zeroed = zeroed._replace(
        table=zeroed.table.at[:, table_lib.FLAGS].set(0))
    got_state, got_outs = Engine(base).run(padded, valid=valid,
                                           state=zeroed, donate=False)
    for k in ("returns", "device", "latency"):
        np.testing.assert_array_equal(np.asarray(got_outs[k]),
                                      np.asarray(want_outs[k]))
    np.testing.assert_array_equal(np.asarray(got_state.table),
                                  np.asarray(want_state.table))
    assert int(got_state.clock_ptr) == int(want_state.clock_ptr)
    assert int(got_state.dma.swaps_done) == int(want_state.dma.swaps_done)


def test_auto_resolver_heuristic():
    assert pick_bank_resolver(small_platform(n_banks=16)) == "segmented"
    assert pick_bank_resolver(small_platform(n_banks=4)) == "dense"
    assert pick_bank_resolver(
        small_platform(n_banks=4, bank_resolver="segmented")) == "segmented"
    with pytest.raises(ValueError, match="unknown bank_resolver"):
        pick_bank_resolver(small_platform(bank_resolver="typo"))


def test_donated_continuation_bitwise_and_consumes_state():
    cfg = small_platform(chunk=16, hot_threshold=2)
    t = _trace(cfg, 96)
    padded, valid = pad_trace(cfg, t)

    engine = Engine(cfg)
    s0, _ = engine.run(padded, valid=valid, donate=False)
    want_state, want_outs = engine.run(padded, valid=valid, state=s0,
                                       donate=False)

    s0b, _ = engine.run(padded, valid=valid, donate=False)
    got_state, got_outs = engine.run(padded, valid=valid, state=s0b,
                                     donate=True)

    np.testing.assert_array_equal(np.asarray(got_outs["returns"]),
                                  np.asarray(want_outs["returns"]))
    np.testing.assert_array_equal(np.asarray(got_state.table),
                                  np.asarray(want_state.table))
    assert int(got_state.clock) == int(want_state.clock)
    # the donated state is consumed (its buffers alias the new state)
    with pytest.raises(RuntimeError):
        np.asarray(s0b.table)


def test_channels_thread_params_and_registry():
    """Regression: channel-parallel runs once silently dropped
    params/registry — swept runtime parameters must bite per channel."""
    cfg = small_platform(chunk=16, hot_threshold=2)
    params = RuntimeParams.from_config(cfg).with_(
        slow_read_lat=jnp.int32(9999), policy_id=jnp.int32(0))
    registry = ("static",)
    per = 64
    traces = Trace(*(jnp.stack([x[:per], x[per:2 * per]])
                     for x in _trace(cfg, 2 * per)))
    engine = Engine(cfg, registry=registry)
    states, outs = engine.run_channels(traces, params=params)
    for i in range(2):
        one = Trace(*(x[i] for x in traces))
        want_state, want_outs = engine.run(one, params=params)
        np.testing.assert_array_equal(np.asarray(outs["returns"][i]),
                                      np.asarray(want_outs["returns"]))
        assert int(states.clock[i]) == int(want_state.clock)
    # and the params actually bite: default params give different timing
    _, outs_default = Engine(cfg).run_channels(traces)
    assert not np.array_equal(np.asarray(outs["returns"]),
                              np.asarray(outs_default["returns"]))


def test_continued_sweep_matches_one_long_sweep():
    """states= continuation (with and without donation) must be bitwise
    equal to emulating the concatenated trace in one go."""
    base = small_platform(chunk=16, hot_threshold=2, decay_every=8)
    points = build_points(SweepSpec(
        base=base, technologies=("3dxpoint", "stt-ram"),
        policies=("static", "hotness")))
    t = _trace(base, 96, hot_fraction=0.5)
    n = len(t)
    t2 = Trace(*(jnp.concatenate([x, x]) for x in t))

    engine = Engine(base)
    full = engine.sweep(points, t2)
    first = engine.sweep(points, t)
    cont = engine.sweep(points, t, states=first.states, donate=False)
    np.testing.assert_array_equal(np.asarray(cont.outs["returns"]),
                                  np.asarray(full.outs["returns"][:, n:]))
    np.testing.assert_array_equal(np.asarray(cont.states.table),
                                  np.asarray(full.states.table))

    first_d = engine.sweep(points, t)
    cont_d = engine.sweep(points, t, states=first_d.states, donate=True)
    np.testing.assert_array_equal(np.asarray(cont_d.states.table),
                                  np.asarray(full.states.table))
    with pytest.raises(RuntimeError):
        np.asarray(first_d.states.table)
