"""Per-architecture smoke + decode-consistency tests (reduced configs,
one forward/train step on CPU, shapes + finiteness + cache correctness)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import (ShardCtx, decode_step, init_params, loss_fn,
                          prefill)
from repro.models import layers
from repro.models import transformer as T

SH = ShardCtx()
KEY = jax.random.PRNGKey(0)
B, S = 2, 12


def _inputs(cfg, rng, s=S, b=B):
    if cfg.frontend == "frames":
        return jnp.asarray(rng.standard_normal((b, s, cfg.frame_dim)),
                           jnp.float32)
    return jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)


def _uncapped(cfg):
    if cfg.moe:
        return cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                 capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", C.ARCHS)
def test_train_step_shapes_and_grads_finite(arch):
    cfg = C.get_smoke(arch)
    params = init_params(cfg, KEY)
    rng = np.random.default_rng(0)
    batch = {"inputs": _inputs(cfg, rng),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(cfg, p, b, SH), has_aux=True))(params, batch)
    assert jnp.isfinite(loss), arch
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch
    # at least one grad is nonzero for every top-level param group
    gsum = jax.tree.map(lambda g: float(jnp.sum(jnp.abs(g))), grads)
    assert sum(jax.tree.leaves(gsum)) > 0


@pytest.mark.parametrize("arch", C.ARCHS)
def test_decode_matches_teacher_forced(arch):
    cfg = _uncapped(C.get_smoke(arch))
    params = init_params(cfg, KEY)
    rng = np.random.default_rng(1)
    full = _inputs(cfg, rng, s=S + 3)

    x, _, _ = T.forward_seq(cfg, params, full, SH, collect_cache=False)
    ref_logits = layers.lm_logits(cfg, params, x, SH)

    logits, cache, pos = prefill(cfg, params, full[:, :S], SH, S + 3)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(ref_logits[:, S - 1]), atol=2e-4)
    for t in range(3):
        nxt = full[:, S + t]
        logits, cache, pos = decode_step(cfg, params, nxt, cache, pos, SH)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits[:, S + t]),
                                   atol=2e-4, err_msg=f"{arch} step {t}")


def test_gemma3_window_pattern():
    cfg = C.get_smoke("gemma3_4b")        # 3 layers, global_every=3
    w = T.layer_windows(cfg)
    assert w is not None
    w = np.asarray(w)
    assert w[2] == int(T.NO_WINDOW)        # every 3rd layer global
    assert w[0] == w[1] == cfg.window


def test_hymba_global_layers():
    cfg = C.get_smoke("hymba_1p5b")       # globals at (0, 2)
    w = np.asarray(T.layer_windows(cfg))
    assert w[0] == int(T.NO_WINDOW) and w[2] == int(T.NO_WINDOW)
    assert w[1] == cfg.window


def test_long_context_flags():
    assert C.get("rwkv6-7b").supports_long_context
    assert C.get("hymba-1.5b").supports_long_context
    for a in ("phi3-mini-3.8b", "gemma3-4b", "deepseek-v2-236b"):
        assert not C.get(a).supports_long_context


def test_param_counts_match_published_class():
    """n_params() should land within ~15% of each model's nameplate."""
    targets = {"phi3-mini-3.8b": 3.8e9, "rwkv6-7b": 7.6e9,
               "minitron-8b": 8e9, "internlm2-1.8b": 1.9e9,
               "deepseek-v2-236b": 236e9, "phi3.5-moe-42b-a6.6b": 42e9,
               "hymba-1.5b": 1.5e9, "gemma3-4b": 4e9,
               "musicgen-medium": 1.5e9, "phi-3-vision-4.2b": 4.2e9}
    for arch, want in targets.items():
        got = C.get(arch).n_params()
        assert 0.7 * want < got < 1.4 * want, (arch, got, want)


def test_moe_active_params():
    cfg = C.get("deepseek-v2-236b")
    assert cfg.n_active_params() < 0.15 * cfg.n_params()
