"""The one-kernel Pallas chunk step (kernels.chunk_step) is a pure perf
knob: with ``chunk_step_kernel="on"`` every emulation is BITWISE
identical to the scan path, across bank_resolver x fuse_swap_gather x
donation, including the adversarial corners — requests that hit the DMA
swap pair mid-chunk (progress redirection), poisoned/pinned FLAGS state,
and the chunk=1 degenerate grid — plus the sequential software oracle.

Also pins the satellite bugfix: the swap-commit OWNER write is routed
through a ``mode="drop"`` sentinel scatter, so an idle/unfinished DMA
engine no longer clobbers ``table[0, OWNER]`` with a dummy write.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need hypothesis; CI installs it via the "test" extra
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from conftest import make_trace_arrays
from repro import Engine
from repro.core import Trace, dma as dma_lib, init_state, init_table, \
    pad_trace, small_platform
from repro.core import table as table_lib
from repro.kernels import chunk_step as chunk_step_lib
from repro.sims import trace_sim


def _trace(cfg, n, seed=0, **kw):
    arrays = make_trace_arrays(cfg, n, np.random.default_rng(seed), **kw)
    return Trace(*(jnp.asarray(x) for x in arrays))


def _adversarial_state(cfg, *, midswap=True, flags=True):
    """A start state exercising the hard corners of the chunk schedule:
    an in-flight swap whose members the trace will hit mid-chunk (the
    progress indicator redirects sub-blocks already exchanged), plus
    pinned and poisoned pages for the FLAGS machinery."""
    state = init_state(cfg, cfg.runtime())
    table = state.table
    if flags:
        table = table_lib.set_flags(table, [0, 1], table_lib.PIN_FAST)
        table = table_lib.set_flags(
            table, [cfg.n_fast_pages + 1], table_lib.PIN_SLOW)
        table = table_lib.set_flags(
            table, [cfg.n_fast_pages + 3], table_lib.POISONED)
    state = state._replace(table=table)
    if midswap:
        # swap in flight between slow page a and fast page b, started at
        # cycle 0 — the first chunks of the run land mid-swap.
        a = jnp.int32(cfg.n_fast_pages + 2)
        b = jnp.int32(cfg.n_fast_pages - 1)
        state = state._replace(dma=state.dma._replace(
            active=jnp.int32(1), page_a=a, page_b=b, start=jnp.int32(0)))
    return state


def _swap_pair_trace(cfg, n, seed=0):
    """Random trace biased so ~half the requests hit the in-flight swap
    pair of :func:`_adversarial_state` at varied offsets (both sides of
    the progress cutoff), the rest a migrating hot set."""
    rng = np.random.default_rng(seed)
    page, off, w, sz = make_trace_arrays(cfg, n, rng, hot_fraction=0.4)
    hit = rng.random(n) < 0.5
    pair = np.where(rng.random(n) < 0.5, cfg.n_fast_pages + 2,
                    cfg.n_fast_pages - 1).astype(np.int32)
    page = np.where(hit, pair, page).astype(np.int32)
    off = (rng.integers(0, cfg.page_size // 64, n) * 64).astype(np.int32)
    return Trace(jnp.asarray(page), jnp.asarray(off), jnp.asarray(w),
                 jnp.asarray(sz))


def _run_pair(base, knobs, t, state_fn, donate):
    """Run the same two-leg emulation with chunk_step_kernel off and on:
    one undonated run from the adversarial start state, then a continued
    run with the requested donation (donating a run-produced state, per
    the session contract — a hand-built init_state aliases its zero
    buffers, which XLA rejects as a double donation)."""
    out = []
    for mode in ("off", "on"):
        cfg = base.with_(chunk_step_kernel=mode, **knobs)
        padded, valid = pad_trace(cfg, t)
        engine = Engine(cfg)
        res = engine.run(padded, valid=valid, state=state_fn(cfg),
                         donate=False)
        res = engine.run(padded, valid=valid, state=res.state,
                         donate=donate)
        out.append(res)
    return out


def _assert_bitwise(a, b):
    for k in ("returns", "device", "latency"):
        np.testing.assert_array_equal(np.asarray(a.outs[k]),
                                      np.asarray(b.outs[k]))
    np.testing.assert_array_equal(np.asarray(a.state.table),
                                  np.asarray(b.state.table))
    np.testing.assert_array_equal(np.asarray(a.state.bank_free),
                                  np.asarray(b.state.bank_free))
    for f in ("clock", "clock_ptr", "link_free_rx", "link_free_tx",
              "last_return", "chunk_idx"):
        assert int(getattr(a.state, f)) == int(getattr(b.state, f)), f
    for f in ("active", "page_a", "page_b", "start", "swaps_done"):
        assert int(getattr(a.state.dma, f)) == int(getattr(b.state.dma, f))


_KNOBS = [
    dict(bank_resolver="dense", fuse_swap_gather=False),
    dict(bank_resolver="dense", fuse_swap_gather=True),
    dict(bank_resolver="segmented", fuse_swap_gather=False),
    dict(bank_resolver="segmented", fuse_swap_gather=True),
]


@pytest.mark.parametrize("knobs", _KNOBS)
@pytest.mark.parametrize("donate", [False, True])
def test_kernel_bitwise_identical_on_adversarial_state(knobs, donate):
    """Deterministic bit-identity across the full knob matrix, with
    mid-chunk DMA redirects and pinned/poisoned FLAGS in play (the
    hypothesis sweep below widens the input space when available)."""
    base = small_platform(chunk=8, hot_threshold=2, decay_every=8,
                          policy="hotness")
    t = _swap_pair_trace(base, 96)
    off, on = _run_pair(base, knobs, t,
                        lambda cfg: _adversarial_state(cfg), donate)
    _assert_bitwise(off, on)
    assert int(off.state.dma.swaps_done) > 0   # the corner actually fired


def test_kernel_chunk1_matches_trace_sim_oracle():
    """chunk=1 degenerate grid: the kernel path still matches the
    sequential software oracle request-for-request."""
    cfg = small_platform(chunk=1, hot_threshold=2, decay_every=8,
                         chunk_step_kernel="on")
    arrays = make_trace_arrays(cfg, 160, np.random.default_rng(3))
    t = Trace(*(jnp.asarray(x) for x in arrays))
    state, outs = Engine(cfg).run(t)
    oracle = trace_sim.simulate(cfg, *arrays)
    np.testing.assert_array_equal(np.asarray(outs["returns"]),
                                  oracle.returns)
    np.testing.assert_array_equal(np.asarray(outs["device"]), oracle.device)
    assert int(state.clock) == oracle.clock
    assert int(state.dma.swaps_done) == oracle.swaps


def test_auto_knob_resolves_and_validates():
    base = small_platform()
    assert isinstance(chunk_step_lib.use_chunk_step_kernel(base), bool)
    assert chunk_step_lib.use_chunk_step_kernel(
        base.with_(chunk_step_kernel="off")) is False
    assert chunk_step_lib.use_chunk_step_kernel(
        base.with_(chunk_step_kernel="on")) is True
    with pytest.raises(ValueError, match="chunk_step_kernel"):
        chunk_step_lib.use_chunk_step_kernel(
            base.with_(chunk_step_kernel="bogus"))


@pytest.mark.parametrize("mode", ["off", "on"])
def test_owner_row0_untouched_without_swap_commit(mode):
    """Regression (swap-commit OWNER write): with no swap completing, the
    old set-style commit wrote a dummy value through ``table[0, OWNER]``;
    the drop-sentinel scatter must leave row 0 bit-identical."""
    cfg = small_platform(chunk=8, policy="static",
                         chunk_step_kernel=mode)
    state = init_state(cfg, cfg.runtime())
    sentinel = 12345
    table = state.table.at[0, table_lib.OWNER].set(sentinel)
    t = _trace(cfg, 64, hot_fraction=0.0)
    padded, valid = pad_trace(cfg, t)
    res = Engine(cfg).run(padded, valid=valid,
                          state=state._replace(table=table), donate=False)
    assert int(res.state.dma.swaps_done) == 0
    assert int(res.state.table[0, table_lib.OWNER]) == sentinel


def test_owner_row0_untouched_by_unfinished_maybe_complete():
    """Same regression at the DMA-engine level: idle AND in-flight-but-
    unfinished engines leave the whole table (row 0 included) unchanged."""
    cfg = small_platform()
    table = init_table(cfg).at[0, table_lib.OWNER].set(777)
    for dma in (dma_lib.DMAState.idle(),
                dma_lib.DMAState.idle()._replace(
                    active=jnp.int32(1),
                    page_a=jnp.int32(cfg.n_fast_pages + 2),
                    page_b=jnp.int32(0), start=jnp.int32(10**6))):
        _, t2, done = dma_lib.maybe_complete(cfg, dma, jnp.int32(50), table)
        assert not bool(done)
        np.testing.assert_array_equal(np.asarray(t2), np.asarray(table))


if HAVE_HYPOTHESIS:
    @given(st.data())
    @settings(max_examples=10, deadline=None)
    def test_kernel_bitwise_identical_property(data):
        """Property: for random knobs, policies, traces, donation and
        adversarial start states, kernel == scan bit-for-bit."""
        knobs = dict(
            bank_resolver=data.draw(st.sampled_from(
                ["dense", "segmented", "auto"])),
            fuse_swap_gather=data.draw(st.booleans()),
        )
        donate = data.draw(st.booleans())
        policy = data.draw(st.sampled_from(
            ["hotness", "write_bias", "wear_level", "static"]))
        base = small_platform(chunk=8, hot_threshold=2, decay_every=8,
                              policy=policy)
        seed = data.draw(st.integers(0, 2**16))
        midswap = data.draw(st.booleans())
        flags = data.draw(st.booleans())
        t = _swap_pair_trace(base, 64, seed=seed)
        off, on = _run_pair(
            base, knobs, t,
            lambda cfg: _adversarial_state(cfg, midswap=midswap,
                                           flags=flags), donate)
        _assert_bitwise(off, on)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_kernel_bitwise_identical_property():
        pass
