"""Packed redirection-table layout (repro.core.table): lane accessors,
pack/unpack round-trip, and init/check invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property tests need hypothesis; CI installs it via the "test" extra
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import small_platform
from repro.core import table as table_lib
from repro.core.config import FAST, SLOW

I32 = np.iinfo(np.int32)


def test_init_table_layout():
    cfg = small_platform()
    table = table_lib.init_table(cfg)
    assert table.shape == (cfg.n_pages, table_lib.ROW_W)
    assert table.dtype == jnp.int32
    dev = np.asarray(table_lib.device(table))
    frm = np.asarray(table_lib.frame(table))
    assert (dev[:cfg.n_fast_pages] == FAST).all()
    assert (dev[cfg.n_fast_pages:] == SLOW).all()
    np.testing.assert_array_equal(frm[:cfg.n_fast_pages],
                                  np.arange(cfg.n_fast_pages))
    np.testing.assert_array_equal(
        frm[cfg.n_fast_pages:], np.arange(cfg.n_pages - cfg.n_fast_pages))
    # fresh metadata lanes are zero, OWNER is the identity map
    assert not np.asarray(table_lib.hotness(table)).any()
    assert not np.asarray(table_lib.wear(table)).any()
    assert not np.asarray(table_lib.epoch(table)).any()
    assert not np.asarray(table_lib.flags(table)).any()
    np.testing.assert_array_equal(np.asarray(table_lib.owner(table)),
                                  np.arange(cfg.n_pages))
    table_lib.check_table(cfg, np.asarray(table))


def test_traced_tier_boundary():
    """init_table with a traced n_fast_pages (the sweep's tier-ratio axis)
    must match the static boundary bit-for-bit."""
    cfg = small_platform()
    static = table_lib.init_table(cfg)
    traced = table_lib.init_table(cfg, jnp.int32(cfg.n_fast_pages))
    np.testing.assert_array_equal(np.asarray(static), np.asarray(traced))


def _roundtrip(device, frame, hotness, wear, owner, epoch, flags):
    table = table_lib.pack_rows(device, frame, hotness=hotness, wear=wear,
                                owner=owner, epoch=epoch, flags=flags)
    assert table.shape == (len(device), table_lib.ROW_W)
    assert table.dtype == jnp.int32
    rows = table_lib.unpack(table)
    for got, want in zip(rows, (device, frame, hotness, wear, owner,
                                epoch, flags)):
        np.testing.assert_array_equal(np.asarray(got), want)
    # accessor views agree with the unpacked tuple
    np.testing.assert_array_equal(np.asarray(table_lib.device(table)), device)
    np.testing.assert_array_equal(np.asarray(table_lib.hotness(table)), hotness)
    np.testing.assert_array_equal(np.asarray(table_lib.flags(table)), flags)


if HAVE_HYPOTHESIS:
    lane = st.integers(int(I32.min), int(I32.max))

    @given(st.data())
    @settings(max_examples=25, deadline=None)
    def test_pack_unpack_roundtrip(data):
        n = data.draw(st.integers(1, 32))
        draw_lane = lambda: np.asarray(
            data.draw(st.lists(lane, min_size=n, max_size=n)), np.int32)
        _roundtrip(*(draw_lane() for _ in range(7)))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_pack_unpack_roundtrip():
        pass


def test_pack_unpack_roundtrip_fixed():
    rng = np.random.default_rng(0)
    lanes = rng.integers(I32.min, I32.max, (7, 16)).astype(np.int32)
    _roundtrip(*lanes)


def test_pack_rows_defaults_zero():
    table = table_lib.pack_rows([1, 0], [5, 6])
    rows = table_lib.unpack(table)
    np.testing.assert_array_equal(np.asarray(rows.device), [1, 0])
    np.testing.assert_array_equal(np.asarray(rows.frame), [5, 6])
    for lane in ("hotness", "wear", "owner", "epoch", "flags"):
        assert not np.asarray(getattr(rows, lane)).any()


def test_check_table_catches_stale_owner():
    cfg = small_platform()
    table = table_lib.init_table(cfg)
    table_lib.check_table(cfg, np.asarray(table))
    bad = table.at[0, table_lib.OWNER].set(cfg.n_fast_pages + 1)  # slow page
    with pytest.raises(AssertionError, match="OWNER lane stale"):
        table_lib.check_table(cfg, np.asarray(bad))


def test_check_table_catches_broken_bijection():
    cfg = small_platform()
    table = table_lib.init_table(cfg)
    bad = table.at[0, table_lib.FRAME].set(1)  # two pages claim fast frame 1
    with pytest.raises(AssertionError, match="bijection"):
        table_lib.check_table(cfg, np.asarray(bad))
