"""Vmapped sweep executor: many design points, one compiled emulation.

``run_sweep`` stacks each point's ``RuntimeParams`` into a single pytree
with a leading point axis and vmaps ``emulate`` over it, so N design
points cost one XLA compilation and one fused device computation — the
paper's core value proposition (fast design exploration) as a batch axis.

For multi-chip fan-out, pass a mesh (or ``mesh="auto"``): the stacked
params are placed with a ``NamedSharding`` over the point axis and XLA
partitions the batch across devices — the same spatial-parallelism story
as ``emulate_channels``, but over *designs* instead of traces.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.config import RuntimeParams, canonical_config, static_key
from repro.core.emulator import Trace, emulate, pad_trace

from .results import SweepResult
from .spec import DesignPoint, SweepSpec, build_points


@functools.partial(jax.jit, static_argnames=("cfg", "registry"))
def _emulate_batch(cfg, registry, trace, valid, params):
    """The sweep engine's single compiled computation: ``emulate`` vmapped
    over a stacked ``RuntimeParams`` batch (fresh per-point state)."""
    def one(p):
        return emulate(cfg, trace, valid, None, p, registry)

    return jax.vmap(one)(params)


def compile_count():
    """Number of compiled sweep computations held by the executor (one per
    static geometry x policy set x trace shape x point count). None if
    the runtime doesn't expose jit cache sizes."""
    try:
        return _emulate_batch._cache_size()
    except AttributeError:
        return None


def stack_params(points: list[DesignPoint]) -> RuntimeParams:
    """Stack per-point RuntimeParams into one pytree with a leading
    point axis (the vmap axis)."""
    ps = [p.params for p in points]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def sweep_mesh():
    """A 1-D device mesh over every local device, for sharded sweeps."""
    from repro.launch.mesh import make_dev_mesh

    return make_dev_mesh(model=1)


def _pad_to_multiple(params: RuntimeParams, n: int, mult: int):
    pad = (-n) % mult
    if pad == 0:
        return params, 0
    padded = jax.tree.map(
        lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]),
        params,
    )
    return padded, pad


def run_sweep(
    spec: SweepSpec | list[DesignPoint],
    trace: Trace,
    *,
    mesh=None,
) -> SweepResult:
    """Evaluate every design point of ``spec`` on ``trace``.

    All points share one ``emulate`` compilation (they must agree on
    ``config.static_key``; :func:`build_points` enforces this). Each
    point starts from a fresh per-point initial state — the tier split is
    a runtime parameter, so the redirection table differs per point.

    ``mesh``: None runs on the default device; ``"auto"`` builds a 1-D
    mesh over all local devices; an explicit ``jax.sharding.Mesh`` shards
    the point axis over its first axis. The point count is padded to a
    multiple of the mesh size (padding replicates the last point and is
    dropped from the results).
    """
    points = spec if isinstance(spec, (list, tuple)) else build_points(spec)
    points = list(points)
    if not points:
        raise ValueError("empty sweep")
    keys = {static_key(p.cfg) for p in points}
    if len(keys) > 1:
        raise ValueError(f"points disagree on static geometry: {keys}")
    # Key the compilation on static geometry only: sweeps whose bases
    # differ in runtime fields share one executable.
    cfg = canonical_config(points[0].cfg)

    # Compile the policy switch only over policies actually present;
    # remap each point's policy_id into that restricted registry.
    registry = []
    for p in points:
        if p.cfg.policy not in registry:
            registry.append(p.cfg.policy)
    registry = tuple(registry)
    ids = jnp.asarray([registry.index(p.cfg.policy) for p in points], jnp.int32)

    padded, valid = pad_trace(cfg, trace)
    params = stack_params(points)._replace(policy_id=ids)

    n = len(points)
    n_padded = 0
    if mesh == "auto":
        mesh = sweep_mesh()
    if mesh is not None:
        axis = mesh.axis_names[0]
        params, n_padded = _pad_to_multiple(params, n, mesh.devices.shape[0])
        sharding = NamedSharding(mesh, PartitionSpec(axis))
        params = jax.device_put(params, sharding)

    states, outs = _emulate_batch(cfg, registry, padded, valid, params)
    if n_padded:
        states, outs = jax.tree.map(lambda x: x[:n], (states, outs))
    return SweepResult(points=points, states=states, outs=outs)
