"""Legacy sweep executor shim — the session API owns sweeps now.

``run_sweep`` predates the stateful session API and survives as a thin
deprecated wrapper over :meth:`repro.Engine.sweep` (bitwise identical —
tests/test_engine.py): one compiled, vmapped ``emulate`` per static
geometry, optional ``mesh=`` sharding of the point axis, optional
``states=``/``donate=`` continuation. New code should hold an
``Engine`` and call ``engine.sweep(...)`` / ``engine.continue_sweep(...)``
— which, unlike this wrapper's historical behaviour, also compose
``states=`` with ``mesh=`` (the stacked states are sharded alongside the
params).

``stack_params`` / ``sweep_mesh`` moved to ``repro.engine`` and are
re-exported here unchanged; ``compile_count`` is now backed by the
unified entry-point cache (``Engine.compile_count`` scoped to one
geometry is the session-level equivalent).
"""

from __future__ import annotations

import warnings

from repro.core.config import canonical_config, static_key
from repro.core.emulator import Trace, entry_cache_count

from .results import SweepResult
from .spec import DesignPoint, SweepSpec, build_points


def compile_count():
    """Number of compiled emulation entry points held by the unified
    cache (every geometry, single runs and vmapped sweeps alike). Kept
    for delta-style assertions; per-geometry sessions should read
    ``Engine.compile_count``."""
    return entry_cache_count()


def stack_params(points):
    """Stack per-point RuntimeParams into one pytree with a leading
    point axis (moved to ``repro.engine``; re-exported)."""
    from repro.engine import stack_params as _stack_params

    return _stack_params(points)


def sweep_mesh():
    """A 1-D device mesh over every local device, for sharded sweeps
    (moved to ``repro.engine``; re-exported)."""
    from repro.engine import sweep_mesh as _sweep_mesh

    return _sweep_mesh()


def run_sweep(
    spec: SweepSpec | list[DesignPoint],
    trace: Trace,
    *,
    mesh=None,
    states=None,
    donate: bool = False,
) -> SweepResult:
    """Deprecated — use ``repro.Engine.sweep`` (and
    ``Engine.continue_sweep`` for ``states=`` continuations, which also
    composes with ``mesh=``).

    Evaluates every design point of ``spec`` on ``trace`` in one
    compiled, vmapped emulation; see :meth:`repro.Engine.sweep` for the
    full parameter semantics (this wrapper forwards them verbatim, with
    the historical ``donate=False`` default).
    """
    warnings.warn(
        "legacy run_sweep() is deprecated: drive the platform through the "
        "session API — Engine(cfg).sweep(spec, trace, mesh=...) / "
        "Engine.continue_sweep(result, trace) (see repro.Engine)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.engine import Engine

    points = spec if isinstance(spec, (list, tuple)) else build_points(spec)
    points = list(points)
    if not points:
        raise ValueError("empty sweep")
    keys = {static_key(p.cfg) for p in points}
    if len(keys) > 1:
        raise ValueError(f"points disagree on static geometry: {keys}")
    # Key the compilation on static geometry only: sweeps whose bases
    # differ in runtime fields share one executable.
    engine = Engine(canonical_config(points[0].cfg))
    return engine.sweep(points, trace, mesh=mesh, states=states, donate=donate)
