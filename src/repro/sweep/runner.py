"""Vmapped sweep executor: many design points, one compiled emulation.

``run_sweep`` stacks each point's ``RuntimeParams`` into a single pytree
with a leading point axis and vmaps ``emulate`` over it, so N design
points cost one XLA compilation and one fused device computation — the
paper's core value proposition (fast design exploration) as a batch axis.

For multi-chip fan-out, pass a mesh (or ``mesh="auto"``): the stacked
params are placed with a ``NamedSharding`` over the point axis and XLA
partitions the batch across devices — the same spatial-parallelism story
as ``emulate_channels``, but over *designs* instead of traces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.config import RuntimeParams, canonical_config, static_key
from repro.core.emulator import Trace, emulate, pad_trace

from .results import SweepResult
from .spec import DesignPoint, SweepSpec, build_points


def _emulate_batch_impl(cfg, registry, trace, valid, params, states=None):
    """The sweep engine's single compiled computation: ``emulate`` vmapped
    over a stacked ``RuntimeParams`` batch. ``states`` is an optional
    stacked ``EmulatorState`` with the same leading point axis (e.g. a
    previous ``SweepResult.states``) — fresh per-point state when None."""
    if states is None:
        def one(p):
            return emulate(cfg, trace, valid, None, p, registry)

        return jax.vmap(one)(params)

    def one(p, s):
        return emulate(cfg, trace, valid, s, p, registry)

    return jax.vmap(one)(params, states)


_emulate_batch = jax.jit(_emulate_batch_impl, static_argnames=("cfg", "registry"))
# Donated variant for incremental sweeps: the stacked per-point states
# (notably every point's packed table) alias into the outputs instead of
# being copied each call. The caller's states are CONSUMED.
_emulate_batch_donated = jax.jit(
    _emulate_batch_impl, static_argnames=("cfg", "registry"), donate_argnums=(5,)
)


def compile_count():
    """Number of compiled sweep computations held by the executor (one per
    static geometry x policy set x trace shape x point count, summed over
    the plain and donated entry points). None if the runtime doesn't
    expose jit cache sizes."""
    try:
        return _emulate_batch._cache_size() + _emulate_batch_donated._cache_size()
    except AttributeError:
        return None


def stack_params(points: list[DesignPoint]) -> RuntimeParams:
    """Stack per-point RuntimeParams into one pytree with a leading
    point axis (the vmap axis)."""
    ps = [p.params for p in points]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def sweep_mesh():
    """A 1-D device mesh over every local device, for sharded sweeps."""
    from repro.launch.mesh import make_dev_mesh

    return make_dev_mesh(model=1)


def _pad_to_multiple(params: RuntimeParams, n: int, mult: int):
    pad = (-n) % mult
    if pad == 0:
        return params, 0
    padded = jax.tree.map(
        lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]),
        params,
    )
    return padded, pad


def run_sweep(
    spec: SweepSpec | list[DesignPoint],
    trace: Trace,
    *,
    mesh=None,
    states=None,
    donate: bool = False,
) -> SweepResult:
    """Evaluate every design point of ``spec`` on ``trace``.

    All points share one ``emulate`` compilation (they must agree on
    ``config.static_key``; :func:`build_points` enforces this). Each
    point starts from a fresh per-point initial state — the tier split is
    a runtime parameter, so the redirection table differs per point.

    ``mesh``: None runs on the default device; ``"auto"`` builds a 1-D
    mesh over all local devices; an explicit ``jax.sharding.Mesh`` shards
    the point axis over its first axis. The point count is padded to a
    multiple of the mesh size (padding replicates the last point and is
    dropped from the results).

    ``states``: stacked per-point ``EmulatorState`` (a previous run's
    ``SweepResult.states``) to continue an incremental sweep from instead
    of fresh state. With ``donate=True`` the states' buffers (every
    point's packed table) are donated and updated in place rather than
    copied — the passed-in states are CONSUMED and must not be reused.
    ``mesh`` is unsupported with ``states`` (shard/pad them yourself).
    """
    points = spec if isinstance(spec, (list, tuple)) else build_points(spec)
    points = list(points)
    if not points:
        raise ValueError("empty sweep")
    if donate and states is None:
        raise ValueError(
            "donate=True requires states=... (a previous SweepResult.states): "
            "donation aliases the carried per-point states into the outputs, "
            "and a fresh-state sweep has nothing to donate — without states= "
            "the flag used to be silently ignored"
        )
    keys = {static_key(p.cfg) for p in points}
    if len(keys) > 1:
        raise ValueError(f"points disagree on static geometry: {keys}")
    # Key the compilation on static geometry only: sweeps whose bases
    # differ in runtime fields share one executable.
    cfg = canonical_config(points[0].cfg)

    # Compile the policy switch only over policies actually present;
    # remap each point's policy_id into that restricted registry.
    registry = []
    for p in points:
        if p.cfg.policy not in registry:
            registry.append(p.cfg.policy)
    registry = tuple(registry)
    ids = jnp.asarray([registry.index(p.cfg.policy) for p in points], jnp.int32)

    padded, valid = pad_trace(cfg, trace)
    params = stack_params(points)._replace(policy_id=ids)

    n = len(points)
    n_padded = 0
    if mesh == "auto":
        mesh = sweep_mesh()
    if mesh is not None and states is not None:
        raise ValueError("continued sweeps (states=...) don't support mesh=")
    if mesh is not None:
        axis = mesh.axis_names[0]
        params, n_padded = _pad_to_multiple(params, n, mesh.devices.shape[0])
        sharding = NamedSharding(mesh, PartitionSpec(axis))
        params = jax.device_put(params, sharding)

    fn = _emulate_batch_donated if donate else _emulate_batch
    states, outs = fn(cfg, registry, padded, valid, params, states)
    if n_padded:
        states, outs = jax.tree.map(lambda x: x[:n], (states, outs))
    return SweepResult(points=points, states=states, outs=outs)
