"""Design-space exploration: sweep grids and results tables.

The paper's platform exists to evaluate many hybrid-memory designs
quickly; this package turns the design axis into a batch axis. Build a
grid with :class:`SweepSpec` (expand with :func:`build_points`) and
evaluate it through the session API — ``repro.Engine.sweep`` runs every
point against one trace in a single compiled, vmapped emulation,
optionally sharded across devices, and ``Engine.continue_sweep`` resumes
the whole grid from its stacked warm states (mesh-shardable too).
:func:`run_sweep` is the deprecated free-function wrapper over it.
"""

from .results import SweepResult, load_rows
from .runner import run_sweep, stack_params, sweep_mesh
from .spec import RUNTIME_FIELDS, DesignPoint, SweepSpec, build_points

__all__ = [
    "SweepSpec",
    "DesignPoint",
    "RUNTIME_FIELDS",
    "build_points",
    "stack_params",
    "run_sweep",
    "sweep_mesh",
    "SweepResult",
    "load_rows",
]
