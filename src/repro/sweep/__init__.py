"""Design-space exploration engine: batched (vmapped) parameter sweeps.

The paper's platform exists to evaluate many hybrid-memory designs
quickly; this package turns the design axis into a batch axis. Build a
grid with :class:`SweepSpec`, expand it with :func:`build_points`, and
:func:`run_sweep` evaluates every point against one trace in a single
compiled, vmapped ``emulate`` call — optionally sharded across devices.
"""

from .results import SweepResult, load_rows
from .runner import run_sweep, stack_params, sweep_mesh
from .spec import RUNTIME_FIELDS, DesignPoint, SweepSpec, build_points

__all__ = [
    "SweepSpec",
    "DesignPoint",
    "RUNTIME_FIELDS",
    "build_points",
    "stack_params",
    "run_sweep",
    "sweep_mesh",
    "SweepResult",
    "load_rows",
]
