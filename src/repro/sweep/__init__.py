"""Design-space exploration: sweep grids and results tables.

The paper's platform exists to evaluate many hybrid-memory designs
quickly; this package turns the design axis into a batch axis. Build a
grid with :class:`SweepSpec` (expand with :func:`build_points`) and
evaluate it through the session API — ``repro.Engine.sweep`` runs every
point against one trace in a single compiled, vmapped emulation,
optionally sharded across devices, and ``Engine.continue_sweep`` resumes
the whole grid from its stacked warm states (mesh-shardable too).
``stack_params`` / ``sweep_mesh`` live in ``repro.engine`` and are
re-exported here for convenience.
"""

from .results import SweepResult, load_rows
from .spec import RUNTIME_FIELDS, DesignPoint, SweepSpec, build_points


def __getattr__(name):
    # Lazy re-exports: repro.engine itself imports this package (for
    # SweepResult), so pulling these eagerly would be circular.
    if name in ("stack_params", "sweep_mesh"):
        from repro import engine

        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "SweepSpec",
    "DesignPoint",
    "RUNTIME_FIELDS",
    "build_points",
    "stack_params",
    "sweep_mesh",
    "SweepResult",
    "load_rows",
]
