"""Sweep results table: per-design-point summaries of the batched state.

The executor returns one ``EmulatorState`` with a leading point axis;
this module reduces it to the host-side numbers a design study reads —
AMAT, fast-tier hit rate, migration count, NVM wear, held-response and
energy statistics — one row per point.

Results persist for cross-run comparison: :meth:`SweepResult.to_csv` /
:meth:`SweepResult.to_jsonl` write one row per design point, and
:func:`load_rows` reads either format back (keyed by extension), so a
perf trajectory can be assembled from many CI runs.
"""

from __future__ import annotations

import csv
import dataclasses
import json
import os

import numpy as np

from repro.core import table as table_lib


@dataclasses.dataclass
class SweepResult:
    """Batched outcome of :meth:`repro.Engine.sweep`.

    ``states``/``outs`` carry a leading point axis aligned with
    ``points``; :meth:`rows` reduces them to one summary dict per point.
    ``states`` doubles as the continuation handle: feed the whole result
    to :meth:`repro.Engine.continue_sweep` to resume every point from
    its warm state (donated, and mesh-shardable). ``params``/``registry``
    record the exact stacked batch and policy registry the sweep
    executed with, so a continuation re-runs precisely the same design
    points — including sweeps launched from a pre-stacked
    ``RuntimeParams`` batch, whose knobs are not recoverable from
    ``points``.
    """

    points: list
    states: object
    outs: dict
    params: object = None
    registry: object = None

    def __len__(self) -> int:
        return len(self.points)

    def rows(self) -> list[dict]:
        c = self.states.counters
        reads_fast = np.asarray(c.reads_fast)
        writes_fast = np.asarray(c.writes_fast)
        reads_slow = np.asarray(c.reads_slow)
        writes_slow = np.asarray(c.writes_slow)
        sum_read_lat = np.asarray(c.sum_read_latency)
        n_reads = np.asarray(c.n_reads)
        max_lat = np.asarray(c.max_latency)
        held = np.asarray(c.reorder_held)
        energy = np.asarray(c.energy_pj)
        faults = np.asarray(c.poison_faults)
        retired = np.asarray(c.frames_retired)
        injected = np.asarray(c.transient_faults)
        clock = np.asarray(self.states.clock)
        swaps = np.asarray(self.states.dma.swaps_done)
        wear = np.asarray(table_lib.wear(self.states.table))

        rows = []
        for i, pt in enumerate(self.points):
            fast = int(reads_fast[i]) + int(writes_fast[i])
            slow = int(reads_slow[i]) + int(writes_slow[i])
            total = max(1, fast + slow)
            rows.append(
                {
                    "index": pt.index,
                    "label": pt.label,
                    **dict(pt.coords),
                    "amat_cyc": float(sum_read_lat[i]) / max(1, int(n_reads[i])),
                    "fast_hit_rate": fast / total,
                    "swaps": int(swaps[i]),
                    "nvm_peak_wear": int(wear[i].max()),
                    "nvm_total_writes": int(wear[i].sum()),
                    "reorder_held": int(held[i]),
                    "poison_faults": int(faults[i]),
                    "frames_retired": int(retired[i]),
                    "transient_faults": int(injected[i]),
                    "max_latency_cyc": int(max_lat[i]),
                    "energy_mJ": float(energy[i]) / 1e9,
                    "emulated_ms": int(clock[i]) / 1e6,
                }
            )
        return rows

    def best(self, key: str = "amat_cyc") -> dict:
        """The row minimizing ``key`` (AMAT by default)."""
        return min(self.rows(), key=lambda r: r[key])

    def to_csv(self, path: str | os.PathLike) -> str:
        """Write one CSV line per design point (header from the first
        row; every point of one sweep shares the same keys). Returns the
        path written."""
        rows = self.rows()
        with open(path, "w", newline="") as fh:
            writer = csv.DictWriter(fh, fieldnames=list(rows[0]))
            writer.writeheader()
            writer.writerows(rows)
        return str(path)

    def to_jsonl(self, path: str | os.PathLike) -> str:
        """Write one JSON object per line per design point. Returns the
        path written."""
        with open(path, "w") as fh:
            for row in self.rows():
                fh.write(json.dumps(row) + "\n")
        return str(path)

    def table(self, keys: tuple[str, ...] | None = None) -> str:
        """Fixed-width text table of per-point summaries."""
        rows = self.rows()
        if keys is None:
            keys = (
                "label",
                "amat_cyc",
                "fast_hit_rate",
                "swaps",
                "nvm_peak_wear",
                "reorder_held",
                "energy_mJ",
                "emulated_ms",
            )

        def fmt(v):
            if isinstance(v, float):
                return f"{v:.3f}"
            return str(v)

        def width(j, k):
            return max(len(k), *(len(row[j]) for row in cells))

        cells = [[fmt(r.get(k, "")) for k in keys] for r in rows]
        widths = [width(j, k) for j, k in enumerate(keys)]
        header = "  ".join(k.ljust(w) for k, w in zip(keys, widths))
        lines = [header, "-" * len(header)]
        for row in cells:
            lines.append("  ".join(v.rjust(w) for v, w in zip(row, widths)))
        return "\n".join(lines)


def _coerce(value: str):
    """CSV cells back to int/float where they parse (labels stay str)."""
    for cast in (int, float):
        try:
            return cast(value)
        except ValueError:
            continue
    return value


def load_rows(path: str | os.PathLike) -> list[dict]:
    """Read rows persisted by :meth:`SweepResult.to_csv` /
    :meth:`SweepResult.to_jsonl` (format keyed by extension: ``.jsonl``
    vs anything else = CSV). JSONL round-trips types exactly; CSV cells
    are coerced back to int/float where they parse."""
    p = str(path)
    if p.endswith(".jsonl"):
        with open(p) as fh:
            return [json.loads(line) for line in fh if line.strip()]
    with open(p, newline="") as fh:
        return [{k: _coerce(v) for k, v in row.items()}
                for row in csv.DictReader(fh)]
