"""Design-space sweep specification (paper Fig 8 / Table III studies).

A sweep is a cartesian grid over *runtime* design axes — NVM technology,
fast-tier share, placement policy, link latency, plus any
``RuntimeParams``-backed ``EmulatorConfig`` field — expanded into a list
of :class:`DesignPoint`. Every point must agree on the static geometry
(``config.static_key``): that is what lets the executor stack the
per-point ``RuntimeParams`` and evaluate the whole grid in one compiled,
vmapped emulation program (``repro.Engine.sweep``).
"""

from __future__ import annotations

import dataclasses
import itertools

from repro.core.config import (
    TECHNOLOGIES,
    EmulatorConfig,
    RuntimeParams,
    static_key,
)

# EmulatorConfig fields that map 1:1 onto RuntimeParams and are therefore
# sweepable via ``extra_axes`` without recompilation.
RUNTIME_FIELDS = frozenset(
    {
        "link_lat",
        "link_bytes_per_cycle",
        "issue_gap",
        "dma_bytes_per_cycle",
        "hot_threshold",
        "hotness_decay_shift",
        "decay_every",
        "write_weight",
        "wear_slack",
        "pin_fast_fraction",
        "endurance_budget",
        "power_pj_per_bit_fast",
        "power_pj_per_bit_slow_read",
        "power_pj_per_bit_slow_write",
    }
)


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One evaluated configuration: its coordinates on the sweep axes and
    the fully-resolved ``EmulatorConfig``."""

    index: int
    coords: tuple[tuple[str, object], ...]
    cfg: EmulatorConfig

    @property
    def label(self) -> str:
        return "/".join(f"{k}={v}" for k, v in self.coords)

    @property
    def params(self) -> RuntimeParams:
        return RuntimeParams.from_config(self.cfg)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Cartesian sweep recipe over the platform's runtime design axes.

    ``technologies`` names entries of ``TECHNOLOGIES`` for the slow tier;
    ``fast_fractions`` are fast-tier shares of the (static) total page
    space; ``policies`` are registered policy names; ``link_lats`` are
    link round-trip cycle counts. ``extra_axes`` sweeps any field in
    ``RUNTIME_FIELDS``, e.g. ``(("hot_threshold", (2, 8)),)``. Axes left
    empty stay at the ``base`` value.
    """

    base: EmulatorConfig
    technologies: tuple[str, ...] = ()
    fast_fractions: tuple[float, ...] = ()
    policies: tuple[str, ...] = ()
    link_lats: tuple[int, ...] = ()
    extra_axes: tuple[tuple[str, tuple], ...] = ()

    def build(self) -> list[DesignPoint]:
        """Expand the grid (:func:`build_points` as a method — handy when
        passing explicit point lists to ``repro.Engine.sweep``)."""
        return build_points(self)


def _with_fast_fraction(cfg: EmulatorConfig, frac: float) -> EmulatorConfig:
    n = cfg.n_pages
    nf = min(max(int(round(n * frac)), 1), n - 1)
    return cfg.with_(n_fast_pages=nf, n_slow_pages=n - nf)


def _set_tech(name: str):
    return lambda c: c.with_(slow=TECHNOLOGIES[name])


def _set_fast_fraction(frac: float):
    return lambda c: _with_fast_fraction(c, frac)


def _set_field(field: str, value):
    return lambda c: c.with_(**{field: value})


def _axes(spec: SweepSpec) -> list[tuple[str, list[tuple[object, object]]]]:
    """Each axis is (name, [(coordinate value, cfg transform), ...])."""
    axes = []
    if spec.technologies:
        axes.append(("tech", [(t, _set_tech(t)) for t in spec.technologies]))
    if spec.fast_fractions:
        pairs = [(round(f, 4), _set_fast_fraction(f)) for f in spec.fast_fractions]
        axes.append(("fast_frac", pairs))
    if spec.policies:
        pairs = [(p, _set_field("policy", p)) for p in spec.policies]
        axes.append(("policy", pairs))
    if spec.link_lats:
        pairs = [(v, _set_field("link_lat", v)) for v in spec.link_lats]
        axes.append(("link_lat", pairs))
    for field, values in spec.extra_axes:
        if field not in RUNTIME_FIELDS:
            msg = (
                f"{field!r} is not a runtime-sweepable field; choose from "
                f"{sorted(RUNTIME_FIELDS)} (static geometry changes require "
                "a separate compilation)"
            )
            raise ValueError(msg)
        axes.append((field, [(v, _set_field(field, v)) for v in values]))
    return axes


def build_points(spec: SweepSpec) -> list[DesignPoint]:
    """Expand the cartesian grid; validates static-geometry agreement."""
    axes = _axes(spec)
    base_key = static_key(spec.base)
    points = []
    choices = [axis_vals for _, axis_vals in axes]
    names = [name for name, _ in axes]
    for i, combo in enumerate(itertools.product(*choices)):
        cfg = spec.base
        coords = []
        for name, (value, transform) in zip(names, combo):
            cfg = transform(cfg)
            coords.append((name, value))
        if static_key(cfg) != base_key:
            msg = (
                f"design point {coords} changed static geometry "
                f"({static_key(cfg)} != {base_key})"
            )
            raise ValueError(msg)
        points.append(DesignPoint(index=i, coords=tuple(coords), cfg=cfg))
    return points
