"""gem5-class baseline: an event-driven, cycle-level software simulator.

Every pipeline stage of every request, every DMA sub-block transfer and
(optionally) every DRAM refresh window is a discrete event on a heap —
the detailed-but-sequential methodology whose slowness motivates the
paper's platform.

With ``refresh=False`` the timing semantics are *identical* to
``trace_sim`` (and hence to a chunk=1 ``repro.Engine`` session); the
cross-check lives in tests/test_latency_consistency.py and the Engine
oracle parity in tests/test_engine.py. ``refresh=True`` adds tREFI/tRFC
DRAM refresh modelling — extra fidelity the flat simulators lack.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.core.config import EmulatorConfig, FAST, SLOW
from repro.core import dma as dma_lib
from repro.core import table as table_lib
from .trace_sim import SimResult, _ceil_div


def simulate(cfg: EmulatorConfig, page, offset, is_write, size,
             refresh: bool = False, tREFI: int = 7800, tRFC: int = 350,
             cpu_model: bool = False, insns_per_request: int = 12
             ) -> SimResult:
    """``cpu_model=True`` additionally simulates the host CPU pipeline the
    way gem5 SE-mode does: every memory request is surrounded by the
    retirement events of the non-memory instructions between misses
    (``insns_per_request``, ~ SPEC's MPKI). Timing-neutral with respect to
    the memory system (instructions retire in the issue gap), but it is
    the dominant *simulation* cost — exactly the overhead the paper
    escapes by running applications on real hard-IP cores."""
    page = np.asarray(page)
    offset = np.asarray(offset)
    is_write = np.asarray(is_write)
    size = np.asarray(size)
    n = len(page)

    n_pages = cfg.n_pages
    device = np.where(np.arange(n_pages) < cfg.n_fast_pages, FAST, SLOW)
    frame = np.where(np.arange(n_pages) < cfg.n_fast_pages,
                     np.arange(n_pages), np.arange(n_pages) - cfg.n_fast_pages)
    hotness = np.zeros(n_pages, np.int64)
    fast_owner = np.arange(cfg.n_fast_pages, dtype=np.int64)
    clock_ptr = 0

    bank_free = np.zeros(2 * cfg.n_banks, np.int64)
    link_rx = link_tx = last_ret = clock = 0
    dma = {"active": False, "a": -1, "b": -1, "start": 0, "progress": 0}
    swaps = 0
    exch = dma_lib.exchange_cycles_per_subblock(cfg)
    dur = dma_lib.swap_duration(cfg)
    spp = cfg.subblocks_per_page

    returns = np.zeros(n, np.int64)
    latency = np.zeros(n, np.int64)
    dev_out = np.zeros(n, np.int64)
    ctr = {"reads_fast": 0, "writes_fast": 0, "reads_slow": 0,
           "writes_slow": 0, "bytes_read": 0, "bytes_written": 0,
           "reorder_held": 0, "energy_pj": 0.0}

    if cfg.policy not in ("static", "hotness", "write_bias"):
        raise NotImplementedError(cfg.policy)

    heap: list = []
    seq = 0

    def push(t, kind, data):
        nonlocal seq
        heapq.heappush(heap, (t, seq, kind, data))
        seq += 1

    req = {}  # in-flight request scratch

    retired = 0

    def start_request(i, t_clock):
        issue = t_clock + cfg.issue_gap
        if cpu_model:
            # Retire the instruction window between the previous miss and
            # this one, one pipeline event each (gem5-style per-insn cost).
            for k in range(insns_per_request):
                push(t_clock + (k * cfg.issue_gap) // max(1, insns_per_request),
                     "cpu", k)
        push(issue, "issue", i)
        req["issue"] = issue

    if refresh:
        for d in range(2):
            push(tREFI, "refresh", d)

    start_request(0, clock)

    while heap:
        t, _, kind, data = heapq.heappop(heap)

        if kind == "refresh":
            d = data
            end = t + tRFC
            for b in range(cfg.n_banks):
                lane = d * cfg.n_banks + b
                bank_free[lane] = max(bank_free[lane], end)
            push(t + tREFI, "refresh", d)
            if not heap or all(k == "refresh" for _, _, k, _ in heap):
                break  # only refresh events left -> done
            continue

        if kind == "cpu":
            retired += 1  # scoreboard update; no memory-system interaction
            continue

        if kind == "dma_blk":
            dma["progress"] += 1
            if dma["progress"] >= spp:
                a, b = dma["a"], dma["b"]
                device[a], device[b] = device[b], device[a]
                frame[a], frame[b] = frame[b], frame[a]
                if device[a] == FAST:
                    fast_owner[frame[a]] = a
                dma.update(active=False, a=-1, b=-1, progress=0)
                swaps += 1
            continue

        i = data
        if kind == "issue":
            w, sz = bool(is_write[i]), int(size[i])
            rx_b = sz if w else 16
            rx_done = max(t, link_rx) + _ceil_div(rx_b, cfg.link_bytes_per_cycle)
            link_rx = rx_done
            push(rx_done + cfg.link_lat // 2, "arrive", i)
            continue

        if kind == "arrive":
            p, off = int(page[i]), int(offset[i])
            w, sz = bool(is_write[i]), int(size[i])
            d, f = int(device[p]), int(frame[p])
            if dma["active"] and p in (dma["a"], dma["b"]):
                if off // cfg.subblock < dma["progress"]:
                    other = dma["b"] if p == dma["a"] else dma["a"]
                    d, f = int(device[other]), int(frame[other])
            tech = cfg.slow if d == SLOW else cfg.fast
            srv = (tech.write_lat if w else tech.read_lat) + \
                _ceil_div(sz, tech.bytes_per_cycle)
            lane = d * cfg.n_banks + f % cfg.n_banks
            med_done = max(t, int(bank_free[lane])) + srv
            bank_free[lane] = med_done
            req["dev"], req["med_done"] = d, med_done
            push(med_done, "med_done", i)
            continue

        if kind == "med_done":
            w, sz = bool(is_write[i]), int(size[i])
            ordered = max(t, last_ret)
            if ordered > t:
                ctr["reorder_held"] += 1
            tx_b = 16 if w else sz
            ret = max(ordered, link_tx) + _ceil_div(tx_b, cfg.link_bytes_per_cycle)
            link_tx = ret
            push(ret + cfg.link_lat // 2, "ret", i)
            continue

        if kind == "ret":
            p = int(page[i])
            w, sz = bool(is_write[i]), int(size[i])
            d = req["dev"]
            returns[i] = t
            latency[i] = t - req["issue"]
            dev_out[i] = d
            key = ("writes_" if w else "reads_") + ("slow" if d == SLOW else "fast")
            ctr[key] += 1
            ctr["bytes_written" if w else "bytes_read"] += sz
            if d == SLOW:
                ctr["energy_pj"] += 8.0 * sz * (
                    cfg.power_pj_per_bit_slow_write if w
                    else cfg.power_pj_per_bit_slow_read)
            else:
                ctr["energy_pj"] += 8.0 * sz * cfg.power_pj_per_bit_fast

            # write_weight is policy-scoped: only write_bias biases hotness.
            ww = cfg.write_weight if cfg.policy == "write_bias" else 1
            # Saturating like the emulator's HOTNESS lane (identity below
            # the cap).
            hotness[p] = min(hotness[p] + 1 + (ww - 1) * int(w),
                             table_lib.HOTNESS_CAP)
            if i % cfg.decay_every == cfg.decay_every - 1:
                hotness >>= cfg.hotness_decay_shift
            last_ret = t
            now = max(clock + cfg.issue_gap, t)

            if cfg.policy in ("hotness", "write_bias"):
                heat = int(hotness[p]) if device[p] == SLOW else -1
                cand = p
                victim = int(fast_owner[clock_ptr])
                want = (heat >= cfg.hot_threshold
                        and heat > int(hotness[victim])
                        and device[cand] == SLOW and device[victim] == FAST)
                # Pointer commits only with a started swap (see trace_sim).
                if want and not dma["active"]:
                    dma.update(active=True, a=cand, b=victim,
                               start=now, progress=0)
                    clock_ptr = (clock_ptr + 1) % cfg.n_fast_pages
                    for k in range(1, spp + 1):
                        push(now + k * exch, "dma_blk", None)

            clock = now
            if i + 1 < n:
                start_request(i + 1, clock)
            continue

    rd = ~is_write.astype(bool)
    ctr["mean_read_latency_cyc"] = float(latency[rd].mean()) if rd.any() else 0.0
    return SimResult(returns=returns, latency=latency, device=dev_out,
                     clock=clock, swaps=swaps, counters=ctr)
