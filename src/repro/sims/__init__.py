"""Software-simulator baselines (the role gem5 / ChampSim play in the paper).

``trace_sim``  — per-request sequential Python simulator ("ChampSim-class").
                 Implements *exactly* the chunk=1 semantics of the JAX
                 emulator, so it doubles as the correctness oracle.
``cycle_sim``  — event-driven cycle-level simulator ("gem5-class"): every
                 pipeline stage, bank occupancy window and DMA sub-block is
                 a discrete event on a heap. Slowest, most detailed.
"""
from . import trace_sim, cycle_sim

__all__ = ["trace_sim", "cycle_sim"]
