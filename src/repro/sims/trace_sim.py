"""ChampSim-class baseline: a sequential, per-request software simulator.

Implements exactly the chunk=1 semantics of the JAX emulation pipeline
(repro.core.emulator), one request at a time in a Python loop — the
software-simulator methodology the paper compares against. Because the
semantics match, this module is also the *oracle* for the platform's
correctness tests: a chunk=1 ``repro.Engine.run`` must be bit-identical
to this loop (tests/test_emulator_oracle.py, tests/test_engine.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.config import EmulatorConfig, FAST, SLOW
from repro.core import dma as dma_lib
from repro.core import table as table_lib


@dataclass
class SimResult:
    returns: np.ndarray
    latency: np.ndarray
    device: np.ndarray
    clock: int
    swaps: int
    counters: dict = field(default_factory=dict)


def _ceil_div(size: int, bpc: float) -> int:
    return int(math.ceil(size / bpc))


def simulate(cfg: EmulatorConfig, page, offset, is_write, size) -> SimResult:
    page = np.asarray(page)
    offset = np.asarray(offset)
    is_write = np.asarray(is_write)
    size = np.asarray(size)
    n = len(page)

    n_pages = cfg.n_pages
    device = np.where(np.arange(n_pages) < cfg.n_fast_pages, FAST, SLOW)
    frame = np.where(np.arange(n_pages) < cfg.n_fast_pages,
                     np.arange(n_pages), np.arange(n_pages) - cfg.n_fast_pages)
    hotness = np.zeros(n_pages, np.int64)
    fast_owner = np.arange(cfg.n_fast_pages, dtype=np.int64)
    clock_ptr = 0

    bank_free = np.zeros(2 * cfg.n_banks, np.int64)
    link_rx = link_tx = last_ret = clock = 0
    dma_active, dma_a, dma_b, dma_start, swaps = False, -1, -1, 0, 0
    exch = dma_lib.exchange_cycles_per_subblock(cfg)
    dur = dma_lib.swap_duration(cfg)
    spp = cfg.subblocks_per_page

    returns = np.zeros(n, np.int64)
    latency = np.zeros(n, np.int64)
    dev_out = np.zeros(n, np.int64)
    ctr = {"reads_fast": 0, "writes_fast": 0, "reads_slow": 0,
           "writes_slow": 0, "bytes_read": 0, "bytes_written": 0,
           "reorder_held": 0, "energy_pj": 0.0}

    if cfg.policy not in ("static", "hotness", "write_bias"):
        raise NotImplementedError(
            f"oracle mirrors static/hotness/write_bias, not {cfg.policy!r}")

    for i in range(n):
        p, off, w, sz = int(page[i]), int(offset[i]), bool(is_write[i]), int(size[i])

        # --- RX link
        issue = clock + cfg.issue_gap
        rx_b = sz if w else 16
        rx_done = max(issue, link_rx) + _ceil_div(rx_b, cfg.link_bytes_per_cycle)
        link_rx = rx_done
        arrive = rx_done + cfg.link_lat // 2

        # --- table lookup + DMA conflict redirect (paper §III-D)
        d, f = int(device[p]), int(frame[p])
        if dma_active and p in (dma_a, dma_b):
            prog = min(max((arrive - dma_start) // exch, 0), spp)
            if off // cfg.subblock < prog:
                other = dma_b if p == dma_a else dma_a
                d, f = int(device[other]), int(frame[other])

        # --- bank queue + media access
        tech = cfg.slow if d == SLOW else cfg.fast
        srv = (tech.write_lat if w else tech.read_lat) + \
            _ceil_div(sz, tech.bytes_per_cycle)
        lane = d * cfg.n_banks + f % cfg.n_banks
        med_done = max(arrive, int(bank_free[lane])) + srv
        bank_free[lane] = med_done

        # --- tag-match in-order return, then TX link
        ordered = max(med_done, last_ret)
        if ordered > med_done:
            ctr["reorder_held"] += 1
        tx_b = 16 if w else sz
        ret = max(ordered, link_tx) + _ceil_div(tx_b, cfg.link_bytes_per_cycle)
        link_tx = ret
        ret += cfg.link_lat // 2

        returns[i] = ret
        latency[i] = ret - issue
        dev_out[i] = d

        # --- counters (per post-redirect device, like the FPGA counters)
        key = ("writes_" if w else "reads_") + ("slow" if d == SLOW else "fast")
        ctr[key] += 1
        ctr["bytes_written" if w else "bytes_read"] += sz
        if d == SLOW:
            ctr["energy_pj"] += 8.0 * sz * (
                cfg.power_pj_per_bit_slow_write if w else cfg.power_pj_per_bit_slow_read)
        else:
            ctr["energy_pj"] += 8.0 * sz * cfg.power_pj_per_bit_fast

        # --- chunk boundary (chunk == 1): hotness, DMA, policy.
        # write_weight is policy-scoped: only write_bias biases hotness.
        ww = cfg.write_weight if cfg.policy == "write_bias" else 1
        # Saturating like the emulator's HOTNESS lane (identity below cap).
        hotness[p] = min(hotness[p] + 1 + (ww - 1) * int(w),
                         table_lib.HOTNESS_CAP)
        if i % cfg.decay_every == cfg.decay_every - 1:
            hotness >>= cfg.hotness_decay_shift

        last_ret = ret
        now = max(clock + cfg.issue_gap, ret)

        if dma_active and now >= dma_start + dur:
            device[dma_a], device[dma_b] = device[dma_b], device[dma_a]
            frame[dma_a], frame[dma_b] = frame[dma_b], frame[dma_a]
            if device[dma_a] == FAST:  # promoted page now owns its frame
                fast_owner[frame[dma_a]] = dma_a
            dma_active, dma_a, dma_b = False, -1, -1
            swaps += 1

        if cfg.policy in ("hotness", "write_bias"):
            # chunk-local candidate (the single request) + CLOCK victim
            heat = int(hotness[p]) if device[p] == SLOW else -1
            cand = p
            victim = int(fast_owner[clock_ptr])
            want = (heat >= cfg.hot_threshold and heat > int(hotness[victim])
                    and device[cand] == SLOW and device[victim] == FAST)
            # The CLOCK pointer commits only with an accepted + started
            # proposal (engine idle): a dropped proposal must not skip
            # its victim frame (matches the emulator's pointer commit).
            if want and not dma_active:
                dma_active, dma_a, dma_b, dma_start = True, cand, victim, now
                clock_ptr = (clock_ptr + 1) % cfg.n_fast_pages

        clock = now

    ctr["mean_read_latency_cyc"] = (
        float(latency[~is_write.astype(bool)].mean()) if (~is_write.astype(bool)).any() else 0.0)
    return SimResult(returns=returns, latency=latency, device=dev_out,
                     clock=clock, swaps=swaps, counters=ctr)
