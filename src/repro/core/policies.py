"""Data placement / migration / pattern-recognition policies (paper §III-A).

The paper's platform exists so users can drop *their own* policies into the
HMMU pipeline. A policy here is a pure function examining the chunk's
access stream plus the policy state, and proposing (at most) one page swap
for the single DMA engine — exactly the three policy aspects the paper
names: access-pattern recognition, data placement, data migration.

Policy state is the packed redirection table (``core.table``): hotness
counters ride the HOTNESS lane, the CLOCK inverse map rides the OWNER
lane, placement is the DEVICE lane — policies read named lanes, never raw
columns.

Hardware faithfulness note: policies only use O(chunk) work plus O(1)
row lookups — promotion candidates come from the *current* access stream
(what the RTL pipeline sees), and victims come from a CLOCK-style
round-robin pointer over DRAM frames (the OWNER lane inverse map), not
from a global argmin no RTL could compute in a cycle. A global-scan
variant ("hotness_global") is kept as an idealized reference policy for
design-space studies.

Policy interface::

    propose(cfg, params, table, ptr, pages, is_write, valid)
        -> (want: bool[], slow_page: int32[], fast_victim: int32[], new_ptr)

A policy may additionally declare a keyword parameter named ``min_wear``
(see ``wear_level``): the emulator detects it by signature inspection at
trace time and passes the maintained global min-wear register
(``EmulatorState.min_wear``). Plain seven-argument policies keep working
unchanged.

``cfg`` carries static geometry, ``params`` the traced knobs
(``hot_threshold``, ``n_fast_pages``, ...), ``table`` the packed
``int32[n_pages, ROW_W]`` metadata store. New policies register via
``@register("name")``; the emulator dispatches on the traced
``params.policy_id`` with ``jax.lax.switch`` over the registration order,
which makes the policy itself a batchable design axis (sweeps evaluate
several policies in one compiled computation).

``new_ptr`` is the CLOCK pointer the policy wants, under a two-case
commit contract enforced by the emulator:

* ``want`` proposals only commit ``new_ptr`` when the swap actually
  *starts* (the emulator re-masks ``want`` — validity, device sanity,
  pin bits — and the single DMA engine may be busy): a rejected or
  dropped proposal leaves the pointer where it was, so no usable victim
  frame is silently consumed;
* with ``want`` False, ``new_ptr`` commits unconditionally — that is the
  channel for skipping a *pinned* CLOCK frame (a pinned frame is not in
  the victim rotation at all, so stepping past it consumes nothing; a
  policy that never skips just returns ``ptr``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import table as table_lib
from .config import FAST, SLOW

POLICIES: dict[str, Callable] = {}


def register(name: str):
    def deco(fn):
        POLICIES[name] = fn
        return fn
    return deco


def get(name: str) -> Callable:
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; have {sorted(POLICIES)}")
    return POLICIES[name]


def policy_id(name: str) -> int:
    """Index of ``name`` in registration order — the ``lax.switch`` branch
    index carried by ``RuntimeParams.policy_id``."""
    get(name)
    return list(POLICIES).index(name)


@dataclasses.dataclass(frozen=True)
class PolicyRegistry:
    """An immutable ``name -> policy fn`` snapshot — the unit the compiled
    pipeline dispatches over.

    The mutable module dict above stays the *registration* surface
    (``@register`` keeps working), but nothing compiled ever reads it:
    ``repro.Engine`` and the legacy wrappers take a snapshot at
    construction/call time, and the ``lax.switch`` branches are built from
    the snapshot's own function tuple. A late ``@register`` (or a
    re-registration of an existing name) therefore changes *future*
    snapshots only — it can neither invalidate nor silently leak into an
    existing session's compiled executables, which is exactly the
    import-order hazard the old global-dict lookups had.

    Frozen + tuple-valued, so a registry is hashable and usable as a jit
    static argument; two snapshots of an unchanged global dict compare
    equal and share compilations.
    """

    names: tuple[str, ...]
    fns: tuple[Callable, ...]

    def __post_init__(self):
        if len(self.names) != len(self.fns):
            raise ValueError("names and fns length mismatch")
        if len(set(self.names)) != len(self.names):
            raise ValueError(f"duplicate policy names: {self.names}")

    @classmethod
    def snapshot(cls, names=None) -> "PolicyRegistry":
        """Snapshot the global registration dict (all registered policies,
        in registration order, when ``names`` is None; else the named
        subset in the given order)."""
        if names is None:
            names = tuple(POLICIES)
        return cls(tuple(names), tuple(get(n) for n in names))

    def index(self, name: str) -> int:
        """Branch index of ``name`` — what ``RuntimeParams.policy_id``
        must carry for this registry."""
        if name not in self.names:
            raise KeyError(
                f"policy {name!r} is not in this registry; have {self.names}")
        return self.names.index(name)

    def subset(self, names) -> "PolicyRegistry":
        """A restricted registry carrying the same snapshotted functions
        (sweeps compile the switch only over policies actually present)."""
        return PolicyRegistry(tuple(names),
                              tuple(self.fns[self.index(n)] for n in names))

    def __contains__(self, name) -> bool:
        return name in self.names

    def __len__(self) -> int:
        return len(self.names)

    def __iter__(self):
        return iter(self.names)



def update_hotness(p, table: jax.Array, pages: jax.Array,
                   is_write: jax.Array, valid: jax.Array,
                   do_decay: jax.Array,
                   write_weight: jax.Array | int | None = None) -> jax.Array:
    """Scatter-add chunk accesses into the HOTNESS lane, then
    decay-by-shift on ``do_decay`` boundaries (hardware aging counters).
    ``p`` is an ``EmulatorConfig`` or traced ``RuntimeParams`` (shared
    field names).

    ``write_weight`` overrides ``p.write_weight`` — the emulator passes
    the *policy-scoped* effective weight (``p.write_weight`` only when the
    active policy is ``write_bias``, else 1), so the weighting is part of
    the write_bias policy rather than a global knob that silently changes
    every other policy's hotness accounting."""
    ww = p.write_weight if write_weight is None else write_weight
    w = 1 + (ww - 1) * is_write.astype(jnp.int32)
    w = jnp.where(valid, w, 0)
    table = table_lib.add_hotness(table, pages, w)
    return jax.lax.cond(
        do_decay,
        lambda t: table_lib.decay_hotness(t, p.hotness_decay_shift),
        lambda t: t, table)


def _chunk_candidate(table, pages, valid, extra_mask=None):
    """Hottest slow-resident page among this chunk's accesses. Pinned
    pages (PIN_SLOW — nailed to NVM) and retirement tombstones (parked on
    dead frames) are never candidates; the emulator would veto them
    anyway, and a vetoed hottest page would livelock the proposal stream.
    ``extra_mask`` further restricts eligibility (wear_level's
    destination freshness)."""
    rows = table[pages]
    ok = valid & (table_lib.device(rows) == SLOW) & \
        ~table_lib.is_pinned(rows) & ~table_lib.is_retired(rows)
    if extra_mask is not None:
        ok = ok & extra_mask
    heat = jnp.where(ok, table_lib.hotness(rows), -1)
    j = jnp.argmax(heat)
    return pages[j], heat[j]


# CLOCK pin-skip lookahead: how many frames from the pointer a policy
# examines per chunk to find an unpinned victim (an 8-wide pin-bit
# priority encoder in RTL terms). Pinned frames are not in the victim
# rotation; without lookahead a long pinned run (pin_fast_fraction pins
# a contiguous prefix) would stall migration one chunk per frame.
_CLOCK_WINDOW = 8


def _clock_victim(table, ptr, nf):
    """First eligible CLOCK victim within ``_CLOCK_WINDOW`` frames of the
    pointer — pinned owners and retirement tombstones (a dead fast frame
    is permanently out of the victim rotation) are stepped over alike.
    Returns ``(victim_page, found, skip)`` where ``skip`` is the
    number of skipped frames stepped over to reach it (== the window width
    when every probed frame is ineligible and ``found`` is False).

    Policies fold it into the pointer-commit contract as
    ``new_ptr = (ptr + skip + want) % nf``: the pinned run is consumed
    unconditionally (``want=False`` commits unconditionally, and a
    started swap consumes it along with the victim), while the victim
    itself is only consumed by a started swap. With no pins ``skip`` is 0
    and the arithmetic reduces exactly to the classic ``ptr + want``."""
    offs = jnp.arange(_CLOCK_WINDOW, dtype=jnp.int32)
    frames = (ptr + offs) % nf
    owners = table_lib.owner(table)[frames]
    rows = table[owners]
    pinned = table_lib.is_pinned(rows) | table_lib.is_retired(rows)
    first = jnp.argmin(pinned).astype(jnp.int32)   # first False, else 0
    found = ~pinned[first]
    victim = owners[first]
    skip = jnp.where(found, first, _CLOCK_WINDOW)
    return victim, found, skip


@register("static")
def static_policy(cfg, params, table, ptr, pages, is_write, valid):
    """Placement fixed at initialization; never migrate (the baseline the
    paper's users compare their designs against)."""
    z = jnp.zeros((), jnp.int32)
    return jnp.zeros((), bool), z, z, ptr


@register("hotness")
def hotness_policy(cfg, params, table, ptr, pages, is_write, valid):
    """Promote the hottest slow page seen in this chunk once it crosses
    ``hot_threshold``; victim = CLOCK pointer over DRAM frames, skipped if
    the victim is hotter than the candidate. Pinned frames at the pointer
    are stepped over without a proposal (they are not victims)."""
    cand, heat = _chunk_candidate(table, pages, valid)
    victim, vfound, skip = _clock_victim(table, ptr, params.n_fast_pages)
    want = vfound & (heat >= params.hot_threshold) & \
        (heat > table_lib.hotness_at(table, victim))
    new_ptr = (ptr + skip + want.astype(jnp.int32)) % params.n_fast_pages
    return want, cand, victim, new_ptr


@register("write_bias")
def write_bias_policy(cfg, params, table, ptr, pages, is_write, valid):
    """Same promotion rule as ``hotness``, but hotness accumulation
    weights writes by ``params.write_weight`` (configure > 1) — and ONLY
    this policy applies the weight (the emulator scopes it by the traced
    ``policy_id``, so a policy-axis sweep of hotness vs write_bias at
    equal ``write_weight`` actually diverges). NVM writes are the
    expensive, endurance-limited operation (paper Table I), so
    write-heavy pages should live in DRAM."""
    return hotness_policy(cfg, params, table, ptr, pages, is_write, valid)


@register("stream")
def stream_policy(cfg, params, table, ptr, pages, is_write, valid):
    """Access-pattern recognition: detect a dominant small stride in the
    chunk's page stream and *pre-promote* the stream's next page before
    demand accesses pay NVM latency (prefetch-style migration). Falls back
    to the hotness rule when no stream is detected."""
    deltas = jnp.where(valid[1:] & valid[:-1], pages[1:] - pages[:-1], 0)
    span = 4  # recognise strides in [-span, span] \ {0}
    in_range = (jnp.abs(deltas) <= span) & (deltas != 0)
    hist = jnp.zeros(2 * span + 1, jnp.int32).at[
        jnp.clip(deltas + span, 0, 2 * span)].add(
        in_range.astype(jnp.int32), mode="drop")
    stride = jnp.argmax(hist).astype(jnp.int32) - span
    strength = jnp.max(hist)
    streaming = strength > (pages.shape[0] // 4)

    last = pages[jnp.argmax(jnp.where(valid, jnp.arange(pages.shape[0]), -1))]
    target = jnp.clip(last + stride, 0, table.shape[0] - 1)
    target_row = table[target]
    target_is_slow = (table_lib.device(target_row) == SLOW) & \
        ~table_lib.is_pinned(target_row) & ~table_lib.is_retired(target_row)

    hw, hc, _, _ = hotness_policy(cfg, params, table, ptr, pages, is_write,
                                  valid)
    victim, vfound, skip = _clock_victim(table, ptr, params.n_fast_pages)
    want_stream = streaming & target_is_slow & vfound
    want = want_stream | hw
    cand = jnp.where(want_stream, target, hc)
    new_ptr = (ptr + skip + want.astype(jnp.int32)) % params.n_fast_pages
    return want, cand, victim, new_ptr


@register("hotness_global")
def hotness_global_policy(cfg, params, table, ptr, pages, is_write, valid):
    """Idealized reference: global hottest-slow / coldest-fast scan each
    chunk. No RTL implements this in a cycle — kept for design-space
    comparison against the realizable policies above."""
    dev = table_lib.device(table)
    hot = table_lib.hotness(table)
    pinned = table_lib.is_pinned(table) | table_lib.is_retired(table)
    heat_all = jnp.where((dev == SLOW) & ~pinned, hot, -1)
    cand = jnp.argmax(heat_all).astype(jnp.int32)
    heat = heat_all[cand]
    cold = jnp.where((dev == FAST) & ~pinned, hot, 2 ** 30)
    victim = jnp.argmin(cold).astype(jnp.int32)
    want = (heat >= params.hot_threshold) & (heat > hot[victim])
    return want, cand, victim, ptr


@register("wear_level")
def wear_level_policy(cfg, params, table, ptr, pages, is_write, valid,
                      min_wear=None):
    """Endurance-aware promotion (paper Table I's write-endurance
    asymmetry as a first-class policy axis): same hottest-page promotion
    rule as ``hotness``, but the demotion *destination* is chosen
    wear-aware. A swap demotes the CLOCK victim into the candidate's slow
    frame, and that frame absorbs the full-page migration write plus the
    victim's future demand writes — so candidates whose frame has already
    absorbed more than ``params.wear_slack`` writes beyond the global
    minimum are skipped, steering migration traffic toward fresh frames
    and flattening the WEAR histogram (max-lifetime leveling) at
    near-equal hit rate.

    ``min_wear`` is the emulator-maintained global min-wear register
    (``EmulatorState.min_wear``): the true minimum over every slow
    frame's WEAR, refreshed at decay boundaries (a hardware-style
    periodic scrub riding the aging tick — between refreshes the
    register is stale but monotone, since wear only grows, so the
    ``wear_slack`` band is conservative by at most one decay period's
    writes). ``wear_slack`` is therefore measured against the *whole
    histogram's* floor; policies invoked outside the emulator (tests,
    notebooks) may pass ``min_wear=None`` to fall back to the historical
    chunk-local floor over this chunk's slow frames."""
    rows = table[pages]
    slow = valid & (table_lib.device(rows) == SLOW)
    frm = table_lib.frame(rows)
    # WEAR is keyed by slow frame: one O(chunk) gather of the candidates'
    # frame rows (the page rows above are the stage-2-style gather every
    # chunk-local policy already pays).
    frame_wear = table_lib.wear_at(table, jnp.where(slow, frm, 0))
    if min_wear is None:
        wmin = jnp.min(jnp.where(slow, frame_wear, 2 ** 30))
    else:
        wmin = min_wear
    fresh = frame_wear <= wmin + params.wear_slack
    cand, cheat = _chunk_candidate(table, pages, valid, extra_mask=fresh)
    victim, vfound, skip = _clock_victim(table, ptr, params.n_fast_pages)
    want = vfound & (cheat >= params.hot_threshold) & \
        (cheat > table_lib.hotness_at(table, victim))
    new_ptr = (ptr + skip + want.astype(jnp.int32)) % params.n_fast_pages
    return want, cand, victim, new_ptr
