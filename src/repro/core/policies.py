"""Data placement / migration / pattern-recognition policies (paper §III-A).

The paper's platform exists so users can drop *their own* policies into the
HMMU pipeline. A policy here is a pure function examining the chunk's
access stream plus the policy state, and proposing (at most) one page swap
for the single DMA engine — exactly the three policy aspects the paper
names: access-pattern recognition, data placement, data migration.

Policy state is the packed redirection table (``core.table``): hotness
counters ride the HOTNESS lane, the CLOCK inverse map rides the OWNER
lane, placement is the DEVICE lane — policies read named lanes, never raw
columns.

Hardware faithfulness note: policies only use O(chunk) work plus O(1)
row lookups — promotion candidates come from the *current* access stream
(what the RTL pipeline sees), and victims come from a CLOCK-style
round-robin pointer over DRAM frames (the OWNER lane inverse map), not
from a global argmin no RTL could compute in a cycle. A global-scan
variant ("hotness_global") is kept as an idealized reference policy for
design-space studies.

Policy interface::

    propose(cfg, params, table, ptr, pages, is_write, valid)
        -> (want: bool[], slow_page: int32[], fast_victim: int32[], new_ptr)

``cfg`` carries static geometry, ``params`` the traced knobs
(``hot_threshold``, ``n_fast_pages``, ...), ``table`` the packed
``int32[n_pages, ROW_W]`` metadata store. New policies register via
``@register("name")``; the emulator dispatches on the traced
``params.policy_id`` with ``jax.lax.switch`` over the registration order,
which makes the policy itself a batchable design axis (sweeps evaluate
several policies in one compiled computation).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from . import table as table_lib
from .config import FAST, SLOW

POLICIES: dict[str, Callable] = {}


def register(name: str):
    def deco(fn):
        POLICIES[name] = fn
        return fn
    return deco


def get(name: str) -> Callable:
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; have {sorted(POLICIES)}")
    return POLICIES[name]


def policy_id(name: str) -> int:
    """Index of ``name`` in registration order — the ``lax.switch`` branch
    index carried by ``RuntimeParams.policy_id``."""
    get(name)
    return list(POLICIES).index(name)



def update_hotness(p, table: jax.Array, pages: jax.Array,
                   is_write: jax.Array, valid: jax.Array,
                   do_decay: jax.Array) -> jax.Array:
    """Scatter-add chunk accesses (writes weighted) into the HOTNESS lane,
    then decay-by-shift on ``do_decay`` boundaries (hardware aging
    counters). ``p`` is an ``EmulatorConfig`` or traced ``RuntimeParams``
    (shared field names)."""
    w = 1 + (p.write_weight - 1) * is_write.astype(jnp.int32)
    w = jnp.where(valid, w, 0)
    table = table.at[pages, table_lib.HOTNESS].add(w, mode="drop")
    return jax.lax.cond(
        do_decay,
        lambda t: t.at[:, table_lib.HOTNESS].set(
            t[:, table_lib.HOTNESS] >> p.hotness_decay_shift),
        lambda t: t, table)


def _chunk_candidate(table, pages, valid):
    """Hottest slow-resident page among this chunk's accesses."""
    rows = table[pages]
    heat = jnp.where(valid & (table_lib.device(rows) == SLOW),
                     table_lib.hotness(rows), -1)
    j = jnp.argmax(heat)
    return pages[j], heat[j]


def _clock_victim(table, ptr):
    return table_lib.owner(table)[ptr]


@register("static")
def static_policy(cfg, params, table, ptr, pages, is_write, valid):
    """Placement fixed at initialization; never migrate (the baseline the
    paper's users compare their designs against)."""
    z = jnp.int32(0)
    return jnp.bool_(False), z, z, ptr


@register("hotness")
def hotness_policy(cfg, params, table, ptr, pages, is_write, valid):
    """Promote the hottest slow page seen in this chunk once it crosses
    ``hot_threshold``; victim = CLOCK pointer over DRAM frames, skipped if
    the victim is hotter than the candidate."""
    cand, heat = _chunk_candidate(table, pages, valid)
    victim = _clock_victim(table, ptr)
    want = (heat >= params.hot_threshold) & \
        (heat > table[victim, table_lib.HOTNESS])
    new_ptr = jnp.where(want, (ptr + 1) % params.n_fast_pages, ptr)
    return want, cand, victim, new_ptr


@register("write_bias")
def write_bias_policy(cfg, params, table, ptr, pages, is_write, valid):
    """Same promotion rule, but hotness accumulation weights writes by
    ``cfg.write_weight`` (configure > 1): NVM writes are the expensive,
    endurance-limited operation (paper Table I), so write-heavy pages
    should live in DRAM."""
    return hotness_policy(cfg, params, table, ptr, pages, is_write, valid)


@register("stream")
def stream_policy(cfg, params, table, ptr, pages, is_write, valid):
    """Access-pattern recognition: detect a dominant small stride in the
    chunk's page stream and *pre-promote* the stream's next page before
    demand accesses pay NVM latency (prefetch-style migration). Falls back
    to the hotness rule when no stream is detected."""
    deltas = jnp.where(valid[1:] & valid[:-1], pages[1:] - pages[:-1], 0)
    span = 4  # recognise strides in [-span, span] \ {0}
    in_range = (jnp.abs(deltas) <= span) & (deltas != 0)
    hist = jnp.zeros(2 * span + 1, jnp.int32).at[
        jnp.clip(deltas + span, 0, 2 * span)].add(
        in_range.astype(jnp.int32), mode="drop")
    stride = jnp.argmax(hist).astype(jnp.int32) - span
    strength = jnp.max(hist)
    streaming = strength > (pages.shape[0] // 4)

    last = pages[jnp.argmax(jnp.where(valid, jnp.arange(pages.shape[0]), -1))]
    target = jnp.clip(last + stride, 0, table.shape[0] - 1)
    target_is_slow = table[target, table_lib.DEVICE] == SLOW

    hw, hc, hv, _ = hotness_policy(cfg, params, table, ptr, pages, is_write,
                                   valid)
    want_stream = streaming & target_is_slow
    want = want_stream | hw
    cand = jnp.where(want_stream, target, hc)
    victim = hv
    new_ptr = jnp.where(want, (ptr + 1) % params.n_fast_pages, ptr)
    return want, cand, victim, new_ptr


@register("hotness_global")
def hotness_global_policy(cfg, params, table, ptr, pages, is_write, valid):
    """Idealized reference: global hottest-slow / coldest-fast scan each
    chunk. No RTL implements this in a cycle — kept for design-space
    comparison against the realizable policies above."""
    dev = table_lib.device(table)
    hot = table_lib.hotness(table)
    heat_all = jnp.where(dev == SLOW, hot, -1)
    cand = jnp.argmax(heat_all).astype(jnp.int32)
    heat = heat_all[cand]
    cold = jnp.where(dev == FAST, hot, jnp.int32(2 ** 30))
    victim = jnp.argmin(cold).astype(jnp.int32)
    want = (heat >= params.hot_threshold) & (heat > hot[victim])
    return want, cand, victim, ptr
