"""Core hybrid-memory emulation platform (the paper's contribution).

Public API:
    EmulatorConfig, TECHNOLOGIES, paper_platform, small_platform
    Trace, pad_trace, PolicyRegistry
    policies (register your own), counters.summary

Execution goes through the session API — ``repro.Engine`` — which owns
the compiled entry points.
"""
from .config import (EmulatorConfig, RuntimeParams, TechnologyParams,
                     TECHNOLOGIES, paper_platform, small_platform, static_key,
                     FAST, SLOW)
from .emulator import Trace, EmulatorState, pad_trace, init_state
from .faults import FaultPlan, seeded_plan, stack_plans, pad_plan
from .policies import PolicyRegistry
from .table import HybridAllocator, init_table, check_table
from . import policies, counters, dma, faults, latency, consistency, table

__all__ = [
    "EmulatorConfig", "RuntimeParams", "TechnologyParams", "TECHNOLOGIES",
    "paper_platform", "small_platform", "static_key",
    "FAST", "SLOW", "Trace", "EmulatorState", "pad_trace", "init_state",
    "FaultPlan", "seeded_plan", "stack_plans", "pad_plan",
    "PolicyRegistry", "HybridAllocator", "init_table", "check_table",
    "policies", "counters", "dma", "faults", "latency", "consistency",
    "table",
]
