"""Core hybrid-memory emulation platform (the paper's contribution).

Public API:
    EmulatorConfig, TECHNOLOGIES, paper_platform, small_platform
    Trace, emulate, emulate_channels, run_trace, pad_trace
    policies (register your own), counters.summary
"""
from .config import (EmulatorConfig, RuntimeParams, TechnologyParams,
                     TECHNOLOGIES, paper_platform, small_platform, static_key,
                     FAST, SLOW)
from .emulator import (Trace, EmulatorState, emulate, emulate_channels,
                       run_trace, pad_trace, init_state)
from .table import HybridAllocator, init_table, check_table
from . import policies, counters, dma, latency, consistency, table

__all__ = [
    "EmulatorConfig", "RuntimeParams", "TechnologyParams", "TECHNOLOGIES",
    "paper_platform", "small_platform", "static_key",
    "FAST", "SLOW", "Trace", "EmulatorState", "emulate",
    "emulate_channels", "run_trace", "pad_trace", "init_state",
    "HybridAllocator", "init_table", "check_table", "policies", "counters",
    "dma", "latency", "consistency", "table",
]
