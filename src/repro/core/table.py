"""Address-redirection table + allocator middleware.

Heterogeneity transparency (paper §III-B): the OS/application sees one flat
physical space; the HMMU translates physical page -> (device, frame). The
mapping *is* the placement policy's state and migrations rewrite it.

The paper's middleware (mem_driver.ko + modified jemalloc, §III-G) becomes
``HybridAllocator``: a host-side page allocator over the flat space that
honours placement *hints* (the paper's extended malloc API) by choosing
pages whose initial mapping lands on the preferred device. The serving
stack (repro.memtier) allocates KV-cache pages through this API.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import EmulatorConfig, FAST, SLOW


def init_table(cfg: EmulatorConfig, n_fast_pages=None
               ) -> tuple[jax.Array, jax.Array]:
    """Initial placement: first ``n_fast_pages`` of the flat space map to
    DRAM frames, the rest to NVM frames (paper's BAR window layout maps the
    two DIMMs contiguously).

    ``n_fast_pages`` may be a traced int32 (``RuntimeParams.n_fast_pages``)
    — the total space is static but the tier boundary is a runtime design
    axis. Defaults to ``cfg.n_fast_pages``.
    """
    n = cfg.n_pages
    nf = cfg.n_fast_pages if n_fast_pages is None else n_fast_pages
    ar = jnp.arange(n)
    device = jnp.where(ar < nf, FAST, SLOW).astype(jnp.int32)
    frame = jnp.where(ar < nf, ar, ar - nf).astype(jnp.int32)
    return device, frame


def check_table(cfg: EmulatorConfig, device: np.ndarray,
                frame: np.ndarray, n_fast_pages: int | None = None) -> None:
    """Invariant: the mapping is a bijection onto device frames — every
    fast frame and slow frame is owned by exactly one page. Raises on
    violation (used by tests and by the emulator's debug mode)."""
    nf = cfg.n_fast_pages if n_fast_pages is None else int(n_fast_pages)
    ns = cfg.n_pages - nf
    device = np.asarray(device)
    frame = np.asarray(frame)
    fast_frames = np.sort(frame[device == FAST])
    slow_frames = np.sort(frame[device == SLOW])
    if fast_frames.size != nf or \
            not np.array_equal(fast_frames, np.arange(nf)):
        raise AssertionError("fast-frame mapping is not a bijection")
    if slow_frames.size != ns or \
            not np.array_equal(slow_frames, np.arange(ns)):
        raise AssertionError("slow-frame mapping is not a bijection")


class HybridAllocator:
    """Host-side allocator over the flat hybrid space with placement hints.

    Mirrors the paper's driver+jemalloc middleware: allocations are ranges
    of flat pages; ``hint`` expresses device preference honoured on a
    best-effort basis (like the extended malloc API of §III-G).
    """

    def __init__(self, cfg: EmulatorConfig):
        self.cfg = cfg
        # Free pools of flat page numbers whose *initial* mapping is on the
        # given device.
        self._free = {
            FAST: list(range(cfg.n_fast_pages - 1, -1, -1)),
            SLOW: list(range(cfg.n_pages - 1, cfg.n_fast_pages - 1, -1)),
        }
        self._owned: dict[int, list[int]] = {}
        self._next_handle = 0

    def alloc(self, n_pages: int, hint: int = FAST) -> tuple[int, np.ndarray]:
        """Allocate ``n_pages`` flat pages, preferring ``hint`` device.
        Returns (handle, page_numbers)."""
        other = SLOW if hint == FAST else FAST
        take = []
        for pool in (self._free[hint], self._free[other]):
            while pool and len(take) < n_pages:
                take.append(pool.pop())
        if len(take) < n_pages:
            for p in take:  # roll back
                self._free[FAST if p < self.cfg.n_fast_pages else SLOW].append(p)
            raise MemoryError(f"out of hybrid memory ({n_pages} pages)")
        handle = self._next_handle
        self._next_handle += 1
        self._owned[handle] = take
        return handle, np.asarray(take, np.int32)

    def free(self, handle: int) -> None:
        for p in self._owned.pop(handle):
            self._free[FAST if p < self.cfg.n_fast_pages else SLOW].append(p)

    @property
    def free_pages(self) -> dict[int, int]:
        return {d: len(v) for d, v in self._free.items()}
