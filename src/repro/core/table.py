"""Packed redirection-table store + allocator middleware.

Heterogeneity transparency (paper §III-B): the OS/application sees one flat
physical space; the HMMU translates physical page -> (device, frame). The
mapping *is* the placement policy's state and migrations rewrite it.

All per-page metadata lives in ONE packed ``int32[n_pages, ROW_W]`` array
whose row layout is shared verbatim with the Pallas lookup engine
(``repro.kernels.hmmu_lookup``) — on the FPGA this is the BRAM word the
redirection table serves per cycle. Lanes (columns) of row ``i``:

    ======= ===========================================================
    lane    meaning
    ======= ===========================================================
    DEVICE  tier of page ``i`` (FAST=0 / SLOW=1)
    FRAME   frame of page ``i`` within its device
    HOTNESS aging access counter of page ``i`` (policy state)
    WEAR    writes absorbed by *slow frame* ``i`` (endurance histogram)
    OWNER   inverse map: page owning *fast frame* ``i`` (CLOCK victims)
    EPOCH   cycle at which row ``i``'s mapping last changed (0 = never)
    FLAGS   protection bitfield: PIN_FAST / PIN_SLOW / POISONED
    ======= ===========================================================

FLAGS bits (the paper's §III-G placement hints, hardened into the table):

    ``PIN_FAST``  page is nailed to the fast tier — never a migration
                  candidate nor a CLOCK victim (hinted DRAM allocations);
    ``PIN_SLOW``  page is nailed to the slow tier — never promoted
                  (bulk/streaming allocations the hint keeps out of DRAM);
    ``POISONED``  the frame under this page is dead (its WEAR crossed
                  ``endurance_budget``, or a ``FaultPlan`` death fired) —
                  accesses still complete but raise ``poison_faults`` and
                  a rescue migration to a healthy frame is pending;
    ``RETIRED``   permanent tombstone: the page is parked on a dead frame
                  to keep it out of service (always POISONED too). It is
                  never a migration candidate, CLOCK victim or rescue
                  target — the frame is permanently out of circulation.

Retirement lifecycle: a frame death stamps POISONED on the resident page
(pins force-cleared — the hardware broke the contract; serving
renegotiates) and schedules a rescue swap with a healthy donor. When the
swap commits, the rescued page clears POISONED and the donor — now
sitting on the dead frame — becomes the ``POISONED|RETIRED`` tombstone.

Pin bits are enforced twice on the hot path (the emulator's post-policy
proposal mask AND ``dma.maybe_start``), so no policy — including
user-registered ones — can migrate a pinned page; the same double
enforcement keeps poisoned pages out of policy proposals and tombstones
out of every swap, so a pinned page can never land on a poisoned frame.

DEVICE/FRAME/HOTNESS/EPOCH/FLAGS are keyed by page number; WEAR and OWNER
reuse the same rows keyed by frame number (frames < n_pages always).
Policies, the DMA engine and the counters read named lanes through the
accessors below — never raw column indices.

The paper's middleware (mem_driver.ko + modified jemalloc, §III-G) becomes
``HybridAllocator``: a host-side page allocator over the flat space that
honours placement *hints* (the paper's extended malloc API) by choosing
pages whose initial mapping lands on the preferred device. The serving
stack (repro.memtier) allocates KV-cache pages through this API.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import EmulatorConfig, FAST, SLOW

# Row layout. ``ROW_W`` is the row width the lookup kernel gathers; it must
# match ``repro.kernels.hmmu_lookup.ROW_W`` (asserted by the test suite —
# the kernel itself is layout-agnostic and reads the width off the array).
ROW_W = 8
DEVICE, FRAME, HOTNESS, WEAR, OWNER, EPOCH, FLAGS = range(7)
_PAD = 7  # spare lane keeping the row a power-of-two width

LANES = ("device", "frame", "hotness", "wear", "owner", "epoch", "flags")

# FLAGS-lane bits. PINNED is the "cannot migrate" test mask: either pin
# bit freezes the page's mapping (they differ only in which tier the page
# is nailed to, validated by check_table).
PIN_FAST = 1 << 0
PIN_SLOW = 1 << 1
POISONED = 1 << 2
RETIRED = 1 << 3
PINNED = PIN_FAST | PIN_SLOW
KNOWN_FLAGS = PIN_FAST | PIN_SLOW | POISONED | RETIRED

# Accumulator-lane saturation caps. HOTNESS and WEAR are monotone
# scatter-add counters fed every chunk; on runs long enough to matter
# (the paper's whole point) an uncapped int32 eventually wraps and
# silently corrupts the placement/retirement decision it drives. Both
# lanes saturate at this cap instead: far above any decision threshold
# (endurance budgets are < 2^27; hot_threshold is single digits) yet
# leaving > 2 bits of headroom below int32 overflow, so even a full
# chunk of duplicate weights added to a saturated lane cannot wrap.
# ``check_table`` (runtime) and ``repro.analysis.ranges`` (static)
# enforce the same invariant from these two constants.
HOTNESS_CAP = 1 << 29
WEAR_CAP = 1 << 29


class TableRows(NamedTuple):
    """Unpacked view of table rows — one array per named lane."""
    device: jax.Array
    frame: jax.Array
    hotness: jax.Array
    wear: jax.Array
    owner: jax.Array
    epoch: jax.Array
    flags: jax.Array


def device(table: jax.Array) -> jax.Array:
    """Tier of each page (FAST/SLOW). Works on [..., n, ROW_W] and on
    single rows [..., ROW_W]."""
    return table[..., DEVICE]


def frame(table: jax.Array) -> jax.Array:
    return table[..., FRAME]


def hotness(table: jax.Array) -> jax.Array:
    return table[..., HOTNESS]


def wear(table: jax.Array) -> jax.Array:
    return table[..., WEAR]


def owner(table: jax.Array) -> jax.Array:
    return table[..., OWNER]


def epoch(table: jax.Array) -> jax.Array:
    return table[..., EPOCH]


def flags(table: jax.Array) -> jax.Array:
    return table[..., FLAGS]


def is_pinned(table: jax.Array) -> jax.Array:
    """True where either pin bit is set. Works on full tables and on
    gathered rows ([..., ROW_W])."""
    return (table[..., FLAGS] & PINNED) != 0


def is_poisoned(table: jax.Array) -> jax.Array:
    return (table[..., FLAGS] & POISONED) != 0


def is_retired(table: jax.Array) -> jax.Array:
    """True where the page is a permanent tombstone on a dead frame."""
    return (table[..., FLAGS] & RETIRED) != 0


def device_at(table: jax.Array, pages) -> jax.Array:
    """DEVICE lane of ``pages`` as a single-lane gather (no full-row
    fetch) — the read the stamp/veto paths use."""
    return table[pages, DEVICE]


def hotness_at(table: jax.Array, pages) -> jax.Array:
    """HOTNESS lane of ``pages`` as a single-lane gather."""
    return table[pages, HOTNESS]


def wear_at(table: jax.Array, frames) -> jax.Array:
    """WEAR lane of ``frames`` (WEAR is keyed by slow frame) as a
    single-lane gather."""
    return table[frames, WEAR]


def flags_at(table: jax.Array, pages) -> jax.Array:
    """FLAGS lane of ``pages`` as a single-lane gather."""
    return table[pages, FLAGS]


def add_hotness(table: jax.Array, pages, w) -> jax.Array:
    """Scatter-add access weights into the HOTNESS lane (out-of-range
    pages drop — the sentinel-index convention of the boundary commit)."""
    return table.at[pages, HOTNESS].add(w, mode="drop")


def saturating_weights(targets: jax.Array, weights: jax.Array,
                       pre: jax.Array, cap: int) -> jax.Array:
    """Clip scatter-add ``weights`` so the accumulator lane at each
    target saturates at ``cap`` instead of wrapping ("fill until full").

    ``pre`` holds the pre-commit lane value gathered at ``targets``.
    Duplicate targets are exact: element ``i`` may add at most what is
    left of ``cap`` after the pre-value and every *earlier* element
    aimed at the same slot, so the scatter-add total per slot is
    ``min(sum(w), max(0, cap - pre))`` — order-independent, and the
    identity whenever the slot stays below the cap (existing golden
    digests are untouched). O(n^2) in the chunk width via a masked
    matrix, which is trivial next to the bank resolver.

    Written as explicit ``minimum(maximum(...))`` over a literal cap so
    the ``ranges`` static pass can recognise the saturation idiom in the
    jaxpr and certify the lane's int32 bound.
    """
    w = jnp.asarray(weights, jnp.int32)
    n = w.shape[0]
    i = jnp.arange(n, dtype=jnp.int32)
    same_earlier = (targets[None, :] == targets[:, None]) & (i[None, :] <
                                                             i[:, None])
    psum = jnp.sum(jnp.where(same_earlier, w[None, :], 0), axis=1)
    allow = jnp.int32(cap) - pre - psum
    return jnp.minimum(jnp.maximum(allow, 0), w)


def decay_hotness(table: jax.Array, shift) -> jax.Array:
    """The aging tick: arithmetic-shift every page's HOTNESS lane."""
    return table.at[:, HOTNESS].set(table[:, HOTNESS] >> shift)


def store_flags(table: jax.Array, idx, values) -> jax.Array:
    """Store precomputed FLAGS values at rows ``idx`` (out-of-range
    sentinel rows drop). The traced counterpart of
    :func:`set_flags`/:func:`clear_flags` for batched stamp programs that
    compute the new FLAGS words themselves."""
    return table.at[idx, FLAGS].set(values, mode="drop")


def swap_commit_lanes(k: jax.Array) -> jax.Array:
    """Lane ids of the DMA swap commit's delta pairs, by pair index
    ``k``: (DEVICE, FRAME, EPOCH, WEAR, FLAGS) — the one place outside
    this module's accessors where lane numbers route a scatter, kept
    here so ``dma.plan_commit`` stays lane-layout-agnostic. Traces
    inside the Pallas chunk-step body (pure ``jnp.where`` chain, no
    captured device constants)."""
    return jnp.where(
        k == 0, DEVICE,
        jnp.where(k == 1, FRAME,
                  jnp.where(k == 2, EPOCH,
                            jnp.where(k == 3, WEAR, FLAGS))))


def set_flags(table: jax.Array, pages, bits: int) -> jax.Array:
    """OR ``bits`` into the FLAGS lane of ``pages`` (scenario/middleware
    side — the hot path never writes FLAGS)."""
    pages = jnp.asarray(pages, jnp.int32)
    cur = table[pages, FLAGS]
    return table.at[pages, FLAGS].set(cur | jnp.int32(bits))


def clear_flags(table: jax.Array, pages, bits: int = KNOWN_FLAGS) -> jax.Array:
    """Clear ``bits`` (default: all known bits) on ``pages``."""
    pages = jnp.asarray(pages, jnp.int32)
    cur = table[pages, FLAGS]
    return table.at[pages, FLAGS].set(cur & ~jnp.int32(bits))


def pack_rows(device, frame, hotness=None, wear=None, owner=None,
              epoch=None, flags=None) -> jax.Array:
    """Pack per-lane arrays into a table. Unspecified lanes default to
    zero (the pad lane always does). Inverse of :func:`unpack`."""
    device = jnp.asarray(device, jnp.int32)
    z = jnp.zeros_like(device)
    lanes = [device, jnp.asarray(frame, jnp.int32)]
    for lane in (hotness, wear, owner, epoch, flags):
        lanes.append(z if lane is None else jnp.asarray(lane, jnp.int32))
    lanes.append(z)  # _PAD
    return jnp.stack(lanes, axis=-1)


def unpack(table: jax.Array) -> TableRows:
    """Split a packed table into named lanes (drops the pad lane)."""
    return TableRows(*(table[..., lane] for lane in range(len(LANES))))


def init_table(cfg: EmulatorConfig, n_fast_pages=None,
               pin_fast_fraction=None) -> jax.Array:
    """Initial packed table: the first ``n_fast_pages`` of the flat space
    map to DRAM frames, the rest to NVM frames (the paper's BAR window
    layout maps the two DIMMs contiguously). Fast frame ``f`` starts owned
    by page ``f``; hotness/wear/epoch start at zero.

    ``n_fast_pages`` may be a traced int32 (``RuntimeParams.n_fast_pages``)
    — the total space is static but the tier boundary is a runtime design
    axis. Defaults to ``cfg.n_fast_pages``. ``pin_fast_fraction`` (also
    traceable — ``RuntimeParams.pin_fast_fraction``) pins that share of
    the fast tier with ``PIN_FAST``, modelling §III-G-hinted allocations
    that must stay in DRAM; 0.0 leaves the FLAGS lane all-zero.
    """
    n = cfg.n_pages
    nf = cfg.n_fast_pages if n_fast_pages is None else n_fast_pages
    frac = (cfg.pin_fast_fraction if pin_fast_fraction is None
            else pin_fast_fraction)
    ar = jnp.arange(n)
    dev = jnp.where(ar < nf, FAST, SLOW).astype(jnp.int32)
    frm = jnp.where(ar < nf, ar, ar - nf).astype(jnp.int32)
    n_pin = jnp.floor(jnp.float32(frac) *
                      jnp.asarray(nf, jnp.float32)).astype(jnp.int32)
    flg = jnp.where(ar < n_pin, PIN_FAST, 0).astype(jnp.int32)
    return pack_rows(dev, frm, owner=ar.astype(jnp.int32), flags=flg)


def check_table(cfg: EmulatorConfig, table: np.ndarray,
                n_fast_pages: int | None = None) -> None:
    """Invariants of a packed table:

    * the (device, frame) mapping is a bijection onto device frames —
      every fast and slow frame is owned by exactly one page;
    * the OWNER lane is the exact inverse of the fast-tier mapping;
    * the FLAGS lane carries only known bits, never both pin bits at
      once, and every pin bit agrees with the page's DEVICE lane (a
      PIN_FAST page on the slow tier means a pinned page migrated);
    * RETIRED implies POISONED (a tombstone is always on a dead frame)
      and no page is both PINNED and POISONED (retirement force-clears
      pins, so a pinned page never sits on a poisoned frame);
    * the accumulator lanes saturate: ``0 <= HOTNESS <= HOTNESS_CAP``
      and ``0 <= WEAR <= WEAR_CAP`` — the runtime half of the contract
      the ``ranges`` static pass proves from the same two constants.

    Raises on violation (used by tests and the emulator's debug mode).
    """
    nf = cfg.n_fast_pages if n_fast_pages is None else int(n_fast_pages)
    ns = cfg.n_pages - nf
    table = np.asarray(table)
    dev = table[..., DEVICE]
    frm = table[..., FRAME]
    fast_frames = np.sort(frm[dev == FAST])
    slow_frames = np.sort(frm[dev == SLOW])
    if fast_frames.size != nf or \
            not np.array_equal(fast_frames, np.arange(nf)):
        raise AssertionError("fast-frame mapping is not a bijection")
    if slow_frames.size != ns or \
            not np.array_equal(slow_frames, np.arange(ns)):
        raise AssertionError("slow-frame mapping is not a bijection")
    own = table[..., OWNER]
    for f in range(nf):
        p = own[f]
        if not 0 <= p < cfg.n_pages or dev[p] != FAST or frm[p] != f:
            raise AssertionError(
                f"OWNER lane stale: fast frame {f} claims page {p}")
    flg = table[..., FLAGS]
    bad = np.nonzero(flg & ~KNOWN_FLAGS)[0]
    if bad.size:
        raise AssertionError(
            f"unknown FLAGS bits on page {bad[0]}: {flg[bad[0]]:#x}")
    both = np.nonzero((flg & PINNED) == PINNED)[0]
    if both.size:
        raise AssertionError(
            f"page {both[0]} pinned to both tiers ({flg[both[0]]:#x})")
    stray = np.nonzero(((flg & PIN_FAST) != 0) & (dev != FAST))[0]
    if stray.size:
        raise AssertionError(
            f"PIN_FAST page {stray[0]} migrated to the slow tier")
    stray = np.nonzero(((flg & PIN_SLOW) != 0) & (dev != SLOW))[0]
    if stray.size:
        raise AssertionError(
            f"PIN_SLOW page {stray[0]} migrated to the fast tier")
    orphan = np.nonzero(((flg & RETIRED) != 0) & ((flg & POISONED) == 0))[0]
    if orphan.size:
        raise AssertionError(
            f"RETIRED page {orphan[0]} is not POISONED ({flg[orphan[0]]:#x})")
    hot = np.nonzero(((flg & PINNED) != 0) & ((flg & POISONED) != 0))[0]
    if hot.size:
        raise AssertionError(
            f"page {hot[0]} is pinned on a poisoned frame "
            f"({flg[hot[0]]:#x})")
    for lane, cap, name in ((HOTNESS, HOTNESS_CAP, "HOTNESS"),
                            (WEAR, WEAR_CAP, "WEAR")):
        vals = table[..., lane]
        bad = np.nonzero((vals < 0) | (vals > cap))[0]
        if bad.size:
            raise AssertionError(
                f"{name} lane of row {bad[0]} outside [0, {name}_CAP]: "
                f"{vals[bad[0]]} (wrapped or unsaturated accumulator)")


class HybridAllocator:
    """Host-side allocator over the flat hybrid space with placement hints.

    Mirrors the paper's driver+jemalloc middleware: allocations are ranges
    of flat pages; ``hint`` expresses device preference honoured on a
    best-effort basis (like the extended malloc API of §III-G).
    """

    def __init__(self, cfg: EmulatorConfig):
        self.cfg = cfg
        # Free pools of flat page numbers whose *initial* mapping is on the
        # given device.
        self._free = {
            FAST: list(range(cfg.n_fast_pages - 1, -1, -1)),
            SLOW: list(range(cfg.n_pages - 1, cfg.n_fast_pages - 1, -1)),
        }
        self._owned: dict[int, list[int]] = {}
        self._pinned: dict[int, list[int]] = {}
        self._retired: set[int] = set()
        self._next_handle = 0

    def alloc(self, n_pages: int, hint: int = FAST,
              pin: bool = False) -> tuple[int, np.ndarray]:
        """Allocate ``n_pages`` flat pages, preferring ``hint`` device.
        Returns (handle, page_numbers).

        ``pin=True`` is the strong form of the paper's placement hint:
        each page is nailed to the device it actually landed on (PIN_FAST
        below the tier boundary, PIN_SLOW above — a spilled page pins
        where it spilled). Call :meth:`apply_flags` to stamp the pin bits
        of every live pinned allocation into a packed table's FLAGS lane;
        :meth:`free` releases the pins for subsequent ``apply_flags``
        calls."""
        other = SLOW if hint == FAST else FAST
        take = []
        for pool in (self._free[hint], self._free[other]):
            while pool and len(take) < n_pages:
                take.append(pool.pop())
        if len(take) < n_pages:
            for p in take:  # roll back
                self._free[FAST if p < self.cfg.n_fast_pages else SLOW].append(p)
            raise MemoryError(f"out of hybrid memory ({n_pages} pages)")
        handle = self._next_handle
        self._next_handle += 1
        self._owned[handle] = take
        if pin:
            self._pinned[handle] = take
        return handle, np.asarray(take, np.int32)

    def free(self, handle: int) -> None:
        self._pinned.pop(handle, None)
        for p in self._owned.pop(handle):
            if p in self._retired:
                continue  # dead frames never return to the free pools
            self._free[FAST if p < self.cfg.n_fast_pages else SLOW].append(p)

    def retire(self, pages) -> None:
        """Take ``pages`` permanently out of circulation (their frames
        died — emulation reported them POISONED/RETIRED). Free copies are
        removed from the pools immediately; owned copies are dropped when
        their handle is freed. Capacity degrades gracefully: subsequent
        allocations simply see smaller pools."""
        dead = {int(p) for p in np.atleast_1d(np.asarray(pages, np.int64))}
        self._retired.update(dead)
        for d in (FAST, SLOW):
            self._free[d] = [p for p in self._free[d] if p not in dead]

    @property
    def retired_pages(self) -> set[int]:
        return set(self._retired)

    def apply_flags(self, table: jax.Array) -> jax.Array:
        """Stamp the pin bits of every live pinned allocation into
        ``table``'s FLAGS lane (device chosen per page from its *initial*
        placement, which is where the page still is — pins are applied
        before emulation moves anything). Returns the updated table."""
        nf = self.cfg.n_fast_pages
        fast = [p for ps in self._pinned.values() for p in ps if p < nf]
        slow = [p for ps in self._pinned.values() for p in ps if p >= nf]
        if fast:
            table = set_flags(table, np.asarray(fast, np.int32), PIN_FAST)
        if slow:
            table = set_flags(table, np.asarray(slow, np.int32), PIN_SLOW)
        return table

    @property
    def free_pages(self) -> dict[int, int]:
        return {d: len(v) for d, v in self._free.items()}
