"""Latency model: arbitrary stall-cycle injection (paper §III-F).

The paper emulates any NVM technology by inserting stall cycles scaled from
the measured DRAM round trip. Here the same idea is analytic: every request
gets ``service = device latency + transfer + bank-queue wait + link``,
with all terms derived from the technology table (``config.TECHNOLOGIES``).

Queue contention is resolved *exactly* inside a chunk with a max-plus
associative scan: the recurrence

    done_i = max(arrival_i, done_{prev in same bank}) + service_i

is the composition of functions f(x) = max(M, x + C) with
M = arrival + service, C = service, which is associative — so a chunk of
requests resolves in O(log chunk) depth instead of sequentially, exactly
like the pipelined RTL in the FPGA resolves one request per cycle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import EmulatorConfig, RuntimeParams, SLOW


def maxplus_scan(arrival: jax.Array, service: jax.Array) -> jax.Array:
    """Resolve ``done_i = max(arrival_i, done_{i-1}) + service_i`` in parallel.

    Closed form: unrolling gives done_i = max_{j<=i}(arr_j + sum_{k=j..i}
    srv_k) = cummax(arr_j - CS_{j-1}) + CS_i with CS = cumsum(srv) — two
    *native* cumulative primitives instead of an associative_scan with a
    custom combine (a 5.5x win on the CPU backend; EXPERIMENTS.md §Perf).

    Works on int32 cycle counts. Shapes: arrival/service [..., n] scanned
    over the last axis. Elements with ``service == 0`` and
    ``arrival == INT_MIN`` are identity pass-throughs (used for bank masks).
    """
    ax = arrival.ndim - 1
    cs = jnp.cumsum(service, axis=ax)
    return jax.lax.cummax(arrival - (cs - service), axis=ax) + cs


_NEG = jnp.int32(-(2**30))


def resolve_bank_queues(arrival: jax.Array, service: jax.Array,
                        bank: jax.Array, n_banks: int,
                        bank_free: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-bank queue resolution for one chunk — dense one-hot formulation.

    arrival, service, bank: int32[chunk]; bank in [0, n_banks).
    bank_free: int32[n_banks] — next-free time of each bank at chunk start.

    Returns (done[chunk], new_bank_free[n_banks]).

    Materializes a [n_banks, chunk] lane matrix and scans every lane, so
    cost is O(n_banks * chunk). Kept as the oracle formulation;
    :func:`resolve_bank_queues_segmented` is the O(chunk log chunk)
    equivalent, selected via ``EmulatorConfig.bank_resolver``.
    """
    onehot = bank[None, :] == jnp.arange(n_banks, dtype=bank.dtype)[:, None]
    # Seed each bank's lane with its chunk-start busy time via a virtual
    # element folded into the first real arrival of the lane.
    arr = jnp.where(onehot, jnp.maximum(arrival[None, :], _NEG), _NEG)
    srv = jnp.where(onehot, service[None, :], 0)
    # Fold bank_free in: a request can't start before the bank frees up.
    arr = jnp.where(onehot, jnp.maximum(arr, bank_free[:, None]), arr)
    done_lanes = maxplus_scan(arr, srv)               # [n_banks, chunk]
    done = jnp.sum(jnp.where(onehot, done_lanes, 0), axis=0)
    new_free = done_lanes[:, -1]
    # Lanes that saw no request keep their old busy time.
    saw = jnp.any(onehot, axis=1)
    new_free = jnp.where(saw, new_free, bank_free)
    return done, new_free


def _seg_combine(a, b):
    """Segmented-cummax combine: (value, segment-start flag) pairs. A set
    flag on the right element blocks the max from crossing the segment
    boundary — the standard segmented-scan operator, associative."""
    av, ar = a
    bv, br = b
    return jnp.where(br, bv, jnp.maximum(av, bv)), ar | br


def segmented_maxplus_scan(arrival: jax.Array, service: jax.Array,
                           seg_start: jax.Array) -> jax.Array:
    """:func:`maxplus_scan` with the recurrence reset wherever
    ``seg_start`` is True — many independent queues laid out contiguously
    in one array, resolved by a single scan.

    Same closed form as the unsegmented scan: done_i = max_{j<=i, j in
    seg(i)}(arr_j - CS_{j-1}) + CS_i. The *global* cumsum CS telescopes
    correctly because j and i share a segment, so only the running max
    needs segmentation (an associative_scan carrying a reset flag).
    Requires ``service >= 0``. Exact on int32.
    """
    cs = jnp.cumsum(service, axis=-1)
    m = arrival - (cs - service)
    v, _ = jax.lax.associative_scan(_seg_combine, (m, seg_start), axis=-1)
    return v + cs


def resolve_bank_queues_segmented(arrival: jax.Array, service: jax.Array,
                                  bank: jax.Array, n_banks: int,
                                  bank_free: jax.Array
                                  ) -> tuple[jax.Array, jax.Array]:
    """Per-bank queue resolution — sort-based segmented formulation.

    Bitwise-identical to :func:`resolve_bank_queues` (property-tested) but
    O(chunk log chunk) independent of ``n_banks``: stable-sort requests by
    bank so each bank's queue is one contiguous segment, fold the bank's
    chunk-start busy time into its segment head, run ONE segmented
    max-plus scan, and scatter results back to request order. New
    ``bank_free`` values are the segment tails — done times are monotone
    within a queue (service >= 0), so a scatter-max reads them off while
    leaving request-free banks untouched.
    """
    order = jnp.argsort(bank, stable=True)
    arr_s = jnp.maximum(arrival, _NEG)[order]
    srv_s = service[order]
    bank_s = bank[order]
    head = jnp.concatenate(
        [jnp.ones((1,), bool), bank_s[1:] != bank_s[:-1]])
    # Seeding only the segment head with bank_free is enough: done times
    # never drop below the seed afterwards (service >= 0), exactly as if
    # every element were seeded (the dense path's formulation).
    arr_s = jnp.where(head, jnp.maximum(arr_s, bank_free[bank_s]), arr_s)
    done_s = segmented_maxplus_scan(arr_s, srv_s, head)
    done = jnp.zeros_like(done_s).at[order].set(done_s)
    new_free = bank_free.at[bank_s].max(done_s)
    return done, new_free


def pick_bank_resolver(cfg: EmulatorConfig) -> str:
    """Resolve ``cfg.bank_resolver`` ("auto" uses geometry: the dense
    one-hot path wins for a handful of lanes, the segmented sort path wins
    from ~32 lanes up — measured in benchmarks/bench_chunk_step.py)."""
    if cfg.bank_resolver != "auto":
        if cfg.bank_resolver not in ("dense", "segmented"):
            raise ValueError(
                f"unknown bank_resolver {cfg.bank_resolver!r}; expected "
                "'auto', 'dense' or 'segmented'")
        return cfg.bank_resolver
    return "segmented" if 2 * cfg.n_banks >= 32 else "dense"


def device_service_cycles(p: EmulatorConfig | RuntimeParams, device: jax.Array,
                          is_write: jax.Array, size: jax.Array) -> jax.Array:
    """Media access time (latency + transfer) per request, int32 cycles.

    ``p`` is a traced ``RuntimeParams`` on the hot path; a plain
    ``EmulatorConfig`` is accepted for host-side/diagnostic use.
    """
    if isinstance(p, EmulatorConfig):
        p = RuntimeParams.from_config(p)
    lat_fast = jnp.where(is_write, p.fast_write_lat, p.fast_read_lat)
    lat_slow = jnp.where(is_write, p.slow_write_lat, p.slow_read_lat)
    xfer_fast = jnp.ceil(size / p.fast_bytes_per_cycle).astype(jnp.int32)
    xfer_slow = jnp.ceil(size / p.slow_bytes_per_cycle).astype(jnp.int32)
    slow = device == SLOW
    return jnp.where(slow, lat_slow + xfer_slow, lat_fast + xfer_fast)


def link_service_cycles(p: EmulatorConfig | RuntimeParams,
                        size: jax.Array) -> jax.Array:
    """Serialization time on the host<->HMMU link (PCIe analogue).
    ``p`` may be an ``EmulatorConfig`` or ``RuntimeParams`` (shared field)."""
    return jnp.ceil(size / p.link_bytes_per_cycle).astype(jnp.int32)
