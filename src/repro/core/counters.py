"""Performance counters (paper §II-B, Fig 8).

The FPGA platform's key observability feature: users drop in counters of
their choice. We carry a counter pytree through the emulation scan and
update it per chunk — read/write transactions and bytes per device (the
paper's Fig 8 data), migration counts, reorder-hold events, latency sums,
and the dynamic-power estimate the paper derives from transaction counts.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import SLOW


class Counters(NamedTuple):
    reads_fast: jax.Array      # int32 counts
    writes_fast: jax.Array
    reads_slow: jax.Array
    writes_slow: jax.Array
    bytes_read_fast: jax.Array   # float32 (bytes overflow int32)
    bytes_write_fast: jax.Array
    bytes_read_slow: jax.Array
    bytes_write_slow: jax.Array
    sum_read_latency: jax.Array  # float32, cycles summed over read requests
    n_reads: jax.Array           # int32
    max_latency: jax.Array       # int32
    reorder_held: jax.Array      # int32 — responses delayed by tag matching
    energy_pj: jax.Array         # float32 — dynamic energy estimate
    poison_faults: jax.Array     # int32 — accesses to POISONED pages
    #   (dead frames, table FLAGS lane): the access completes — the
    #   emulated device returns corrupt data rather than stalling — but
    #   the platform surfaces the fault the way the paper's counters
    #   surface traffic. With retirement enabled the resident page is
    #   rescued to a healthy frame, so a nonzero count here measures the
    #   rescue-latency window (plus any tombstone touches).
    frames_retired: jax.Array    # int32 — frames taken out of service
    #   (endurance_budget crossings + FaultPlan deaths that fired)
    transient_faults: jax.Array  # int32 — FaultPlan transient injections

    @staticmethod
    def zeros() -> "Counters":
        i = jnp.int32(0)
        f = jnp.float32(0.0)
        return Counters(i, i, i, i, f, f, f, f, f, i, i, i, f, i, i, i)


def update(p, c: Counters, *, device: jax.Array,
           is_write: jax.Array, size: jax.Array, valid: jax.Array,
           latency: jax.Array, held: jax.Array,
           poisoned: jax.Array | None = None,
           retired: jax.Array | None = None,
           injected: jax.Array | None = None) -> Counters:
    """Accumulate one chunk. All request fields are int32[chunk]. ``p`` is
    an ``EmulatorConfig`` or traced ``RuntimeParams`` (shared power
    coefficients). ``poisoned`` is a bool[chunk] mask of requests that
    touched a POISONED page (already masked by validity); ``retired`` an
    int32 count of frames retired at this boundary; ``injected`` a
    bool[chunk] mask of transient fault injections; None counts none."""
    v = valid
    w = is_write & v
    r = (~is_write) & v
    slow = device == SLOW
    fsize = size.astype(jnp.float32)

    def cnt(mask):
        return jnp.sum(mask).astype(jnp.int32)

    def byt(mask):
        return jnp.sum(jnp.where(mask, fsize, 0.0))

    bits_fast = 8.0 * (byt(r & ~slow) + byt(w & ~slow))
    energy = (bits_fast * p.power_pj_per_bit_fast
              + 8.0 * byt(r & slow) * p.power_pj_per_bit_slow_read
              + 8.0 * byt(w & slow) * p.power_pj_per_bit_slow_write)

    read_lat = jnp.where(r, latency, 0)
    return Counters(
        reads_fast=c.reads_fast + cnt(r & ~slow),
        writes_fast=c.writes_fast + cnt(w & ~slow),
        reads_slow=c.reads_slow + cnt(r & slow),
        writes_slow=c.writes_slow + cnt(w & slow),
        bytes_read_fast=c.bytes_read_fast + byt(r & ~slow),
        bytes_write_fast=c.bytes_write_fast + byt(w & ~slow),
        bytes_read_slow=c.bytes_read_slow + byt(r & slow),
        bytes_write_slow=c.bytes_write_slow + byt(w & slow),
        sum_read_latency=c.sum_read_latency + jnp.sum(read_lat.astype(jnp.float32)),
        n_reads=c.n_reads + cnt(r),
        max_latency=jnp.maximum(c.max_latency, jnp.max(jnp.where(v, latency, 0))),
        reorder_held=c.reorder_held + held,
        energy_pj=c.energy_pj + energy,
        poison_faults=c.poison_faults +
        (jnp.int32(0) if poisoned is None else cnt(poisoned)),
        frames_retired=c.frames_retired +
        (jnp.int32(0) if retired is None else jnp.int32(retired)),
        transient_faults=c.transient_faults +
        (jnp.int32(0) if injected is None else cnt(injected)),
    )


def summary(c: Counters) -> dict:
    """Host-side readable summary (concrete values)."""
    g = lambda x: x.item() if hasattr(x, "item") else x
    n_reads = max(1, g(c.n_reads))
    return {
        "reads_fast": g(c.reads_fast), "writes_fast": g(c.writes_fast),
        "reads_slow": g(c.reads_slow), "writes_slow": g(c.writes_slow),
        "GB_read": (g(c.bytes_read_fast) + g(c.bytes_read_slow)) / 1e9,
        "GB_written": (g(c.bytes_write_fast) + g(c.bytes_write_slow)) / 1e9,
        "mean_read_latency_cyc": g(c.sum_read_latency) / n_reads,
        "max_latency_cyc": g(c.max_latency),
        "reorder_held": g(c.reorder_held),
        "energy_mJ": g(c.energy_pj) / 1e9,
        "poison_faults": g(c.poison_faults),
        "frames_retired": g(c.frames_retired),
        "transient_faults": g(c.transient_faults),
    }
