"""DMA page-migration engine with swap-progress conflict redirection
(paper §III-D).

The engine swaps two pages (one per device) in 512 B sub-blocks through an
internal staging buffer, tracking exactly which sub-blocks have already
been exchanged. A request that hits a page mid-swap is redirected by the
progress indicator: if its sub-block has already been transferred, the
request goes to the *destination* location; otherwise to the source. This
is the logic the paper reports spending "considerable time to design and
verify" — reproduced here and verified by property tests
(tests/test_dma.py).

One swap is in flight at a time (a single engine, as in the paper);
additional migration requests wait for the engine.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import table as table_lib
from .config import SLOW, EmulatorConfig, RuntimeParams


class DMAState(NamedTuple):
    active: jax.Array    # int32 {0,1}
    page_a: jax.Array    # int32 — page being demoted/first swap member
    page_b: jax.Array    # int32 — page being promoted/second swap member
    start: jax.Array     # int32 cycle at which the swap began
    swaps_done: jax.Array  # int32 counter — completed migrations

    @staticmethod
    def idle() -> "DMAState":
        z = jnp.int32(0)
        return DMAState(active=z, page_a=jnp.int32(-1), page_b=jnp.int32(-1),
                        start=z, swaps_done=z)


def exchange_cycles_per_subblock(cfg: EmulatorConfig,
                                 params: RuntimeParams | None = None):
    """Cycles to exchange one sub-block (A->buffer, B->A, buffer->B).
    Returns a python int from ``cfg`` alone (host-side simulators), or a
    traced int32 when ``params`` carries the DMA bandwidth."""
    eff = cfg if params is None else params
    return 3 * eff.dma_cycles_per_subblock


def swap_duration(cfg: EmulatorConfig, params: RuntimeParams | None = None):
    return cfg.subblocks_per_page * exchange_cycles_per_subblock(cfg, params)


def progress_subblocks(cfg: EmulatorConfig, dma: DMAState, t: jax.Array,
                       params: RuntimeParams | None = None) -> jax.Array:
    """Number of fully exchanged sub-blocks at time ``t`` (int32, clamped)."""
    raw = (t - dma.start) // exchange_cycles_per_subblock(cfg, params)
    raw = jnp.where(dma.active == 1, raw, 0)
    return jnp.clip(raw, 0, cfg.subblocks_per_page)


def redirect(cfg: EmulatorConfig, dma: DMAState,
             page: jax.Array, offset: jax.Array, t: jax.Array,
             device: jax.Array, frame: jax.Array,
             row_a: jax.Array, row_b: jax.Array,
             params: RuntimeParams | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """Apply swap-progress redirection to a chunk of requests.

    page/offset/t/device/frame: int32[chunk] — request fields and the
    *pre-swap* table lookup results. ``row_a``/``row_b`` are the packed
    table rows (pre-swap) of the in-flight swap pair.

    Returns (device, frame) actually accessed by each request.
    """
    prog = progress_subblocks(cfg, dma, t, params)    # int32[chunk]
    blk = offset // cfg.subblock
    transferred = blk < prog                           # sub-block already moved

    hit_a = (dma.active == 1) & (page == dma.page_a)
    hit_b = (dma.active == 1) & (page == dma.page_b)

    # Transferred sub-blocks live at the counterpart's (pre-swap) location.
    device = jnp.where(hit_a & transferred, table_lib.device(row_b), device)
    frame = jnp.where(hit_a & transferred, table_lib.frame(row_b), frame)
    device = jnp.where(hit_b & transferred, table_lib.device(row_a), device)
    frame = jnp.where(hit_b & transferred, table_lib.frame(row_a), frame)
    return device, frame


class SwapCommit(NamedTuple):
    """A swap commit expressed as pure data: the new engine state plus the
    table writes as (row, lane, int32-delta) scatter-add triples computed
    from *prefetched* pre-chunk rows. The emulator folds these triples into
    its single combined boundary scatter (one in-place update per chunk);
    :func:`maybe_complete` applies them directly for standalone callers.
    Every write is an exact int32 delta against the prefetched value, so
    add-commit is bitwise identical to the historical set-commit."""
    dma: DMAState
    done: jax.Array    # bool — swap finished this boundary
    rows: jax.Array    # int32[10] target rows (idle/no-op entries hit row 0
    #   with delta 0 — the guard-index convention of the old set path)
    lanes: jax.Array   # int32[10] target lanes, aligned with ``rows``
    delta: jax.Array   # int32[10] value to add at (row, lane)
    tombstone: jax.Array  # int32 — page parked on a dead frame by this
    #   commit (POISONED|RETIRED stamped via the FLAGS deltas), else -1
    rescued: jax.Array    # int32 — page whose pending rescue this commit
    #   completed (POISONED cleared), else -1


def plan_commit(cfg: EmulatorConfig, dma: DMAState, now: jax.Array,
                row_a: jax.Array, row_b: jax.Array,
                params: RuntimeParams | None = None,
                rescue_page=None) -> SwapCommit:
    """Plan the chunk-boundary swap commit from prefetched rows.

    ``row_a``/``row_b`` are the packed *pre-chunk* table rows of the swap
    pair (guard-indexed: row 0 when idle) — the same rows stage 2's fused
    gather already fetched, so the commit needs NO table reads of its own.
    Valid because nothing earlier in a chunk writes the DEVICE/FRAME/EPOCH
    lanes these deltas are computed against (the read-before-write chunk
    schedule; see kernels.chunk_step).

    If the in-flight swap has finished by ``now``: exchange the two pages'
    DEVICE and FRAME lanes, stamp their EPOCH lane with the commit cycle,
    and charge the migration's full-page write to the WEAR lane of
    whichever slow frame received data (endurance accounting for the swap
    traffic itself, in line-sized units comparable to demand writes).

    Poison travel (retirement rescues): POISONED marks "the frame under
    this page is dead", so when a swap involving a poisoned member
    commits, the poison stays with the *frame*: the counterpart page —
    which now sits on the dead frame — becomes a ``POISONED|RETIRED``
    tombstone (pins force-cleared; the serving layer renegotiates), and
    the formerly poisoned member comes out clean on the healthy frame.
    This one rule covers both scheduled rescue migrations and the
    adversarial corner where a frame dies while its page is already a
    swap endpoint.

    ``rescue_page`` is the emulator's rescue register
    (``EmulatorState.rescue_page``): poison only travels for the page the
    retirement subsystem actually marked dying (the register holds at
    most one). POISONED set by anything else — tests poison pages purely
    for the observability counter — commits exactly as before, and with
    ``rescue_page`` absent (None / -1, the default and every legacy
    caller) every FLAGS delta is zero, so the commit stays
    bitwise-identical to the pre-retirement engine.
    """
    done = (dma.active == 1) & (now >= dma.start + swap_duration(cfg, params))

    a, b = dma.page_a, dma.page_b
    # `a`/`b` are -1 when idle; guard indices target row 0 with delta 0.
    ia = jnp.where(a >= 0, a, 0)
    ib = jnp.where(b >= 0, b, 0)
    da, db = table_lib.device(row_a), table_lib.device(row_b)
    fa, fb = table_lib.frame(row_a), table_lib.frame(row_b)
    ea, eb = table_lib.epoch(row_a), table_lib.epoch(row_b)
    commit_a = done & (a >= 0)
    commit_b = done & (b >= 0)

    # WEAR charge: the DMA wrote one whole page into each destination; only
    # the slow-tier destination has limited endurance. Post-commit, member
    # `a` sits on device `db` at frame `fb` (and vice versa) — charge the
    # member that landed on SLOW.
    charge = cfg.page_size // cfg.line_size
    chg_a = commit_a & (db == SLOW)   # a demoted into slow frame fb
    chg_b = commit_b & (da == SLOW)   # b demoted into slow frame fa

    # Constants stay Python literals (not eager jnp arrays): this function
    # also traces inside the one-kernel Pallas body, which rejects
    # captured device constants. The DEVICE/DEVICE/FRAME/FRAME/EPOCH/
    # EPOCH/WEAR/WEAR lane vector is built from an iota for the same
    # reason.
    # Poison travel (see docstring): new FLAGS as pure int32 deltas against
    # the prefetched pre-chunk values. Bit constants are Python literals
    # for the same Pallas reason as above.
    rp = -1 if rescue_page is None else rescue_page
    fla, flb = table_lib.flags(row_a), table_lib.flags(row_b)
    dead_a = ((fla & table_lib.POISONED) != 0) & (a == rp) & (a >= 0)
    dead_b = ((flb & table_lib.POISONED) != 0) & (b == rp) & (b >= 0)
    dead_bits = table_lib.POISONED | table_lib.RETIRED
    new_fla = jnp.where(dead_b, (fla | dead_bits) & ~table_lib.PINNED,
                        jnp.where(dead_a, fla & ~dead_bits, fla))
    new_flb = jnp.where(dead_a, (flb | dead_bits) & ~table_lib.PINNED,
                        jnp.where(dead_b, flb & ~dead_bits, flb))

    rows = jnp.stack([ia, ib, ia, ib, ia, ib,
                      jnp.where(chg_a, fb, 0), jnp.where(chg_b, fa, 0),
                      ia, ib])
    k = jnp.repeat(jnp.arange(5, dtype=jnp.int32), 2)
    lanes = table_lib.swap_commit_lanes(k)
    delta = jnp.stack([jnp.where(commit_a, db - da, 0),
                       jnp.where(commit_b, da - db, 0),
                       jnp.where(commit_a, fb - fa, 0),
                       jnp.where(commit_b, fa - fb, 0),
                       jnp.where(commit_a, now - ea, 0),
                       jnp.where(commit_b, now - eb, 0),
                       jnp.where(chg_a, charge, 0),
                       jnp.where(chg_b, charge, 0),
                       jnp.where(commit_a, new_fla - fla, 0),
                       jnp.where(commit_b, new_flb - flb, 0)])

    any_dead = (commit_a & dead_a) | (commit_b & dead_b)
    tombstone = jnp.where(any_dead, jnp.where(dead_a, b, a), -1)
    rescued = jnp.where(any_dead, jnp.where(dead_a, a, b), -1)

    new = DMAState(
        active=jnp.where(done, 0, dma.active).astype(jnp.int32),
        page_a=jnp.where(done, -1, dma.page_a).astype(jnp.int32),
        page_b=jnp.where(done, -1, dma.page_b).astype(jnp.int32),
        start=dma.start,
        swaps_done=dma.swaps_done + done.astype(jnp.int32),
    )
    return SwapCommit(dma=new, done=done, rows=rows, lanes=lanes,
                      delta=delta, tombstone=jnp.int32(tombstone),
                      rescued=jnp.int32(rescued))


def maybe_complete(cfg: EmulatorConfig, dma: DMAState, now: jax.Array,
                   table: jax.Array, params: RuntimeParams | None = None,
                   rescue_page=None
                   ) -> tuple["DMAState", jax.Array, jax.Array]:
    """At a chunk boundary: commit the in-flight swap if it has finished
    by ``now`` (see :func:`plan_commit` for the semantics). Standalone
    entry point over :func:`plan_commit` that gathers the swap pair's rows
    itself and applies the planned deltas to ``table``.
    Returns (state, table, done_flag)."""
    ia = jnp.maximum(dma.page_a, 0)
    ib = jnp.maximum(dma.page_b, 0)
    plan = plan_commit(cfg, dma, now, table[ia], table[ib], params,
                       rescue_page)
    # WEAR deltas saturate at WEAR_CAP like the chunk-boundary commit
    # (at most one WEAR charge per commit — a swap always pairs FAST with
    # SLOW — so a plain min against the headroom is exact).
    pre = table[plan.rows, plan.lanes]
    delta = jnp.where(
        plan.lanes == table_lib.WEAR,
        jnp.minimum(plan.delta,
                    jnp.maximum(jnp.int32(table_lib.WEAR_CAP) - pre, 0)),
        plan.delta)
    table = table.at[plan.rows, plan.lanes].add(delta)
    return plan.dma, table, plan.done


def maybe_start(dma: DMAState, want: jax.Array, page_a: jax.Array,
                page_b: jax.Array, now: jax.Array,
                table: jax.Array | None = None
                ) -> tuple[DMAState, jax.Array]:
    """Start a new swap if the engine is idle, the policy wants one, and
    neither swap member is pinned or a retirement tombstone (when
    ``table`` is given, its FLAGS lane is the engine's own guard —
    defense in depth below the emulator's post-policy mask, so
    user-registered policies cannot migrate pinned pages or exhume dead
    frames either; a merely POISONED member is allowed — that is how
    rescue migrations move a page off its dead frame). Returns
    ``(state, started)``; callers thread ``started`` back into the CLOCK
    pointer commit, so a dropped proposal (engine busy, pinned member,
    re-masked want) never advances the pointer past an unconsumed victim
    frame."""
    if table is not None:
        veto_bits = table_lib.PINNED | table_lib.RETIRED
        vetoed = ((table_lib.flags_at(table, page_a) |
                   table_lib.flags_at(table, page_b)) & veto_bits) != 0
        want = want & ~vetoed
    start_it = (dma.active == 0) & want
    return DMAState(
        active=jnp.where(start_it, 1, dma.active).astype(jnp.int32),
        page_a=jnp.where(start_it, page_a, dma.page_a).astype(jnp.int32),
        page_b=jnp.where(start_it, page_b, dma.page_b).astype(jnp.int32),
        start=jnp.where(start_it, now, dma.start).astype(jnp.int32),
        swaps_done=dma.swaps_done,
    ), start_it
