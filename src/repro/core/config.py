"""Configuration for the hybrid-memory emulation platform.

All times are integer *cycles* of the emulated HMMU clock (1 cycle == 1 ns
at the paper's 1 GHz fabric reference), mirroring the paper's stall-cycle
latency-injection mechanism (paper §III-F): technologies are emulated by
scaling cycle counts from the DRAM round trip, not by modelling devices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# Device ids used throughout the platform.
FAST = 0  # "DRAM"  — the fast tier
SLOW = 1  # "NVM"   — the slow tier (emulated technology)


@dataclasses.dataclass(frozen=True)
class TechnologyParams:
    """Per-technology access characteristics (paper Table I).

    read/write latencies in cycles (== ns); bandwidth in bytes/cycle
    (== GB/s at 1 GHz).
    """

    name: str
    read_lat: int
    write_lat: int
    bytes_per_cycle: float
    # Write endurance (cycles of the cell, not clock cycles) — tracked by a
    # counter so wear policies can be studied; no behavioural effect here.
    endurance_log10: float = 16.0


@dataclasses.dataclass(frozen=True)
class EmulatorConfig:
    """Static configuration of the emulation platform (paper Table II)."""

    # --- address space geometry -------------------------------------------------
    page_size: int = 4096           # bytes per page (migration granularity)
    subblock: int = 512             # DMA transfer sub-block (paper §III-D)
    n_fast_pages: int = 32768       # 128 MB DRAM tier  (paper Table II)
    n_slow_pages: int = 262144      # 1 GB NVM tier     (paper Table II)
    line_size: int = 64             # request granularity after cache filtering

    # --- device timing ------------------------------------------------------------
    fast: TechnologyParams = dataclasses.field(
        default_factory=lambda: TECHNOLOGIES["dram"])
    slow: TechnologyParams = dataclasses.field(
        default_factory=lambda: TECHNOLOGIES["3dxpoint"])
    n_banks: int = 16               # banks per device (queue contention model)

    # --- interconnect ("PCIe" in the paper's platform) ----------------------------
    link_lat: int = 600             # per-request link round-trip overhead, cycles.
    #   The paper identifies PCIe latency as the dominant slowdown term for
    #   request-heavy workloads (§IV-B); 600 ns ≈ PCIe Gen3 round trip.
    link_bytes_per_cycle: float = 8.0   # PCIe Gen3 x8 ≈ 8 GB/s

    # --- host issue model ---------------------------------------------------------
    issue_gap: int = 4              # cycles between consecutive requests leaving
    #   the host cache hierarchy (open-loop arrival); chunk boundaries are
    #   closed-loop: the next chunk starts no earlier than the last in-order
    #   return of the previous chunk (host blocks on outstanding reads).
    max_inflight: int = 64          # host MSHR-like cap within a chunk

    # --- DMA engine (paper §III-D) -------------------------------------------------
    dma_bytes_per_cycle: float = 16.0  # dedicated migration engine bandwidth
    dma_buffer_bytes: int = 8192       # internal staging buffer (2 pages)

    # --- emulation pipeline -----------------------------------------------------
    chunk: int = 256                # requests per pipeline chunk (policy-commit
    #   granularity; chunk=1 reproduces a fully sequential model exactly)

    # --- policy -------------------------------------------------------------------
    policy: str = "hotness"         # one of core.policies.POLICIES
    hot_threshold: int = 8          # accesses before a slow page is promoted
    hotness_decay_shift: int = 1    # hotness >>= shift at each decay boundary
    decay_every: int = 16           # decay every N chunks (hardware aging tick)
    write_weight: int = 1           # extra hotness weight for writes ("write_bias")

    # --- misc ----------------------------------------------------------------------
    power_pj_per_bit_fast: float = 1.2   # dynamic-power estimate coefficients
    power_pj_per_bit_slow_read: float = 2.0
    power_pj_per_bit_slow_write: float = 12.0

    @property
    def n_pages(self) -> int:
        return self.n_fast_pages + self.n_slow_pages

    @property
    def subblocks_per_page(self) -> int:
        return self.page_size // self.subblock

    @property
    def dma_cycles_per_subblock(self) -> int:
        return max(1, round(self.subblock / self.dma_bytes_per_cycle))

    def with_(self, **kw) -> "EmulatorConfig":
        return dataclasses.replace(self, **kw)


# Paper Table I, converted to cycles (ns) and bytes/cycle. Bandwidths are
# platform-level defaults (a DDR4 DIMM, Optane-class media, ...), since
# Table I only gives latencies; all are overridable per experiment.
TECHNOLOGIES: dict[str, TechnologyParams] = {
    "dram":     TechnologyParams("dram", read_lat=50, write_lat=50,
                                 bytes_per_cycle=19.2, endurance_log10=16),
    "3dxpoint": TechnologyParams("3dxpoint", read_lat=100, write_lat=275,
                                 bytes_per_cycle=2.4, endurance_log10=9),
    "stt-ram":  TechnologyParams("stt-ram", read_lat=20, write_lat=20,
                                 bytes_per_cycle=12.8, endurance_log10=16),
    "mram":     TechnologyParams("mram", read_lat=20, write_lat=20,
                                 bytes_per_cycle=12.8, endurance_log10=15),
    "flash":    TechnologyParams("flash", read_lat=100_000, write_lat=100_000,
                                 bytes_per_cycle=0.5, endurance_log10=4),
    # "hdd" from Table I is out of scope for a memory bus (5 ms) but kept for
    # completeness of the technology table.
    "hdd":      TechnologyParams("hdd", read_lat=5_000_000, write_lat=5_000_000,
                                 bytes_per_cycle=0.15, endurance_log10=15),
}


def paper_platform() -> EmulatorConfig:
    """The exact platform of paper Table II: 128 MB DRAM + 1 GB emulated
    3D XPoint behind a PCIe Gen3 link."""
    return EmulatorConfig()


def small_platform(**kw) -> EmulatorConfig:
    """A reduced platform for tests: tiny page counts, small chunks."""
    base = dict(n_fast_pages=8, n_slow_pages=56, chunk=16, hot_threshold=3)
    base.update(kw)
    return EmulatorConfig(**base)
