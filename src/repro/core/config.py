"""Configuration for the hybrid-memory emulation platform.

All times are integer *cycles* of the emulated HMMU clock (1 cycle == 1 ns
at the paper's 1 GHz fabric reference), mirroring the paper's stall-cycle
latency-injection mechanism (paper §III-F): technologies are emulated by
scaling cycle counts from the DRAM round trip, not by modelling devices.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Device ids used throughout the platform.
FAST = 0  # "DRAM"  — the fast tier
SLOW = 1  # "NVM"   — the slow tier (emulated technology)


@dataclasses.dataclass(frozen=True)
class TechnologyParams:
    """Per-technology access characteristics (paper Table I).

    read/write latencies in cycles (== ns); bandwidth in bytes/cycle
    (== GB/s at 1 GHz).
    """

    name: str
    read_lat: int
    write_lat: int
    bytes_per_cycle: float
    # Write endurance (cycles of the cell, not clock cycles) — tracked by a
    # counter so wear policies can be studied; no behavioural effect here.
    endurance_log10: float = 16.0


@dataclasses.dataclass(frozen=True)
class EmulatorConfig:
    """Static configuration of the emulation platform (paper Table II)."""

    # --- address space geometry -------------------------------------------------
    page_size: int = 4096           # bytes per page (migration granularity)
    subblock: int = 512             # DMA transfer sub-block (paper §III-D)
    n_fast_pages: int = 32768       # 128 MB DRAM tier  (paper Table II)
    n_slow_pages: int = 262144      # 1 GB NVM tier     (paper Table II)
    line_size: int = 64             # request granularity after cache filtering

    # --- device timing ------------------------------------------------------------
    fast: TechnologyParams = dataclasses.field(
        default_factory=lambda: TECHNOLOGIES["dram"])
    slow: TechnologyParams = dataclasses.field(
        default_factory=lambda: TECHNOLOGIES["3dxpoint"])
    n_banks: int = 16               # banks per device (queue contention model)

    # --- interconnect ("PCIe" in the paper's platform) ----------------------------
    link_lat: int = 600             # per-request link round-trip overhead, cycles.
    #   The paper identifies PCIe latency as the dominant slowdown term for
    #   request-heavy workloads (§IV-B); 600 ns ≈ PCIe Gen3 round trip.
    link_bytes_per_cycle: float = 8.0   # PCIe Gen3 x8 ≈ 8 GB/s

    # --- host issue model ---------------------------------------------------------
    issue_gap: int = 4              # cycles between consecutive requests leaving
    #   the host cache hierarchy (open-loop arrival); chunk boundaries are
    #   closed-loop: the next chunk starts no earlier than the last in-order
    #   return of the previous chunk (host blocks on outstanding reads).
    max_inflight: int = 64          # host MSHR-like cap within a chunk

    # --- DMA engine (paper §III-D) -------------------------------------------------
    dma_bytes_per_cycle: float = 16.0  # dedicated migration engine bandwidth
    dma_buffer_bytes: int = 8192       # internal staging buffer (2 pages)

    # --- emulation pipeline -----------------------------------------------------
    chunk: int = 256                # requests per pipeline chunk (policy-commit
    #   granularity; chunk=1 reproduces a fully sequential model exactly)
    bank_resolver: str = "auto"     # bank-queue resolution algorithm:
    #   "dense"     — one-hot [2*n_banks, chunk] lane matrix, O(n_banks*chunk)
    #                 (the original formulation; kept as the oracle)
    #   "segmented" — stable-sort by bank + segmented max-plus scan,
    #                 O(chunk log chunk) independent of n_banks
    #   "auto"      — pick by geometry (latency.pick_bank_resolver)
    #   Both are bitwise-identical (tests/test_latency_consistency.py).
    fuse_swap_gather: bool = True   # fetch the DMA swap pair's table rows in
    #   the same lookup-kernel launch as the chunk's pages (chunk+2 rows)
    #   instead of two separate dynamic-slice gathers
    scan_unroll: int = 1            # unroll factor of the chunk lax.scan
    chunk_step_kernel: str = "auto"  # one-kernel Pallas chunk step:
    #   "on"   — run the whole per-chunk step (gather, redirect, bank
    #            resolve, in-order return, commit, policy proposal) as ONE
    #            pallas_call with the packed table staged through VMEM
    #            (interpret mode off-TPU, so tests can force it anywhere)
    #   "off"  — the composable jnp scan path (bitwise identical)
    #   "auto" — kernel when the Pallas dispatch says so (TPU, or
    #            REPRO_FORCE_PALLAS=1) and the table fits the VMEM budget
    #   Resolution in kernels.chunk_step.use_chunk_step_kernel.

    # --- policy -------------------------------------------------------------------
    policy: str = "hotness"         # one of core.policies.POLICIES
    hot_threshold: int = 8          # accesses before a slow page is promoted
    hotness_decay_shift: int = 1    # hotness >>= shift at each decay boundary
    decay_every: int = 16           # decay every N chunks (hardware aging tick)
    write_weight: int = 1           # extra hotness weight for writes — applied
    #   ONLY by the "write_bias" policy (policy-scoped; other policies weight
    #   reads and writes equally so a policy-axis sweep actually compares)
    wear_slack: int = 64            # "wear_level" destination tolerance: slow
    #   frames worn more than (chunk minimum + slack) writes are skipped as
    #   demotion destinations (one full-page migration = page_size/line_size
    #   = 64 line-writes with the default geometry)
    pin_fast_fraction: float = 0.0  # fraction of the fast tier pinned
    #   (FLAGS |= PIN_FAST) at init — pages the paper's §III-G malloc hints
    #   nail to DRAM; pinned frames are never CLOCK victims
    endurance_budget: int = 0       # frame retirement threshold in WEAR-lane
    #   line-writes: when a slow frame's WEAR crosses the budget at a chunk
    #   boundary, the frame is retired — its resident page is POISONED and a
    #   rescue migration remaps it to a healthy frame (core.faults has the
    #   fault-injection companion). <= 0 disables retirement entirely (the
    #   default: runs are bitwise-identical to the pre-retirement emulator)

    # --- misc ----------------------------------------------------------------------
    power_pj_per_bit_fast: float = 1.2   # dynamic-power estimate coefficients
    power_pj_per_bit_slow_read: float = 2.0
    power_pj_per_bit_slow_write: float = 12.0

    @property
    def n_pages(self) -> int:
        return self.n_fast_pages + self.n_slow_pages

    @property
    def subblocks_per_page(self) -> int:
        return self.page_size // self.subblock

    @property
    def dma_cycles_per_subblock(self) -> int:
        return max(1, round(self.subblock / self.dma_bytes_per_cycle))

    def with_(self, **kw) -> "EmulatorConfig":
        return dataclasses.replace(self, **kw)

    def runtime(self) -> "RuntimeParams":
        return RuntimeParams.from_config(self)


def static_key(cfg: EmulatorConfig) -> tuple:
    """The fields of ``cfg`` that determine compiled shapes and program
    structure. Two configs with equal ``static_key`` share every compiled
    emulation program — this tuple is the leading component of the
    session API's unified entry-point cache key (``repro.Engine``; two
    same-geometry Engines reuse each other's executables). Everything
    else lives in ``RuntimeParams`` and is traced.

    Note the *total* page count is static but the fast/slow split is not:
    the redirection table is initialized from a traced boundary, so tier
    ratios are a batchable design axis.
    """
    return (cfg.page_size, cfg.subblock, cfg.n_pages, cfg.line_size,
            cfg.n_banks, cfg.chunk, cfg.max_inflight, cfg.dma_buffer_bytes,
            cfg.bank_resolver, cfg.fuse_swap_gather, cfg.scan_unroll,
            cfg.chunk_step_kernel)


def canonical_config(cfg: EmulatorConfig) -> EmulatorConfig:
    """A representative config carrying only ``cfg``'s static fields, with
    every runtime field left at its class default. Configs with equal
    :func:`static_key` canonicalize identically, so jit caches keyed on
    the canonical config are shared across sweeps that differ only in
    runtime parameters. Only meaningful where ``params`` is always
    supplied explicitly (the sweep executor) — the runtime defaults of
    the result are arbitrary."""
    return EmulatorConfig(
        page_size=cfg.page_size, subblock=cfg.subblock,
        n_fast_pages=1, n_slow_pages=cfg.n_pages - 1,
        line_size=cfg.line_size, n_banks=cfg.n_banks, chunk=cfg.chunk,
        max_inflight=cfg.max_inflight, dma_buffer_bytes=cfg.dma_buffer_bytes,
        bank_resolver=cfg.bank_resolver,
        fuse_swap_gather=cfg.fuse_swap_gather, scan_unroll=cfg.scan_unroll,
        chunk_step_kernel=cfg.chunk_step_kernel)


class RuntimeParams(NamedTuple):
    """Traced runtime parameters of the platform — a JAX pytree.

    Everything the emulation pipeline reads per design point (technology
    timings, bandwidths, link/issue timing, policy knobs, the fast-tier
    boundary, the policy selector) lives here as a scalar array, so
    the emulation program compiles once per :func:`static_key` and any number of
    design points run through the same XLA computation — vmapping over a
    stacked ``RuntimeParams`` batch is the sweep engine's core mechanism.

    Field names deliberately mirror ``EmulatorConfig`` (flattened for the
    two ``TechnologyParams``), so helpers that only touch shared fields
    accept either object.
    """

    # device timing (cfg.fast / cfg.slow, flattened)
    fast_read_lat: jax.Array       # int32 cycles
    fast_write_lat: jax.Array
    fast_bytes_per_cycle: jax.Array  # float32
    slow_read_lat: jax.Array
    slow_write_lat: jax.Array
    slow_bytes_per_cycle: jax.Array
    # interconnect + host issue model
    link_lat: jax.Array            # int32
    link_bytes_per_cycle: jax.Array  # float32
    issue_gap: jax.Array           # int32
    # DMA engine bandwidth (pre-divided: cycles per 512B sub-block move)
    dma_cycles_per_subblock: jax.Array  # int32
    # tier geometry: fast/slow boundary within the static n_pages space
    n_fast_pages: jax.Array        # int32
    # policy knobs + selector (index into policies.POLICIES order)
    hot_threshold: jax.Array       # int32
    hotness_decay_shift: jax.Array
    decay_every: jax.Array
    write_weight: jax.Array
    wear_slack: jax.Array          # int32 — wear_level destination tolerance
    pin_fast_fraction: jax.Array   # float32 — fast-tier share pinned at init
    endurance_budget: jax.Array    # int32 — frame retirement threshold
    #   (<= 0 disables retirement; see EmulatorConfig.endurance_budget)
    policy_id: jax.Array
    # power model coefficients
    power_pj_per_bit_fast: jax.Array        # float32
    power_pj_per_bit_slow_read: jax.Array
    power_pj_per_bit_slow_write: jax.Array

    @classmethod
    def from_config(cls, cfg: EmulatorConfig) -> "RuntimeParams":
        from . import policies  # deferred; policies imports this module
        i32, f32 = jnp.int32, jnp.float32
        return cls(
            fast_read_lat=i32(cfg.fast.read_lat),
            fast_write_lat=i32(cfg.fast.write_lat),
            fast_bytes_per_cycle=f32(cfg.fast.bytes_per_cycle),
            slow_read_lat=i32(cfg.slow.read_lat),
            slow_write_lat=i32(cfg.slow.write_lat),
            slow_bytes_per_cycle=f32(cfg.slow.bytes_per_cycle),
            link_lat=i32(cfg.link_lat),
            link_bytes_per_cycle=f32(cfg.link_bytes_per_cycle),
            issue_gap=i32(cfg.issue_gap),
            dma_cycles_per_subblock=i32(cfg.dma_cycles_per_subblock),
            n_fast_pages=i32(cfg.n_fast_pages),
            hot_threshold=i32(cfg.hot_threshold),
            hotness_decay_shift=i32(cfg.hotness_decay_shift),
            decay_every=i32(cfg.decay_every),
            write_weight=i32(cfg.write_weight),
            wear_slack=i32(cfg.wear_slack),
            pin_fast_fraction=f32(cfg.pin_fast_fraction),
            endurance_budget=i32(cfg.endurance_budget),
            policy_id=i32(policies.policy_id(cfg.policy)),
            power_pj_per_bit_fast=f32(cfg.power_pj_per_bit_fast),
            power_pj_per_bit_slow_read=f32(cfg.power_pj_per_bit_slow_read),
            power_pj_per_bit_slow_write=f32(cfg.power_pj_per_bit_slow_write),
        )

    def with_(self, **kw) -> "RuntimeParams":
        return self._replace(**kw)


# Paper Table I, converted to cycles (ns) and bytes/cycle. Bandwidths are
# platform-level defaults (a DDR4 DIMM, Optane-class media, ...), since
# Table I only gives latencies; all are overridable per experiment.
TECHNOLOGIES: dict[str, TechnologyParams] = {
    "dram": TechnologyParams("dram", read_lat=50, write_lat=50,
                             bytes_per_cycle=19.2, endurance_log10=16),
    "3dxpoint": TechnologyParams("3dxpoint", read_lat=100, write_lat=275,
                                 bytes_per_cycle=2.4, endurance_log10=9),
    "stt-ram": TechnologyParams("stt-ram", read_lat=20, write_lat=20,
                                bytes_per_cycle=12.8, endurance_log10=16),
    "mram": TechnologyParams("mram", read_lat=20, write_lat=20,
                             bytes_per_cycle=12.8, endurance_log10=15),
    "flash": TechnologyParams("flash", read_lat=100_000, write_lat=100_000,
                              bytes_per_cycle=0.5, endurance_log10=4),
    # "hdd" from Table I is out of scope for a memory bus (5 ms) but kept for
    # completeness of the technology table.
    "hdd": TechnologyParams("hdd", read_lat=5_000_000, write_lat=5_000_000,
                            bytes_per_cycle=0.15, endurance_log10=15),
}


def paper_platform() -> EmulatorConfig:
    """The exact platform of paper Table II: 128 MB DRAM + 1 GB emulated
    3D XPoint behind a PCIe Gen3 link."""
    return EmulatorConfig()


def small_platform(**kw) -> EmulatorConfig:
    """A reduced platform for tests: tiny page counts, small chunks."""
    base = dict(n_fast_pages=8, n_slow_pages=56, chunk=16, hot_threshold=3)
    base.update(kw)
    return EmulatorConfig(**base)
