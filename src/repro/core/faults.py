"""Deterministic fault injection for the emulated hybrid memory.

A :class:`FaultPlan` is a *traced pytree* of scheduled hardware faults,
so fault scenarios are sweepable design points like any other axis: a
stacked plan batch vmaps through the one-compilation sweep engine and
AMAT x lifetime x SLO can be studied under increasing failure rates in
one compiled program. Two fault classes (both keyed on the absolute
``chunk_idx`` of the carried :class:`~repro.core.emulator.EmulatorState`,
so plans stay meaningful across continued runs and serving dispatches):

``transient``
    int32[nt, 2] rows of (chunk, page): at boundary ``chunk`` every
    access to ``page`` within that chunk completes but returns corrupt
    data — the request is marked in the per-request ``injected`` output
    and counted in ``Counters.transient_faults``. No table effect (the
    frame survives); the serving layer refetches the page's contents.

``deaths``
    int32[nd, 2] rows of (chunk, page), sorted by chunk: an early frame
    death. At the first boundary at or after ``chunk`` whose rescue
    register is free, the frame currently under ``page`` dies — the page
    is POISONED exactly like an ``endurance_budget`` crossing and a
    rescue migration is scheduled (``core.table`` docstring has the
    lifecycle). Deaths are consumed serially through the
    ``fault_cursor`` register (one in-flight rescue at a time — the DMA
    engine has one channel), so closely spaced deaths retire on later
    boundaries than scheduled; the plan order is preserved.

Sentinel rows pad both arrays to static shapes: ``chunk = -1`` rows in
``transient`` never match (boundaries count from 0) and ``chunk =
NEVER`` rows in ``deaths`` are never due. An empty plan is therefore a
single sentinel row per class, and running with ``FaultPlan.empty()`` is
bitwise-identical to not injecting faults at all.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# Death rows at this chunk stamp are never due (padding sentinel).
NEVER = 2**30


class FaultPlan(NamedTuple):
    """Scheduled faults as a traced pytree (see module docstring)."""

    transient: jax.Array  # int32[nt, 2] (chunk, page); chunk=-1 padding
    deaths: jax.Array     # int32[nd, 2] (chunk, page) sorted; chunk=NEVER pad

    @staticmethod
    def empty() -> "FaultPlan":
        """A plan injecting nothing (single sentinel row per class).
        Bitwise-identical to ``FaultPlan.of()`` and to a zero-event
        ``seeded_plan``, so all three share one compiled entry point."""
        return FaultPlan.of()

    @staticmethod
    def of(transient=(), deaths=()) -> "FaultPlan":
        """Build a plan from explicit (chunk, page) event lists. Deaths
        are sorted by chunk; empty classes get one sentinel row."""
        return FaultPlan(transient=_rows(transient, -1),
                         deaths=_rows(sorted(map(tuple, deaths)), NEVER))

    @property
    def shape_sig(self) -> tuple:
        """Static shape signature (joins the entry-point cache key)."""
        return (self.transient.shape, self.deaths.shape)

    @property
    def is_batched(self) -> bool:
        """True for a stacked per-design-point plan batch."""
        return self.transient.ndim == 3


def _rows(events, sentinel_chunk: int) -> jax.Array:
    rows = np.asarray(list(events), np.int32).reshape(-1, 2)
    if rows.shape[0] == 0:
        rows = np.asarray([[sentinel_chunk, 0]], np.int32)
    return jnp.asarray(rows)


def seeded_plan(seed: int, *, pages, n_chunks: int, n_deaths: int = 0,
                n_transient: int = 0, start_chunk: int = 0) -> FaultPlan:
    """A deterministic plan over candidate ``pages``: ``n_deaths``
    distinct frames die, evenly spread across ``[start_chunk, n_chunks)``
    (rescues serialize through one DMA channel — even spacing keeps the
    retirement backlog shallow), plus ``n_transient`` transient faults at
    random (chunk, page) points. Same seed, same plan."""
    pages = np.asarray(pages, np.int32)
    rng = np.random.default_rng(seed)
    deaths = []
    if n_deaths:
        if n_deaths > pages.size:
            raise ValueError(f"n_deaths={n_deaths} > {pages.size} pages")
        victims = rng.choice(pages, size=n_deaths, replace=False)
        stamps = np.linspace(start_chunk, max(n_chunks - 1, start_chunk),
                             n_deaths).astype(np.int64)
        deaths = list(zip(stamps.tolist(), victims.tolist()))
    transient = []
    if n_transient:
        t_pages = rng.choice(pages, size=n_transient, replace=True)
        t_chunks = rng.integers(start_chunk, max(n_chunks, start_chunk + 1),
                                size=n_transient)
        transient = list(zip(t_chunks.tolist(), t_pages.tolist()))
    return FaultPlan.of(transient=transient, deaths=deaths)


def pad_plan(plan: FaultPlan, nt: int, nd: int) -> FaultPlan:
    """Pad a plan's event arrays with sentinel rows to (nt, nd) — plans
    in one stacked sweep batch must share shapes, and a padded plan
    injects exactly the same faults."""
    def pad(rows, n, sentinel):
        if rows.shape[0] > n:
            raise ValueError(f"plan has {rows.shape[0]} events > pad {n}")
        fill = jnp.asarray([[sentinel, 0]], jnp.int32)
        reps = jnp.tile(fill, (n - rows.shape[0], 1))
        return jnp.concatenate([rows, reps]) if reps.shape[0] else rows
    return FaultPlan(transient=pad(plan.transient, nt, -1),
                     deaths=pad(plan.deaths, nd, NEVER))


def stack_plans(plans: list[FaultPlan]) -> FaultPlan:
    """Stack same-shape plans into a per-design-point batch for sweeps.
    All plans must share (nt, nd) — see :func:`pad_plan`."""
    sigs = {p.shape_sig for p in plans}
    if len(sigs) != 1:
        raise ValueError(f"plans disagree on event-array shapes: {sigs}")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *plans)


__all__ = ["FaultPlan", "NEVER", "seeded_plan", "stack_plans", "pad_plan"]
