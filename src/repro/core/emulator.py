"""The HMMU emulation pipeline — the platform's "FPGA fabric".

Requests flow through the same stages as the paper's Fig 2 workflow:

    RX link -> TLP decode -> redirection-table lookup -> DMA-conflict
    redirect -> bank queues (per device) -> media access -> tag-match
    in-order return -> TX link

Each stage is a vectorized array computation over a *chunk* of requests;
ordering-sensitive stages (bank queues, link serialization, in-order
return) are resolved exactly with associative scans (see latency.py,
consistency.py). Policy state (hotness, migrations) commits at chunk
boundaries — the pipeline-depth visibility delay real RTL has.

The chunk step itself lives in ``repro.kernels.chunk_step`` — ONE fused
step (Pallas kernel with the packed table in VMEM, or the bitwise-
identical jnp scan path) covering all five pipeline stages plus the
boundary commit and the policy proposal. That module documents the
authoritative read-before-write chunk schedule; this one just scans it
over the trace and accumulates counters.

``chunk=1`` degrades to a fully sequential model, which the oracle tests
compare against; large chunks are the "FPGA mode" delivering the paper's
orders-of-magnitude speedup over sequential software simulation.

**Drive the platform through the session API.** ``repro.Engine``
(``repro/engine.py``) is the public entry point: it owns the static
geometry, a frozen :class:`~repro.core.policies.PolicyRegistry`, and the
unified jit entry-point cache below (:func:`entry_point`), and exposes
``run`` / ``run_stream`` / ``run_channels`` / ``sweep`` /
``continue_sweep``.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import counters as counters_lib, dma as dma_lib, table as table_lib
from .config import EmulatorConfig, RuntimeParams, static_key
from .faults import FaultPlan
from .policies import PolicyRegistry
from repro.kernels import chunk_step as chunk_step_lib


class Trace(NamedTuple):
    """A memory-request trace (struct-of-arrays, int32)."""
    page: jax.Array      # flat page number
    offset: jax.Array    # byte offset within the page
    is_write: jax.Array  # bool
    size: jax.Array      # bytes (usually the 64B line size)

    def __len__(self):
        return self.page.shape[-1]


class EmulatorState(NamedTuple):
    table: jax.Array          # int32[n_pages, table.ROW_W] — the packed
    #   per-page metadata store (core.table): redirection mapping
    #   (DEVICE/FRAME lanes), policy hotness, NVM wear histogram (WEAR
    #   lane keyed by slow frame — the endurance row of paper Table I;
    #   policies like write_bias exist to flatten exactly this histogram),
    #   the CLOCK inverse map (OWNER lane keyed by fast frame), and the
    #   last-migration EPOCH stamp. Same row format the Pallas lookup
    #   kernel serves on the hot path.
    clock_ptr: jax.Array      # int32 — CLOCK victim pointer over fast frames
    chunk_idx: jax.Array      # int32 — chunks processed (decay ticks)
    dma: dma_lib.DMAState
    clock: jax.Array          # int32 cycles
    bank_free: jax.Array      # int32[2 * n_banks] — per device x bank
    link_free_rx: jax.Array   # int32
    link_free_tx: jax.Array   # int32
    last_return: jax.Array    # int32
    counters: counters_lib.Counters
    rescue_page: jax.Array    # int32 — page awaiting rescue off a dead
    #   frame (-1 when idle); at most one rescue is in flight at a time
    #   (kernels.chunk_step.retire_phase documents the lifecycle)
    min_wear: jax.Array       # int32 — global min slow-frame WEAR,
    #   rescrubbed at decay boundaries (wear_level's slack reference)
    fault_cursor: jax.Array   # int32 — next unconsumed FaultPlan death


def init_state(cfg: EmulatorConfig,
               params: RuntimeParams | None = None) -> EmulatorState:
    """Fresh platform state. The table's WEAR and OWNER lanes are sized by
    the static total page count (the fast/slow split is a runtime
    parameter); rows beyond the active tier are never read. A nonzero
    ``pin_fast_fraction`` (config or params) pre-pins that share of the
    fast tier via the FLAGS lane."""
    nf = None if params is None else params.n_fast_pages
    pin = None if params is None else params.pin_fast_fraction
    z = jnp.int32(0)
    return EmulatorState(
        table=table_lib.init_table(cfg, nf, pin),
        clock_ptr=z, chunk_idx=z,
        dma=dma_lib.DMAState.idle(),
        clock=z,
        bank_free=jnp.zeros(2 * cfg.n_banks, jnp.int32),
        link_free_rx=z, link_free_tx=z, last_return=z,
        counters=counters_lib.Counters.zeros(),
        rescue_page=jnp.int32(-1), min_wear=z, fault_cursor=z,
    )


def pad_trace(cfg: EmulatorConfig, t: Trace) -> tuple[Trace, jax.Array]:
    """Pad to a multiple of cfg.chunk; returns (trace, valid mask)."""
    n = len(t)
    rem = (-n) % cfg.chunk
    valid = jnp.arange(n + rem) < n
    if rem:
        t = Trace(*(jnp.pad(x, (0, rem)) for x in t))
    return t, valid


def _chunk_step(cfg: EmulatorConfig, params: RuntimeParams,
                registry: PolicyRegistry, faults: FaultPlan,
                state: EmulatorState, chunk: tuple[Trace, jax.Array]):
    """One scan step = one chunk through the fused step.

    The five pipeline stages (RX link -> lookup/redirect -> bank queues ->
    in-order return -> TX link), the boundary commit, and the policy
    proposal all execute inside ``kernels.chunk_step`` — as one Pallas
    kernel or the bitwise-identical scan path, per the
    ``cfg.chunk_step_kernel`` knob. That module's docstring is the
    authoritative statement of the chunk's read/write schedule (all table
    reads against the pre-chunk table; ONE combined boundary scatter; the
    policy reads the committed table). Here we only split state into the
    kernel's carry (scalars + table + bank_free), step it, and fold the
    chunk's results into the float counter accumulators — which stay
    outside the kernel, int32-in float32-out.
    """
    trace, valid = chunk
    page, offset, is_write, size = trace
    size = jnp.where(valid, size, 0)
    sc = chunk_step_lib.StepScalars(
        clock=state.clock, clock_ptr=state.clock_ptr,
        chunk_idx=state.chunk_idx, dma=state.dma,
        link_free_rx=state.link_free_rx, link_free_tx=state.link_free_tx,
        last_return=state.last_return, rescue_page=state.rescue_page,
        min_wear=state.min_wear, fault_cursor=state.fault_cursor)
    table, sc, bank_free, outs = chunk_step_lib.chunk_step(
        cfg, registry, state.table, params, sc, state.bank_free,
        page, offset, is_write, size, valid, faults)
    ctr = counters_lib.update(params, state.counters, device=outs["device"],
                              is_write=is_write, size=size, valid=valid,
                              latency=outs["latency"], held=outs["held"],
                              poisoned=outs["poisoned"],
                              retired=outs["retired"] >= 0,
                              injected=outs["injected"])
    new_state = EmulatorState(
        table=table, clock_ptr=sc.clock_ptr, chunk_idx=sc.chunk_idx,
        dma=sc.dma, clock=sc.clock, bank_free=bank_free,
        link_free_rx=sc.link_free_rx, link_free_tx=sc.link_free_tx,
        last_return=sc.last_return, counters=ctr,
        rescue_page=sc.rescue_page, min_wear=sc.min_wear,
        fault_cursor=sc.fault_cursor)
    n = page.shape[0]
    # The boundary's retired/tombstone page scalars broadcast to the
    # chunk's request positions so the scan's stacked outputs reshape to
    # the flat trace like everything else; harvesters take unique >= 0.
    out = {"returns": outs["returns"],
           "device": jnp.where(valid, outs["device"], -1),
           "latency": outs["latency"],
           "faulted": (outs["poisoned"] | outs["injected"]) & valid,
           "retired_page": jnp.full((n,), 1, jnp.int32) * outs["retired"],
           "tombstone": jnp.full((n,), 1, jnp.int32) * outs["tombstone"]}
    return new_state, out


def _emulate_impl(cfg: EmulatorConfig, registry: PolicyRegistry, trace: Trace,
                  valid: jax.Array | None = None,
                  state: EmulatorState | None = None,
                  params: RuntimeParams | None = None,
                  faults: FaultPlan | None = None
                  ) -> tuple[EmulatorState, dict]:
    if params is None:
        params = RuntimeParams.from_config(cfg)
    if faults is None:
        faults = FaultPlan.empty()
    n = len(trace)
    assert n % cfg.chunk == 0, "pad the trace to a chunk multiple first"
    if valid is None:
        valid = jnp.ones(n, bool)
    if state is None:
        state = init_state(cfg, params)
    chunks = jax.tree.map(lambda x: x.reshape(n // cfg.chunk, cfg.chunk),
                          (trace, valid))
    state, outs = jax.lax.scan(
        functools.partial(_chunk_step, cfg, params, registry, faults), state,
        chunks, unroll=cfg.scan_unroll)
    outs = jax.tree.map(lambda x: x.reshape(n), outs)
    return state, outs


def _emulate_batch_impl(cfg: EmulatorConfig, registry: PolicyRegistry,
                        trace: Trace, valid: jax.Array,
                        states, params: RuntimeParams,
                        faults: FaultPlan | None = None):
    """The sweep executor's computation: :func:`_emulate_impl` vmapped over
    a stacked ``RuntimeParams`` batch. ``states`` is an optional stacked
    ``EmulatorState`` with the same leading point axis (a previous
    ``SweepResult.states``) — fresh per-point state when None. ``faults``
    is either one shared plan (broadcast to every point) or a stacked
    per-point batch (``FaultPlan.is_batched`` — failure rate as a design
    axis). Argument order matches ``_emulate_impl`` so one
    ``donate_argnums`` spec serves both entry points."""
    if faults is None:
        faults = FaultPlan.empty()
    f_ax = 0 if faults.is_batched else None
    if states is None:
        def one(p, f):
            return _emulate_impl(cfg, registry, trace, valid, None, p, f)

        return jax.vmap(one, in_axes=(0, f_ax))(params, faults)

    def one(s, p, f):
        return _emulate_impl(cfg, registry, trace, valid, s, p, f)

    return jax.vmap(one, in_axes=(0, 0, f_ax))(states, params, faults)


# ---------------------------------------------------------------------------
# The unified jit entry-point cache.
#
# One cache subsumes the four hand-rolled jit variants this repo used to
# carry (_emulate / _emulate_donated / _emulate_batch /
# _emulate_batch_donated): every compiled emulation program — single run
# or vmapped sweep, donated or not, sharded or not — is one entry, keyed
# by (static geometry, frozen policy registry, batch?, donate?, shape
# signature). The key captures everything that forces a distinct
# executable, so ``entry_cache_count`` IS the compile count (what
# ``Engine.compile_count`` reports) with no reaching into jit internals,
# and a new same-geometry ``Engine`` reuses cached executables for free.
# ---------------------------------------------------------------------------
_ENTRY_CACHE: dict[tuple, Callable] = {}


def entry_point(cfg: EmulatorConfig, registry: PolicyRegistry, *,
                batch: bool = False, donate: bool = False,
                shape_sig: tuple = ()) -> Callable:
    """The compiled entry point for one program shape.

    ``cfg`` must already be canonical (:func:`config.canonical_config`) so
    geometry-equal sessions share entries. ``shape_sig`` carries the
    remaining executable determinants (trace length, point count,
    fresh-vs-carried state, mesh) — callers pass exactly what they are
    about to trace with, keeping one compiled executable per cache entry.

    ``donate=True`` donates the carried state (argument 4 of either
    impl), letting XLA alias its buffers into the outputs: a continued
    emulation updates the packed table in place instead of copying
    n_pages * ROW_W ints every call. The caller's state is CONSUMED.
    """
    key = (static_key(cfg), registry, batch, donate, shape_sig)
    fn = _ENTRY_CACHE.get(key)
    if fn is None:
        impl = _emulate_batch_impl if batch else _emulate_impl
        fn = jax.jit(impl, static_argnames=("cfg", "registry"),
                     donate_argnums=(4,) if donate else ())
        _ENTRY_CACHE[key] = fn
    return fn


def entry_cache_count(skey: tuple | None = None) -> int:
    """Number of compiled emulation entry points — all geometries, or one
    (``skey`` from :func:`config.static_key`). Backs
    ``Engine.compile_count``."""
    if skey is None:
        return len(_ENTRY_CACHE)
    return sum(1 for k in _ENTRY_CACHE if k[0] == skey)


def as_registry(registry) -> PolicyRegistry:
    """Normalize ``None`` / a tuple of names / a ``PolicyRegistry`` into a
    frozen snapshot (``None`` = every registered policy, in registration
    order, snapshotted now)."""
    if isinstance(registry, PolicyRegistry):
        return registry
    return PolicyRegistry.snapshot(registry)
