"""The HMMU emulation pipeline — the platform's "FPGA fabric".

Requests flow through the same stages as the paper's Fig 2 workflow:

    RX link -> TLP decode -> redirection-table lookup -> DMA-conflict
    redirect -> bank queues (per device) -> media access -> tag-match
    in-order return -> TX link

Each stage is a vectorized array computation over a *chunk* of requests;
ordering-sensitive stages (bank queues, link serialization, in-order
return) are resolved exactly with associative scans (see latency.py,
consistency.py). Policy state (hotness, migrations) commits at chunk
boundaries — the pipeline-depth visibility delay real RTL has.

``chunk=1`` degrades to a fully sequential model, which the oracle tests
compare against; large chunks are the "FPGA mode" delivering the paper's
orders-of-magnitude speedup over sequential software simulation.

**Drive the platform through the session API.** ``repro.Engine``
(``repro/engine.py``) is the public entry point: it owns the static
geometry, a frozen :class:`~repro.core.policies.PolicyRegistry`, and the
unified jit entry-point cache below (:func:`entry_point`), and exposes
``run`` / ``run_stream`` / ``run_channels`` / ``sweep`` /
``continue_sweep``. The free functions at the bottom of this module
(``emulate``, ``emulate_channels``, ``run_trace``) are thin deprecated
wrappers kept for bitwise-compatibility tests.
"""
from __future__ import annotations

import functools
import warnings
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import consistency, counters as counters_lib, dma as dma_lib
from . import latency, policies as policies_lib, table as table_lib
from .config import (EmulatorConfig, RuntimeParams, FAST, SLOW,
                     canonical_config, static_key)
from .policies import PolicyRegistry
from repro.kernels import ops as kernel_ops


class Trace(NamedTuple):
    """A memory-request trace (struct-of-arrays, int32)."""
    page: jax.Array      # flat page number
    offset: jax.Array    # byte offset within the page
    is_write: jax.Array  # bool
    size: jax.Array      # bytes (usually the 64B line size)

    def __len__(self):
        return self.page.shape[-1]


class EmulatorState(NamedTuple):
    table: jax.Array          # int32[n_pages, table.ROW_W] — the packed
    #   per-page metadata store (core.table): redirection mapping
    #   (DEVICE/FRAME lanes), policy hotness, NVM wear histogram (WEAR
    #   lane keyed by slow frame — the endurance row of paper Table I;
    #   policies like write_bias exist to flatten exactly this histogram),
    #   the CLOCK inverse map (OWNER lane keyed by fast frame), and the
    #   last-migration EPOCH stamp. Same row format the Pallas lookup
    #   kernel serves on the hot path.
    clock_ptr: jax.Array      # int32 — CLOCK victim pointer over fast frames
    chunk_idx: jax.Array      # int32 — chunks processed (decay ticks)
    dma: dma_lib.DMAState
    clock: jax.Array          # int32 cycles
    bank_free: jax.Array      # int32[2 * n_banks] — per device x bank
    link_free_rx: jax.Array   # int32
    link_free_tx: jax.Array   # int32
    last_return: jax.Array    # int32
    counters: counters_lib.Counters


def init_state(cfg: EmulatorConfig,
               params: RuntimeParams | None = None) -> EmulatorState:
    """Fresh platform state. The table's WEAR and OWNER lanes are sized by
    the static total page count (the fast/slow split is a runtime
    parameter); rows beyond the active tier are never read. A nonzero
    ``pin_fast_fraction`` (config or params) pre-pins that share of the
    fast tier via the FLAGS lane."""
    nf = None if params is None else params.n_fast_pages
    pin = None if params is None else params.pin_fast_fraction
    z = jnp.int32(0)
    return EmulatorState(
        table=table_lib.init_table(cfg, nf, pin),
        clock_ptr=z, chunk_idx=z,
        dma=dma_lib.DMAState.idle(),
        clock=z,
        bank_free=jnp.zeros(2 * cfg.n_banks, jnp.int32),
        link_free_rx=z, link_free_tx=z, last_return=z,
        counters=counters_lib.Counters.zeros(),
    )


def pad_trace(cfg: EmulatorConfig, t: Trace) -> tuple[Trace, jax.Array]:
    """Pad to a multiple of cfg.chunk; returns (trace, valid mask)."""
    n = len(t)
    rem = (-n) % cfg.chunk
    valid = jnp.arange(n + rem) < n
    if rem:
        t = Trace(*(jnp.pad(x, (0, rem)) for x in t))
    return t, valid


def _chunk_step(cfg: EmulatorConfig, params: RuntimeParams,
                registry: PolicyRegistry, state: EmulatorState,
                chunk: tuple[Trace, jax.Array]):
    trace, valid = chunk
    page, offset, is_write, size = trace
    n = page.shape[0]
    size = jnp.where(valid, size, 0)

    # --- stage 1: RX link (host -> HMMU). Writes carry payload, reads a header.
    issue = state.clock + params.issue_gap * (1 + jnp.arange(n, dtype=jnp.int32))
    issue = jnp.where(valid, issue, latency._NEG)
    rx_bytes = jnp.where(is_write, size, 16)
    rx_srv = jnp.where(valid, latency.link_service_cycles(params, rx_bytes), 0)
    rx_done = latency.maxplus_scan(
        jnp.maximum(issue, jnp.where(valid, state.link_free_rx, latency._NEG)),
        rx_srv)
    arrive = rx_done + jnp.where(valid, params.link_lat // 2, 0)

    # --- stage 2: redirection-table lookup (+ DMA swap-progress redirect).
    # One packed-row fetch through the lookup engine (Pallas on TPU, jnp
    # gather elsewhere) replaces per-field gathers — the BRAM read per
    # cycle of the paper's pipeline. Under a vmapped sweep the kernel
    # batches over the design-point axis (one launch for all points).
    # The fused path appends the DMA swap pair to the chunk's page vector
    # (chunk + 2 rows, one launch) so the conflict redirect consumes
    # prefetched rows instead of two extra dynamic-slice gathers.
    a = jnp.maximum(state.dma.page_a, 0)
    b = jnp.maximum(state.dma.page_b, 0)
    if cfg.fuse_swap_gather:
        rows, swap_rows = kernel_ops.hmmu_lookup_fused(
            state.table, page, jnp.stack([a, b]))
        row_a, row_b = swap_rows[..., 0, :], swap_rows[..., 1, :]
    else:
        rows = kernel_ops.hmmu_lookup(state.table, page)
        row_a, row_b = state.table[a], state.table[b]
    dev = table_lib.device(rows)
    frm = table_lib.frame(rows)
    dev, frm = dma_lib.redirect(
        cfg, state.dma, page, offset, arrive, dev, frm,
        row_a, row_b, params)

    # --- stage 3: per-device bank queues + media access.
    bank = dev * cfg.n_banks + frm % cfg.n_banks
    med_srv = jnp.where(
        valid, latency.device_service_cycles(params, dev, is_write, size), 0)
    resolve = (latency.resolve_bank_queues_segmented
               if latency.pick_bank_resolver(cfg) == "segmented"
               else latency.resolve_bank_queues)
    med_done, bank_free = resolve(
        arrive, med_srv, bank, 2 * cfg.n_banks, state.bank_free)

    # --- stage 4: tag-match in-order return (paper §III-C) ...
    ordered = consistency.in_order_returns(
        jnp.where(valid, med_done, latency._NEG), state.last_return)
    held = jnp.sum((ordered > med_done) & valid).astype(jnp.int32)

    # --- stage 5: ... then TX link serialization (responses leave in order).
    tx_bytes = jnp.where(is_write, 16, size)
    tx_srv = jnp.where(valid, latency.link_service_cycles(params, tx_bytes), 0)
    returns = latency.maxplus_scan(
        jnp.maximum(ordered, jnp.where(valid, state.link_free_tx, latency._NEG)),
        tx_srv) + jnp.where(valid, params.link_lat // 2, 0)

    lat = jnp.where(valid, returns - issue, 0)

    # --- chunk boundary: counters, hotness, DMA completion, policy commit.
    # Poison faults: accesses that touched a POISONED page (flags come
    # from the stage-2 row gather — FLAGS never changes mid-chunk).
    poisoned = valid & table_lib.is_poisoned(rows)
    ctr = counters_lib.update(params, state.counters, device=dev,
                              is_write=is_write, size=size, valid=valid,
                              latency=lat, held=held, poisoned=poisoned)
    do_decay = (state.chunk_idx % params.decay_every) == (params.decay_every - 1)
    # Policy-scoped write weighting: only the write_bias policy biases
    # hotness by write_weight; every other policy (including plain
    # hotness at the same swept write_weight) counts reads and writes
    # equally, so the policy axis is a real comparison.
    if "write_bias" in registry.names:
        eff_weight = jnp.where(
            params.policy_id == registry.index("write_bias"),
            params.write_weight, jnp.int32(1))
    else:
        eff_weight = jnp.int32(1)
    table = policies_lib.update_hotness(params, state.table, page,
                                        is_write, valid, do_decay,
                                        write_weight=eff_weight)
    # NVM endurance: count demand writes per slow frame in the WEAR lane
    # (the DMA migration's full-page write is charged separately at swap
    # commit in dma.maybe_complete).
    slow_wr = is_write & valid & (dev == SLOW)
    table = table.at[jnp.where(slow_wr, frm, 0), table_lib.WEAR].add(
        slow_wr.astype(jnp.int32), mode="drop")

    any_valid = jnp.any(valid)
    last_ret = jnp.where(any_valid, jnp.max(jnp.where(valid, returns, state.last_return)),
                         state.last_return)
    now = jnp.maximum(state.clock + params.issue_gap * n, last_ret)

    swap_a = jnp.maximum(state.dma.page_a, 0)  # pre-completion swap pair
    dma, table, done = dma_lib.maybe_complete(cfg, state.dma, now, table,
                                              params)
    # Maintain the frame -> page inverse map (OWNER lane): the promoted
    # page (swap_a, now FAST) owns its new frame.
    row_a = table[swap_a]
    promoted = done & (table_lib.device(row_a) == FAST)
    own_idx = jnp.where(promoted, table_lib.frame(row_a), 0)
    own_val = jnp.where(promoted, swap_a, table[0, table_lib.OWNER])
    table = table.at[own_idx, table_lib.OWNER].set(own_val)

    # Policy dispatch on the *traced* policy id: lax.switch over the
    # (static, frozen) registry snapshot makes the policy itself a
    # batchable design axis. params.policy_id indexes ``registry.names``;
    # a single-policy registry skips the switch so vmapped non-policy
    # sweeps never pay for branches they don't use. Branches come from
    # the snapshot's own function tuple — re-registering a policy name
    # after the snapshot cannot leak into this compilation.
    branches = [functools.partial(fn, cfg, params) for fn in registry.fns]
    ops = (table, state.clock_ptr, page, is_write, valid)
    if len(branches) == 1:
        p_want, cand, victim, new_ptr = branches[0](*ops)
    else:
        p_want, cand, victim, new_ptr = jax.lax.switch(
            params.policy_id, branches, *ops)
    # Post-policy proposal mask: device sanity plus FLAGS enforcement —
    # a pinned candidate or victim vetoes the swap no matter what the
    # policy proposed (maybe_start re-checks the same pin bits). One row
    # gather per swap member serves both checks.
    cand_row, victim_row = table[cand], table[victim]
    unpinned = ~(table_lib.is_pinned(cand_row) |
                 table_lib.is_pinned(victim_row))
    want = p_want & any_valid & unpinned & \
        (table_lib.device(cand_row) == SLOW) & \
        (table_lib.device(victim_row) == FAST)
    dma, started = dma_lib.maybe_start(dma, want, cand, victim, now, table)
    # CLOCK pointer commit (two cases, see policies.py): a proposal only
    # consumes its victim frame when the swap actually started — a
    # rejected/dropped proposal (engine busy, re-masked want) leaves the
    # pointer unchanged instead of silently skipping victims. With no
    # proposal at all, the policy's pointer motion commits as-is: that is
    # how a pinned frame (never a victim) is stepped over for free.
    clock_ptr = jnp.where(started | ~p_want, new_ptr, state.clock_ptr)

    new_state = EmulatorState(
        table=table, clock_ptr=clock_ptr,
        chunk_idx=state.chunk_idx + 1, dma=dma,
        clock=now,
        bank_free=bank_free,
        link_free_rx=jnp.where(any_valid, rx_done[-1], state.link_free_rx),
        link_free_tx=jnp.where(any_valid, returns[-1], state.link_free_tx),
        last_return=last_ret,
        counters=ctr,
    )
    out = {"returns": jnp.where(valid, returns, 0),
           "device": jnp.where(valid, dev, -1),
           "latency": lat}
    return new_state, out


def _emulate_impl(cfg: EmulatorConfig, registry: PolicyRegistry, trace: Trace,
                  valid: jax.Array | None = None,
                  state: EmulatorState | None = None,
                  params: RuntimeParams | None = None
                  ) -> tuple[EmulatorState, dict]:
    if params is None:
        params = RuntimeParams.from_config(cfg)
    n = len(trace)
    assert n % cfg.chunk == 0, "pad the trace to a chunk multiple first"
    if valid is None:
        valid = jnp.ones(n, bool)
    if state is None:
        state = init_state(cfg, params)
    chunks = jax.tree.map(lambda x: x.reshape(n // cfg.chunk, cfg.chunk),
                          (trace, valid))
    state, outs = jax.lax.scan(
        functools.partial(_chunk_step, cfg, params, registry), state, chunks,
        unroll=cfg.scan_unroll)
    outs = jax.tree.map(lambda x: x.reshape(n), outs)
    return state, outs


def _emulate_batch_impl(cfg: EmulatorConfig, registry: PolicyRegistry,
                        trace: Trace, valid: jax.Array,
                        states, params: RuntimeParams):
    """The sweep executor's computation: :func:`_emulate_impl` vmapped over
    a stacked ``RuntimeParams`` batch. ``states`` is an optional stacked
    ``EmulatorState`` with the same leading point axis (a previous
    ``SweepResult.states``) — fresh per-point state when None. Argument
    order matches ``_emulate_impl`` so one ``donate_argnums`` spec serves
    both entry points."""
    if states is None:
        def one(p):
            return _emulate_impl(cfg, registry, trace, valid, None, p)

        return jax.vmap(one)(params)

    def one(s, p):
        return _emulate_impl(cfg, registry, trace, valid, s, p)

    return jax.vmap(one)(states, params)


# ---------------------------------------------------------------------------
# The unified jit entry-point cache.
#
# One cache subsumes the four hand-rolled jit variants this repo used to
# carry (_emulate / _emulate_donated / _emulate_batch /
# _emulate_batch_donated): every compiled emulation program — single run
# or vmapped sweep, donated or not, sharded or not — is one entry, keyed
# by (static geometry, frozen policy registry, batch?, donate?, shape
# signature). The key captures everything that forces a distinct
# executable, so ``entry_cache_count`` IS the compile count (what
# ``Engine.compile_count`` reports) with no reaching into jit internals,
# and a new same-geometry ``Engine`` reuses cached executables for free.
# ---------------------------------------------------------------------------
_ENTRY_CACHE: dict[tuple, Callable] = {}


def entry_point(cfg: EmulatorConfig, registry: PolicyRegistry, *,
                batch: bool = False, donate: bool = False,
                shape_sig: tuple = ()) -> Callable:
    """The compiled entry point for one program shape.

    ``cfg`` must already be canonical (:func:`config.canonical_config`) so
    geometry-equal sessions share entries. ``shape_sig`` carries the
    remaining executable determinants (trace length, point count,
    fresh-vs-carried state, mesh) — callers pass exactly what they are
    about to trace with, keeping one compiled executable per cache entry.

    ``donate=True`` donates the carried state (argument 4 of either
    impl), letting XLA alias its buffers into the outputs: a continued
    emulation updates the packed table in place instead of copying
    n_pages * ROW_W ints every call. The caller's state is CONSUMED.
    """
    key = (static_key(cfg), registry, batch, donate, shape_sig)
    fn = _ENTRY_CACHE.get(key)
    if fn is None:
        impl = _emulate_batch_impl if batch else _emulate_impl
        fn = jax.jit(impl, static_argnames=("cfg", "registry"),
                     donate_argnums=(4,) if donate else ())
        _ENTRY_CACHE[key] = fn
    return fn


def entry_cache_count(skey: tuple | None = None) -> int:
    """Number of compiled emulation entry points — all geometries, or one
    (``skey`` from :func:`config.static_key`). Backs
    ``Engine.compile_count`` and the legacy ``sweep.runner.compile_count``.
    """
    if skey is None:
        return len(_ENTRY_CACHE)
    return sum(1 for k in _ENTRY_CACHE if k[0] == skey)


def as_registry(registry) -> PolicyRegistry:
    """Normalize ``None`` / a tuple of names / a ``PolicyRegistry`` into a
    frozen snapshot (``None`` = every registered policy, in registration
    order, snapshotted now)."""
    if isinstance(registry, PolicyRegistry):
        return registry
    return PolicyRegistry.snapshot(registry)


def _warn_legacy(old: str, new: str) -> None:
    warnings.warn(
        f"legacy {old} is deprecated: drive the platform through the "
        f"session API — {new} (see repro.Engine)",
        DeprecationWarning, stacklevel=3)


def emulate(cfg: EmulatorConfig, trace: Trace, valid: jax.Array | None = None,
            state: EmulatorState | None = None,
            params: RuntimeParams | None = None,
            registry=None,
            donate: bool = False) -> tuple[EmulatorState, dict]:
    """Deprecated free-function entry point — use ``repro.Engine.run``.

    Kept as a thin wrapper over the unified entry-point cache (bitwise
    identical to ``Engine.run``, guaranteed by tests/test_engine.py). The
    trace length must be a multiple of ``cfg.chunk`` (use ``pad_trace``;
    ``Engine.run`` pads for you). ``donate=True`` donates ``state``'s
    buffers — the passed-in state is CONSUMED (``Engine.run`` donates by
    default). ``registry`` may be a tuple of policy names or a
    ``PolicyRegistry``; default is a snapshot of every registered policy.
    """
    _warn_legacy("emulate()", "Engine(cfg).run(trace, state=..., params=...)")
    if donate and state is None:
        raise ValueError(
            "donate=True requires state=...: donation aliases the carried "
            "state's buffers into the outputs, and a fresh-state run has "
            "nothing to donate (it would silently run undonated)")
    reg = as_registry(registry)
    if params is None:
        params = RuntimeParams.from_config(cfg)
    static = canonical_config(cfg)
    fn = entry_point(static, reg, donate=donate,
                     shape_sig=(len(trace), valid is None, state is None))
    return fn(static, reg, trace, valid, state, params)


def emulate_channels(cfg: EmulatorConfig, traces: Trace,
                     params: RuntimeParams | None = None,
                     registry=None):
    """Deprecated — use ``repro.Engine.run_channels``. FPGA-style spatial
    parallelism: emulate many independent trace channels at once (vmapped
    over a leading channel axis); ``params``/``registry`` apply to every
    channel."""
    _warn_legacy("emulate_channels()", "Engine(cfg).run_channels(traces)")
    from repro.engine import Engine
    return Engine(cfg, registry=registry).run_channels(traces, params=params)


def run_trace(cfg: EmulatorConfig, trace: Trace,
              params: RuntimeParams | None = None):
    """Deprecated — use ``repro.Engine.run`` (+ ``RunResult.summary()``).
    Pads, emulates, returns (state, padded outputs, counters summary)."""
    _warn_legacy("run_trace()", "Engine(cfg).run(trace) + result.summary()")
    from repro.engine import Engine
    padded, valid = pad_trace(cfg, trace)
    state, outs = Engine(cfg).run(padded, valid=valid, params=params,
                                  donate=False)
    return state, outs, counters_lib.summary(state.counters)
