"""Memory-consistency tag matching (paper §III-C, Fig 3).

Requests split across the DRAM/NVM channels complete out of order (a later
DRAM request overtakes an earlier NVM one). The paper stores request
headers in a FIFO and matches returned data against the head tag so the
host always sees responses in request order.

The timing consequence of that mechanism is exactly a running maximum:

    return_i = max_{j <= i} complete_j

because a response is held until every earlier response has been released.
``jax.lax.cummax`` computes this in O(log n) depth — the vectorized
equivalent of the HDR-FIFO tag match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def in_order_returns(complete: jax.Array, last_return: jax.Array) -> jax.Array:
    """Map out-of-order completion times to in-order return times.

    complete: int32[chunk] — media/link completion time per request, in
        request-issue order.
    last_return: int32 scalar — return time of the final request of the
        previous chunk (the FIFO never reorders across chunks either).
    """
    shifted = jnp.maximum(complete, last_return)
    return jax.lax.cummax(shifted, axis=shifted.ndim - 1)


def reorder_depth(complete: jax.Array) -> jax.Array:
    """Diagnostic: how many responses each request had to wait behind
    (0 == it was already in order). Used by tests and counters."""
    ret = jax.lax.cummax(complete, axis=complete.ndim - 1)
    return jnp.sum(ret > complete)
