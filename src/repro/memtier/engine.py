"""Batched serving engine with the HMMU-managed tiered KV cache.

Continuous-batching style: requests join a fixed-capacity batch slot-wise,
prefill fills the slot's cache region, decode advances every active slot
one token per step. The accelerator-side compute uses the model's decode
path; the memory-system behaviour of the cache streams through the
TieredKVAccounting platform (the paper's contribution) each step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EmulatorConfig
from repro.models import (ModelConfig, ShardCtx, decode_step, init_cache,
                          prefill)
from .tiered_cache import TieredKVAccounting


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # int32 [S] (or frames [S, frame_dim])
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 4,
                 smax: int = 256, emu_cfg: EmulatorConfig | None = None,
                 policy: str = "hotness", sh: ShardCtx | None = None,
                 eos: int | None = None, pin_pages_per_seq: int = 1):
        self.cfg = cfg
        self.params = params
        self.sh = sh or ShardCtx()
        self.b = batch_size
        self.smax = smax
        self.eos = eos
        self.cache = init_cache(cfg, batch_size, smax)
        self.pos = jnp.zeros((batch_size,), jnp.int32)
        self.tokens = jnp.zeros((batch_size,), jnp.int32)
        self.active: list[Request | None] = [None] * batch_size
        self.queue: list[Request] = []
        emu_cfg = emu_cfg or EmulatorConfig(
            n_fast_pages=256, n_slow_pages=2048, chunk=64, policy=policy)
        if emu_cfg.policy != policy:
            emu_cfg = emu_cfg.with_(policy=policy)
        kv_bytes = self._kv_bytes_per_position()
        # pin_pages_per_seq: §III-G placement contracts — each sequence's
        # first KV pages (streamed every decode step) are allocated
        # pin=True; report() exposes the pinned-page fast hit rate.
        self.tier = TieredKVAccounting(emu_cfg, cfg.n_layers,
                                       positions_per_page=64,
                                       bytes_per_position=max(64, kv_bytes),
                                       pin_pages_per_seq=pin_pages_per_seq)
        self._decode = jax.jit(
            lambda p, t, c, q: decode_step(cfg, p, t, c, q, self.sh))
        self._prefill = jax.jit(
            lambda p, i: prefill(cfg, p, i, self.sh, smax))

    def _kv_bytes_per_position(self) -> int:
        c = self.cfg
        if c.attn_type == "mla":
            return 2 * (c.mla.kv_lora_rank + c.mla.rope_head_dim)
        if c.attn_type == "rwkv6":
            return 0
        return 2 * 2 * c.n_kv_heads * c.head_dim_

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.b):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.active[slot] = req
                # slot-wise prefill: run the prompt through its own lane
                prompt = jnp.asarray(req.prompt)[None]
                logits, cache1, pos1 = self._prefill(self.params, prompt)
                # splice lane 0 of the fresh cache into this slot
                def splice(dst, src):
                    return dst.at[:, slot].set(src[:, 0])
                self.cache = jax.tree.map(splice, self.cache, cache1)
                self.pos = self.pos.at[slot].set(pos1[0])
                nxt = int(jnp.argmax(logits[0]))
                self.tokens = self.tokens.at[slot].set(nxt)
                req.out.append(nxt)

    def step(self) -> bool:
        """One decode step for the whole batch. Returns False when idle."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return False

        logits, self.cache, self.pos = self._decode(
            self.params, self.tokens, self.cache, self.pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.tokens = nxt

        # --- memory-system accounting through the HMMU platform -------------
        kv_lens = [int(self.pos[i]) for i in live]
        windows = None
        if self.cfg.window is not None:
            windows = [self.cfg.window] * len(live)
        trace = self.tier.access_trace([self.active[i].rid for i in live],
                                       kv_lens, windows)
        self.tier.account(trace)

        for i in live:
            req = self.active[i]
            tok = int(nxt[i])
            req.out.append(tok)
            if len(req.out) >= req.max_new_tokens or \
                    (self.eos is not None and tok == self.eos) or \
                    int(self.pos[i]) >= self.smax - 1:
                req.done = True
                self.tier.free_sequence(req.rid)
                self.active[i] = None
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(self.active)) and steps < max_steps:
            if not self.step():
                break
            steps += 1
        return steps

    def report(self) -> dict:
        return self.tier.report()
