"""The paper's technique as a first-class serving feature: a tiered,
paged KV cache whose placement/migration is managed by the core HMMU."""
from .tiered_cache import TieredKVAccounting
from .engine import ServeEngine

__all__ = ["TieredKVAccounting", "ServeEngine"]
