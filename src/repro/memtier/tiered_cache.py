"""Tiered paged KV-cache accounting — the HMMU managing a serving cache.

This is the paper's platform doing its job inside the serving stack:
the *real application* is the decoding LM (runs at full speed on the
accelerator); the *design under test* is a KV-cache tier-management
policy. KV pages (``positions_per_page`` consecutive cache slots of one
layer group) are allocated in the emulated hybrid space through the
middleware API (core.table.HybridAllocator — the paper's driver+jemalloc
analogue, with placement hints: fresh pages prefer the fast tier). Every
decode step emits the page-access stream the attention kernels would
issue; the stream feeds the HMMU session (``repro.Engine``)
incrementally — donated carried state, so the packed redirection table
moves forward in place step after step — which

  * applies the configured placement/migration policy (promoting hot KV
    pages to the DRAM tier, demoting cold ones),
  * accounts per-request latency through the full pipeline model, and
  * exposes the paper's performance counters (per-tier traffic, energy).

§III-G placement *contracts*: the first ``pin_pages_per_seq`` KV pages
of each sequence — the pages the attention pass streams on every single
decode step, for the sequence's whole lifetime — are latency-critical
and allocated with ``HybridAllocator.alloc(pin=True)``. The pin bit is
stamped into the table's FLAGS lane (PIN_FAST below the tier boundary,
PIN_SLOW where the allocation spilled), so no migration policy can evict
a contracted page. The **pinned-page fast hit rate** — the fraction of
accesses to contracted pages actually served from DRAM — is the
contract-quality metric ``report()`` exposes (1.0 means every
latency-critical page got, and kept, its DRAM frame; less means the
fast tier was too small and contracts spilled).

Policies are swappable per engine (`policy="hotness" | "static" | ...`),
so the engine doubles as the policy-exploration harness the paper built
its platform for (examples/policy_exploration.py).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import (EmulatorConfig, HybridAllocator, Trace, counters,
                        FAST, SLOW)
from repro.engine import Engine
from repro.serve.contracts import release_pin_pages, stamp_pin_pages


@dataclasses.dataclass
class TierStats:
    steps: int = 0
    requests: int = 0
    est_cycles: int = 0
    pinned_accesses: int = 0
    pinned_fast_hits: int = 0


class TieredKVAccounting:
    """Tracks one model's decode-cache pages in the hybrid space."""

    def __init__(self, emu_cfg: EmulatorConfig, n_layers: int,
                 positions_per_page: int = 256,
                 bytes_per_position: int = 1024,
                 pin_pages_per_seq: int = 1):
        self.cfg = emu_cfg
        self.alloc = HybridAllocator(emu_cfg)
        self.n_layers = n_layers
        self.ppp = positions_per_page
        self.bpp = bytes_per_position
        self.pin_pages_per_seq = pin_pages_per_seq
        self.engine = Engine(emu_cfg)
        self.state = self.engine.init_state()
        # (seq_id, seq_page) -> flat page
        self._pages: dict[tuple, int] = {}
        self._handles: dict[tuple, int] = {}
        self._pinned: set[int] = set()
        self.stats = TierStats()

    def _page_for(self, seq_id: int, pos_page: int) -> int:
        key = (seq_id, pos_page)
        if key not in self._pages:
            # Fresh (hot) KV pages prefer the fast tier — the placement
            # hint the paper's extended malloc carries (§III-G). The
            # sequence's first pin_pages_per_seq pages get the *strong*
            # form: a pin contract stamped into the FLAGS lane.
            pin = pos_page < self.pin_pages_per_seq
            handle, pages = self.alloc.alloc(1, hint=FAST, pin=pin)
            page = int(pages[0])
            self._pages[key] = page
            self._handles[key] = handle
            if pin:
                # Pin the page to the tier it will actually OCCUPY —
                # device-accurate and DMA-swap-aware. The FLAGS lifecycle
                # is shared with the serving scheduler
                # (repro.serve.contracts): the stamp reads the DEVICE
                # lane and the swap membership *inside* the traced
                # program, so it composes with async dispatch and never
                # syncs the host. The allocator's own pin record
                # (alloc(pin=True)) serves pre-run apply_flags()
                # workflows; mid-emulation this incremental stamp is the
                # source of truth (stamp here, clear in free_sequence).
                self.state = stamp_pin_pages(self.state, [page], width=1)
                self._pinned.add(page)
        return self._pages[key]

    def access_trace(self, seq_ids, kv_lens, windows=None):
        """Build one decode step's page-access stream.

        seq_ids: list of active sequence ids; kv_lens: tokens cached per
        sequence; windows: per-sequence effective attention windows (None
        = full). Reads touch every page the attention pass streams; the
        new token's page gets a write.
        """
        pages, offsets, writes = [], [], []
        for sid, klen, win in zip(
                seq_ids, kv_lens,
                windows if windows is not None else [None] * len(seq_ids)):
            first = 0 if win is None else max(0, (klen - win) // self.ppp)
            last = (klen - 1) // self.ppp
            for pp in range(first, last + 1):
                pages.append(self._page_for(sid, pp))
                offsets.append((pp % 4) * self.cfg.subblock)
                writes.append(False)
            pages.append(self._page_for(sid, last))
            offsets.append(((klen - 1) % self.ppp) * self.bpp
                           % self.cfg.page_size)
            writes.append(True)
        trace = Trace(
            page=jnp.asarray(pages, jnp.int32),
            offset=jnp.asarray(offsets, jnp.int32),
            is_write=jnp.asarray(writes, bool),
            size=jnp.full(len(pages), min(self.bpp, 4096), jnp.int32))
        return trace

    def account(self, trace: Trace) -> dict:
        """Feed one step's stream through the HMMU session (incremental;
        the carried state is donated and moves forward in place)."""
        before = int(self.state.clock)
        self.state, outs = self.engine.run(trace, state=self.state)
        self.stats.steps += 1
        self.stats.requests += len(trace)
        self.stats.est_cycles = int(self.state.clock)
        if self._pinned:
            pages = np.asarray(trace.page)
            dev = np.asarray(outs["device"])
            pin = np.isin(pages, np.fromiter(self._pinned, np.int32))
            self.stats.pinned_accesses += int(pin.sum())
            self.stats.pinned_fast_hits += int((pin & (dev == FAST)).sum())
        return {"step_cycles": int(self.state.clock) - before}

    def free_sequence(self, seq_id: int):
        for key in [k for k in self._pages if k[0] == seq_id]:
            page = self._pages[key]
            if page in self._pinned:
                # Release the §III-G contract with the allocation.
                self.state = release_pin_pages(self.state, [page], width=1)
                self._pinned.discard(page)
            self.alloc.free(self._handles.pop(key))
            del self._pages[key]

    def report(self) -> dict:
        summ = counters.summary(self.state.counters)
        pinned_hits = self.stats.pinned_fast_hits
        summ.update(est_total_cycles=self.stats.est_cycles,
                    migrations=int(self.state.dma.swaps_done),
                    steps=self.stats.steps,
                    requests=self.stats.requests,
                    fast_free=self.alloc.free_pages[FAST],
                    slow_free=self.alloc.free_pages[SLOW],
                    pinned_pages=len(self._pinned),
                    pinned_accesses=self.stats.pinned_accesses,
                    # 0.0, not nan: a sequence can complete before any
                    # decode access lands on a contracted page, and nan
                    # poisons downstream SLO aggregation (bench_serve
                    # averages these across engines).
                    pinned_fast_hit_rate=(
                        pinned_hits / self.stats.pinned_accesses
                        if self.stats.pinned_accesses else 0.0))
        return summ
