"""Tiered paged KV-cache accounting — the HMMU managing a serving cache.

This is the paper's platform doing its job inside the serving stack:
the *real application* is the decoding LM (runs at full speed on the
accelerator); the *design under test* is a KV-cache tier-management
policy. KV pages (``positions_per_page`` consecutive cache slots of one
layer group) are allocated in the emulated hybrid space through the
middleware API (core.table.HybridAllocator — the paper's driver+jemalloc
analogue, with placement hints: fresh pages prefer the fast tier). Every
decode step emits the page-access stream the attention kernels would
issue; the stream feeds the HMMU emulator incrementally, which

  * applies the configured placement/migration policy (promoting hot KV
    pages to the DRAM tier, demoting cold ones),
  * accounts per-request latency through the full pipeline model, and
  * exposes the paper's performance counters (per-tier traffic, energy).

Policies are swappable per engine (`policy="hotness" | "static" | ...`),
so the engine doubles as the policy-exploration harness the paper built
its platform for (examples/policy_exploration.py).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import (EmulatorConfig, HybridAllocator, Trace, counters,
                        emulator as emu, FAST, SLOW)


@dataclasses.dataclass
class TierStats:
    steps: int = 0
    requests: int = 0
    est_cycles: int = 0


class TieredKVAccounting:
    """Tracks one model's decode-cache pages in the hybrid space."""

    def __init__(self, emu_cfg: EmulatorConfig, n_layers: int,
                 positions_per_page: int = 256,
                 bytes_per_position: int = 1024):
        self.cfg = emu_cfg
        self.alloc = HybridAllocator(emu_cfg)
        self.n_layers = n_layers
        self.ppp = positions_per_page
        self.bpp = bytes_per_position
        self.state = emu.init_state(emu_cfg)
        # (seq_id, layer_group, seq_page) -> flat page
        self._pages: dict[tuple, int] = {}
        self._handles: dict[tuple, int] = {}
        self.stats = TierStats()

    def _page_for(self, seq_id: int, pos_page: int) -> int:
        key = (seq_id, pos_page)
        if key not in self._pages:
            # Fresh (hot) KV pages prefer the fast tier — the placement
            # hint the paper's extended malloc carries (§III-G).
            handle, pages = self.alloc.alloc(1, hint=FAST)
            self._pages[key] = int(pages[0])
            self._handles[key] = handle
        return self._pages[key]

    def access_trace(self, seq_ids, kv_lens, windows=None):
        """Build one decode step's page-access stream.

        seq_ids: list of active sequence ids; kv_lens: tokens cached per
        sequence; windows: per-sequence effective attention windows (None
        = full). Reads touch every page the attention pass streams; the
        new token's page gets a write.
        """
        pages, offsets, writes = [], [], []
        for sid, klen, win in zip(
                seq_ids, kv_lens,
                windows if windows is not None else [None] * len(seq_ids)):
            first = 0 if win is None else max(0, (klen - win) // self.ppp)
            last = (klen - 1) // self.ppp
            for pp in range(first, last + 1):
                pages.append(self._page_for(sid, pp))
                offsets.append((pp % 4) * self.cfg.subblock)
                writes.append(False)
            pages.append(self._page_for(sid, last))
            offsets.append(((klen - 1) % self.ppp) * self.bpp
                           % self.cfg.page_size)
            writes.append(True)
        trace = Trace(
            page=jnp.asarray(pages, jnp.int32),
            offset=jnp.asarray(offsets, jnp.int32),
            is_write=jnp.asarray(writes, bool),
            size=jnp.full(len(pages), min(self.bpp, 4096), jnp.int32))
        return trace

    def account(self, trace: Trace) -> dict:
        """Feed one step's stream through the HMMU emulator (incremental)."""
        padded, valid = emu.pad_trace(self.cfg, trace)
        before = int(self.state.clock)
        self.state, _ = emu.emulate(self.cfg, padded, valid, self.state)
        self.stats.steps += 1
        self.stats.requests += len(trace)
        self.stats.est_cycles = int(self.state.clock)
        return {"step_cycles": int(self.state.clock) - before}

    def free_sequence(self, seq_id: int):
        for key in [k for k in self._pages if k[0] == seq_id]:
            self.alloc.free(self._handles.pop(key))
            del self._pages[key]

    def report(self) -> dict:
        summ = counters.summary(self.state.counters)
        summ.update(est_total_cycles=self.stats.est_cycles,
                    migrations=int(self.state.dma.swaps_done),
                    steps=self.stats.steps,
                    requests=self.stats.requests,
                    fast_free=self.alloc.free_pages[FAST],
                    slow_free=self.alloc.free_pages[SLOW])
        return summ
