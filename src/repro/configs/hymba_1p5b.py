"""hymba-1.5b [hybrid]: parallel attention + mamba heads per layer.
32L d1600 25H (kv=5, head_dim 64) d_ff 5504 vocab 32001, ssm_state=16.
Sliding-window (1024) attention except global layers {0, 16, 31}.
[arXiv:2411.13676; hf]
Runs long_500k (windowed attention + O(1) SSM state).
"""
from repro.models import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", n_layers=32, d_model=1600, n_heads=25,
        n_kv_heads=5, d_ff=5504, vocab=32001, head_dim=64,
        attn_type="hymba", window=1024, hymba_global_layers=(0, 16, 31),
        ssm=SSMConfig(d_state=16, d_conv=4))


def smoke_config() -> ModelConfig:
    return config().with_(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=128, head_dim=16, window=8,
                          hymba_global_layers=(0, 2),
                          ssm=SSMConfig(d_state=4, d_conv=3),
                          param_dtype="float32", activation_dtype="float32")
