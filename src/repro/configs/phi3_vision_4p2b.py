"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP patch frontend (stub).
32L d3072 32H (kv=32) d_ff 8192 vocab 32064.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (CLIP-L/14 hidden size 1024); the learned
adapter projection + the full LM backbone are real.
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", n_layers=32, d_model=3072, n_heads=32,
        n_kv_heads=32, d_ff=8192, vocab=32064, head_dim=96,
        attn_type="gqa", frontend="frames", frame_dim=1024)


def smoke_config() -> ModelConfig:
    return config().with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab=128, head_dim=16, frame_dim=24,
                          param_dtype="float32", activation_dtype="float32")
