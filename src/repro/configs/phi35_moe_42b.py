"""phi3.5-moe-42b-a6.6b [moe]: 16 experts, top-2 routing.
32L d4096 32H (GQA kv=8) d_ff 6400 vocab 32064.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from repro.models import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=6400, vocab=32064, head_dim=128, attn_type="gqa",
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400))


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=128,
        head_dim=16, moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96),
        param_dtype="float32", activation_dtype="float32")
