"""internlm2-1.8b [dense]: GQA. 24L d2048 16H (kv=8) d_ff 8192
vocab 92544. [arXiv:2403.17297; hf]
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=8, d_ff=8192, vocab=92544, head_dim=128, attn_type="gqa")


def smoke_config() -> ModelConfig:
    return config().with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=128, head_dim=16,
                          param_dtype="float32", activation_dtype="float32")
