"""Assigned input-shape set (identical across the LM archs) and the
applicability rules for the 40 (arch x shape) dry-run cells."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg, shape: Shape) -> tuple[bool, str]:
    """long_500k requires sub-quadratic sequence scaling: it runs for the
    SSM/hybrid archs (rwkv6, hymba) and is skipped for pure full-attention
    archs (incl. gemma3, whose every 6th layer is global full attention).
    All archs here are decoder-style, so decode shapes are well-defined."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("skipped: pure full-attention arch — long_500k needs "
                       "sub-quadratic attention (DESIGN.md §5)")
    return True, ""
