"""Architecture registry: one module per assigned architecture.

``get(arch_id)`` returns the full published config; ``get_smoke(arch_id)``
a reduced same-family config for CPU tests. ``ARCHS`` lists all ids.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "phi3_vision_4p2b", "musicgen_medium", "phi35_moe_42b",
    "deepseek_v2_236b", "rwkv6_7b", "phi3_mini_3p8b", "gemma3_4b",
    "internlm2_1p8b", "minitron_8b", "hymba_1p5b",
]

# public --arch ids (hyphenated) -> module names
ALIASES = {
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "musicgen-medium": "musicgen_medium",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "rwkv6-7b": "rwkv6_7b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "gemma3-4b": "gemma3_4b",
    "internlm2-1.8b": "internlm2_1p8b",
    "minitron-8b": "minitron_8b",
    "hymba-1.5b": "hymba_1p5b",
}


def _module(arch: str):
    name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    return importlib.import_module(f"repro.configs.{name}")


def get(arch: str):
    return _module(arch).config()


def get_smoke(arch: str):
    return _module(arch).smoke_config()


from .shapes import SHAPES, shape_applicable  # noqa: E402

__all__ = ["ARCHS", "ALIASES", "get", "get_smoke", "SHAPES",
           "shape_applicable"]
