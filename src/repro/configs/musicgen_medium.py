"""musicgen-medium [audio]: decoder-only over EnCodec tokens.
48L d1536 24H (kv=24) d_ff 6144 vocab 2048. [arXiv:2306.05284; hf]

Modality frontend (EnCodec codebook-sum embeddings) is a STUB:
input_specs() supplies precomputed frame embeddings; generation emits
EnCodec token ids (vocab 2048).
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", n_layers=48, d_model=1536, n_heads=24,
        n_kv_heads=24, d_ff=6144, vocab=2048, head_dim=64,
        attn_type="gqa", frontend="frames", frame_dim=512)


def smoke_config() -> ModelConfig:
    return config().with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab=64, head_dim=16, frame_dim=24,
                          param_dtype="float32", activation_dtype="float32")
