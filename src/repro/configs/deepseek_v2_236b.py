"""deepseek-v2-236b [moe]: MLA (kv_lora=512) + 2 shared / 160 routed
top-6 experts. 60L d5120 128H d_ff(expert) 1536 vocab 102400.
[arXiv:2405.04434; hf]

Deviation noted per DESIGN.md: the reference model keeps layer 0 dense;
here all 60 layers are MoE (uniform layer stack for the scanned body).
Shared experts are fused into one SwiGLU of width 2*1536.
"""
from repro.models import ModelConfig, MoEConfig, MLAConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", n_layers=60, d_model=5120, n_heads=128,
        n_kv_heads=128, d_ff=12288, vocab=102400, attn_type="mla",
        head_dim=128,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                      nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                      n_shared=2, d_ff_shared=3072))


def smoke_config() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
        head_dim=16,
        mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64, n_shared=2,
                      d_ff_shared=64),
        param_dtype="float32", activation_dtype="float32")
