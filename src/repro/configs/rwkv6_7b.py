"""rwkv6-7b [ssm] "Finch": attention-free, data-dependent decay.
32L d4096 d_ff 14336 vocab 65536. [arXiv:2404.05892; hf]
64 heads of 64 channels; chunked-parallel linear attention (models.rwkv).
Runs long_500k (O(1) state decode).
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", n_layers=32, d_model=4096, n_heads=64,
        n_kv_heads=64, d_ff=14336, vocab=65536, head_dim=64,
        attn_type="rwkv6")


def smoke_config() -> ModelConfig:
    return config().with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab=128, head_dim=16, rwkv_chunk=8,
                          param_dtype="float32", activation_dtype="float32")
