"""minitron-8b [dense]: pruned nemotron. 32L d4096 32H (kv=8) d_ff 16384
vocab 256000. [arXiv:2407.14679; hf]
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=16384, vocab=256000, head_dim=128,
        attn_type="gqa")


def smoke_config() -> ModelConfig:
    return config().with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=128, head_dim=16,
                          param_dtype="float32", activation_dtype="float32")
