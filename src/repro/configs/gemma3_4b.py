"""gemma3-4b [dense]: 5:1 local(1024-window):global interleave, 128k
context, tied embeddings. 34L d2560 8H (kv=4, head_dim 256) d_ff 10240
vocab 262144. [hf:google/gemma-3-1b-pt; unverified]

8 q heads cannot split a 16-way model axis: attention runs batch-parallel
with replicated attention weights; FFN/vocab are model-sharded
(models.sharding head rules).
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", n_layers=34, d_model=2560, n_heads=8,
        n_kv_heads=4, d_ff=10240, vocab=262144, head_dim=256,
        attn_type="gqa", window=1024, global_every=6, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return config().with_(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=256, head_dim=16, window=8,
                          global_every=3,
                          param_dtype="float32", activation_dtype="float32")
