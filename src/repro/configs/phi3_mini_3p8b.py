"""phi3-mini-3.8b [dense]: RoPE SwiGLU GQA. 32L d3072 32H (kv=32)
d_ff 8192 vocab 32064. [arXiv:2404.14219; unverified]
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b", n_layers=32, d_model=3072, n_heads=32,
        n_kv_heads=32, d_ff=8192, vocab=32064, head_dim=96, attn_type="gqa")


def smoke_config() -> ModelConfig:
    return config().with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab=128, head_dim=16,
                          param_dtype="float32", activation_dtype="float32")
