"""One-kernel chunk step: the whole HMMU pipeline as a single Pallas call.

The paper's HMMU resolves a request per cycle because lookup, bank
arbitration, and migration control live in ONE pipeline next to BRAM.
This module is that pipeline's software twin, written once and executed
two ways:

* :func:`step_ref` — the composable jnp "scan path": closed-form max-plus
  scans (``core.latency``), the batched lookup kernel for stage 2, one
  combined boundary scatter for every table write. This is what
  ``core.emulator`` runs by default on CPU.
* the Pallas path — ``pl.pallas_call`` with the packed redirection table
  staged through VMEM, the sequential max-plus recurrences expressed as
  in-kernel ``fori_loop``s (the RTL formulation, not the closed form),
  the scalar state and ``RuntimeParams`` riding a scalar-prefetch int
  vector (``policy_id`` dispatch included), and a leading batch axis +
  ``custom_vmap`` rule so a vmapped design-space sweep launches ONE
  kernel per chunk for all points. Interpret mode off-TPU.

Both paths are bitwise identical on every knob combination (property
tests in tests/test_chunk_step_kernel.py): all pipeline arithmetic is
exact int32, and the sequential recurrences are provably equal to the
associative closed forms.

The one true chunk schedule (the ordering contract the kernel implements
and ``core.emulator`` documents):

1. **Reads** — every table read of the chunk happens against the
   *pre-chunk* table: the stage-2 row gather (chunk pages + DMA swap
   pair), the swap pair's DEVICE/FRAME/EPOCH pre-values consumed by
   ``dma.plan_commit``, and the OWNER pre-value of the promoted frame.
2. **Boundary commit** — every table write lands in ONE flattened
   scatter-add over exact int32 deltas (hotness accumulation, demand-
   write WEAR, the swap commit's lane exchanges, the OWNER inverse-map
   update routed through a ``mode="drop"`` sentinel), followed by the
   decay shift. One in-place update instead of ~a dozen copying
   scatters — the restructure that makes the scan path fast and the
   kernel possible.
3. **Retire** — the retirement subsystem (:func:`retire_phase`) reads
   the committed table and stamps at most one dying frame's resident
   page POISONED: a second, sentinel-guarded single-row FLAGS scatter —
   the one documented extension to the "one scatter" rule, a dropped
   no-op whenever retirement is idle.
4. **Policy** — the proposal phase reads the *committed* table (policies
   see this chunk's accesses and completed migration, exactly as
   before), then ``dma.maybe_start`` and the CLOCK pointer commit. A
   pending rescue preempts the policy's proposal on the single DMA
   channel.

Nothing mid-pipeline reads a mid-chunk write; FLAGS is only written at
boundaries (the swap commit's poison travel and the retirement stamp),
never on the hot path.

TPU note: the body gathers/scatters table rows by value index, which
interpret mode (and the bit-identity suite) exercises everywhere; on a
real TPU the gather lowers via the same dynamic-slice machinery as the
lookup kernel, and the VMEM budget check in
:func:`use_chunk_step_kernel` keeps the resident table within a core's
VMEM (paper geometry: 294912 rows x 8 lanes x 4 B ~ 9.4 MB of ~16 MB).
"""
from __future__ import annotations

import functools
import inspect
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import custom_batching
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import consistency, dma as dma_lib, latency
from repro.core import faults as faults_lib
from repro.core import policies as policies_lib
from repro.core import table as table_lib
from repro.core.config import FAST, SLOW, EmulatorConfig, RuntimeParams
from repro.core.policies import PolicyRegistry
from . import ops as kernel_ops

# Python literals, NOT eager jnp arrays: everything below also traces
# inside the Pallas body, which rejects captured device constants.
_MIN = -(2 ** 31)
_NEG = -(2 ** 30)  # == int(_NEG), the invalid-slot arrival time

# VMEM the resident table may claim before "auto" falls back to the scan
# path (a TPU core has ~16 MB; leave room for the chunk vectors + double
# buffering of the blocked operands).
VMEM_TABLE_BUDGET = 12 * 2 ** 20


class StepScalars(NamedTuple):
    """The scalar slice of ``EmulatorState`` a chunk step carries (the
    packed table and ``bank_free`` travel separately; counters stay in
    the emulator — float accumulation never enters the kernel).

    The trailing three registers are the retirement subsystem's state
    (rescue register, global min-wear register, FaultPlan death cursor);
    they default so pre-retirement callers constructing scalars by
    keyword keep working."""
    clock: jax.Array
    clock_ptr: jax.Array
    chunk_idx: jax.Array
    dma: dma_lib.DMAState
    link_free_rx: jax.Array
    link_free_tx: jax.Array
    last_return: jax.Array
    rescue_page: jax.Array = -1   # page awaiting rescue off a dead frame
    min_wear: jax.Array = 0       # global min slow-frame WEAR (scrubbed)
    fault_cursor: jax.Array = 0   # next unconsumed FaultPlan death row


class PipelineOut(NamedTuple):
    """Everything the pipeline phase hands the boundary phases."""
    dev: jax.Array        # int32[chunk] — device actually accessed
    frm: jax.Array        # int32[chunk] — frame actually accessed
    row_a: jax.Array      # int32[W] — pre-chunk row of DMA swap member a
    row_b: jax.Array      # int32[W] — pre-chunk row of DMA swap member b
    returns: jax.Array    # int32[chunk] — TX return time (unmasked)
    lat: jax.Array        # int32[chunk] — request latency (masked)
    held: jax.Array       # int32 — responses delayed by tag matching
    poisoned: jax.Array   # bool[chunk] — touched a POISONED page
    bank_free: jax.Array  # int32[2*n_banks] — post-chunk bank busy times
    rx_last: jax.Array    # int32 — RX link busy-until after the chunk
    tx_last: jax.Array    # int32 — TX link busy-until after the chunk
    hot_pre: jax.Array    # int32[chunk] — pre-chunk HOTNESS of the pages
    #   (commit_phase saturates the hotness scatter against it)


# --------------------------------------------------------------------------- #
# sequential (in-kernel) formulations of the ordering-sensitive stages
# --------------------------------------------------------------------------- #
# Each is the direct RTL recurrence; ``core.latency`` proves the closed
# forms equal, so these are bitwise-identical on int32 (no float anywhere).

def _seq_maxplus(arrival: jax.Array, service: jax.Array) -> jax.Array:
    """``done_i = max(arrival_i, done_{i-1}) + service_i`` as a loop."""
    n = arrival.shape[0]

    def body(i, carry):
        prev, done = carry
        d = jnp.maximum(arrival[i], prev) + service[i]
        return d, done.at[i].set(d)

    init = (jnp.full((), _MIN, jnp.int32), jnp.zeros(n, jnp.int32))
    return jax.lax.fori_loop(0, n, body, init)[1]


def _seq_bank_resolve(arrival, service, bank, bank_free):
    """One pass over the chunk with a live ``bank_free`` register file —
    what the FPGA's per-bank queue head pointers do. Equal to the dense
    one-hot resolver: folding ``bank_free`` into only the first request
    of each bank suffices because done times never drop below the seed
    (service >= 0)."""
    n = arrival.shape[0]
    arr = jnp.maximum(arrival, _NEG)

    def body(i, carry):
        free, done = carry
        d = jnp.maximum(arr[i], free[bank[i]]) + service[i]
        return free.at[bank[i]].set(d), done.at[i].set(d)

    free, done = jax.lax.fori_loop(
        0, n, body, (bank_free, jnp.zeros(n, jnp.int32)))
    return done, free


def _seq_inorder(complete: jax.Array, last_return: jax.Array) -> jax.Array:
    """Running max over ``max(complete_i, last_return)`` — the HDR-FIFO
    tag match as a loop."""
    n = complete.shape[0]

    def body(i, carry):
        run, out = carry
        r = jnp.maximum(jnp.maximum(complete[i], last_return), run)
        return r, out.at[i].set(r)

    init = (jnp.full((), _MIN, jnp.int32), jnp.zeros(n, jnp.int32))
    return jax.lax.fori_loop(0, n, body, init)[1]


# --------------------------------------------------------------------------- #
# phase 1: the request pipeline (pure reads)
# --------------------------------------------------------------------------- #

def pipeline_phase(cfg: EmulatorConfig, params: RuntimeParams,
                   table: jax.Array, sc: StepScalars, bank_free: jax.Array,
                   page, offset, is_write, size, valid, *,
                   seq: bool = False, upto: str = "full") -> PipelineOut:
    """Stages 1-5 of the paper's Fig 2 workflow: RX link, table lookup +
    DMA-conflict redirect, bank queues + media access, tag-match in-order
    return, TX link. Touches the table READ-ONLY (schedule contract §1).

    ``seq=True`` selects the in-kernel sequential recurrences (the Pallas
    body); default is the closed-form scan path. ``upto`` truncates after
    a named stage ("rx" / "gather" / "resolve") for the per-stage bench —
    missing fields come back zeroed.
    """
    n = page.shape[0]
    size = jnp.where(valid, size, 0)
    mp = _seq_maxplus if seq else latency.maxplus_scan
    zv = jnp.zeros(n, jnp.int32)
    zs = jnp.zeros((), jnp.int32)
    zrow = jnp.zeros(table.shape[-1], jnp.int32)

    # --- stage 1: RX link (host -> HMMU). Writes carry payload, reads a
    # header.
    issue = sc.clock + params.issue_gap * (1 + jnp.arange(n, dtype=jnp.int32))
    issue = jnp.where(valid, issue, _NEG)
    rx_bytes = jnp.where(is_write, size, 16)
    rx_srv = jnp.where(valid, latency.link_service_cycles(params, rx_bytes), 0)
    rx_done = mp(
        jnp.maximum(issue, jnp.where(valid, sc.link_free_rx, _NEG)),
        rx_srv)
    arrive = rx_done + jnp.where(valid, params.link_lat // 2, 0)
    if upto == "rx":
        return PipelineOut(zv, zv, zrow, zrow, zv, zv, zs,
                           jnp.zeros(n, bool), bank_free, rx_done[-1], zs,
                           zv)

    # --- stage 2: redirection-table lookup (+ DMA swap-progress redirect).
    # One packed-row fetch — the BRAM read per cycle of the paper's
    # pipeline. The scan path goes through the batched lookup engine
    # (Pallas gather on TPU, jnp elsewhere; the fused flavour appends the
    # DMA swap pair, chunk + 2 rows in one launch). Inside the one-kernel
    # body the table is already VMEM-resident, so the gather is a direct
    # row index. All paths clamp indices identically.
    a = jnp.maximum(sc.dma.page_a, 0)
    b = jnp.maximum(sc.dma.page_b, 0)
    if seq:
        pg = jnp.clip(page, 0, table.shape[0] - 1)
        rows = table[pg]
        row_a, row_b = table[a], table[b]
    elif cfg.fuse_swap_gather:
        rows, swap_rows = kernel_ops.hmmu_lookup_fused(
            table, page, jnp.stack([a, b]))
        row_a, row_b = swap_rows[..., 0, :], swap_rows[..., 1, :]
    else:
        rows = kernel_ops.hmmu_lookup(table, page)
        row_a, row_b = table[a], table[b]
    dev = table_lib.device(rows)
    frm = table_lib.frame(rows)
    hot_pre = table_lib.hotness(rows)
    dev, frm = dma_lib.redirect(
        cfg, sc.dma, page, offset, arrive, dev, frm, row_a, row_b, params)
    poisoned = valid & table_lib.is_poisoned(rows)
    if upto == "gather":
        return PipelineOut(dev, frm, row_a, row_b, zv, zv, zs, poisoned,
                           bank_free, rx_done[-1], zs, hot_pre)

    # --- stage 3: per-device bank queues + media access.
    bank = dev * cfg.n_banks + frm % cfg.n_banks
    med_srv = jnp.where(
        valid, latency.device_service_cycles(params, dev, is_write, size), 0)
    if seq:
        med_done, bank_free2 = _seq_bank_resolve(arrive, med_srv, bank,
                                                 bank_free)
    else:
        resolve = (latency.resolve_bank_queues_segmented
                   if latency.pick_bank_resolver(cfg) == "segmented"
                   else latency.resolve_bank_queues)
        med_done, bank_free2 = resolve(
            arrive, med_srv, bank, 2 * cfg.n_banks, bank_free)
    if upto == "resolve":
        return PipelineOut(dev, frm, row_a, row_b, zv, zv, zs, poisoned,
                           bank_free2, rx_done[-1], zs, hot_pre)

    # --- stage 4: tag-match in-order return (paper §III-C) ...
    inorder = _seq_inorder if seq else consistency.in_order_returns
    ordered = inorder(jnp.where(valid, med_done, _NEG),
                      sc.last_return)
    held = jnp.sum((ordered > med_done) & valid).astype(jnp.int32)

    # --- stage 5: ... then TX link serialization (responses leave in
    # order).
    tx_bytes = jnp.where(is_write, 16, size)
    tx_srv = jnp.where(valid, latency.link_service_cycles(params, tx_bytes), 0)
    returns = mp(
        jnp.maximum(ordered, jnp.where(valid, sc.link_free_tx, _NEG)),
        tx_srv) + jnp.where(valid, params.link_lat // 2, 0)
    lat = jnp.where(valid, returns - issue, 0)
    return PipelineOut(dev, frm, row_a, row_b, returns, lat, held, poisoned,
                       bank_free2, rx_done[-1], returns[-1], hot_pre)


# --------------------------------------------------------------------------- #
# phase 2: the boundary commit (pure writes — ONE combined scatter)
# --------------------------------------------------------------------------- #

def eff_write_weight(params: RuntimeParams, registry: PolicyRegistry):
    """Policy-scoped hotness write weighting: only the ``write_bias``
    policy biases hotness by ``write_weight``; every other policy counts
    reads and writes equally, so the policy axis is a real comparison."""
    if "write_bias" in registry.names:
        return jnp.where(params.policy_id == registry.index("write_bias"),
                         params.write_weight, 1)
    return 1


def commit_phase(cfg: EmulatorConfig, params: RuntimeParams,
                 table: jax.Array, sc: StepScalars, pipe: PipelineOut,
                 page, is_write, valid, eff_weight):
    """Commit the chunk to the table: hotness accumulation, demand-write
    WEAR, the DMA swap commit, and the OWNER inverse-map update — all as
    exact int32 deltas in ONE flattened scatter-add (then the decay
    shift). Every delta is computed against pre-chunk reads (schedule
    contract §2), and distinct updates target distinct (row, lane) slots
    except WEAR, where duplicate targets sum exactly as the historical
    sequential adds did.

    Retirement extensions (both exactly zero-effect when the subsystem is
    idle): the swap commit's FLAGS triples carry poison travel for the
    page in the rescue register (``dma.plan_commit``), and the global
    min-wear register is rescrubbed on decay boundaries — a periodic
    whole-histogram min over the slow frames' WEAR lane riding the aging
    tick, so ``wear_level``'s slack band is measured against the true
    floor at decay granularity.

    Returns ``(table, dma, done, now, last_ret, min_wear, tombstone)``;
    ``tombstone`` is the page this commit parked on a dead frame (-1 if
    none — when set, the pending rescue completed and the register
    clears).
    """
    n = page.shape[0]
    w_lanes = table.shape[-1]
    n_pages = table.shape[0]
    any_valid = jnp.any(valid)
    last_ret = jnp.where(
        any_valid, jnp.max(jnp.where(valid, pipe.returns, sc.last_return)),
        sc.last_return)
    now = jnp.maximum(sc.clock + params.issue_gap * n, last_ret)

    # Hotness accumulation (decayed below, after the combined scatter —
    # nothing else in the scatter touches the HOTNESS lane). Weights are
    # clipped against the pre-chunk lane value so the counter saturates
    # at HOTNESS_CAP instead of wrapping — exact under duplicate pages,
    # identity below the cap.
    hot_w = 1 + (jnp.asarray(eff_weight, jnp.int32) - 1) * \
        is_write.astype(jnp.int32)
    hot_w = jnp.where(valid, hot_w, 0)
    hot_w = table_lib.saturating_weights(page, hot_w, pipe.hot_pre,
                                         table_lib.HOTNESS_CAP)
    # NVM endurance: demand writes per slow frame (the DMA migration's
    # full-page write is charged by the swap commit's WEAR deltas).
    slow_wr = is_write & valid & (pipe.dev == SLOW)

    # DMA swap commit, planned from the stage-2 prefetched rows.
    swap_a = jnp.maximum(sc.dma.page_a, 0)  # pre-completion swap pair
    plan = dma_lib.plan_commit(cfg, sc.dma, now, pipe.row_a, pipe.row_b,
                               params, sc.rescue_page)
    # OWNER inverse map (fast frame -> owning page, the CLOCK victim
    # rotation): the promoted page (swap_a, now FAST) owns its new frame.
    # No swap completed => route the write through an out-of-range
    # sentinel dropped by the scatter, so row 0's OWNER lane can never be
    # clobbered by the idle guard index.
    db = table_lib.device(pipe.row_b)
    fb = table_lib.frame(pipe.row_b)
    promoted = plan.done & (db == FAST)
    own_pre = table[fb, table_lib.OWNER]
    own_idx = jnp.where(promoted, fb * w_lanes + table_lib.OWNER,
                        n_pages * w_lanes)
    own_delta = jnp.where(promoted, swap_a - own_pre, 0)

    # WEAR saturation: demand charges and the swap commit's migration
    # charges can land on the SAME slow frame in one boundary, so both
    # sources join ONE fill-until-full pass against the pre-chunk WEAR
    # (one extra pre-commit single-lane gather — a read, schedule §1).
    # The plan keeps its non-WEAR deltas; its WEAR entries move into the
    # joint fill (scatter-add totals are order-independent, so below the
    # cap this is bitwise the historical commit).
    wear_mask = plan.lanes == table_lib.WEAR
    wear_rows = jnp.concatenate([
        jnp.where(slow_wr, pipe.frm, 0),
        jnp.where(wear_mask, plan.rows, 0)])
    wear_w = jnp.concatenate([
        slow_wr.astype(jnp.int32),
        jnp.where(wear_mask, plan.delta, 0)])
    wear_pre = table[wear_rows, table_lib.WEAR]
    wear_w = table_lib.saturating_weights(wear_rows, wear_w, wear_pre,
                                          table_lib.WEAR_CAP)
    plan_delta = jnp.where(wear_mask, 0, plan.delta)

    idx = jnp.concatenate([
        page * w_lanes + table_lib.HOTNESS,
        wear_rows * w_lanes + table_lib.WEAR,
        plan.rows * w_lanes + plan.lanes,
        own_idx[None],
    ])
    upd = jnp.concatenate([
        hot_w, wear_w, plan_delta, own_delta[None],
    ])
    table = table.reshape(-1).at[idx].add(upd, mode="drop") \
        .reshape(n_pages, w_lanes)

    do_decay = (sc.chunk_idx % params.decay_every) == (params.decay_every - 1)
    table = jax.lax.cond(
        do_decay,
        lambda t: t.at[:, table_lib.HOTNESS].set(
            t[:, table_lib.HOTNESS] >> params.hotness_decay_shift),
        lambda t: t, table)
    # Min-wear scrub: slow frames are rows [0, n_slow) of the WEAR lane.
    n_slow = n_pages - params.n_fast_pages
    wmin_global = jnp.min(jnp.where(
        jnp.arange(n_pages, dtype=jnp.int32) < n_slow,
        table[:, table_lib.WEAR], 2 ** 30))
    min_wear = jnp.where(do_decay, wmin_global, sc.min_wear)
    return table, plan.dma, plan.done, now, last_ret, min_wear, \
        plan.tombstone


# --------------------------------------------------------------------------- #
# phase 2.5: endurance-driven frame retirement (reads the committed table)
# --------------------------------------------------------------------------- #

def retire_phase(cfg: EmulatorConfig, params: RuntimeParams,
                 table: jax.Array, sc: StepScalars, rescue_page,
                 fault_cursor, faults: faults_lib.FaultPlan, page, valid):
    """Detect at most ONE frame death per boundary and mark its resident
    page POISONED (pins force-cleared — a dying frame exits every pin
    contract; the serving layer renegotiates). Two detectors, gated on a
    free rescue register (one rescue in flight at a time — the single DMA
    engine):

    * **FaultPlan deaths** (priority): the next death row fires once its
      chunk stamp is due. A due row whose page is already POISONED or a
      RETIRED tombstone is consumed without effect (the frame is already
      dead).
    * **Endurance crossings**: with ``endurance_budget > 0``, any page
      *observed this boundary* (the chunk's accesses plus the in-flight
      swap members — the only rows whose WEAR can have just moved) that
      is slow-resident on a frame whose WEAR exceeds the budget.

    The stamp is one sentinel-guarded single-row FLAGS scatter — the
    documented second boundary write after the combined commit scatter,
    and a dropped no-op whenever nothing fires (``endurance_budget <= 0``
    and an empty plan leave the table bitwise-untouched).

    Returns ``(table, rescue_page, fault_cursor, retired_page)`` with
    ``retired_page`` = the page marked this boundary, else -1.
    """
    n_pages = table.shape[0]
    free = rescue_page < 0

    # FaultPlan death detector (serialized through the cursor).
    deaths = faults.deaths
    nd = deaths.shape[0]
    cur = jnp.minimum(fault_cursor, nd - 1)
    due = (fault_cursor < nd) & (deaths[cur, 0] <= sc.chunk_idx)
    consume = due & free
    ev_p = jnp.clip(deaths[cur, 1], 0, n_pages - 1)
    ev_flags = table[ev_p, table_lib.FLAGS]
    death_fire = consume & \
        ((ev_flags & (table_lib.POISONED | table_lib.RETIRED)) == 0)
    fault_cursor = fault_cursor + consume.astype(jnp.int32)

    # Endurance detector over the boundary's observed pages.
    a, b = sc.dma.page_a, sc.dma.page_b
    cand = jnp.concatenate([
        page, jnp.stack([jnp.maximum(a, 0), jnp.maximum(b, 0)])])
    cand_ok = jnp.concatenate([valid, jnp.stack([a >= 0, b >= 0])])
    cand = jnp.clip(cand, 0, n_pages - 1)
    rows = table[cand]
    wear = table[jnp.where(table_lib.device(rows) == SLOW,
                           table_lib.frame(rows), 0), table_lib.WEAR]
    over = cand_ok & (params.endurance_budget > 0) & \
        (table_lib.device(rows) == SLOW) & \
        (wear > params.endurance_budget) & \
        ((table_lib.flags(rows) &
          (table_lib.POISONED | table_lib.RETIRED)) == 0)
    j = jnp.argmax(over)
    wear_fire = free & ~death_fire & over[j]

    fire = death_fire | wear_fire
    p_ret = jnp.where(death_fire, ev_p, cand[j])
    new_fl = (table[p_ret, table_lib.FLAGS] | table_lib.POISONED) & \
        ~table_lib.PINNED
    table = table.at[jnp.where(fire, p_ret, n_pages),
                     table_lib.FLAGS].set(new_fl, mode="drop")
    rescue_page = jnp.where(fire, p_ret, rescue_page)
    return table, rescue_page, fault_cursor, jnp.where(fire, p_ret, -1)


# --------------------------------------------------------------------------- #
# phase 3: the policy proposal (reads the committed table)
# --------------------------------------------------------------------------- #

def policy_phase(cfg: EmulatorConfig, params: RuntimeParams,
                 registry: PolicyRegistry, table: jax.Array, sc: StepScalars,
                 dma: dma_lib.DMAState, now, page, is_write, valid,
                 rescue_page, min_wear):
    """Policy dispatch on the *traced* policy id: ``lax.switch`` over the
    (static, frozen) registry snapshot makes the policy itself a
    batchable design axis — inside the Pallas body the id arrives via the
    scalar-prefetch vector. A single-policy registry skips the switch.
    Branches come from the snapshot's own function tuple, so
    re-registering a policy name after the snapshot cannot leak into this
    compilation. A branch declaring a ``min_wear`` keyword (signature
    inspection at trace time — see policies.py) receives the maintained
    global min-wear register.

    While a rescue is pending (``rescue_page >= 0``) policy proposals are
    suppressed and the single DMA channel is offered the rescue migration
    instead: a slow-resident dying page promotes into a CLOCK victim
    frame (consuming the victim from the rotation exactly like a policy
    promotion); a fast-resident dying page swaps with the first healthy
    slow-resident page of this chunk's access stream (the donor parks on
    the dead frame as the tombstone — poison travel in the swap commit).
    Returns ``(dma, clock_ptr)``."""
    any_valid = jnp.any(valid)
    branches = [
        functools.partial(fn, cfg, params, min_wear=min_wear)
        if "min_wear" in inspect.signature(fn).parameters
        else functools.partial(fn, cfg, params)
        for fn in registry.fns]
    ops_ = (table, sc.clock_ptr, page, is_write, valid)
    if len(branches) == 1:
        p_want, cand, victim, new_ptr = branches[0](*ops_)
    else:
        p_want, cand, victim, new_ptr = jax.lax.switch(
            params.policy_id, branches, *ops_)
    # Post-policy proposal mask: device sanity plus FLAGS enforcement — a
    # pinned candidate or victim vetoes the swap no matter what the
    # policy proposed (maybe_start re-checks the same pin bits).
    cand_row, victim_row = table[cand], table[victim]
    unpinned = ~(table_lib.is_pinned(cand_row) |
                 table_lib.is_pinned(victim_row))
    want = p_want & any_valid & unpinned & \
        (table_lib.device(cand_row) == SLOW) & \
        (table_lib.device(victim_row) == FAST)

    # Rescue migration override (exactly no-effect while the register is
    # idle — every committed value reduces to the policy's).
    pending = rescue_page >= 0
    resc = jnp.clip(rescue_page, 0, table.shape[0] - 1)
    r_slow = table_lib.device(table[resc]) == SLOW
    r_victim, r_found, r_skip = policies_lib._clock_victim(
        table, sc.clock_ptr, params.n_fast_pages)
    pg = jnp.clip(page, 0, table.shape[0] - 1)
    rows_pg = table[pg]
    donor_ok = valid & (table_lib.device(rows_pg) == SLOW) & \
        ((table_lib.flags(rows_pg) &
          (table_lib.PINNED | table_lib.RETIRED | table_lib.POISONED)) == 0)
    dj = jnp.argmax(donor_ok)
    r_want = pending & jnp.where(r_slow, r_found, donor_ok[dj])
    final_want = jnp.where(pending, r_want, want)
    page_a = jnp.where(pending, jnp.where(r_slow, resc, pg[dj]), cand)
    page_b = jnp.where(pending, jnp.where(r_slow, r_victim, resc), victim)

    dma, started = dma_lib.maybe_start(dma, final_want, page_a, page_b, now,
                                       table)
    # CLOCK pointer commit (two cases, see policies.py): a proposal only
    # consumes its victim frame when the swap actually started; with no
    # proposal, the policy's pointer motion commits as-is (pin skipping).
    # A started slow-resident rescue consumes its victim the same way; a
    # fast-resident rescue touches no CLOCK frame. While a rescue is
    # merely pending (engine busy, no donor yet) the pointer holds — the
    # suppressed policy proposal consumed nothing.
    ptr_rescue = (sc.clock_ptr + r_skip + 1) % params.n_fast_pages
    clock_ptr = jnp.where(
        pending,
        jnp.where(r_slow & started, ptr_rescue, sc.clock_ptr),
        jnp.where(started | ~p_want, new_ptr, sc.clock_ptr))
    return dma, clock_ptr


# --------------------------------------------------------------------------- #
# the whole step: ref composition + truncated variants for the bench
# --------------------------------------------------------------------------- #

def step_ref(cfg: EmulatorConfig, registry: PolicyRegistry, table: jax.Array,
             params: RuntimeParams, sc: StepScalars, bank_free: jax.Array,
             page, offset, is_write, size, valid,
             faults: faults_lib.FaultPlan | None = None, *,
             seq: bool = False):
    """One chunk end-to-end (reads -> commit -> retire -> policy). The
    jnp reference AND the scan path; ``seq=True`` is the same step with
    the sequential in-kernel recurrences (what the Pallas body runs).
    ``faults`` defaults to the empty plan (bitwise no-op).

    Returns ``(table, scalars, bank_free, outs)`` with ``outs`` carrying
    per-request results (``returns`` masked, ``device`` raw post-redirect,
    ``latency`` masked), the ``held``/``poisoned``/``injected`` counter
    inputs, and the boundary's ``retired``/``tombstone`` page scalars
    (-1 when none).
    """
    if faults is None:
        faults = faults_lib.FaultPlan.empty()
    pipe = pipeline_phase(cfg, params, table, sc, bank_free,
                          page, offset, is_write, size, valid, seq=seq)
    # Transient fault injection: purely observational — the access
    # completes (the emulated device returned corrupt data); the serving
    # layer refetches.
    tc, tp = faults.transient[:, 0], faults.transient[:, 1]
    injected = ((page[:, None] == tp[None, :]) &
                (tc[None, :] == sc.chunk_idx)).any(axis=1) & valid
    table, dma, done, now, last_ret, min_wear, tombstone = commit_phase(
        cfg, params, table, sc, pipe, page, is_write, valid,
        eff_write_weight(params, registry))
    rescue_page = jnp.where(done & (tombstone >= 0), -1,
                            jnp.asarray(sc.rescue_page, jnp.int32))
    table, rescue_page, fault_cursor, retired = retire_phase(
        cfg, params, table, sc, rescue_page,
        jnp.asarray(sc.fault_cursor, jnp.int32), faults, page, valid)
    dma, clock_ptr = policy_phase(cfg, params, registry, table, sc, dma, now,
                                  page, is_write, valid, rescue_page,
                                  min_wear)
    any_valid = jnp.any(valid)
    sc2 = StepScalars(
        clock=now, clock_ptr=clock_ptr, chunk_idx=sc.chunk_idx + 1, dma=dma,
        link_free_rx=jnp.where(any_valid, pipe.rx_last, sc.link_free_rx),
        link_free_tx=jnp.where(any_valid, pipe.tx_last, sc.link_free_tx),
        last_return=last_ret, rescue_page=rescue_page,
        min_wear=jnp.asarray(min_wear, jnp.int32), fault_cursor=fault_cursor)
    outs = {"returns": jnp.where(valid, pipe.returns, 0),
            "device": pipe.dev, "latency": pipe.lat,
            "held": pipe.held, "poisoned": pipe.poisoned,
            "injected": injected, "retired": retired,
            "tombstone": jnp.asarray(tombstone, jnp.int32)}
    return table, sc2, pipe.bank_free, outs


STAGES = ("rx", "gather", "resolve", "return", "commit", "full")


def step_until(cfg: EmulatorConfig, registry: PolicyRegistry,
               table: jax.Array, params: RuntimeParams, sc: StepScalars,
               bank_free: jax.Array, page, offset, is_write, size, valid,
               faults: faults_lib.FaultPlan | None = None, *,
               upto: str = "full"):
    """A :func:`step_ref`-shaped step truncated after ``upto`` (one of
    :data:`STAGES`) — the per-stage breakdown lever of
    ``benchmarks/bench_chunk_step.py``. Truncated variants keep the carry
    structure (clock still advances; the retirement registers pass
    through untouched) so they scan; timing deltas between successive
    stages isolate each stage's cost."""
    if upto == "full":
        return step_ref(cfg, registry, table, params, sc, bank_free,
                        page, offset, is_write, size, valid, faults)
    if upto not in STAGES:
        raise ValueError(f"unknown stage {upto!r}; expected one of {STAGES}")
    n = page.shape[0]
    pipe_upto = upto if upto in ("rx", "gather", "resolve") else "full"
    pipe = pipeline_phase(cfg, params, table, sc, bank_free,
                          page, offset, is_write, size, valid,
                          upto=pipe_upto)
    outs = {"returns": jnp.where(valid, pipe.returns, 0),
            "device": pipe.dev, "latency": pipe.lat,
            "held": pipe.held, "poisoned": pipe.poisoned}
    any_valid = jnp.any(valid)
    if upto == "commit":
        table, dma, _, now, last_ret, min_wear, _ = commit_phase(
            cfg, params, table, sc, pipe, page, is_write, valid,
            eff_write_weight(params, registry))
        sc2 = StepScalars(
            clock=now, clock_ptr=sc.clock_ptr, chunk_idx=sc.chunk_idx + 1,
            dma=dma,
            link_free_rx=jnp.where(any_valid, pipe.rx_last, sc.link_free_rx),
            link_free_tx=jnp.where(any_valid, pipe.tx_last, sc.link_free_tx),
            last_return=last_ret, rescue_page=sc.rescue_page,
            min_wear=min_wear, fault_cursor=sc.fault_cursor)
        return table, sc2, pipe.bank_free, outs
    sc2 = StepScalars(
        clock=sc.clock + params.issue_gap * n, clock_ptr=sc.clock_ptr,
        chunk_idx=sc.chunk_idx + 1, dma=sc.dma,
        link_free_rx=jnp.where(any_valid, pipe.rx_last, sc.link_free_rx),
        link_free_tx=jnp.where(any_valid & (pipe_upto == "full"),
                               pipe.tx_last, sc.link_free_tx),
        last_return=sc.last_return, rescue_page=sc.rescue_page,
        min_wear=sc.min_wear, fault_cursor=sc.fault_cursor)
    return table, sc2, pipe.bank_free, outs


# --------------------------------------------------------------------------- #
# the Pallas path
# --------------------------------------------------------------------------- #

# RuntimeParams fields carried as float32 in the kernel's float operand;
# everything else rides the int32 scalar-prefetch vector. Must agree with
# RuntimeParams.from_config dtypes (asserted by the kernel test suite).
_FLOAT_PARAM_FIELDS = frozenset({
    "fast_bytes_per_cycle", "slow_bytes_per_cycle", "link_bytes_per_cycle",
    "pin_fast_fraction", "power_pj_per_bit_fast",
    "power_pj_per_bit_slow_read", "power_pj_per_bit_slow_write"})

# Scalar-state slots at the head of the int vector (before int params).
_N_SC = 14


def _pack_scalars(params: RuntimeParams, sc: StepScalars):
    """(int32[NI], float32[NF]): 14 state scalars + int params, and the
    float params. ``policy_id`` rides the int vector — that is the
    scalar-prefetched dispatch operand."""
    ints = [sc.clock, sc.clock_ptr, sc.chunk_idx, sc.dma.active,
            sc.dma.page_a, sc.dma.page_b, sc.dma.start, sc.dma.swaps_done,
            sc.link_free_rx, sc.link_free_tx, sc.last_return,
            sc.rescue_page, sc.min_wear, sc.fault_cursor]
    floats = []
    for name, v in zip(RuntimeParams._fields, params):
        (floats if name in _FLOAT_PARAM_FIELDS else ints).append(v)
    return (jnp.stack([jnp.asarray(v, jnp.int32) for v in ints]),
            jnp.stack([jnp.asarray(v, jnp.float32) for v in floats]))


def _unpack_scalars(ints: jax.Array, floats: jax.Array):
    """Inverse of :func:`_pack_scalars` (inside the kernel body)."""
    sc = StepScalars(
        clock=ints[0], clock_ptr=ints[1], chunk_idx=ints[2],
        dma=dma_lib.DMAState(active=ints[3], page_a=ints[4], page_b=ints[5],
                             start=ints[6], swaps_done=ints[7]),
        link_free_rx=ints[8], link_free_tx=ints[9], last_return=ints[10],
        rescue_page=ints[11], min_wear=ints[12], fault_cursor=ints[13])
    vals, ii, fi = {}, _N_SC, 0
    for name in RuntimeParams._fields:
        if name in _FLOAT_PARAM_FIELDS:
            vals[name] = floats[fi]
            fi += 1
        else:
            vals[name] = ints[ii]
            ii += 1
    return RuntimeParams(**vals), sc


@functools.lru_cache(maxsize=None)
def _pallas_step_fn(cfg: EmulatorConfig, registry: PolicyRegistry,
                    interpret: bool):
    """Build (and cache) the batched one-kernel step for one static
    geometry + frozen registry. The returned function takes/returns
    arrays with an arbitrary leading batch shape; its ``custom_vmap``
    rule maps a vmapped sweep's design-point axis onto the kernel's grid,
    so all points launch once per chunk."""

    def _body(ints_ref, table_ref, page_ref, offset_ref, iw_ref, size_ref,
              valid_ref, floats_ref, bank_free_ref, transient_ref,
              deaths_ref,
              out_table_ref, out_sc_ref, out_bank_ref,
              out_ret_ref, out_dev_ref, out_lat_ref, out_poi_ref,
              out_inj_ref):
        bi = pl.program_id(0)
        params, sc = _unpack_scalars(ints_ref[bi], floats_ref[0])
        faults = faults_lib.FaultPlan(transient=transient_ref[0],
                                      deaths=deaths_ref[0])
        table, sc2, bank_free2, outs = step_ref(
            cfg, registry, table_ref[0], params, sc, bank_free_ref[0],
            page_ref[0], offset_ref[0], iw_ref[0] != 0, size_ref[0],
            valid_ref[0] != 0, faults, seq=True)
        out_table_ref[0] = table
        out_sc_ref[0] = jnp.stack(
            [sc2.clock, sc2.clock_ptr, sc2.chunk_idx, sc2.dma.active,
             sc2.dma.page_a, sc2.dma.page_b, sc2.dma.start,
             sc2.dma.swaps_done, sc2.link_free_rx, sc2.link_free_tx,
             sc2.last_return, sc2.rescue_page, sc2.min_wear,
             sc2.fault_cursor, outs["held"], outs["retired"],
             outs["tombstone"]])
        out_bank_ref[0] = bank_free2
        out_ret_ref[0] = outs["returns"]
        out_dev_ref[0] = outs["device"]
        out_lat_ref[0] = outs["latency"]
        out_poi_ref[0] = outs["poisoned"].astype(jnp.int32)
        out_inj_ref[0] = outs["injected"].astype(jnp.int32)

    @custom_batching.custom_vmap
    def step(table, page, offset, is_write, size, valid, ints, floats,
             bank_free, transient, deaths):
        batch = table.shape[:-2]
        n_pages, w = table.shape[-2:]
        chunk = page.shape[-1]
        ni = ints.shape[-1]
        nf = floats.shape[-1]
        nb = bank_free.shape[-1]
        nt = transient.shape[-2]
        nd = deaths.shape[-2]
        tb = table.reshape(-1, n_pages, w)
        b = tb.shape[0]

        def vec(x):
            return x.reshape(b, -1)

        def spec(*shape):
            return pl.BlockSpec((1, *shape),
                                lambda bi, ints: (bi,) + (0,) * len(shape))

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b,),
            in_specs=[spec(n_pages, w), spec(chunk), spec(chunk),
                      spec(chunk), spec(chunk), spec(chunk), spec(nf),
                      spec(nb), spec(nt, 2), spec(nd, 2)],
            out_specs=[spec(n_pages, w), spec(_N_SC + 3), spec(nb),
                       spec(chunk), spec(chunk), spec(chunk), spec(chunk),
                       spec(chunk)],
        )
        i32 = jnp.int32
        outs = pl.pallas_call(
            _body,
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct((b, n_pages, w), i32),
                jax.ShapeDtypeStruct((b, _N_SC + 3), i32),
                jax.ShapeDtypeStruct((b, nb), i32),
                jax.ShapeDtypeStruct((b, chunk), i32),
                jax.ShapeDtypeStruct((b, chunk), i32),
                jax.ShapeDtypeStruct((b, chunk), i32),
                jax.ShapeDtypeStruct((b, chunk), i32),
                jax.ShapeDtypeStruct((b, chunk), i32),
            ],
            interpret=interpret,
        )(vec(ints), tb, vec(page), vec(offset), vec(is_write), vec(size),
          vec(valid), vec(floats), vec(bank_free),
          transient.reshape(-1, nt, 2), deaths.reshape(-1, nd, 2))
        tbl2, scv, bf2, ret, dev, lat, poi, inj = outs
        return (tbl2.reshape(*batch, n_pages, w),
                scv.reshape(*batch, _N_SC + 3),
                bf2.reshape(*batch, nb),
                ret.reshape(*batch, chunk), dev.reshape(*batch, chunk),
                lat.reshape(*batch, chunk), poi.reshape(*batch, chunk),
                inj.reshape(*batch, chunk))

    @step.def_vmap
    def _step_vmap(axis_size, in_batched, *args):
        # vmap (the sweep's design-point axis) becomes the kernel's
        # leading grid axis: one launch steps every design point's chunk.
        # The sweep batches state + params but shares the trace (and, for
        # a shared fault scenario, the plan), so broadcast whichever
        # operands aren't batched.
        args = tuple(
            a if b else jnp.broadcast_to(a, (axis_size, *a.shape))
            for a, b in zip(args, in_batched))
        return step(*args), (True,) * 8

    return step


def use_chunk_step_kernel(cfg: EmulatorConfig) -> bool:
    """Resolve the ``chunk_step_kernel`` knob (static, host-side): "on"
    forces the kernel (interpret mode off-TPU — how CPU tests run it),
    "off" forces the scan path, "auto" follows the same dispatch as
    ``hmmu_lookup`` (:func:`kernels.ops.use_pallas`) with a VMEM budget
    check on the resident table."""
    knob = cfg.chunk_step_kernel
    if knob == "off":
        return False
    if knob == "on":
        return True
    if knob != "auto":
        raise ValueError(f"unknown chunk_step_kernel {knob!r}; expected "
                         "'auto', 'on' or 'off'")
    return (kernel_ops.use_pallas() and
            cfg.n_pages * table_lib.ROW_W * 4 <= VMEM_TABLE_BUDGET)


def chunk_step(cfg: EmulatorConfig, registry: PolicyRegistry,
               table: jax.Array, params: RuntimeParams, sc: StepScalars,
               bank_free: jax.Array, page, offset, is_write, size, valid,
               faults: faults_lib.FaultPlan | None = None):
    """THE chunk step — one-kernel Pallas path or the scan path, resolved
    by :func:`use_chunk_step_kernel` (bitwise identical either way).
    Signature/returns as :func:`step_ref`."""
    if faults is None:
        faults = faults_lib.FaultPlan.empty()
    if not use_chunk_step_kernel(cfg):
        return step_ref(cfg, registry, table, params, sc, bank_free,
                        page, offset, is_write, size, valid, faults)
    fn = _pallas_step_fn(cfg, registry, kernel_ops._interpret())
    ints, floats = _pack_scalars(params, sc)
    tbl2, scv, bank_free2, returns, dev, lat, poi, inj = fn(
        table, page, offset, is_write.astype(jnp.int32), size,
        valid.astype(jnp.int32), ints, floats, bank_free,
        faults.transient, faults.deaths)
    sc2 = StepScalars(
        clock=scv[0], clock_ptr=scv[1], chunk_idx=scv[2],
        dma=dma_lib.DMAState(active=scv[3], page_a=scv[4], page_b=scv[5],
                             start=scv[6], swaps_done=scv[7]),
        link_free_rx=scv[8], link_free_tx=scv[9], last_return=scv[10],
        rescue_page=scv[11], min_wear=scv[12], fault_cursor=scv[13])
    outs = {"returns": returns, "device": dev, "latency": lat,
            "held": scv[14], "poisoned": poi != 0, "injected": inj != 0,
            "retired": scv[15], "tombstone": scv[16]}
    return tbl2, sc2, bank_free2, outs
