"""RWKV6 chunked linear attention — Pallas TPU kernel.

The chunked formulation (models/rwkv.py) turns the data-dependent-decay
recurrence into per-chunk matmuls plus a tiny cross-chunk state update.
This kernel keeps the [Dk, Dv] state in VMEM scratch across the chunk
grid axis ('arbitrary'), so HBM sees each token exactly once — the
recurrence never round-trips.

Grid: (B*H, S/C). Blocks: r/k/v/logw tiles [C, D] in VMEM; u row [1, D].
All accumulation fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_ref, *,
            chunk: int):
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)          # [C, Dk]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # [C, Dv]
    lw = lw_ref[0].astype(jnp.float32)        # [C, Dk] log-decay (negative)
    u = u_ref[0].astype(jnp.float32)          # [Dk]

    lw_cum = jnp.cumsum(lw, axis=0)
    lw_tot = lw_cum[-1]                       # [Dk]

    qp = r * jnp.exp(lw_cum - lw)             # r_t * A_{t-1}
    kp = k * jnp.exp(-lw_cum)                 # k_s / A_s
    kt = k * jnp.exp(lw_tot[None, :] - lw_cum)

    att = jax.lax.dot_general(qp, kp, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    c = att.shape[0]
    ti = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    att = jnp.where(si < ti, att, 0.0)        # strictly lower triangular
    diag = jnp.sum(r * k * u[None, :], axis=1)

    intra = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    intra = intra + diag[:, None] * v
    carry = jax.lax.dot_general(qp, state_ref[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0] = (intra + carry).astype(o_ref.dtype)

    state_ref[...] = state_ref[...] * jnp.exp(lw_tot)[:, None] + \
        jax.lax.dot_general(kt, v, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv_chunk_scan(r, k, v, logw, u, chunk: int = 128,
                    interpret: bool = False):
    """r/k/v/logw: [B,H,S,D]; u: [H,D] -> out [B,H,S,Dv] (fp32).

    Returns the per-position outputs only (the final state, needed for
    decode hand-off, comes from the jnp reference path — training uses
    outputs alone)."""
    b, h, s, dk = r.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    assert s % c == 0, (s, c)

    flat = lambda x: x.reshape(b * h, s, x.shape[-1])
    u_flat = jnp.broadcast_to(u[None], (b, h, dk)).reshape(b * h, dk)

    grid = (b * h, s // c)
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, dk), lambda i, n: (i, n, 0)),
            pl.BlockSpec((1, c, dk), lambda i, n: (i, n, 0)),
            pl.BlockSpec((1, c, dv), lambda i, n: (i, n, 0)),
            pl.BlockSpec((1, c, dk), lambda i, n: (i, n, 0)),
            pl.BlockSpec((1, dk), lambda i, n: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, dv), lambda i, n: (i, n, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dv), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(flat(r), flat(k), flat(v), flat(logw), u_flat)
    return out.reshape(b, h, s, dv)
