"""HMMU redirection-table lookup engine — Pallas TPU kernel.

The paper's hottest pipeline stage: for every request in a chunk, fetch
the page's redirection-table row (device, frame, flags, hotness, ...).
On the FPGA this is a BRAM read per cycle; the TPU-native analogue is a
scalar-prefetch-driven DMA gather: the page indices ride in SMEM ahead of
the grid (``PrefetchScalarGridSpec``), and each grid step's BlockSpec
index_map *is* the table lookup — the DMA engine chases the indices
through HBM while compute overlaps.

Table rows are packed int32[W] (device, frame, hotness, epoch, flags,
pad...). W=8 keeps rows compact; on a real TPU the row tile pads to the
(8, 128) int32 native tile, which the dry-run roofline accounts as the
gather's bandwidth cost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROW_W = 8  # int32 lanes per table row


def _kernel(pages_ref, table_ref, out_ref):
    # pages_ref is the scalar-prefetch operand; the gather already happened
    # in the index_map. The body just moves the row VMEM -> VMEM.
    out_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def hmmu_lookup(table: jax.Array, pages: jax.Array, *,
                interpret: bool = False) -> jax.Array:
    """Gather redirection-table rows for a request chunk.

    table: int32[n_pages, ROW_W]; pages: int32[chunk] -> int32[chunk, ROW_W].
    """
    chunk = pages.shape[0]
    w = table.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(chunk,),
        in_specs=[
            pl.BlockSpec((1, w), lambda i, pages: (pages[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda i, pages: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((chunk, w), jnp.int32),
        interpret=interpret,
    )(pages.astype(jnp.int32), table)
