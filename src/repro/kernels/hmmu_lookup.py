"""HMMU redirection-table lookup engine — Pallas TPU kernel.

The paper's hottest pipeline stage: for every request in a chunk, fetch
the page's redirection-table row (device, frame, hotness, wear, owner,
epoch, flags — the packed layout defined in ``repro.core.table``).
On the FPGA this is a BRAM read per cycle; the TPU-native analogue is a
scalar-prefetch-driven DMA gather: the page indices ride in SMEM ahead of
the grid (``PrefetchScalarGridSpec``), and each grid step's BlockSpec
index_map *is* the table lookup — the DMA engine chases the indices
through HBM while compute overlaps.

The kernel is layout-agnostic (it gathers whole rows of whatever width
the table carries) and batched: a leading batch axis on ``table`` and
``pages`` maps to a leading grid axis, so a vmapped design-space sweep
(``repro.sweep``) gathers the rows of *every* design point's chunk in one
kernel launch. Page indices are clamped to the table extent before the
gather — an out-of-range page can never make the index_map fetch an
arbitrary row.

W=8 keeps rows compact; on a real TPU the row tile pads to the (8, 128)
int32 native tile, which the dry-run roofline accounts as the gather's
bandwidth cost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# int32 lanes per table row. Must equal ``repro.core.table.ROW_W`` (the
# authoritative layout; kept separate to avoid a core <-> kernels import
# cycle — the test suite asserts the two agree).
ROW_W = 8


def _kernel(pages_ref, table_ref, out_ref):
    # pages_ref is the scalar-prefetch operand; the gather already happened
    # in the index_map. The body just moves the row VMEM -> VMEM.
    out_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def hmmu_lookup(table: jax.Array, pages: jax.Array, *,
                interpret: bool = False) -> jax.Array:
    """Gather redirection-table rows for one or many request chunks.

    table: int32[*batch, n_pages, W]; pages: int32[*batch, chunk]
    -> int32[*batch, chunk, W]. ``batch`` may be empty (single platform)
    or any leading shape (e.g. the sweep's design-point axis); batch dims
    of ``table`` and ``pages`` must match. ``pages`` entries are clamped
    to [0, n_pages).
    """
    batch = table.shape[:-2]
    n_pages, w = table.shape[-2:]
    chunk = pages.shape[-1]
    if pages.shape[:-1] != batch:
        raise ValueError(
            f"batch dims disagree: table {batch} vs pages {pages.shape[:-1]}")
    # Bounds safety: an out-of-range page must not index whatever the
    # index_map would produce (mod-n wraparound on TPU, UB elsewhere).
    pages = jnp.clip(pages.astype(jnp.int32), 0, n_pages - 1)

    tb = table.reshape((-1, n_pages, w))
    pg = pages.reshape((-1, chunk))
    b = tb.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, chunk),
        in_specs=[
            pl.BlockSpec((1, 1, w), lambda bi, i, pages: (bi, pages[bi, i], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, w), lambda bi, i, pages: (bi, i, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, chunk, w), jnp.int32),
        interpret=interpret,
    )(pg, tb)
    return out.reshape(*batch, chunk, w)


def hmmu_lookup_fused(table: jax.Array, pages: jax.Array,
                      extra: jax.Array, *, interpret: bool = False
                      ) -> tuple[jax.Array, jax.Array]:
    """Gather a chunk's rows AND a few extra rows (the DMA swap pair) in
    ONE kernel launch: the extra page indices ride at the tail of the
    scalar-prefetch vector, extending the grid to ``chunk + k`` steps.

    table: int32[*batch, n_pages, W]; pages: int32[*batch, chunk];
    extra: int32[*batch, k] -> (int32[*batch, chunk, W],
    int32[*batch, k, W]). Same clamp semantics as :func:`hmmu_lookup`.
    """
    from .ref import fused_gather
    return fused_gather(functools.partial(hmmu_lookup, interpret=interpret),
                        table, pages, extra)
