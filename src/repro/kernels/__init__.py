"""Pallas TPU kernels for the platform's compute hot-spots.

Each kernel ships three files:
    <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
    ops.py    — jit'd public wrappers that dispatch kernel vs reference
    ref.py    — pure-jnp oracles the tests assert against

Kernels run in interpret mode on CPU (validation) and compiled on TPU.
Set ``REPRO_FORCE_PALLAS=1`` to force the kernel path (interpret on CPU),
``REPRO_FORCE_REF=1`` to force the reference path.
"""
from .ops import (flash_attention, decode_attention, hmmu_lookup,
                  rwkv_chunk, use_pallas)

__all__ = ["flash_attention", "decode_attention", "hmmu_lookup",
           "rwkv_chunk", "use_pallas"]
