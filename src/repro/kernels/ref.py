"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are also the lowering path used on non-TPU backends and for the
multi-pod dry-run: XLA's fused attention is numerically identical and has
the same FLOP count, so roofline compute terms are unaffected.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_expand(k: jax.Array, n_q_heads: int) -> jax.Array:
    """[B, Hkv, S, D] -> [B, Hq, S, D] by repeating each kv head."""
    b, hkv, s, d = k.shape
    group = n_q_heads // hkv
    return jnp.repeat(k, group, axis=1) if group > 1 else k


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              scale: float | None = None) -> jax.Array:
    """Reference multi-head attention with GQA, causal and sliding-window
    masking. q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D]. Sq == Skv or the
    final Sq positions of the kv sequence (prefill continuation)."""
    b, hq, sq, d = q.shape
    skv = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    k = _gqa_expand(k, hq)
    v = _gqa_expand(v, hq)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(sq)[:, None] + (skv - sq)   # absolute q positions
    ki = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= qi - ki < window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, scale: float | None = None,
                     window: int | None = None) -> jax.Array:
    """Single-token decode attention over a (padded) KV cache.

    q: [B, Hq, D]; k_cache/v_cache: [B, Hkv, Smax, D]; kv_len: int32[B] —
    number of valid cache entries per sequence (the new token's position is
    kv_len - 1)."""
    b, hq, d = q.shape
    smax = k_cache.shape[2]
    scale = scale if scale is not None else d ** -0.5
    k = _gqa_expand(k_cache, hq).astype(jnp.float32)
    v = _gqa_expand(v_cache, hq).astype(jnp.float32)
    logits = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), k) * scale
    ki = jnp.arange(smax)[None, None, :]
    mask = ki < kv_len[:, None, None]
    if window is not None:
        mask &= ki >= (kv_len[:, None, None] - window)
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bhkd->bhd", p, v)
    return out.astype(q.dtype)


def hmmu_lookup(table: jax.Array, pages: jax.Array) -> jax.Array:
    """Redirection-table row gather, with the same bounds clamp as the
    Pallas kernel. table: int32[*batch, n_pages, W]; pages:
    int32[*batch, chunk] -> int32[*batch, chunk, W]."""
    n_pages = table.shape[-2]
    pages = jnp.clip(pages, 0, n_pages - 1)
    idx = jnp.broadcast_to(pages[..., None], pages.shape + table.shape[-1:])
    return jnp.take_along_axis(table, idx, axis=-2)


def fused_gather(lookup, table: jax.Array, pages: jax.Array,
                 extra: jax.Array) -> tuple[jax.Array, jax.Array]:
    """THE fused chunk+extra gather: append ``extra`` page indices to the
    chunk's page vector, run ONE ``lookup(table, pages)`` gather over the
    combined ``chunk + k`` indices, split the rows back. Shared by the
    Pallas kernel, the jnp reference and the ops dispatcher so the
    concat/split semantics (and clamping, done inside ``lookup``) can
    never diverge between the bit-compared paths."""
    cat = jnp.concatenate([pages, extra], axis=-1)
    rows = lookup(table, cat)
    n = pages.shape[-1]
    return rows[..., :n, :], rows[..., n:, :]


def hmmu_lookup_fused(table: jax.Array, pages: jax.Array, extra: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Fused chunk + extra-rows gather (reference for the fused kernel).
    Same clamp semantics as :func:`hmmu_lookup`."""
    return fused_gather(hmmu_lookup, table, pages, extra)
