"""Public jit'd wrappers: dispatch Pallas kernel vs jnp reference.

Kernel path on TPU (compiled) or when REPRO_FORCE_PALLAS=1 (interpret mode
on CPU — used by the kernel test suite). Reference path everywhere else,
including the multi-pod dry-run on the CPU host.

``flash_attention`` is differentiable: the Pallas forward pairs with a
recompute-based reference backward via jax.custom_vjp (the standard
memory-saving trade — the backward re-runs reference attention under
autodiff, which XLA fuses; a dedicated backward kernel is a possible
future optimization and would not change the roofline compute term).
"""
from __future__ import annotations

import functools
import os

import jax
from jax import custom_batching

from . import ref
from . import flash_attention as _fa
from . import decode_attention as _da
from . import hmmu_lookup as _hl
from . import rwkv_scan as _rw


def use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_REF"):
        return False
    if os.environ.get("REPRO_FORCE_PALLAS"):
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------- #
# flash attention (training / prefill)
# --------------------------------------------------------------------------- #

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_attn(q, k, v, causal, window, scale):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, interpret=_interpret())


def _flash_attn_fwd(q, k, v, causal, window, scale):
    out = _flash_attn(q, k, v, causal, window, scale)
    return out, (q, k, v)


def _flash_attn_bwd(causal, window, scale, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: ref.attention(q, k, v, causal=causal, window=window,
                                      scale=scale), q, k, v)
    return vjp(g)


_flash_attn.defvjp(_flash_attn_fwd, _flash_attn_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None) -> jax.Array:
    """[B, Hq, Sq, D] x [B, Hkv, Skv, D]^2 -> [B, Hq, Sq, D]."""
    if use_pallas():
        return _flash_attn(q, k, v, causal, window, scale)
    return ref.attention(q, k, v, causal=causal, window=window, scale=scale)


# --------------------------------------------------------------------------- #
# flash decode (serving)
# --------------------------------------------------------------------------- #

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, scale: float | None = None,
                     window: int | None = None) -> jax.Array:
    """[B, Hq, D] x [B, Hkv, Smax, D]^2 + int32[B] -> [B, Hq, D]."""
    if use_pallas():
        return _da.decode_attention(q, k_cache, v_cache, kv_len, scale=scale,
                                    window=window, interpret=_interpret())
    return ref.decode_attention(q, k_cache, v_cache, kv_len, scale=scale,
                                window=window)


# --------------------------------------------------------------------------- #
# HMMU table lookup (emulation platform hot loop)
# --------------------------------------------------------------------------- #

@custom_batching.custom_vmap
def _hmmu_lookup_pallas(table: jax.Array, pages: jax.Array) -> jax.Array:
    return _hl.hmmu_lookup(table, pages, interpret=_interpret())


@_hmmu_lookup_pallas.def_vmap
def _hmmu_lookup_vmap(axis_size, in_batched, table, pages):
    # vmap (the sweep's design-point axis) becomes the kernel's leading
    # batch/grid axis: one launch gathers every design point's chunk. The
    # sweep batches the table (per-point state) but shares the trace, so
    # broadcast whichever operand isn't batched.
    table_b, pages_b = in_batched
    if not table_b:
        table = jax.numpy.broadcast_to(table, (axis_size, *table.shape))
    if not pages_b:
        pages = jax.numpy.broadcast_to(pages, (axis_size, *pages.shape))
    return _hmmu_lookup_pallas(table, pages), True


def hmmu_lookup(table: jax.Array, pages: jax.Array) -> jax.Array:
    """int32[*batch, n_pages, W] x int32[*batch, chunk]
    -> int32[*batch, chunk, W]. Page indices are clamped to the table
    extent in both paths (bounds safety)."""
    if use_pallas():
        return _hmmu_lookup_pallas(table, pages)
    return ref.hmmu_lookup(table, pages)


def hmmu_lookup_fused(table: jax.Array, pages: jax.Array,
                      extra: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused gather of a chunk's rows plus ``k`` extra rows (the emulator
    passes the DMA swap pair, so stage 2 needs exactly one launch per
    step). The extra indices are appended to the prefetch vector and the
    combined gather goes through the SAME batched kernel / custom_vmap
    rule as :func:`hmmu_lookup` — a vmapped sweep still fuses every
    design point into one launch. Returns (chunk rows, extra rows)."""
    if use_pallas():
        return ref.fused_gather(_hmmu_lookup_pallas, table, pages, extra)
    return ref.hmmu_lookup_fused(table, pages, extra)


# --------------------------------------------------------------------------- #
# rwkv6 chunked linear attention (SSM-family training hot spot)
# --------------------------------------------------------------------------- #

def rwkv_chunk(r, k, v, logw, u, *, chunk: int = 128):
    """[B,H,S,D]^4 + [H,D] -> fp32 [B,H,S,Dv]. Kernel on TPU, jnp
    reference elsewhere (the reference also returns the carry state used
    by decode; see models.rwkv)."""
    if use_pallas():
        return _rw.rwkv_chunk_scan(r, k, v, logw, u, chunk=chunk,
                                   interpret=_interpret())
    from repro.models.rwkv import rwkv_chunk_scan as _ref
    return _ref(r, k, v, logw, u, chunk)[0]
