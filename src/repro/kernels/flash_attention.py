"""Blocked causal flash attention — Pallas TPU kernel.

Tiling: grid (batch*q_heads, q_blocks, kv_blocks), kv innermost with
'arbitrary' semantics so the online-softmax accumulators persist in VMEM
scratch across kv steps. Block shapes are MXU-aligned (multiples of 128 on
the matmul dims when the head dim allows). Causal and sliding-window block
skipping happens at grid level via @pl.when — skipped blocks cost a VMEM
tile load, not an MXU pass.

GQA is handled by the k/v index maps (q head h reads kv head h // group),
so kv tiles are fetched once per group from HBM's point of view after
XLA's revisit caching.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, causal: bool,
            window: int | None, seq_q: int, seq_kv: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Absolute positions. q may be the tail of the kv sequence.
    q_off = seq_kv - seq_q
    qi = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_off
    ki = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Block-level skip: entirely-masked kv blocks do no compute.
    blk_lo = ik * block_k                      # first ki in block
    q_hi = iq * block_q + block_q - 1 + q_off  # last qi in block
    needed = True
    if causal:
        needed = blk_lo <= q_hi
    if window is not None:
        q_lo = iq * block_q + q_off
        needed = needed & (ik * block_k + block_k - 1 >= q_lo - window + 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)       # [block_q, d]
        k = k_ref[0].astype(jnp.float32)       # [block_k, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= ki <= qi
        if window is not None:
            mask &= qi - ki < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)        # fully-masked rows -> 0 output
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D] -> [B, Hq, Sq, D]."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, block_q, skv, block_k)

    qr = q.reshape(b * hq, sq, d)
    kr = k.reshape(b * hkv, skv, d)
    vr = v.reshape(b * hkv, skv, d)

    grid = (b * hq, sq // block_q, skv // block_k)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal, window=window,
                          seq_q=sq, seq_kv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda h, iq, ik, g=group: (h // g, ik, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda h, iq, ik, g=group: (h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d)
