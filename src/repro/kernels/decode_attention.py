"""Flash-decode — single-token attention over a long KV cache, Pallas TPU.

The decode hot spot is memory-bound: one query row must stream the whole
cache from HBM. The kernel tiles the cache on the sequence axis (grid
(batch*q_heads, kv_blocks)) and keeps the running (max, sum, acc) partial
softmax in VMEM scratch, so the cache is read exactly once at full HBM
bandwidth — the roofline optimum for decode. Valid-length masking handles
ragged batches; an optional sliding window serves the local layers of
window-attention architectures.

This kernel is what the tiered (DRAM/NVM-style) KV cache of repro.memtier
feeds: hot pages gathered into the contiguous fast-tier buffer are exactly
the ``k_cache``/``v_cache`` arguments here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_k: int, window: int | None, hq: int):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0]
    lo = ik * block_k
    needed = lo < kv_len
    if window is not None:
        needed = needed & (lo + block_k > kv_len - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # [1, d] row
        k = k_ref[0].astype(jnp.float32)            # [block_k, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)[0] * scale
        ki = lo + jax.lax.broadcasted_iota(jnp.int32, (block_k,), 0)
        mask = ki < kv_len
        if window is not None:
            mask &= ki >= kv_len - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p[None, :], v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0]
        m_ref[0] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.where(l_ref[0] == 0.0, 1.0, l_ref[0])
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "block_k", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, scale: float | None = None,
                     window: int | None = None, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: [B, Hq, D]; k_cache/v_cache: [B, Hkv, Smax, D]; kv_len: int32[B]
    -> [B, Hq, D]."""
    b, hq, d = q.shape
    _, hkv, smax, _ = k_cache.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    block_k = min(block_k, smax)
    assert smax % block_k == 0, (smax, block_k)

    qr = q.reshape(b * hq, 1, d)
    kr = k_cache.reshape(b * hkv, smax, d)
    vr = v_cache.reshape(b * hkv, smax, d)
    lens = kv_len.astype(jnp.int32)

    grid = (b * hq, smax // block_k)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k,
                          window=window, hq=hq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda h, ik, hq=hq: (h // hq,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda h, ik: (h, 0, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda h, ik, g=group: (h // g, ik, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda h, ik, g=group: (h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda h, ik: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(b, hq, d)
