"""Pass ``docrefs`` — no stale references to deleted entry points.

PR 7 deleted the legacy free functions (``emulate``, ``run_sweep``,
``run_trace``, ``emulate_channels``) and ``sweep/runner.py`` in favor of
the ``repro.Engine`` session API, but docstrings and comments kept
pointing readers at them. Dead identifiers cannot break tests, so only
a text-level check holds the line: any mention of a legacy token in a
``.py`` file under the scanned roots is a finding.

README.md keeps its migration table (legacy name -> session API) on
purpose, so the scan covers Python sources only. The analysis package
itself is excluded — this file names the banned tokens as data.
"""
from __future__ import annotations

import pathlib
import re

from .common import Finding, apply_pragmas, iter_py_files, rel

PASS = "docrefs"

TOKENS: tuple[tuple[re.Pattern, str], ...] = (
    (re.compile(r"``emulate``"), "``emulate`` doc reference"),
    (re.compile(r"(?<![\w`])emulate\("), "legacy `emulate(` call form"),
    (re.compile(r"\brun_sweep\b"), "legacy `run_sweep`"),
    (re.compile(r"\brun_trace\b"), "legacy `run_trace`"),
    (re.compile(r"\bemulate_channels\b"), "legacy `emulate_channels`"),
    (re.compile(r"\bsweep[./]runner\b"), "deleted `sweep/runner.py`"),
)

SCAN_DIRS = ("src/repro", "benchmarks", "examples", "tests")


def check_source(source: str, path: str) -> list[Finding]:
    findings: list[Finding] = []
    for i, text in enumerate(source.splitlines(), start=1):
        for pat, what in TOKENS:
            if pat.search(text):
                findings.append(Finding(
                    path, i, PASS,
                    f"{what} — deleted in the Engine migration; point "
                    "readers at repro.Engine (see README migration "
                    "table)"))
    return apply_pragmas(findings, source)


def check_file(path: pathlib.Path) -> list[Finding]:
    return check_source(path.read_text(), rel(path))


def run_repo(root: pathlib.Path) -> list[Finding]:
    findings: list[Finding] = []
    for path in iter_py_files(root, SCAN_DIRS):
        if "analysis" in path.parts or "analysis_fixtures" in path.parts:
            continue
        if path.name == "test_analysis.py":
            continue
        findings += check_file(path)
    return findings


def run_paths(paths) -> list[Finding]:
    findings: list[Finding] = []
    for path in paths:
        findings += check_file(pathlib.Path(path))
    return findings
