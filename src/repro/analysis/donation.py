"""Pass ``donation`` — donated buffers must actually alias, and callers
must not read a variable after donating it.

Two silently-dropped-donation bugs were fixed ad hoc in PRs 4 and 5:
XLA ignores ``donate_argnums`` without any error when the donated buffer
cannot be aliased into an output (wrong dtype/shape pairing, or the
argument index drifted after a refactor), and callers kept reading
states they had already donated. This pass makes both mechanical:

  * **aliasing cross-check** (lowering-level): lower every registered
    ``donate_argnums`` entry point and require the donated table buffer
    to carry an input-output aliasing attribute (``tf.aliasing_output``
    / ``jax.buffer_donor``) in the stablehlo module. A donation XLA
    dropped produces no attribute — and a finding.
  * **site registry** (AST): every ``donate_argnums=`` occurrence in the
    tree must be a registered, cross-checked site (or carry a
    ``# reprolint: allow[donation]`` pragma saying why it is exempt).
  * **read-after-donate** (AST): after a statement passes a name as a
    donated argument (``state=``/``states=`` keyword to a session-API
    call without ``donate=False``, the first argument of
    ``continue_sweep``, or any call with ``donate=True``), a later read
    of that name — without an intervening rebind — is a finding.

Fixture protocol: ``reprolint_case()`` returning
``{"kind": "donation", "make": lambda: (jitted_fn, args, donate_argnums)}``
— the checker lowers ``jitted_fn`` on ``args`` and reports donated
arguments whose buffers did not alias.
"""
from __future__ import annotations

import ast
import pathlib
import re

from .common import Finding, apply_pragmas, iter_py_files, rel

PASS = "donation"

# Every donate_argnums site in the tree must appear here (and be covered
# by check_repo_aliasing below) or carry an allow-pragma.
REGISTERED_SITES = {
    "src/repro/core/emulator.py",
    "src/repro/serve/contracts.py",
}

_PARAM_RE = re.compile(
    r"%arg(\d+): tensor<([0-9x]+)x(i32|f32|i1)>\s*(\{[^}]*\})?")


def _aliased_args(lowered_text: str) -> tuple[dict[int, str], set[int]]:
    """Parse the stablehlo ``@main`` signature: returns
    ``{argnum: dims}`` for all params and the set of argnums carrying an
    aliasing/donor attribute."""
    start = lowered_text.find("func.func public @main")
    sig = lowered_text[start:lowered_text.find("{\n", start)]
    dims: dict[int, str] = {}
    aliased: set[int] = set()
    for m in _PARAM_RE.finditer(sig):
        argnum = int(m.group(1))
        dims[argnum] = m.group(2)
        attrs = m.group(4) or ""
        if "aliasing_output" in attrs or "buffer_donor" in attrs:
            aliased.add(argnum)
    return dims, aliased


def _table_dims(n_pages: int, batch: int | None = None) -> str:
    return (f"{batch}x{n_pages}x8" if batch is not None
            else f"{n_pages}x8")


def _require_table_alias(lowered_text, want_dims, site, line) -> list[Finding]:
    dims, aliased = _aliased_args(lowered_text)
    hits = [a for a, d in dims.items() if d == want_dims]
    if not hits:
        return [Finding(site, line, PASS,
                        f"no tensor<{want_dims}xi32> parameter in the "
                        "lowered module — the aliasing cross-check needs "
                        "updating for this entry point")]
    if not any(a in aliased for a in hits):
        return [Finding(site, line, PASS,
                        "donation dropped: the donated table buffer "
                        f"(tensor<{want_dims}xi32>) lowered WITHOUT an "
                        "input-output aliasing attribute — XLA will copy "
                        "the table every call")]
    return []


def _probe_cfg():
    """A geometry no test uses (distinct static_key), so the probe's
    entry-cache entries never perturb compile-count assertions."""
    from repro.core.config import canonical_config, small_platform

    return canonical_config(small_platform(
        n_fast_pages=4, n_slow_pages=28, chunk=8))


def check_repo_aliasing() -> list[Finding]:
    """Lower each registered donation site and verify the table aliases."""
    import jax
    import jax.numpy as jnp

    from repro.core import emulator as emu
    from repro.core.config import RuntimeParams
    from repro.core.faults import FaultPlan
    from repro.serve import contracts

    findings: list[Finding] = []
    cfg = _probe_cfg()
    registry = emu.as_registry(None)
    params = RuntimeParams.from_config(cfg)
    state = emu.init_state(cfg, params)
    i32 = jnp.int32
    n = cfg.chunk
    trace = emu.Trace(page=jnp.zeros(n, i32), offset=jnp.zeros(n, i32),
                      is_write=jnp.zeros(n, bool),
                      size=jnp.full(n, cfg.line_size, i32))
    valid = jnp.ones(n, bool)
    faults = FaultPlan.empty()

    # Site 1: the single-run entry point, donated carried state (arg 4).
    fn = emu.entry_point(cfg, registry, donate=True,
                         shape_sig=("reprolint", n))
    txt = fn.lower(cfg, registry, trace, valid, state, params,
                   faults).as_text()
    findings += _require_table_alias(
        txt, _table_dims(cfg.n_pages), "src/repro/core/emulator.py", 261)

    # Site 2: the batch (sweep) entry point with carried stacked states —
    # the continue_sweep path that regressed in PR 5.
    stack = lambda a, b: jnp.stack([a, b])
    params2 = jax.tree.map(stack, params, params)
    states2 = jax.tree.map(stack, state, state)
    fnb = emu.entry_point(cfg, registry, batch=True, donate=True,
                          shape_sig=("reprolint-batch", n, 2))
    txtb = fnb.lower(cfg, registry, trace, valid, states2, params2,
                     faults).as_text()
    findings += _require_table_alias(
        txtb, _table_dims(cfg.n_pages, 2), "src/repro/core/emulator.py",
        261)

    # Site 3+4: the serving pin-contract FLAGS stamp/release (donate the
    # table, arg 0).
    table = state.table
    pages = jnp.zeros(4, i32)
    live = jnp.ones(4, bool)
    txts = contracts._stamp.lower(
        table, jnp.int32(0), jnp.int32(-1), jnp.int32(-1), pages, live,
        n_pages=cfg.n_pages).as_text()
    findings += _require_table_alias(
        txts, _table_dims(cfg.n_pages), "src/repro/serve/contracts.py", 38)
    txtr = contracts._release.lower(
        table, pages, live, n_pages=cfg.n_pages).as_text()
    findings += _require_table_alias(
        txtr, _table_dims(cfg.n_pages), "src/repro/serve/contracts.py", 55)
    return findings


# --- AST checks -----------------------------------------------------------


def _is_false(node) -> bool:
    return isinstance(node, ast.Constant) and node.value is False


def _donated_names(call: ast.Call) -> list[str]:
    """Names a call consumes under the donation conventions."""
    kw = {k.arg: k.value for k in call.keywords if k.arg}
    if _is_false(kw.get("donate")):
        return []
    out = []
    explicit = isinstance(kw.get("donate"), ast.Constant) and \
        kw["donate"].value is True
    for name in ("state", "states"):
        v = kw.get(name)
        if isinstance(v, ast.Name):
            fn = call.func
            session_call = (isinstance(fn, ast.Attribute) and fn.attr in
                            ("run", "run_stream", "run_channels", "sweep",
                             "continue_sweep"))
            if session_call or explicit:
                out.append(v.id)
    if (isinstance(call.func, ast.Attribute)
            and call.func.attr == "continue_sweep" and call.args
            and isinstance(call.args[0], ast.Name)):
        out.append(call.args[0].id)
    return out


def _assigned_names(stmt) -> set[str]:
    out: set[str] = set()
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign, ast.For)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.With):
        targets = [i.optional_vars for i in stmt.items if i.optional_vars]
    for t in targets:
        for node in ast.walk(t):
            if isinstance(node, ast.Name):
                out.add(node.id)
    return out


def _linearize(stmts):
    """Flatten a statement list into source-order (kind, node) units:
    simple statements as a whole, compound statements as their header
    expression plus their recursively flattened bodies. Nested function
    definitions are skipped — each gets its own visit."""
    for stmt in stmts:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        if isinstance(stmt, (ast.If, ast.While)):
            yield "expr", stmt.test
            yield from _linearize(stmt.body)
            yield from _linearize(stmt.orelse)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            yield "expr", stmt.iter
            yield "bind", stmt.target
            yield from _linearize(stmt.body)
            yield from _linearize(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                yield "expr", item.context_expr
                if item.optional_vars is not None:
                    yield "bind", item.optional_vars
            yield from _linearize(stmt.body)
        elif isinstance(stmt, ast.Try):
            yield from _linearize(stmt.body)
            for h in stmt.handlers:
                yield from _linearize(h.body)
            yield from _linearize(stmt.orelse)
            yield from _linearize(stmt.finalbody)
        else:
            yield "stmt", stmt


def _check_read_after_donate(tree: ast.AST, path: str) -> list[Finding]:
    findings: list[Finding] = []

    def visit_function(fn):
        donated: dict[str, int] = {}  # name -> donating line
        for kind, node in _linearize(fn.body):
            if kind == "bind":
                for n in ast.walk(node):
                    if isinstance(n, ast.Name):
                        donated.pop(n.id, None)
                continue
            # reads of currently-donated names (checked before this
            # unit's own donations take effect)
            for n in ast.walk(node):
                if (isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
                        and n.id in donated):
                    findings.append(Finding(
                        path, n.lineno, PASS,
                        f"`{n.id}` read after being donated on line "
                        f"{donated[n.id]} — donated buffers are "
                        "consumed; rebind the result instead"))
                    donated.pop(n.id)
            new_donations = []
            for call in ast.walk(node):
                if isinstance(call, ast.Call):
                    for name in _donated_names(call):
                        new_donations.append((name, node.lineno))
            bound = _assigned_names(node) if kind == "stmt" else set()
            for name in bound:
                donated.pop(name, None)
            for name, line in new_donations:
                # a donating statement that rebinds the same name
                # (state, outs = eng.run(..., state=state)) is the
                # canonical safe pattern
                if name not in bound:
                    donated[name] = line

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            visit_function(node)
    return findings


def _check_site_registry(tree: ast.AST, path: str) -> list[Finding]:
    if path in REGISTERED_SITES:
        return []
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            for k in node.keywords:
                if k.arg == "donate_argnums":
                    findings.append(Finding(
                        path, node.lineno, PASS,
                        "unregistered donate_argnums site — add it to "
                        "analysis.donation.REGISTERED_SITES (with an "
                        "aliasing cross-check) or pragma-allowlist it"))
    return findings


def check_file(path: pathlib.Path) -> list[Finding]:
    source = path.read_text()
    tree = ast.parse(source)
    p = rel(path)
    findings = _check_read_after_donate(tree, p)
    findings += _check_site_registry(tree, p)
    return apply_pragmas(findings, source)


def run_repo(root: pathlib.Path) -> list[Finding]:
    findings: list[Finding] = []
    for path in iter_py_files(root):
        if "analysis" in path.parts:
            continue
        findings += check_file(path)
    findings += check_repo_aliasing()
    return findings


def run_paths(paths) -> list[Finding]:
    from .common import fixture_case

    findings: list[Finding] = []
    for path in paths:
        path = pathlib.Path(path)
        findings += check_file(path)
        case = fixture_case(path)
        if case and case.get("kind") == "donation":
            fn, args, argnums = case["make"]()
            txt = fn.lower(*args).as_text()
            _, aliased = _aliased_args(txt)
            for a in argnums:
                if a not in aliased:
                    findings.append(Finding(
                        rel(path), case.get("line", 1), PASS,
                        f"donation dropped: donated argument {a} lowered "
                        "without an input-output aliasing attribute"))
    return findings
