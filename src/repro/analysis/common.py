"""Shared plumbing for the reprolint passes.

A *finding* is one contract violation at a file:line. Passes return
``list[Finding]``; the CLI renders them ``path:line: [pass] message`` and
exits non-zero when any survive. A finding on a line carrying a

    # reprolint: allow[<pass>] <reason>

pragma is suppressed — the pragma must name the pass (comma-separate to
allow several) and should state *why* the exemption is sound, because the
lint exists precisely where reviewer memory failed before.
"""
from __future__ import annotations

import dataclasses
import importlib.util
import pathlib
import re

# Directories (relative to the repo root) that the AST passes sweep by
# default. Tests are excluded: they deliberately poke at internals (and
# the seeded-violation fixtures under tests/analysis_fixtures MUST keep
# violating). The analysis package itself is excluded from text-level
# scans — it names the banned tokens as data.
DEFAULT_SCAN_DIRS = ("src/repro", "benchmarks", "examples")

_PRAGMA_RE = re.compile(r"#\s*reprolint:\s*allow\[([a-z0-9_,\s-]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation at ``path:line``."""

    path: str
    line: int
    pass_name: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.pass_name}] {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def repo_root() -> pathlib.Path:
    """The repository root (parent of ``src/repro``), resolved from this
    file so the CLI works from any cwd."""
    root = pathlib.Path(__file__).resolve().parents[3]
    if not (root / "src" / "repro").is_dir():  # installed copy: fall back
        root = pathlib.Path.cwd()
    return root


def rel(path: pathlib.Path | str, root: pathlib.Path | None = None) -> str:
    """Repo-relative display path (absolute when outside the repo)."""
    p = pathlib.Path(path).resolve()
    root = root or repo_root()
    try:
        return str(p.relative_to(root))
    except ValueError:
        return str(p)


def iter_py_files(root: pathlib.Path,
                  subdirs=DEFAULT_SCAN_DIRS) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for sub in subdirs:
        base = root / sub
        if not base.exists():
            continue
        out.extend(p for p in sorted(base.rglob("*.py"))
                   if "__pycache__" not in p.parts)
    return out


def pragma_lines(source: str) -> dict[int, set[str]]:
    """Map of 1-based line number -> pass names allowed on that line.

    An inline pragma covers its own line; a pragma on a comment-only
    line covers the next code line (comment/blank lines in between are
    skipped, so a pragma can open a multi-line explanation)."""
    out: dict[int, set[str]] = {}
    pending: set[str] = set()
    for i, text in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(text)
        stripped = text.strip()
        if m:
            passes = {p.strip() for p in m.group(1).split(",") if p.strip()}
            if stripped.startswith("#"):
                pending |= passes
            else:
                out.setdefault(i, set()).update(passes)
        if stripped.startswith("#") or not stripped:
            continue
        if pending:
            out.setdefault(i, set()).update(pending)
            pending = set()
    return out


def apply_pragmas(findings: list[Finding], source: str) -> list[Finding]:
    """Drop findings whose line carries an allow-pragma for their pass."""
    allowed = pragma_lines(source)
    return [f for f in findings
            if f.pass_name not in allowed.get(f.line, ())]


def load_module_from_path(path: pathlib.Path):
    """Import a fixture module by file path (no package side effects)."""
    path = pathlib.Path(path)
    spec = importlib.util.spec_from_file_location(
        f"_reprolint_fixture_{path.stem}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def fixture_case(path: pathlib.Path):
    """The ``reprolint_case()`` dict of a fixture module, or None."""
    mod = load_module_from_path(path)
    case = getattr(mod, "reprolint_case", None)
    return case() if case is not None else None
