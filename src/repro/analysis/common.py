"""Shared plumbing for the reprolint passes.

A *finding* is one contract violation at a file:line. Passes return
``list[Finding]``; the CLI renders them ``path:line: [pass] message`` and
exits non-zero when any survive. A finding on a line carrying a

    # reprolint: allow[<pass>] <reason>

pragma is suppressed — the pragma must name the pass (comma-separate to
allow several) and should state *why* the exemption is sound, because the
lint exists precisely where reviewer memory failed before.
"""
from __future__ import annotations

import dataclasses
import importlib.util
import pathlib
import re

# Directories (relative to the repo root) that the AST passes sweep by
# default. Tests are excluded: they deliberately poke at internals (and
# the seeded-violation fixtures under tests/analysis_fixtures MUST keep
# violating). The analysis package itself is excluded from text-level
# scans — it names the banned tokens as data.
DEFAULT_SCAN_DIRS = ("src/repro", "benchmarks", "examples")

_PRAGMA_RE = re.compile(r"#\s*reprolint:\s*allow\[([a-z0-9_,\s-]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation at ``path:line``."""

    path: str
    line: int
    pass_name: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.pass_name}] {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def repo_root() -> pathlib.Path:
    """The repository root (parent of ``src/repro``), resolved from this
    file so the CLI works from any cwd."""
    root = pathlib.Path(__file__).resolve().parents[3]
    if not (root / "src" / "repro").is_dir():  # installed copy: fall back
        root = pathlib.Path.cwd()
    return root


def rel(path: pathlib.Path | str, root: pathlib.Path | None = None) -> str:
    """Repo-relative display path (absolute when outside the repo)."""
    p = pathlib.Path(path).resolve()
    root = root or repo_root()
    try:
        return str(p.relative_to(root))
    except ValueError:
        return str(p)


def iter_py_files(root: pathlib.Path,
                  subdirs=DEFAULT_SCAN_DIRS) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for sub in subdirs:
        base = root / sub
        if not base.exists():
            continue
        out.extend(p for p in sorted(base.rglob("*.py"))
                   if "__pycache__" not in p.parts)
    return out


def pragma_lines(source: str) -> dict[int, set[str]]:
    """Map of 1-based line number -> pass names allowed on that line.

    An inline pragma covers its own line; a pragma on a comment-only
    line covers the next code line (comment/blank lines in between are
    skipped, so a pragma can open a multi-line explanation)."""
    out: dict[int, set[str]] = {}
    pending: set[str] = set()
    for i, text in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(text)
        stripped = text.strip()
        if m:
            passes = {p.strip() for p in m.group(1).split(",") if p.strip()}
            if stripped.startswith("#"):
                pending |= passes
            else:
                out.setdefault(i, set()).update(passes)
        if stripped.startswith("#") or not stripped:
            continue
        if pending:
            out.setdefault(i, set()).update(pending)
            pending = set()
    return out


def apply_pragmas(findings: list[Finding], source: str) -> list[Finding]:
    """Drop findings whose line carries an allow-pragma for their pass."""
    allowed = pragma_lines(source)
    return [f for f in findings
            if f.pass_name not in allowed.get(f.line, ())]


# --------------------------------------------------------------------------- #
# Path-linking machinery: building and tracing the emulator's two compiled
# chunk-step programs (the `lax.scan` body of `_emulate_impl` and the
# Pallas kernel body via ``step_ref(seq=True)``). Grown out of the
# schedule pass (PR 9); the ranges pass reuses it with params/faults as
# *traced inputs* so its interval proofs are parametric over the runtime
# knobs instead of specialized to one config's values.
# --------------------------------------------------------------------------- #


def eqn_loc(eqn, default=("<jaxpr>", 0)):
    """(repo-relative path, line) of a jaxpr equation's user frame."""
    try:
        from jax._src import source_info_util

        fr = source_info_util.user_frame(eqn.source_info)
        if fr is not None:
            return rel(fr.file_name), fr.start_line
    except Exception:
        pass
    return default


def step_args(cfg, *, nt: int = 2, nd: int = 2):
    """(params, faults, call_args) for tracing one chunk step.

    ``call_args`` is the positional tail of ``step_ref`` after
    ``(cfg, registry, table, params, ...)``: ``(table, sc, bank_free,
    page, offset, is_write, size, valid)``. ``faults`` is a shaped (not
    empty) plan so cursor arithmetic stays symbolic — a sentinel-only
    plan constant-folds the death detector away and the trace would no
    longer cover fault consumption."""
    import jax.numpy as jnp

    from repro.core import emulator as emu
    from repro.core import faults as faults_lib
    from repro.core.config import RuntimeParams
    from repro.kernels import chunk_step as cs

    params = RuntimeParams.from_config(cfg)
    state = emu.init_state(cfg, params)
    sc = cs.StepScalars(
        clock=state.clock, clock_ptr=state.clock_ptr,
        chunk_idx=state.chunk_idx, dma=state.dma,
        link_free_rx=state.link_free_rx, link_free_tx=state.link_free_tx,
        last_return=state.last_return, rescue_page=state.rescue_page,
        min_wear=state.min_wear, fault_cursor=state.fault_cursor)
    faults = faults_lib.pad_plan(faults_lib.FaultPlan.empty(), nt, nd)
    n = cfg.chunk
    i32 = jnp.int32
    page = jnp.zeros(n, i32)
    offset = jnp.zeros(n, i32)
    is_write = jnp.zeros(n, bool)
    size = jnp.full(n, cfg.line_size, i32)
    valid = jnp.ones(n, bool)
    return params, faults, (state.table, sc, state.bank_free,
                            page, offset, is_write, size, valid)


def _leaf_names(prefix, tree):
    import jax

    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, _leaf in flat:
        name = prefix + "".join(
            f".{p.name}" if hasattr(p, "name") else f"[{p.idx}]"
            if hasattr(p, "idx") else str(p) for p in path)
        out.append(name)
    return out


def trace_step_ref(cfg, registry, seq: bool, *,
                   params_as_inputs: bool = False):
    """Trace ``step_ref`` for one chunk. Returns
    ``(jaxpr, names, out_names)``: ``names[i]`` labels
    ``jaxpr.jaxpr.invars[i]`` and ``out_names[i]`` labels
    ``jaxpr.jaxpr.outvars[i]`` (dotted pytree paths, e.g.
    ``"params.write_weight"`` / ``"sc.dma.page_a"``), or None for both
    when ``params_as_inputs`` is False (params/faults closed over as
    constants — the schedule pass's historical shape)."""
    import jax

    from repro.kernels import chunk_step as cs

    params, faults, (table, sc, bank_free, page, offset, is_write, size,
                     valid) = step_args(cfg)

    if not params_as_inputs:
        def fn(table, sc, bank_free, page, offset, is_write, size, valid):
            return cs.step_ref(cfg, registry, table, params, sc, bank_free,
                               page, offset, is_write, size, valid, None,
                               seq=seq)

        return jax.make_jaxpr(fn)(table, sc, bank_free, page, offset,
                                  is_write, size, valid), None, None

    def fn(table, params, sc, bank_free, page, offset, is_write, size,
           valid, faults):
        return cs.step_ref(cfg, registry, table, params, sc, bank_free,
                           page, offset, is_write, size, valid, faults,
                           seq=seq)

    args = (table, params, sc, bank_free, page, offset, is_write, size,
            valid, faults)
    arg_names = ("table", "params", "sc", "bank_free", "page", "offset",
                 "is_write", "size", "valid", "faults")
    names = []
    for prefix, arg in zip(arg_names, args):
        names += _leaf_names(prefix, arg)
    jaxpr = jax.make_jaxpr(fn)(*args)
    assert len(names) == len(jaxpr.jaxpr.invars), \
        (len(names), len(jaxpr.jaxpr.invars))
    # step_ref returns (table, sc', bank_free', outs-dict); label the
    # flattened outvars the same way so the ranges pass can map its
    # monitored fields.
    out_struct = jax.eval_shape(fn, *args)
    out_names = _leaf_names("out", out_struct)
    # the out tree is (table, sc, bank_free, outs) — relabel the first
    # three to the canonical field names.
    fixed = []
    for nm in out_names:
        nm = nm.replace("out[0]", "table").replace("out[1]", "sc") \
               .replace("out[2]", "bank_free").replace("out[3]", "outs")
        fixed.append(nm)
    assert len(fixed) == len(jaxpr.jaxpr.outvars), \
        (len(fixed), len(jaxpr.jaxpr.outvars))
    return jaxpr, names, fixed


def scan_body_info(cfg, registry):
    """The chunk body of the compiled scan path, with enough structure to
    map its invars: trace ``_emulate_impl`` (params concrete, faults a
    shaped traced input) and pull the ``scan`` equation.

    Returns ``(info, err)`` where info is a dict with:

    * ``outer``: the traced ClosedJaxpr of ``_emulate_impl``;
    * ``outer_names``: dotted labels of the outer invars (trace/faults);
    * ``scan_eqn``: the scan equation inside it;
    * ``body``: the scan body (open) jaxpr;
    * ``num_consts`` / ``num_carry``: the scan's split of body invars;
    * ``carry_names``: dotted ``EmulatorState`` leaf labels for body
      invars ``[num_consts : num_consts + num_carry]`` (flattening order
      of the carry pytree is the flattening order of the state);
    * ``table_index``: body invar index of the packed table carry.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import emulator as emu
    from repro.core import faults as faults_lib
    from repro.core.config import RuntimeParams

    n = cfg.chunk  # one chunk is enough — the body is per-chunk
    i32 = jnp.int32
    trace = emu.Trace(page=jnp.zeros(n, i32), offset=jnp.zeros(n, i32),
                      is_write=jnp.zeros(n, bool),
                      size=jnp.full(n, cfg.line_size, i32))
    faults = faults_lib.pad_plan(faults_lib.FaultPlan.empty(), 2, 2)
    params = RuntimeParams.from_config(cfg)
    state = emu.init_state(cfg, params)

    def fn(trace, faults):
        return emu._emulate_impl(cfg, registry, trace, faults=faults)

    outer = jax.make_jaxpr(fn)(trace, faults)
    outer_names = _leaf_names("trace", trace) + _leaf_names("faults", faults)
    scans = [e for e in outer.jaxpr.eqns if e.primitive.name == "scan"]
    if not scans:
        return None, "no `scan` equation found in _emulate_impl"
    eqn = scans[0]
    body = eqn.params["jaxpr"].jaxpr
    num_consts = eqn.params["num_consts"]
    num_carry = eqn.params["num_carry"]
    carry_names = _leaf_names("state", state)
    if len(carry_names) != num_carry:
        return None, (f"scan carries {num_carry} leaves but EmulatorState "
                      f"flattens to {len(carry_names)} — the carry mapping "
                      "needs retargeting")
    tshape = (cfg.n_pages, 8)
    idx = [i for i, v in enumerate(body.invars)
           if tuple(v.aval.shape) == tshape]
    if len(idx) != 1:
        return None, (f"expected exactly one {tshape} carry in the scan "
                      f"body, found {len(idx)}")
    return {"outer": outer, "outer_names": outer_names, "scan_eqn": eqn,
            "body": body, "num_consts": num_consts, "num_carry": num_carry,
            "carry_names": carry_names, "table_index": idx[0]}, None


def load_module_from_path(path: pathlib.Path):
    """Import a fixture module by file path (no package side effects)."""
    path = pathlib.Path(path)
    spec = importlib.util.spec_from_file_location(
        f"_reprolint_fixture_{path.stem}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def fixture_case(path: pathlib.Path):
    """The ``reprolint_case()`` dict of a fixture module, or None."""
    mod = load_module_from_path(path)
    case = getattr(mod, "reprolint_case", None)
    return case() if case is not None else None
