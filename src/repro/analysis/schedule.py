"""Pass ``schedule`` — the one-true chunk read/write schedule, checked at
the jaxpr level.

The contract (kernels/chunk_step.py, PR 7) that eliminated the 12.3x
table-copy regression class:

  1. every table *read* (the stage-2 lookup gather, the swap-pair rows,
     the policy's candidate scans) happens against the pre-chunk table or
     the committed table — never against a partially-written copy;
  2. the chunk's writes collapse into ONE flattened int32 scatter-add
     (the boundary commit) on the pre-chunk table;
  3. after the commit the only further table writes are the (documented)
     decay cond and the retirement's single-row FLAGS stamp;
  4. no intermediate whole-table copies exist at all.

This pass traces the step with ``jax.make_jaxpr`` and walks the
equations, tracking the lineage of the table value (reshapes alias,
writes bump a generation counter). It checks THREE programs:

  * the scan-path chunk body — the sub-jaxpr of the ``lax.scan`` inside
    ``emulator._emulate_impl`` (what a normal run actually compiles);
  * ``step_ref(..., seq=True)`` — the literal Pallas kernel body
    (``_pallas_step_fn._body`` calls it; an AST check below pins that
    link so tracing ``seq=True`` IS checking the kernel);
  * ``step_ref(..., seq=False)`` — the jnp reference.

Fixture protocol: a ``reprolint_case()`` returning
``{"kind": "schedule", "make": lambda: (fn, args)}``; ``fn(*args)`` is
traced with the table as argument 0.
"""
from __future__ import annotations

import ast
import pathlib

from .common import Finding, eqn_loc, rel, scan_body_info, trace_step_ref

try:  # jax >= 0.4.33 moved the public jaxpr types
    from jax.extend.core import Literal, Var
except ImportError:  # pragma: no cover - older jax
    from jax.core import Literal, Var  # type: ignore

PASS = "schedule"

# Primitives that only *read* their table operand and that we expect to
# see in the step trace. Anything else that consumes the table and emits
# a table-shaped value is flagged as an unrecognized table write/copy.
_WRITE_PRIMS = ("scatter", "scatter-apply", "dynamic_update_slice")


# Shared with the ranges pass (analysis/common.py).
_loc = eqn_loc


def check_jaxpr_schedule(jaxpr, table_invar_index: int = 0,
                         label: str = "step") -> list[Finding]:
    """Walk one jaxpr and enforce the chunk schedule on the table whose
    lineage starts at ``invars[table_invar_index]``."""
    core = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    tvar = core.invars[table_invar_index]
    tshape = tuple(tvar.aval.shape)
    flat = (tshape[0] * tshape[1],) if len(tshape) == 2 else tshape
    findings: list[Finding] = []

    def bad(eqn, msg):
        path, line = _loc(eqn)
        findings.append(Finding(path, line, PASS, f"[{label}] {msg}"))

    gen: dict[Var, int] = {tvar: 0}
    commit_seen = False
    pre_gathers = 0
    post_row_scatters = 0
    post_conds = 0
    for eqn in core.eqns:
        ins = [v for v in eqn.invars
               if isinstance(v, Var) and not isinstance(v, Literal)
               and v in gen]
        if not ins:
            continue
        g = max(gen[v] for v in ins)
        prim = eqn.primitive.name
        t_outs = [o for o in eqn.outvars
                  if tuple(getattr(o.aval, "shape", ())) in (tshape, flat)]
        if prim == "reshape" and t_outs:
            gen[t_outs[0]] = g  # pure alias (table <-> flat view)
            continue
        if prim == "scatter-add":
            if g == 0:
                if commit_seen:
                    bad(eqn, "second scatter-add on the pre-chunk table — "
                             "the boundary commit must be the ONE combined "
                             "scatter")
                else:
                    commit_seen = True
                    op = eqn.invars[0]
                    if tuple(op.aval.shape) != flat:
                        bad(eqn, "boundary commit is not flattened — the "
                                 "contract is one scatter-add on the "
                                 "reshape(-1) view")
            else:
                bad(eqn, "extra scatter-add on the committed table")
            for o in t_outs:
                gen[o] = g + 1
            continue
        if prim in _WRITE_PRIMS:
            if g == 0:
                bad(eqn, f"table write (`{prim}`) before the boundary "
                         "commit — all pre-commit table access must be "
                         "reads")
            else:
                upd = eqn.invars[-1]
                n_upd = 1
                for d in getattr(upd.aval, "shape", ()):
                    n_upd *= d
                if n_upd > tshape[-1]:
                    bad(eqn, f"post-commit `{prim}` larger than one table "
                             "row — only the retirement's single-row FLAGS "
                             "stamp may follow the commit")
                post_row_scatters += 1
                if post_row_scatters > 1:
                    bad(eqn, "more than one post-commit row scatter (the "
                             "retirement stamp must be the only one)")
            for o in t_outs:
                gen[o] = g + 1
            continue
        if prim == "cond":
            if t_outs:
                if g == 0:
                    bad(eqn, "table-writing cond before the boundary commit")
                post_conds += 1
                if post_conds > 1:
                    bad(eqn, "more than one table-writing cond (only the "
                             "decay branch may rewrite the table)")
                for o in t_outs:
                    gen[o] = g + 1
            elif g == 0 and commit_seen:
                bad(eqn, "cond reads the pre-commit table after the "
                         "boundary commit (stale read)")
            continue
        if prim == "copy" or (prim == "convert_element_type" and t_outs):
            bad(eqn, f"intermediate table copy (`{prim}`) — the schedule "
                     "allows zero whole-table copies")
            for o in t_outs:
                gen[o] = g
            continue
        if t_outs:
            bad(eqn, f"unrecognized table-producing op `{prim}` — the "
                     "boundary commit must be the only table write")
            for o in t_outs:
                gen[o] = g
            continue
        # pure read
        if g == 0:
            if commit_seen:
                bad(eqn, f"read of the pre-commit table (`{prim}`) after "
                         "the boundary commit (stale schedule)")
            else:
                pre_gathers += 1
    if not commit_seen:
        findings.append(Finding(
            f"<{label}>", 0, PASS,
            f"[{label}] no flattened scatter-add boundary commit found"))
    elif pre_gathers == 0:
        findings.append(Finding(
            f"<{label}>", 0, PASS,
            f"[{label}] no table gather precedes the boundary commit"))
    return findings


def _trace_step_ref(cfg, registry, seq: bool):
    """One-chunk ``step_ref`` trace (path-linking machinery now lives in
    analysis/common.py — the ranges pass shares it)."""
    jaxpr, _names, _out_names = trace_step_ref(cfg, registry, seq)
    return jaxpr


def _scan_body_jaxpr(cfg, registry):
    """The chunk body of the compiled scan path (via
    :func:`common.scan_body_info`) as ``((body, table_index), err)``."""
    info, err = scan_body_info(cfg, registry)
    if err is not None:
        return None, err
    return (info["body"], info["table_index"]), None


def _check_pallas_body_link(root: pathlib.Path) -> list[Finding]:
    """AST-pin the fact that the Pallas kernel body IS
    ``step_ref(seq=True)``: ``_body`` inside ``_pallas_step_fn`` must
    call ``step_ref`` with ``seq=True``. If that link ever breaks, the
    seq=True trace below no longer covers the kernel and this pass must
    be retargeted."""
    path = root / "src" / "repro" / "kernels" / "chunk_step.py"
    tree = ast.parse(path.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == "_body":
            for call in ast.walk(node):
                if (isinstance(call, ast.Call)
                        and isinstance(call.func, ast.Name)
                        and call.func.id == "step_ref"
                        and any(k.arg == "seq"
                                and isinstance(k.value, ast.Constant)
                                and k.value.value is True
                                for k in call.keywords)):
                    return []
            return [Finding(rel(path), node.lineno, PASS,
                            "_pallas_step_fn._body no longer calls "
                            "step_ref(seq=True) — the seq=True schedule "
                            "trace no longer covers the Pallas kernel")]
    return [Finding(rel(path), 1, PASS,
                    "could not find _body in kernels/chunk_step.py — "
                    "the Pallas-body link check needs updating")]


def run_repo(root: pathlib.Path) -> list[Finding]:
    from repro.core.config import small_platform
    from repro.core.emulator import as_registry

    cfg = small_platform()
    registry = as_registry(None)
    findings = _check_pallas_body_link(root)
    body, err = _scan_body_jaxpr(cfg, registry)
    if err is not None:
        findings.append(Finding("src/repro/core/emulator.py", 1, PASS, err))
    else:
        findings += check_jaxpr_schedule(body[0], body[1],
                                         label="scan-path")
    findings += check_jaxpr_schedule(
        _trace_step_ref(cfg, registry, seq=True), 0, label="pallas-body")
    findings += check_jaxpr_schedule(
        _trace_step_ref(cfg, registry, seq=False), 0, label="jnp-ref")
    return findings


def run_paths(paths) -> list[Finding]:
    import jax

    from .common import fixture_case

    findings: list[Finding] = []
    for path in paths:
        case = fixture_case(path)
        if not case or case.get("kind") != "schedule":
            continue
        fn, args = case["make"]()
        jaxpr = jax.make_jaxpr(fn)(*args)
        findings += check_jaxpr_schedule(
            jaxpr, case.get("table_invar_index", 0),
            label=pathlib.Path(path).stem)
    return findings
