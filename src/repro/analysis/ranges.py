"""Pass ``ranges`` — interval-domain bounds/overflow prover for the
packed table and the chunk-step pipeline.

An abstract interpreter over jaxprs with the interval domain
(per-value ``[lo, hi]`` over unbounded Python numbers) plus a handful
of table-aware refinements:

* **lane-aware table lineage** — a value whose lineage reaches the
  packed ``int32[n_pages, 8]`` table carries *per-lane* intervals, and
  the interpreter tracks lane extraction (row gathers, lane-column
  gathers, ``slice``/``squeeze``) and lane-targeted scatters (the
  flattened boundary commit's index arithmetic is tracked modulo 8, so
  each concatenated section of the ONE commit scatter lands on a known
  lane);
* **saturation certificates** — the ``saturating_weights`` idiom in
  core/table.py (``min(max(CAP - pre - psum, 0), w)``) is recognized
  structurally: a scatter-add of certified weights bounds the lane at
  ``max(pre, CAP)`` no matter how many updates alias one row;
* **exchange certificates** — updates of the shape ``new - gather(lane)``
  (the DMA commit's rebased deltas) bound the lane at
  ``join(pre, new)``;
* **gated increments** — ``cursor + cast(b)`` where ``b``'s lineage
  conjoins ``cursor < N`` proves ``cursor' <= max(cursor, N)`` (the
  fault-cursor consume);
* **guarded indexing** — every gather/scatter whose operand lineage
  reaches the table is classified *proved* (index interval within
  bounds), *guarded* (``mode=drop``/clip), or a finding (XLA's
  ``PROMISE_IN_BOUNDS`` with an unproven index is undefined behavior).

Three programs are checked, reusing the PR 9 path-linking machinery in
analysis/common.py: the ``lax.scan`` chunk body of
``emulator._emulate_impl`` (what a run actually compiles), and
``step_ref(seq=True/False)`` — the literal Pallas kernel body (the
schedule pass AST-pins that link) and the jnp reference — with
``RuntimeParams`` as *traced inputs* so the proofs are parametric over
the declared knob budget, not one config's values.

The run budget (``N_CHUNKS_BUDGET``, ``PARAM_BOUNDS``, trace bounds) is
declared below; per-chunk time growth ``G`` is measured by evaluating
the step from the time origin, giving the int32 horizon
``(2^31-1) // G`` that must cover the declared budget. The idiom
recognizers' side conditions (delta rebasing against the same rows,
time-translation covariance of the step) are property-tested in
tests/test_ranges.py; the runtime ``check_table`` lane asserts are the
dynamic backstop.

Fixture protocol: ``reprolint_case()`` returning
``{"kind": "ranges", "make": lambda: (fn, args)}``; ``fn(*args)`` is
traced with the table as argument 0 and all other inputs bound to the
documented fixture budget (ints ``[0, 2^20]``).
"""
from __future__ import annotations

import math
import pathlib

from .common import (Finding, apply_pragmas, eqn_loc, rel, scan_body_info,
                     trace_step_ref)

PASS = "ranges"

INT32 = (-(1 << 31), (1 << 31) - 1)
INF = float("inf")

# --------------------------------------------------------------------------- #
# The declared per-run budget. The prover's claim is conditional on runs
# staying inside it; `validate_budget` checks the repo's own configs
# against it so the declaration cannot silently rot.
# --------------------------------------------------------------------------- #

#: Chunks per emulation run the int32 proofs cover. With chunk width c,
#: that is `N_CHUNKS_BUDGET * c` requests per `Engine.run` call.
N_CHUNKS_BUDGET = 1 << 10

#: Declared intervals for every RuntimeParams leaf (params are traced
#: inputs on the step_ref paths, so the proofs hold for ALL values in
#: these ranges). A params leaf missing here is itself a finding.
PARAM_BOUNDS = {
    "fast_read_lat": (0, 1 << 11),
    "fast_write_lat": (0, 1 << 11),
    "fast_bytes_per_cycle": (1.0, 1024.0),
    "slow_read_lat": (0, 1 << 11),
    "slow_write_lat": (0, 1 << 11),
    "slow_bytes_per_cycle": (1.0, 1024.0),
    "link_lat": (0, 1 << 11),
    "link_bytes_per_cycle": (1.0, 1024.0),
    "issue_gap": (0, 1 << 8),
    "dma_cycles_per_subblock": (1, 1 << 10),
    "n_fast_pages": (1, None),          # None -> n_pages
    "hot_threshold": (0, 1 << 20),
    "hotness_decay_shift": (0, 31),
    "decay_every": (1, 1 << 20),
    "write_weight": (1, 1 << 10),       # the budget's max_weight
    "wear_slack": (0, 1 << 29),
    "pin_fast_fraction": (0.0, 1.0),
    "endurance_budget": (-(1 << 29), 1 << 29),
    "policy_id": (0, 1 << 4),
    "power_pj_per_bit_fast": (0.0, 1024.0),
    "power_pj_per_bit_slow_read": (0.0, 1024.0),
    "power_pj_per_bit_slow_write": (0.0, 1024.0),
}

#: Request-trace bounds (per field of the traced chunk).
TRACE_BOUNDS = {
    "page": (0, None),                  # None -> n_pages - 1
    "offset": (0, (1 << 12) - 1),       # within one page
    "size": (0, 1 << 12),               # at most one page per request
}

# Carry/StepScalars field policies. TIME fields grow by at most G per
# chunk (G measured from the origin; translation covariance is
# property-tested); MONO fields grow by a measured constant rate;
# everything else must be inductive under its declared interval.
_TIME_FIELDS = ("clock", "bank_free", "link_free_rx", "link_free_tx",
                "last_return", "dma.start")
_MONO_FIELDS = ("chunk_idx", "dma.swaps_done")


def _inductive_fields(n_pages, nd):
    return {
        "clock_ptr": (0, n_pages - 1),
        "dma.active": (0, 1),
        "dma.page_a": (-1, n_pages - 1),
        "dma.page_b": (-1, n_pages - 1),
        "rescue_page": (-1, n_pages - 1),
        "min_wear": (0, 1 << 30),
        "fault_cursor": (0, nd),
    }


def _lane_invariants(n_pages, epoch_hi):
    from repro.core import table as t
    inv = [None] * t.ROW_W
    inv[t.DEVICE] = (0, 1)
    inv[t.FRAME] = (0, n_pages - 1)
    inv[t.HOTNESS] = (0, t.HOTNESS_CAP)
    inv[t.WEAR] = (0, t.WEAR_CAP)
    inv[t.OWNER] = (0, n_pages - 1)
    inv[t.EPOCH] = (0, epoch_hi)
    inv[t.FLAGS] = (0, 15)
    inv[t._PAD] = (0, 0)
    return inv


_LANE_NAMES = ("DEVICE", "FRAME", "HOTNESS", "WEAR", "OWNER", "EPOCH",
               "FLAGS", "_PAD")
#: Lanes checked inductively; EPOCH is time-like (bounded by the cycle
#: budget instead), _PAD never written.
_INDUCTIVE_LANES = (0, 1, 2, 3, 4, 6)


# --------------------------------------------------------------------------- #
# Interval helpers (lo/hi are Python ints, floats, or +-inf).
# --------------------------------------------------------------------------- #


def _join(a, b):
    return (min(a[0], b[0]), max(a[1], b[1]))


def _pmul(a, b):
    if a == 0 or b == 0:
        return 0
    return a * b


def _iv_add(a, b):
    return (a[0] + b[0], a[1] + b[1])


def _iv_sub(a, b):
    return (a[0] - b[1], a[1] - b[0])


def _iv_mul(a, b):
    cs = [_pmul(x, y) for x in a for y in b]
    return (min(cs), max(cs))


def _contains(outer, inner):
    return outer[0] <= inner[0] and inner[1] <= outer[1]


def _dtype_kind(dtype):
    import numpy as np
    d = np.dtype(dtype)
    if d.kind == 'b':
        return 'b', 1
    if d.kind in 'iu':
        return 'i', d.itemsize * 8
    return 'f', d.itemsize * 8


def _dtype_top(kind, bits):
    if kind == 'b':
        return (0, 1)
    if kind == 'i':
        return (-(1 << (bits - 1)), (1 << (bits - 1)) - 1)
    return (-INF, INF)


class AVal:
    """Abstract value: interval + optional refinements.

    const   — concrete np.ndarray (constant folding)
    lanes   — tuple of 8 intervals when the value is table-lineage
              (2-D (n, 8) or the flat reshape(-1) view)
    lane_src— (gen_marker, lane) for an elementwise gather of one lane
    mod     — value ≡ mod (modulo 8), for flat-index lane attribution
    pieces  — [(length, AVal)] axis-0 concatenation structure (1-D)
    cols    — [AVal] per-column structure of a dim=1 concat of (N,1)
    sat     — (lane, cap): saturating_weights certificate
    capminus— (lane, C): value <= C - gather(lane) (sat intermediate)
    exch    — (lane, new_iv): `new - gather(lane)` exchange certificate
    alt     — scalar const of a select_n branch folded into this value
              (drop-guarded sentinel narrowing at scatters)
    padz    — (offset, period): 1-D value is a zero-interior-padded
              dilation — nonzero entries only at positions ≡ offset
              (mod period).  Lets `p_add` recognise the
              pad+pad+add *interleave* step of `lax.associative_scan`
              (disjoint supports ⇒ join, not sum)
    gates   — frozenset of (id(base), bound): value != 0 implies
              base < bound held (lt-lineage of a bool)
    """

    __slots__ = ("shape", "kind", "bits", "iv", "const", "lanes",
                 "lane_src", "mod", "pieces", "cols", "sat", "capminus",
                 "exch", "alt", "gates", "padz")

    def __init__(self, shape, kind, bits, iv, const=None, lanes=None,
                 lane_src=None, mod=None, pieces=None, cols=None,
                 sat=None, capminus=None, exch=None, alt=None,
                 gates=frozenset(), padz=None):
        self.shape = tuple(shape)
        self.kind = kind
        self.bits = bits
        if kind == 'b':
            iv = (max(iv[0], 0), min(iv[1], 1))
        self.iv = iv
        self.const = const
        self.lanes = lanes
        self.lane_src = lane_src
        self.mod = mod
        self.pieces = pieces
        self.cols = cols
        self.sat = sat
        self.capminus = capminus
        self.exch = exch
        self.alt = alt
        self.gates = gates
        self.padz = padz

    # -- constructors ------------------------------------------------------ #

    @classmethod
    def of_const(cls, arr):
        import numpy as np
        arr = np.asarray(arr)
        kind, bits = _dtype_kind(arr.dtype)
        if arr.size:
            lo, hi = arr.min().item(), arr.max().item()
            if kind == 'b':
                lo, hi = int(lo), int(hi)
        else:
            lo, hi = 0, 0
        mod = None
        if kind == 'i' and arr.size:
            mods = np.unique(arr % 8)
            if mods.size == 1:
                mod = int(mods[0])
        return cls(arr.shape, kind, bits, (lo, hi), const=arr, mod=mod)

    @classmethod
    def top_for(cls, aval):
        kind, bits = _dtype_kind(aval.dtype)
        return cls(aval.shape, kind, bits, _dtype_top(kind, bits))

    def with_(self, **kw):
        out = AVal(self.shape, self.kind, self.bits, self.iv)
        for s in self.__slots__:
            setattr(out, s, getattr(self, s))
        for k, v in kw.items():
            setattr(out, k, v)
        return out

    def plain(self, shape=None, iv=None):
        return AVal(self.shape if shape is None else shape, self.kind,
                    self.bits, self.iv if iv is None else iv)

    @property
    def scalar_const(self):
        if self.const is not None and self.const.size == 1:
            return self.const.reshape(()).item()
        return None

    def __repr__(self):
        return (f"AVal{self.shape}{self.kind}{self.bits} iv={self.iv}"
                + (" table" if self.lanes else ""))


def _const_or_none(*avs):
    if all(a.const is not None for a in avs):
        return [a.const for a in avs]
    return None


_FOLD_LIMIT = 1 << 16


# --------------------------------------------------------------------------- #
# The interpreter.
# --------------------------------------------------------------------------- #


class Interp:
    """One abstract evaluation of a jaxpr. Collects index-safety
    results, int32 overflow notes and analysis gaps as it goes."""

    #: optional ``(eqn, ins, outs) -> None`` debug callback (tests only).
    trace_hook = None

    def __init__(self, track_overflow=True):
        self.track_overflow = track_overflow
        self.index_findings = []    # (loc, message)
        self.overflow = []          # (loc, prim, iv)
        self.gaps = []              # (loc, message)
        self.n_proved = 0
        self.n_guarded = 0

    # -- plumbing ---------------------------------------------------------- #

    def eval_closed(self, closed, in_avals):
        jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
        consts = list(getattr(closed, "consts", ()) or ())
        return self.eval_jaxpr(jaxpr, consts, in_avals)

    def eval_jaxpr(self, jaxpr, consts, in_avals):
        env = {}

        def read(atom):
            if hasattr(atom, "val"):        # Literal
                return AVal.of_const(atom.val)
            return env[atom]

        def write(var, aval):
            if type(var).__name__ != "DropVar":
                env[var] = aval

        for v, c in zip(jaxpr.constvars, consts):
            import numpy as np
            env[v] = AVal.of_const(np.asarray(c))
        assert len(jaxpr.invars) == len(in_avals), \
            (len(jaxpr.invars), len(in_avals))
        for v, a in zip(jaxpr.invars, in_avals):
            env[v] = a

        for eqn in jaxpr.eqns:
            ins = [read(x) for x in eqn.invars]
            prim = eqn.primitive.name
            fn = getattr(self, "p_" + prim.replace("-", "_"), None)
            try:
                if fn is None:
                    raise NotImplementedError(prim)
                outs = fn(eqn, ins)
            except NotImplementedError as e:
                self.gaps.append((eqn_loc(eqn),
                                  f"unhandled primitive `{e}`"))
                outs = [AVal.top_for(o.aval) for o in eqn.outvars]
            if isinstance(outs, AVal):
                outs = [outs]
            if self.trace_hook is not None:
                self.trace_hook(eqn, ins, outs)
            if self.track_overflow and prim in ("add", "sub", "mul"):
                out = outs[0]
                if (out.kind == 'i' and out.bits == 32
                        and not _contains(INT32, out.iv)):
                    self.overflow.append((eqn_loc(eqn), prim, out.iv))
            for v, a in zip(eqn.outvars, outs):
                write(v, a)
        return [read(v) for v in jaxpr.outvars]

    # -- generic elementwise machinery ------------------------------------- #

    def _ew2(self, a, b, ivf, constf=None, meta=None):
        """Elementwise binary: broadcasts shapes, folds constants, maps
        over concat pieces when one side is scalar-like or both align."""
        import numpy as np
        shape = np.broadcast_shapes(a.shape, b.shape)
        cs = _const_or_none(a, b)
        if cs is not None and constf is not None:
            arr = constf(*cs)
            if arr.size <= _FOLD_LIMIT:
                return AVal.of_const(arr)
        out = AVal(shape, a.kind if a.kind != 'b' else b.kind,
                   max(a.bits, b.bits), ivf(a.iv, b.iv))
        # piece mapping: keep concat structure through the arithmetic
        # that builds the flattened commit indices/updates.
        for x, y in ((a, b), (b, a)):
            if x.pieces is not None and x.shape == shape:
                if y.scalar_const is not None or y.shape in ((), (1,)):
                    out.pieces = [(ln, self._ew2(p, y, ivf, constf, meta))
                                  for ln, p in x.pieces]
                    break
                if (y.pieces is not None
                        and [ln for ln, _ in y.pieces]
                        == [ln for ln, _ in x.pieces]):
                    if x is a:
                        out.pieces = [
                            (ln, self._ew2(p, q, ivf, constf, meta))
                            for (ln, p), (_, q) in zip(x.pieces, y.pieces)]
                    break
                if (y.const is not None and y.shape == shape
                        and len(shape) == 1):
                    # constant vector against a pieces value: slice the
                    # constant per piece (rows*8 + const lane vector).
                    segs = []
                    off = 0
                    for ln, p in x.pieces:
                        seg = AVal.of_const(
                            np.ascontiguousarray(y.const[off:off + ln]))
                        off += ln
                        pair = (p, seg) if x is a else (seg, p)
                        segs.append((ln, self._ew2(*pair, ivf, constf,
                                                   meta)))
                    out.pieces = segs
                    break
        if meta is not None:
            meta(out, a, b)
        return out

    # -- arithmetic -------------------------------------------------------- #

    def p_add(self, eqn, ins):
        a, b = ins

        def meta(out, a, b):
            for x, y in ((a, b), (b, a)):
                c = y.scalar_const
                if c is not None and x.mod is not None:
                    out.mod = (x.mod + c) % 8
                    break
            else:
                if a.mod is not None and b.mod is not None:
                    out.mod = (a.mod + b.mod) % 8
            # gated increment: x + g where g's gates bound x.
            for x, g in ((a, b), (b, a)):
                for base, bound in g.gates:
                    if base == id(x):
                        out.iv = (out.iv[0] if out.iv[0] < x.iv[0]
                                  else x.iv[0],
                                  max(x.iv[1], bound))
            # capminus survives adding a nonpositive term; sat/exch are
            # consumed at the scatter, not propagated through adds.
            # associative_scan's interleave step: add of two zero-
            # dilated pads with disjoint supports — every output
            # element is an element of ONE operand (or zero), so the
            # sound interval is the join, not the sum.
            if (a.padz is not None and b.padz is not None
                    and a.padz[1] == b.padz[1]
                    and a.padz[0] != b.padz[0]):
                out.iv = _join(a.iv, b.iv)
        return self._ew2(a, b, _iv_add, lambda x, y: x + y, meta)

    def p_sub(self, eqn, ins):
        a, b = ins

        def meta(out, a, b):
            c = b.scalar_const
            if c is not None and a.mod is not None:
                out.mod = (a.mod - c) % 8
            elif a.mod is not None and b.mod is not None:
                out.mod = (a.mod - b.mod) % 8
            # `C - gather(lane)`: the root of the saturation chain.
            ca = a.scalar_const
            if ca is not None and b.lane_src is not None:
                out.capminus = (b.lane_src[1], ca)
            # `capminus - nonneg` (subtracting the prefix sum) keeps it.
            elif a.capminus is not None and b.iv[0] >= 0:
                out.capminus = a.capminus
            # `new - gather(lane)`: exchange certificate.
            if b.lane_src is not None:
                out.exch = (b.lane_src[1], a.iv)
        return self._ew2(a, b, _iv_sub, lambda x, y: x - y, meta)

    def p_mul(self, eqn, ins):
        a, b = ins

        def meta(out, a, b):
            for x, y in ((a, b), (b, a)):
                c = y.scalar_const
                if c is not None and c % 8 == 0:
                    out.mod = 0     # n*8k ≡ 0 (mod 8) for any n
                    break
                if c is not None and x.mod is not None:
                    out.mod = (x.mod * c) % 8
                    break
        return self._ew2(a, b, _iv_mul, lambda x, y: x * y, meta)

    def p_max(self, eqn, ins):
        a, b = ins

        def ivf(x, y):
            return (max(x[0], y[0]), max(x[1], y[1]))

        def meta(out, a, b):
            import numpy as np
            # max(C - pre - psum, 0) -> a certified "allowance".
            for x, y in ((a, b), (b, a)):
                if x.capminus is not None and y.scalar_const == 0:
                    out.capminus = x.capminus
        return self._ew2(a, b, ivf, lambda x, y: __import__("numpy")
                         .maximum(x, y), meta)

    def p_min(self, eqn, ins):
        a, b = ins

        def ivf(x, y):
            return (min(x[0], y[0]), min(x[1], y[1]))

        def meta(out, a, b):
            # min(allowance, w): the full saturating_weights certificate.
            for x, y in ((a, b), (b, a)):
                if x.capminus is not None and y.iv[0] >= 0:
                    out.sat = x.capminus
                    break
        return self._ew2(a, b, ivf, lambda x, y: __import__("numpy")
                         .minimum(x, y), meta)

    def p_div(self, eqn, ins):
        import numpy as np
        a, b = ins
        if a.kind == 'f' or b.kind == 'f':
            def ivf(x, y):
                if y[0] > 0 or y[1] < 0:
                    cs = [u / v for u in x for v in y if v]
                    return (min(cs), max(cs))
                return (-INF, INF)
            return self._ew2(a, b, ivf, lambda x, y: x / y)

        def tdiv(u, v):
            q = abs(u) // abs(v)
            return q if (u >= 0) == (v >= 0) else -q

        def ivf(x, y):
            if y[0] >= 1 or y[1] <= -1:
                cs = [tdiv(u, v) for u in x for v in y]
                return (min(cs), max(cs))
            return _dtype_top('i', max(a.bits, b.bits))
        return self._ew2(a, b, ivf,
                         lambda x, y: (np.sign(x) * np.sign(y)
                                       * (abs(x) // abs(y))).astype(x.dtype))

    def p_rem(self, eqn, ins):
        a, b = ins

        def ivf(x, y):
            m = max(abs(y[0]), abs(y[1]))
            if m == 0:
                return _dtype_top(a.kind, a.bits)
            if x[0] >= 0:
                return (0, min(x[1], m - 1))
            return (-(m - 1), m - 1)
        return self._ew2(a, b, ivf)

    def p_pow(self, eqn, ins):
        raise NotImplementedError("pow")

    def p_neg(self, eqn, ins):
        a, = ins
        import numpy as np
        out = a.plain(iv=(-a.iv[1], -a.iv[0]))
        if a.const is not None:
            return AVal.of_const(-a.const)
        return out

    def p_abs(self, eqn, ins):
        a, = ins
        lo, hi = a.iv
        if lo >= 0:
            return a
        return a.plain(iv=(0 if hi >= 0 else min(-hi, -lo),
                           max(abs(lo), abs(hi))))

    def p_sign(self, eqn, ins):
        a, = ins
        lo = -1 if a.iv[0] < 0 else (0 if a.iv[0] == 0 else 1)
        hi = 1 if a.iv[1] > 0 else (0 if a.iv[1] == 0 else -1)
        return a.plain(iv=(lo, hi))

    def p_ceil(self, eqn, ins):
        a, = ins
        return a.plain(iv=(a.iv[0], a.iv[1] if a.iv[1] == INF
                           else math.ceil(a.iv[1])))

    def p_floor(self, eqn, ins):
        a, = ins
        return a.plain(iv=(a.iv[0] if a.iv[0] == -INF
                           else math.floor(a.iv[0]), a.iv[1]))

    def p_round(self, eqn, ins):
        a, = ins
        return a.plain()

    def p_shift_right_arithmetic(self, eqn, ins):
        a, b = ins

        def ivf(x, y):
            slo, shi = max(y[0], 0), min(y[1], 63)
            cs = [u >> s for u in x for s in (slo, shi)]
            return (min(cs), max(cs))
        return self._ew2(a, b, ivf, lambda x, y: x >> y)

    def p_shift_right_logical(self, eqn, ins):
        a, b = ins
        if a.iv[0] >= 0:
            return self.p_shift_right_arithmetic(eqn, ins)
        return self._ew2(a, b,
                         lambda x, y: (0, (1 << a.bits) - 1))

    def p_shift_left(self, eqn, ins):
        a, b = ins

        def ivf(x, y):
            slo, shi = max(y[0], 0), min(y[1], 63)
            cs = [_pmul(u, 1 << s) for u in x for s in (slo, shi)]
            return (min(cs), max(cs))
        return self._ew2(a, b, ivf, lambda x, y: x << y)

    # -- boolean / bitwise -------------------------------------------------- #

    def _cmp(self, eqn, ins, op, constf):
        a, b = ins
        out = self._ew2(a, b, lambda x, y: (0, 1), constf)
        out.kind, out.bits = 'b', 1
        lo, hi = op(a.iv, b.iv)
        out.iv = (lo, hi)
        return out

    def p_lt(self, eqn, ins):
        import numpy as np
        a, b = ins
        out = self._cmp(
            eqn, ins,
            lambda x, y: ((1, 1) if x[1] < y[0]
                          else (0, 0) if x[0] >= y[1] else (0, 1)),
            lambda x, y: x < y)
        c = b.scalar_const
        if c is not None:
            out.gates = frozenset({(id(a), c)})
        return out

    def p_le(self, eqn, ins):
        a, b = ins
        out = self._cmp(
            eqn, ins,
            lambda x, y: ((1, 1) if x[1] <= y[0]
                          else (0, 0) if x[0] > y[1] else (0, 1)),
            lambda x, y: x <= y)
        c = b.scalar_const
        if c is not None and a.kind == 'i':
            out.gates = frozenset({(id(a), c + 1)})
        return out

    def p_gt(self, eqn, ins):
        return self._cmp(
            eqn, ins,
            lambda x, y: ((1, 1) if x[0] > y[1]
                          else (0, 0) if x[1] <= y[0] else (0, 1)),
            lambda x, y: x > y)

    def p_ge(self, eqn, ins):
        return self._cmp(
            eqn, ins,
            lambda x, y: ((1, 1) if x[0] >= y[1]
                          else (0, 0) if x[1] < y[0] else (0, 1)),
            lambda x, y: x >= y)

    def p_eq(self, eqn, ins):
        return self._cmp(
            eqn, ins,
            lambda x, y: ((1, 1) if x[0] == x[1] == y[0] == y[1]
                          else (0, 0) if x[1] < y[0] or y[1] < x[0]
                          else (0, 1)),
            lambda x, y: x == y)

    def p_ne(self, eqn, ins):
        return self._cmp(
            eqn, ins,
            lambda x, y: ((0, 0) if x[0] == x[1] == y[0] == y[1]
                          else (1, 1) if x[1] < y[0] or y[1] < x[0]
                          else (0, 1)),
            lambda x, y: x != y)

    def p_and(self, eqn, ins):
        a, b = ins
        if a.kind == 'b':
            out = self._ew2(a, b, lambda x, y: (0, min(x[1], y[1])),
                            lambda x, y: x & y)
            out.gates = a.gates | b.gates
            return out

        def ivf(x, y):
            if x[0] >= 0 or y[0] >= 0:
                hi = min(x[1] if x[0] >= 0 else (1 << a.bits),
                         y[1] if y[0] >= 0 else (1 << a.bits))
                return (0, hi)
            # masking with an all-negative (high-bit) constant mask:
            # u & v = u - (u & ~v), and ~v ∈ [0, -v_lo - 1], so the
            # result lives in [u_lo - (-v_lo - 1), u_hi].
            for u, v in ((x, y), (y, x)):
                if v[1] < 0:
                    return (u[0] - (-v[0] - 1), u[1])
            return _dtype_top('i', a.bits)
        return self._ew2(a, b, ivf, lambda x, y: x & y)

    def p_or(self, eqn, ins):
        a, b = ins
        if a.kind == 'b':
            out = self._ew2(a, b, lambda x, y: (max(x[0], y[0]), 1),
                            lambda x, y: x | y)
            out.gates = a.gates & b.gates
            return out

        def ivf(x, y):
            if x[0] >= 0 and y[0] >= 0:
                m = max(x[1], y[1])
                return (0, (1 << max(1, m.bit_length())) - 1)
            # or-ing in a nonnegative value only sets bits below the
            # sign bit: result keeps u's sign, never drops below u,
            # and a negative u stays ≤ -1.
            for u, v in ((x, y), (y, x)):
                if v[0] >= 0:
                    return (u[0], (u[1] + v[1]) if u[1] >= 0 else -1)
            return _dtype_top('i', a.bits)
        return self._ew2(a, b, ivf, lambda x, y: x | y)

    def p_xor(self, eqn, ins):
        return self.p_or(eqn, ins)

    def p_not(self, eqn, ins):
        a, = ins
        if a.kind == 'b':
            return AVal(a.shape, 'b', 1, (1 - a.iv[1], 1 - a.iv[0]))
        return a.plain(iv=_dtype_top('i', a.bits))

    def p_select_n(self, eqn, ins):
        pred, *cases = ins
        if pred.iv == (0, 0):
            return [cases[0]]
        if pred.iv == (1, 1) and len(cases) == 2:
            return [cases[1]]
        c = pred.scalar_const
        if c is not None:
            return [cases[int(c)]]
        import numpy as np
        # full constant fold: a constant pred *vector* over constant
        # cases (the lane-id where-chains in table.swap_commit_lanes).
        if (pred.const is not None
                and all(x.const is not None for x in cases)):
            shape = np.broadcast_shapes(pred.shape,
                                        *[x.shape for x in cases])
            if int(np.prod(shape, dtype=np.int64)) <= _FOLD_LIMIT:
                sel = np.broadcast_to(pred.const, shape).astype(np.int64)
                arrs = [np.broadcast_to(np.asarray(x.const), shape)
                        for x in cases]
                return [AVal.of_const(np.choose(sel, arrs))]
        # piecewise: a constant pred vector over aligned pieces selects
        # each piece exactly (the plan's lane-masked where()s).
        lens = None
        for x in cases:
            if x.pieces is not None:
                lens = [ln for ln, _ in x.pieces]
        if (lens is not None and pred.const is not None
                and pred.const.ndim == 1
                and all(x.pieces is None or
                        [ln for ln, _ in x.pieces] == lens for x in cases)
                and sum(lens) == pred.const.size and len(cases) == 2):
            out_pieces = []
            off = 0
            for i, ln in enumerate(lens):
                seg = pred.const[off:off + ln]
                off += ln
                sub = [x.pieces[i][1] if x.pieces is not None
                       else x for x in cases]
                if not seg.any():
                    out_pieces.append((ln, sub[0]))
                elif seg.all():
                    out_pieces.append((ln, sub[1]))
                else:
                    j = self._joinv(sub[0], sub[1])
                    out_pieces.append((ln, j))
            iv = out_pieces[0][1].iv
            for _, p in out_pieces[1:]:
                iv = _join(iv, p.iv)
            out = AVal(cases[0].shape if cases[0].shape else cases[1].shape,
                       cases[1].kind, cases[1].bits, iv, pieces=out_pieces)
            return [out]
        out = cases[0]
        for x in cases[1:]:
            out = self._joinv(out, x)
        out = out.with_(gates=frozenset.intersection(
            *[x.gates for x in cases]) if cases[0].kind == 'b'
            else frozenset())
        # sentinel narrowing: select against a uniform constant keeps
        # the other branch's lane attribution, recording the constant so
        # a drop-guarded scatter can discharge it.
        for i, x in enumerate(cases):
            if len(cases) != 2:
                break
            sc = x.scalar_const
            other = cases[1 - i]
            if sc is not None and other.scalar_const is None:
                out = out.with_(mod=other.mod, alt=sc, pieces=other.pieces,
                                sat=other.sat if other.sat and sc == 0
                                else None,
                                exch=other.exch if other.exch and sc == 0
                                else None)
                break
        return [out]

    def _joinv(self, a, b):
        import numpy as np
        shape = np.broadcast_shapes(a.shape, b.shape)
        out = AVal(shape, a.kind if a.kind != 'b' else b.kind,
                   max(a.bits, b.bits), _join(a.iv, b.iv))
        if a.mod is not None and a.mod == b.mod:
            out.mod = a.mod
        if a.lane_src is not None and a.lane_src == b.lane_src:
            out.lane_src = a.lane_src
        if (a.lanes is not None and b.lanes is not None
                and a.shape == b.shape):
            out.lanes = tuple(_join(x, y)
                              for x, y in zip(a.lanes, b.lanes))
        if (a.pieces is not None and b.pieces is not None
                and [ln for ln, _ in a.pieces]
                == [ln for ln, _ in b.pieces]):
            out.pieces = [(ln, self._joinv(p, q))
                          for (ln, p), (_, q) in zip(a.pieces, b.pieces)]
        if a.exch and b.exch and a.exch[0] == b.exch[0]:
            out.exch = (a.exch[0], _join(a.exch[1], b.exch[1]))
        if a.sat and b.sat and a.sat == b.sat:
            out.sat = a.sat
        if a.capminus and a.capminus == b.capminus:
            out.capminus = a.capminus
        return out

    # -- structure --------------------------------------------------------- #

    def p_broadcast_in_dim(self, eqn, ins):
        import numpy as np
        a, = ins
        shape = eqn.params["shape"]
        if a.const is not None:
            try:
                arr = np.broadcast_to(
                    a.const.reshape([a.const.shape[
                        eqn.params["broadcast_dimensions"].index(d)]
                        if d in eqn.params["broadcast_dimensions"] else 1
                        for d in range(len(shape))]), shape)
                if arr.size <= _FOLD_LIMIT:
                    return a.with_(shape=tuple(shape),
                                   const=np.ascontiguousarray(arr))
            except Exception:
                pass
        out = a.with_(shape=tuple(shape), const=None)
        if a.shape and a.shape != tuple(shape):
            # (n,) -> (n, 1, ...) keeps flatten order: the axis-0 piece
            # structure survives (the scatter index column needs it).
            bdims = tuple(eqn.params["broadcast_dimensions"])
            keep = (len(a.shape) == 1 and bdims == (0,)
                    and shape[0] == a.shape[0]
                    and all(d == 1 for d in shape[1:]))
            out.cols = None
            if not keep:
                out.pieces = None
        return out

    def p_reshape(self, eqn, ins):
        import numpy as np
        a, = ins
        shape = eqn.params["new_sizes"]
        if a.const is not None:
            return a.with_(shape=tuple(shape),
                           const=a.const.reshape(shape))
        out = a.with_(shape=tuple(shape), const=None, pieces=None,
                      cols=None)
        # the table <-> flat view alias keeps lanes; anything else drops
        if a.lanes is not None and not (
                len(shape) == 1 or
                (len(shape) == 2 and shape[1] == len(a.lanes))):
            out.lanes = None
        return out

    def p_squeeze(self, eqn, ins):
        a, = ins
        import numpy as np
        shape = tuple(d for i, d in enumerate(a.shape)
                      if i not in eqn.params["dimensions"])
        if a.const is not None:
            return a.with_(shape=shape, const=a.const.reshape(shape))
        return a.with_(shape=shape, const=None, cols=None)

    def p_transpose(self, eqn, ins):
        a, = ins
        perm = eqn.params["permutation"]
        shape = tuple(a.shape[p] for p in perm)
        if a.const is not None:
            return AVal.of_const(a.const.transpose(perm))
        return a.plain(shape=shape)

    def p_concatenate(self, eqn, ins):
        import numpy as np
        dim = eqn.params["dimension"]
        cs = _const_or_none(*ins)
        if cs is not None:
            arr = np.concatenate(cs, axis=dim)
            if arr.size <= _FOLD_LIMIT:
                return AVal.of_const(arr)
        iv = ins[0].iv
        for x in ins[1:]:
            iv = _join(iv, x.iv)
        shape = list(ins[0].shape)
        shape[dim] = sum(x.shape[dim] for x in ins)
        out = AVal(tuple(shape), ins[0].kind, ins[0].bits, iv)
        mods = {x.mod for x in ins}
        if len(mods) == 1:
            out.mod = mods.pop()
        if dim == 0 and len(ins[0].shape) == 1:
            pieces = []
            for x in ins:
                if x.pieces is not None:
                    pieces.extend(x.pieces)
                else:
                    pieces.append((x.shape[0], x))
            out.pieces = pieces
        elif (dim == 1 and len(ins[0].shape) == 2
              and all(x.shape[1] == 1 for x in ins)):
            out.cols = [x for x in ins]
        return out

    def p_iota(self, eqn, ins):
        import numpy as np
        shape = eqn.params["shape"]
        d = eqn.params["dimension"]
        if int(np.prod(shape)) <= _FOLD_LIMIT:
            ix = np.arange(shape[d], dtype=eqn.params["dtype"])
            arr = np.broadcast_to(
                ix.reshape([shape[d] if i == d else 1
                            for i in range(len(shape))]), shape)
            return AVal.of_const(np.ascontiguousarray(arr))
        kind, bits = _dtype_kind(eqn.params["dtype"])
        return AVal(shape, kind, bits, (0, shape[d] - 1))

    def p_slice(self, eqn, ins):
        import numpy as np
        a, = ins
        start = eqn.params["start_indices"]
        limit = eqn.params["limit_indices"]
        strides = eqn.params["strides"] or (1,) * len(start)
        if a.const is not None:
            sl = tuple(slice(s, l, st)
                       for s, l, st in zip(start, limit, strides))
            return AVal.of_const(a.const[sl])
        shape = tuple((l - s + st - 1) // st
                      for s, l, st in zip(start, limit, strides))
        out = a.plain(shape=shape)
        # lane extraction from a (rows, 8) table-lineage value — any row
        # subset (the fused swap gather splits its (chunk+2, 8) result
        # with partial row slices)
        if a.lanes is not None and len(a.shape) == 2 and a.shape[1] == 8:
            if start[1] + 1 == limit[1]:
                lane = start[1]
                out.iv = a.lanes[lane]
                out.lane_src = (id(a.lanes), lane)
            elif start[1] == 0 and limit[1] == 8:
                out = AVal(shape, a.kind, a.bits, a.iv, lanes=a.lanes)
        # ... and from a single packed row (the swap pair's row_a/row_b)
        if (a.lanes is not None and len(a.shape) == 1
                and a.shape[0] == len(a.lanes)
                and start[0] + 1 == limit[0]):
            lane = start[0]
            out.iv = a.lanes[lane]
            out.lane_src = (id(a.lanes), lane)
        # axis-0 sub-range of a pieces value: join overlapped pieces
        if a.pieces is not None and len(a.shape) == 1 and strides == (1,):
            off = 0
            ivs = []
            for ln, p in a.pieces:
                if off < limit[0] and off + ln > start[0]:
                    ivs.append(p.iv)
                off += ln
            if ivs:
                iv = ivs[0]
                for x in ivs[1:]:
                    iv = _join(iv, x)
                out.iv = iv
        return out

    def p_pad(self, eqn, ins):
        a, pv = ins
        import numpy as np
        if a.const is not None and pv.const is not None:
            lo, hi, inner = zip(*eqn.params["padding_config"])
            if all(i == 0 for i in inner) and all(
                    x >= 0 for x in lo + hi):
                arr = np.pad(a.const,
                             list(zip(lo, hi)), constant_values=pv.const)
                if arr.size <= _FOLD_LIMIT:
                    return AVal.of_const(arr)
        shape = tuple(d + l + h + (d - 1) * i
                      for d, (l, h, i) in zip(a.shape,
                                              eqn.params["padding_config"]))
        out = a.plain(shape=shape, iv=_join(a.iv, pv.iv))
        if (pv.scalar_const == 0 and len(a.shape) == 1
                and a.shape[0] >= 1):
            lo, hi, inner = eqn.params["padding_config"][0]
            if inner >= 1 and lo >= 0 and hi >= 0:
                # zero-dilated: nonzero only at lo + k*(inner+1)
                out.padz = (lo % (inner + 1), inner + 1)
        return out

    def p_rev(self, eqn, ins):
        a, = ins
        if a.const is not None:
            import numpy as np
            return AVal.of_const(np.flip(a.const,
                                         eqn.params["dimensions"]))
        return a.plain()

    # -- conversions ------------------------------------------------------- #

    def p_convert_element_type(self, eqn, ins):
        a, = ins
        kind, bits = _dtype_kind(eqn.params["new_dtype"])
        if kind == a.kind and bits == a.bits:
            return a               # identity: preserve object id (gates)
        if a.const is not None:
            import numpy as np
            return AVal.of_const(a.const.astype(eqn.params["new_dtype"]))
        lo, hi = a.iv
        if kind == 'i' and a.kind == 'f':
            lo = lo if lo == -INF else math.floor(lo)
            hi = hi if hi == INF else math.ceil(hi)
            lo, hi = (max(lo, _dtype_top(kind, bits)[0]),
                      min(hi, _dtype_top(kind, bits)[1]))
        if kind == 'b':
            lo, hi = (0 if lo <= 0 <= hi else 1, 0 if lo == hi == 0 else 1)
        out = AVal(a.shape, kind, bits, (lo, hi), gates=a.gates,
                   mod=a.mod if kind == 'i' and a.kind == 'i' else None)
        return out

    def p_device_put(self, eqn, ins):
        return ins[0]

    def p_copy(self, eqn, ins):
        return ins[0]

    def p_stop_gradient(self, eqn, ins):
        return ins[0]

    # -- reductions -------------------------------------------------------- #

    def _red_n(self, a, eqn):
        import numpy as np
        n = 1
        for ax in eqn.params["axes"]:
            n *= a.shape[ax]
        shape = tuple(d for i, d in enumerate(a.shape)
                      if i not in eqn.params["axes"])
        return n, shape

    def p_reduce_sum(self, eqn, ins):
        a, = ins
        n, shape = self._red_n(a, eqn)
        if a.const is not None:
            import numpy as np
            return AVal.of_const(a.const.sum(axis=eqn.params["axes"]))
        lo = _pmul(n, a.iv[0]) if a.iv[0] < 0 else min(a.iv[0], 0) \
            if n > 1 else a.iv[0]
        hi = _pmul(n, a.iv[1]) if a.iv[1] > 0 else max(a.iv[1], 0) \
            if n > 1 else a.iv[1]
        return a.plain(shape=shape, iv=(lo, hi))

    def p_reduce_max(self, eqn, ins):
        a, = ins
        _, shape = self._red_n(a, eqn)
        if a.const is not None:
            import numpy as np
            return AVal.of_const(a.const.max(axis=eqn.params["axes"]))
        return a.plain(shape=shape)

    def p_reduce_min(self, eqn, ins):
        a, = ins
        _, shape = self._red_n(a, eqn)
        if a.const is not None:
            import numpy as np
            return AVal.of_const(a.const.min(axis=eqn.params["axes"]))
        return a.plain(shape=shape)

    def p_reduce_or(self, eqn, ins):
        a, = ins
        _, shape = self._red_n(a, eqn)
        return AVal(shape, 'b', 1, a.iv,
                    gates=a.gates if a.shape == () or shape == a.shape
                    else frozenset())

    def p_reduce_and(self, eqn, ins):
        a, = ins
        _, shape = self._red_n(a, eqn)
        return AVal(shape, 'b', 1, a.iv)

    def p_argmax(self, eqn, ins):
        a, = ins
        axes = eqn.params["axes"]
        shape = tuple(d for i, d in enumerate(a.shape) if i not in axes)
        hi = max(a.shape[ax] for ax in axes) - 1
        kind, bits = _dtype_kind(eqn.params["index_dtype"])
        return AVal(shape, kind, bits, (0, hi))

    p_argmin = p_argmax

    def p_cumsum(self, eqn, ins):
        a, = ins
        n = a.shape[eqn.params["axis"]]
        lo = _pmul(n, a.iv[0]) if a.iv[0] < 0 else a.iv[0]
        hi = _pmul(n, a.iv[1]) if a.iv[1] > 0 else a.iv[1]
        return a.plain(iv=(lo, hi))

    def p_cummax(self, eqn, ins):
        return ins[0].plain()

    p_cummin = p_cummax

    def p_sort(self, eqn, ins):
        return [x.plain() for x in ins]

    # -- indexing ---------------------------------------------------------- #

    def _index_cols(self, idx, ndim_indexed):
        """Per-indexed-dimension column AVals of a gather/scatter index
        array of shape (..., k)."""
        import numpy as np
        k = idx.shape[-1] if idx.shape else 1
        if idx.const is not None:
            flat = idx.const.reshape(-1, k)
            return [AVal.of_const(flat[:, j]) for j in range(k)]
        if idx.cols is not None and len(idx.cols) == k:
            return idx.cols
        if (idx.pieces is not None and len(idx.pieces) == k
                and all(ln == 1 for ln, _ in idx.pieces)):
            return [p for _, p in idx.pieces]   # (1, k) single-site index
        if k == 1:
            return [idx]
        return [idx.plain(shape=(0,)) for _ in range(k)]

    def _check_index(self, eqn, cols, dims, sizes, guarded, what):
        """Classify one gather/scatter's table indexing."""
        ok = True
        for col, d in zip(cols, dims):
            lo, hi = col.iv
            # a drop-guarded select-against-sentinel narrows to the
            # live branch; the sentinel constant must itself be either
            # in range or discharged by the guard.
            if not (0 <= lo and hi < sizes[d]):
                ok = False
        if ok:
            self.n_proved += 1
        elif guarded:
            self.n_guarded += 1
        else:
            self.index_findings.append(
                (eqn_loc(eqn),
                 f"{what} index into the table not proven in bounds "
                 f"(index interval {[c.iv for c in cols]} vs dims "
                 f"{[sizes[d] for d in dims]}) and not guarded by "
                 "mode=drop/clip — XLA PROMISE_IN_BOUNDS is undefined "
                 "behavior out of range"))

    @staticmethod
    def _guarded_mode(eqn):
        mode = eqn.params.get("mode")
        name = getattr(mode, "name", str(mode))
        return any(k in str(name) for k in ("FILL_OR_DROP", "CLIP", "DROP"))

    def p_gather(self, eqn, ins):
        import numpy as np
        a, idx = ins
        dnums = eqn.params["dimension_numbers"]
        slice_sizes = eqn.params["slice_sizes"]
        out_aval = eqn.outvars[0].aval
        guarded = self._guarded_mode(eqn)
        cols = self._index_cols(idx, len(dnums.start_index_map))
        if a.lanes is not None:
            self._check_index(eqn, cols, dnums.start_index_map, a.shape,
                              guarded, "gather")
            out = AVal(out_aval.shape, a.kind, a.bits, a.iv)
            if len(a.shape) == 2 and a.shape[1] == len(a.lanes):
                if (len(slice_sizes) == 2
                        and slice_sizes[1] == len(a.lanes)):
                    # whole-row (or row-block) gather: rows keep
                    # per-lane structure
                    out.lanes = a.lanes
                    return out
                if (slice_sizes == (1, 1)
                        and getattr(dnums, "operand_batching_dims",
                                    ()) == (1,)
                        and dnums.start_index_map == (0,)):
                    # take_along_axis row gather: the lane axis is a
                    # batch axis, so rows keep per-lane structure too
                    out.lanes = a.lanes
                    return out
                if (slice_sizes == (1, 1) and len(cols) == 2
                        and cols[1].const is not None
                        and np.unique(cols[1].const).size == 1):
                    lane = int(cols[1].const.reshape(-1)[0])
                    out.iv = a.lanes[lane]
                    out.lane_src = (id(a.lanes), lane)
                    return out
            elif len(a.shape) == 1:
                # flat view: a known index mod narrows to one lane
                if cols and cols[0].mod is not None:
                    out.iv = a.lanes[cols[0].mod]
                    out.lane_src = (id(a.lanes), cols[0].mod)
                    return out
            iv = a.lanes[0]
            for l in a.lanes[1:]:
                iv = _join(iv, l)
            out.iv = iv
            return out
        cs = _const_or_none(a, idx)
        if cs is not None and a.const.size <= _FOLD_LIMIT:
            try:
                from jax import lax
                import jax
                with jax.disable_jit():
                    arr = lax.gather(
                        cs[0], cs[1], dnums, slice_sizes,
                        mode=eqn.params.get("mode"))
                return AVal.of_const(np.asarray(arr))
            except Exception:
                pass
        return AVal(out_aval.shape, a.kind, a.bits, a.iv)

    def _scatter_common(self, eqn, ins, op):
        import numpy as np
        a, idx, upd = ins
        dnums = eqn.params["dimension_numbers"]
        guarded = self._guarded_mode(eqn)
        out = AVal(a.shape, a.kind, a.bits, a.iv, lanes=a.lanes,
                   mod=a.mod)
        if a.lanes is None:
            if op == "add":
                out.iv = _iv_add(a.iv, (min(0, _pmul(
                    int(np.prod(upd.shape) or 1), upd.iv[0])),
                    max(0, _pmul(int(np.prod(upd.shape) or 1),
                                 upd.iv[1]))))
            else:
                out.iv = _join(a.iv, upd.iv)
            return out
        dims = dnums.scatter_dims_to_operand_dims
        cols = self._index_cols(idx, len(dims))
        self._check_index(eqn, cols, dims, a.shape, guarded, "scatter")
        lanes = list(a.lanes)

        def sections():
            """Aligned (length, idx_piece, upd_piece) sections of the
            flattened scatter (cut at every piece boundary)."""
            def cuts(av, total):
                if av.pieces is None:
                    return [(total, av)]
                return list(av.pieces)
            total = idx.shape[0] if idx.shape else 1
            ip = cuts(cols[0] if len(cols) == 1 else idx, total)
            up = cuts(upd, total)
            out_secs = []
            i = j = 0
            ioff = joff = 0
            while i < len(ip) and j < len(up):
                ilen, ipc = ip[i]
                jlen, upc = up[j]
                take = min(ilen - ioff, jlen - joff)
                out_secs.append((take, ipc, upc))
                ioff += take
                joff += take
                if ioff == ilen:
                    i, ioff = i + 1, 0
                if joff == jlen:
                    j, joff = j + 1, 0
            return out_secs

        def col_lane():
            c = cols[1]
            if c.const is not None:
                u = np.unique(c.const)
                if u.size == 1:
                    return int(u[0])
            if c.iv[0] == c.iv[1] and 0 <= c.iv[0] < 8:
                return int(c.iv[0])
            return None

        if len(a.shape) == 2 and len(cols) == 2:
            # row/lane scatter on the 2-D table
            secs = [(int(np.prod(upd.shape) or 1),
                     AVal((0,), 'i', 32, cols[0].iv, mod=col_lane()),
                     upd)]
        else:
            secs = sections()
        for length, ipc, upc in secs:
            lane = ipc.mod if len(a.shape) == 1 else ipc.mod
            targets = range(8) if lane is None else [lane]
            # a drop-guarded sentinel branch contributes nothing when
            # its constant is out of range.
            if (lane is None and ipc.alt is not None and guarded
                    and ipc.mod is None):
                pass
            for ln in targets:
                pre = lanes[ln]
                if op == "set":
                    lanes[ln] = _join(pre, upc.iv)
                elif op == "max":
                    lanes[ln] = (pre[0], max(pre[1], upc.iv[1]))
                elif upc.sat is not None and upc.sat[0] == ln \
                        and upc.iv[0] >= 0:
                    lanes[ln] = (pre[0], max(pre[1], upc.sat[1]))
                elif upc.exch is not None and upc.exch[0] == ln:
                    lanes[ln] = _join(pre, upc.exch[1])
                elif upc.iv == (0, 0):
                    pass
                else:
                    lanes[ln] = (pre[0] + _pmul(length, min(0, upc.iv[0])),
                                 pre[1] + _pmul(length, max(0, upc.iv[1])))
        out.lanes = tuple(lanes)
        lo = min(l[0] for l in lanes)
        hi = max(l[1] for l in lanes)
        out.iv = (lo, hi)
        return out

    def p_scatter_add(self, eqn, ins):
        return self._scatter_common(eqn, ins, "add")

    def p_scatter(self, eqn, ins):
        return self._scatter_common(eqn, ins, "set")

    def p_scatter_max(self, eqn, ins):
        return self._scatter_common(eqn, ins, "max")

    def p_scatter_min(self, eqn, ins):
        a, idx, upd = ins
        out = self._scatter_common(eqn, ins, "set")
        return out

    def p_dynamic_slice(self, eqn, ins):
        a, *starts = ins
        shape = eqn.params["slice_sizes"]
        if a.const is not None and all(s.const is not None
                                       for s in starts):
            import numpy as np
            st = [int(np.clip(s.const, 0, d - z)) for s, d, z in
                  zip(starts, a.shape, shape)]
            sl = tuple(slice(s, s + z) for s, z in zip(st, shape))
            return AVal.of_const(a.const[sl])
        out = a.plain(shape=tuple(shape))
        # single-row fetch from the packed table (`table[scalar]` is a
        # dynamic_slice + squeeze): rows keep per-lane structure —
        # dynamic_slice clamps its start, so the read is always in
        # bounds.
        if (a.lanes is not None and len(a.shape) == 2
                and tuple(shape) == (1, a.shape[1])):
            out = AVal(tuple(shape), a.kind, a.bits, a.iv, lanes=a.lanes)
            iv = a.lanes[0]
            for l in a.lanes[1:]:
                iv = _join(iv, l)
            out.iv = iv
            return out
        # single-cell fetch `table[row, LANE]` with a constant lane
        # column: the cell's interval is that lane's interval.
        if (a.lanes is not None and len(a.shape) == 2
                and a.shape[1] == len(a.lanes)
                and tuple(shape) == (1, 1) and len(starts) == 2):
            c = starts[1].scalar_const
            if c is not None and 0 <= int(c) < len(a.lanes):
                lane = int(c)
                out = AVal(tuple(shape), a.kind, a.bits, a.lanes[lane])
                out.lane_src = (id(a.lanes), lane)
                return out
        if a.pieces is not None:
            iv = a.pieces[0][1].iv
            for _, p in a.pieces[1:]:
                iv = _join(iv, p.iv)
            out.iv = iv
        return out

    def p_dynamic_update_slice(self, eqn, ins):
        a, upd, *starts = ins
        return a.plain(iv=_join(a.iv, upd.iv))

    def p_clamp(self, eqn, ins):
        lo, x, hi = ins

        def c(a, b, d):
            return min(max(a, b), d)
        return x.plain(iv=(c(lo.iv[0], x.iv[0], hi.iv[0]),
                           c(lo.iv[1], x.iv[1], hi.iv[1])))

    # -- higher order ------------------------------------------------------ #

    def p_pjit(self, eqn, ins):
        return self.eval_closed(eqn.params["jaxpr"], ins)

    def p_closed_call(self, eqn, ins):
        return self.eval_closed(eqn.params["call_jaxpr"], ins)

    def p_custom_jvp_call(self, eqn, ins):
        return self.eval_closed(eqn.params["call_jaxpr"], ins)

    def p_custom_vjp_call(self, eqn, ins):
        return self.eval_closed(eqn.params["call_jaxpr"], ins)

    def p_remat(self, eqn, ins):
        return self.eval_jaxpr(eqn.params["jaxpr"], [], ins)

    def p_cond(self, eqn, ins):
        pred, *ops = ins
        branches = eqn.params["branches"]
        c = pred.scalar_const
        if c is not None:
            return self.eval_closed(branches[int(c)], ops)
        lo = max(int(pred.iv[0]), 0)
        hi = min(int(pred.iv[1]), len(branches) - 1)
        outs = None
        for b in range(lo, hi + 1):
            o = self.eval_closed(branches[b], ops)
            outs = o if outs is None else [
                self._joinv(x, y) for x, y in zip(outs, o)]
        return outs

    def p_scan(self, eqn, ins):
        nc = eqn.params["num_consts"]
        nk = eqn.params["num_carry"]
        T = eqn.params["length"]
        body = eqn.params["jaxpr"]
        consts, init, xs = ins[:nc], ins[nc:nc + nk], ins[nc + nk:]
        xelems = [x.plain(shape=x.shape[1:]) for x in xs]

        def run(carry):
            return self.eval_closed(body, consts + list(carry) + xelems)

        if T == 0:
            return list(init) + [
                AVal((0,) + tuple(x.shape[1:]), x.kind, x.bits, x.iv)
                for x in xs] if len(eqn.outvars) > nk else list(init)

        # Affine widening S_t ⊆ base + t·h: base joins the init with
        # the first abstract iteration (absorbing init-sentinel jumps),
        # h is the steady-state slope measured on the SECOND iteration.
        # Verified at both ends (t=0→1 and t=T-1→T); the loop bodies in
        # scope (max-plus pipelines, counters, scatter-set fills) are
        # 1-Lipschitz in the carry, so the two endpoint checks cover
        # the interior steps. A failed component widens to top and the
        # verification re-runs until the choice is stable.
        outs1 = run(init)
        base = [i0.plain(iv=_join(i0.iv, o.iv))
                for i0, o in zip(init, outs1[:nk])]
        outs2 = run(base)
        h = []
        for b, o in zip(base, outs2[:nk]):
            hlo = (min(0, o.iv[0] - b.iv[0]) if -INF < b.iv[0]
                   and -INF < o.iv[0] else -INF)
            hhi = (max(0, o.iv[1] - b.iv[1]) if b.iv[1] < INF
                   and o.iv[1] < INF else INF)
            h.append((hlo, hhi))

        def shift(t):
            out = []
            for b, (hl, hh) in zip(base, h):
                lo = b.iv[0] + _pmul(t, hl) if -INF < b.iv[0] \
                    and -INF < hl else -INF
                hi = b.iv[1] + _pmul(t, hh) if b.iv[1] < INF \
                    and hh < INF else INF
                out.append(b.plain(iv=(lo, hi)))
            return out

        wide = [False] * nk
        for _ in range(3):
            cand = shift(T)
            step1 = shift(1)
            carry3 = [b.plain(iv=_dtype_top(b.kind, b.bits)) if w else c
                      for w, b, c in zip(wide, base, shift(T - 1))]
            outs3 = run(carry3)
            changed = False
            for k in range(nk):
                if wide[k]:
                    continue
                ok = (_contains(step1[k].iv, outs2[k].iv)
                      and _contains(cand[k].iv, outs3[k].iv))
                if not ok:
                    wide[k] = True
                    changed = True
            if not changed:
                break
        final = []
        for k, (i0, c) in enumerate(zip(init, cand)):
            if wide[k]:
                final.append(i0.plain(iv=_dtype_top(i0.kind, i0.bits)))
            else:
                final.append(i0.plain(iv=c.iv))
        ys = []
        for o1, o3 in zip(outs1[nk:], outs3[nk:]):
            ys.append(AVal((T,) + o3.shape, o3.kind, o3.bits,
                           _join(o1.iv, o3.iv)))
        return final + ys

    def p_while(self, eqn, ins):
        raise NotImplementedError("while")


# --------------------------------------------------------------------------- #
# Binding the declared budget to program inputs.
# --------------------------------------------------------------------------- #


def _table_aval(var, n_pages, epoch_hi):
    inv = _lane_invariants(n_pages, epoch_hi)
    lanes = tuple(inv)
    lo = min(l[0] for l in lanes)
    hi = max(l[1] for l in lanes)
    return AVal(var.aval.shape, 'i', 32, (lo, hi), lanes=lanes)


def _field_iv(field, cfg, time_hi, n_chunks, nd, counter_hi=0):
    n_pages = cfg.n_pages
    if field in _TIME_FIELDS:
        return (0, time_hi)
    if field == "chunk_idx":
        return (0, n_chunks)
    if field == "dma.swaps_done":
        return (0, n_chunks)
    ind = _inductive_fields(n_pages, nd)
    if field in ind:
        return ind[field]
    if field.startswith("counters."):
        # event counters: the origin run measures the per-chunk rate,
        # the budget run re-declares them under rate × n_chunks.
        return (0, counter_hi)
    return None


def bind_invar(name, var, cfg, time_hi, n_chunks, nd, notes,
               counter_hi=0):
    """Declared AVal for one named program input, or None + note."""
    kind, bits = _dtype_kind(var.aval.dtype)
    shape = var.aval.shape

    def mk(lo, hi):
        return AVal(shape, kind, bits, (lo, hi))

    if name == "table" or name == "state.table":
        return _table_aval(var, cfg.n_pages, time_hi)
    for pref in ("sc.", "state."):
        if name.startswith(pref):
            iv = _field_iv(name[len(pref):], cfg, time_hi, n_chunks, nd,
                           counter_hi)
            if iv is not None:
                return mk(*iv)
            break
    if name == "bank_free" or name == "state.bank_free":
        return mk(0, time_hi)
    if name.startswith("params."):
        leaf = name.split(".", 1)[1]
        if leaf not in PARAM_BOUNDS:
            notes.append(f"params leaf `{leaf}` has no declared interval "
                         "in PARAM_BOUNDS — the budget declaration must "
                         "cover every runtime knob")
            return AVal.top_for(var.aval)
        lo, hi = PARAM_BOUNDS[leaf]
        if hi is None:
            hi = cfg.n_pages
        return mk(lo, hi)
    base = name.split(".")[-1]
    if base in ("page",):
        return mk(0, cfg.n_pages - 1)
    if base in TRACE_BOUNDS:
        lo, hi = TRACE_BOUNDS[base]
        return mk(lo, hi if hi is not None else cfg.n_pages - 1)
    if base in ("is_write", "valid"):
        return mk(0, 1)
    if name.startswith("faults."):
        return mk(-1, 1 << 30)
    notes.append(f"program input `{name}` has no declared interval")
    return AVal.top_for(var.aval)


# --------------------------------------------------------------------------- #
# Checking one program (origin run for growth, budget run for proofs).
# --------------------------------------------------------------------------- #


def _out_field(name):
    for pref in ("sc.", "state.", "out.sc.", "out.state."):
        if name.startswith(pref):
            return name[len(pref):]
    return name


def check_program(label, jaxpr, consts, invars, in_names, out_names,
                  cfg, nd=2):
    """Run the two-phase budget analysis on one program (all inputs
    bound by name from the declared budget).

    Returns ``(findings, bounds)``; bounds is the per-program proved
    summary that lands in the CLI report."""
    notes: list = []

    def bind(time_hi, counter_hi=0):
        return [bind_invar(name, var, cfg, time_hi, N_CHUNKS_BUDGET, nd,
                           notes, counter_hi)
                for name, var in zip(in_names, invars)]

    findings, bounds = _check_core(label, jaxpr, bind, out_names, cfg,
                                   nd, consts=consts)
    for n in dict.fromkeys(notes):
        findings.append(Finding(f"<{label}>", 0, PASS, f"[{label}] {n}"))
    return findings, bounds


# --------------------------------------------------------------------------- #
# Repo entry points.
# --------------------------------------------------------------------------- #

#: Filled by run_repo: per-program proved-bounds summaries for the CLI
#: report (`--report` embeds it under "proved_bounds").
LAST_BOUNDS: list = []


def validate_budget(cfg) -> list[str]:
    """The repo's own config must sit inside the declared budget."""
    import jax
    from repro.core.config import RuntimeParams
    params = RuntimeParams.from_config(cfg)
    out = []
    for name, leaf in params._asdict().items():
        if name not in PARAM_BOUNDS:
            out.append(f"params leaf `{name}` missing from PARAM_BOUNDS")
            continue
        lo, hi = PARAM_BOUNDS[name]
        if hi is None:
            hi = cfg.n_pages
        v = float(leaf)
        if not (lo <= v <= hi):
            out.append(f"config value {name}={v} outside the declared "
                       f"budget interval [{lo}, {hi}]")
    return out


def _pragma_filter(findings, root):
    """Apply source pragmas per referenced file (jaxpr locs point into
    real sources)."""
    by_path: dict = {}
    out = []
    for f in findings:
        p = root / f.path
        if f.path.startswith("<") or not p.is_file():
            out.append(f)
            continue
        by_path.setdefault(p, []).append(f)
    for p, fs in by_path.items():
        out.extend(apply_pragmas(fs, p.read_text()))
    return out


def run_repo(root: pathlib.Path) -> list[Finding]:
    from repro.core.config import small_platform
    from repro.core.emulator import as_registry

    cfg = small_platform()
    registry = as_registry(None)
    findings: list[Finding] = []
    LAST_BOUNDS.clear()

    for msg in validate_budget(cfg):
        findings.append(Finding("src/repro/analysis/ranges.py", 0, PASS,
                                msg))

    # scan path: the chunk body of the compiled `lax.scan`.
    info, err = scan_body_info(cfg, registry)
    if err is not None:
        findings.append(Finding("src/repro/core/emulator.py", 1, PASS,
                                err))
    else:
        f, b = _check_scan_path(info, cfg)
        findings += f
        LAST_BOUNDS.append(b)

    # step_ref paths: params as traced inputs -> parametric proofs.
    for seq, label in ((True, "pallas-body"), (False, "jnp-ref")):
        jaxpr, names, out_names = trace_step_ref(
            cfg, registry, seq, params_as_inputs=True)
        f, b = check_program(label, jaxpr.jaxpr, jaxpr.consts,
                             jaxpr.jaxpr.invars, names, out_names, cfg)
        findings += f
        LAST_BOUNDS.append(b)
    return _pragma_filter(findings, root)


def _check_scan_path(info, cfg):
    """Bind the scan body: evaluate the outer jaxpr prefix (trace/faults
    declared) to get the scan's const/xs operands, then run the budget
    analysis on the body with the carry declared."""
    outer = info["outer"]
    names = info["outer_names"]
    notes: list = []
    pre = Interp(track_overflow=False)
    env = {}
    import numpy as np
    for v, c in zip(outer.jaxpr.constvars, outer.consts):
        env[v] = AVal.of_const(np.asarray(c))
    for v, name in zip(outer.jaxpr.invars, names):
        env[v] = bind_invar(name, v, cfg, 0, N_CHUNKS_BUDGET, 2, notes)

    target = info["scan_eqn"]
    for eqn in outer.jaxpr.eqns:
        if eqn is target:
            break
        ins = [env[x] if not hasattr(x, "val") else AVal.of_const(x.val)
               for x in eqn.invars]
        fn = getattr(pre, "p_" + eqn.primitive.name.replace("-", "_"),
                     None)
        try:
            outs = (fn(eqn, ins) if fn is not None
                    else [AVal.top_for(o.aval) for o in eqn.outvars])
            if not isinstance(outs, list):
                outs = [outs]
        except Exception:
            outs = [AVal.top_for(o.aval) for o in eqn.outvars]
        for var, a in zip(eqn.outvars, outs):
            if type(var).__name__ != "DropVar":
                env[var] = a

    nc, nk = info["num_consts"], info["num_carry"]
    body = info["body"]

    def read_operand(x):
        if hasattr(x, "val"):
            return AVal.of_const(np.asarray(x.val))
        return env.get(x, AVal.top_for(x.aval))

    const_avs = [read_operand(x) for x in target.invars[:nc]]
    xs_avs = [read_operand(x) for x in target.invars[nc + nk:]]
    xelems = [x.plain(shape=x.shape[1:]) for x in xs_avs]

    core = body.jaxpr if hasattr(body, "jaxpr") else body
    bconsts = list(getattr(body, "consts", ()))
    carry_vars = core.invars[nc:nc + nk]
    out_names = info["carry_names"] + [
        f"ys{i}" for i in range(len(core.outvars) - nk)]

    def bind(time_hi, counter_hi=0):
        # scan consts and xs slices come from the evaluated outer
        # prefix (params, trace columns, fault schedule — all time-
        # independent); the carry is re-declared per phase.
        carry = [bind_invar(name, var, cfg, time_hi, N_CHUNKS_BUDGET,
                            2, notes, counter_hi)
                 for name, var in zip(info["carry_names"], carry_vars)]
        return const_avs + carry + xelems

    findings, bounds = _check_core(
        "scan-path", core, bind, out_names, cfg, nd=2, consts=bconsts)
    for n in dict.fromkeys(notes):
        findings.append(Finding("<scan-path>", 0, PASS,
                                f"[scan-path] {n}"))
    return findings, bounds


def _check_core(label, body, bind, out_names, cfg, nd,
                consts=()):
    findings: list = []
    bounds = {"label": label, "n_chunks_budget": N_CHUNKS_BUDGET}

    def program_finding(msg):
        findings.append(Finding(f"<{label}>", 0, PASS, f"[{label}] {msg}"))

    interp_b = Interp(track_overflow=False)
    try:
        outs_b = interp_b.eval_jaxpr(body, list(consts), bind(0))
    except Exception as e:
        program_finding(f"abstract evaluation failed: {type(e).__name__}: "
                        f"{e}")
        return findings, bounds
    for loc, msg in interp_b.gaps:
        findings.append(Finding(loc[0], loc[1], PASS,
                                f"[{label}] {msg} — interval analysis has "
                                "a soundness hole here"))
    G = 1
    mono_rates = {}
    for name, o in zip(out_names, outs_b):
        field = _out_field(name)
        if field in _TIME_FIELDS:
            if o.iv[1] == INF or o.iv[1] > INT32[1]:
                program_finding(
                    f"per-chunk growth of time field `{field}` is "
                    f"unbounded ({o.iv}) — cannot establish an int32 "
                    "horizon")
                return findings, bounds
            G = max(G, int(o.iv[1]))
        elif ((field in _MONO_FIELDS or field.startswith("counters."))
                and o.kind == 'i'):
            if o.iv[1] == INF:
                program_finding(
                    f"per-chunk growth of counter `{field}` is unbounded")
            else:
                mono_rates[field] = max(1, int(o.iv[1]))
    horizon = INT32[1] // max(G, 1)
    bounds["per_chunk_growth"] = G
    bounds["int32_horizon_chunks"] = horizon
    if horizon < N_CHUNKS_BUDGET:
        program_finding(
            f"int32 clock horizon is {horizon} chunks (per-chunk growth "
            f"{G}) but the declared budget is {N_CHUNKS_BUDGET} chunks — "
            "a budgeted run can overflow the cycle counters")

    B = G * N_CHUNKS_BUDGET
    bounds["cycle_budget"] = B
    counter_hi = max(
        [r for f, r in mono_rates.items() if f.startswith("counters.")],
        default=0) * N_CHUNKS_BUDGET
    interp = Interp(track_overflow=True)
    try:
        outs = interp.eval_jaxpr(body, list(consts),
                                 bind(B, min(counter_hi, INT32[1])))
    except Exception as e:
        program_finding(f"abstract evaluation (budget run) failed: "
                        f"{type(e).__name__}: {e}")
        return findings, bounds
    for loc, msg in interp.gaps:
        findings.append(Finding(loc[0], loc[1], PASS,
                                f"[{label}] {msg} — interval analysis has "
                                "a soundness hole here"))
    for loc, msg in interp.index_findings:
        findings.append(Finding(loc[0], loc[1], PASS, f"[{label}] {msg}"))
    for loc, prim, iv in interp.overflow:
        findings.append(Finding(
            loc[0], loc[1], PASS,
            f"[{label}] int32 `{prim}` can overflow under the declared "
            f"budget (interval {iv}) — saturate or widen it"))
    bounds["table_gathers_proved"] = interp.n_proved
    bounds["table_gathers_guarded"] = interp.n_guarded

    from repro.core import table as table_lib
    inv = _lane_invariants(cfg.n_pages, B)
    for name, o in zip(out_names, outs):
        field = _out_field(name)
        if field == "table" and o.lanes is None:
            program_finding("the table output lost its per-lane interval "
                            "lineage — the lane proofs do not cover this "
                            "program")
        elif field == "table":
            lane_bounds = {}
            for ln in range(8):
                lane_bounds[_LANE_NAMES[ln]] = [o.lanes[ln][0],
                                                o.lanes[ln][1]]
                if ln in _INDUCTIVE_LANES and not _contains(
                        inv[ln], o.lanes[ln]):
                    program_finding(
                        f"{_LANE_NAMES[ln]} lane not inductive: declared "
                        f"{inv[ln]}, one chunk reaches {o.lanes[ln]} — "
                        "an unsaturated accumulation reached the scan "
                        "carry")
                if o.lanes[ln][1] != INF and o.lanes[ln][1] > INT32[1]:
                    program_finding(
                        f"{_LANE_NAMES[ln]} lane can exceed int32 "
                        f"({o.lanes[ln]})")
            epoch = o.lanes[table_lib.EPOCH]
            if epoch[1] != INF and epoch[1] > INT32[1]:
                program_finding(f"EPOCH lane exceeds int32 ({epoch})")
            bounds["lanes"] = lane_bounds
        elif field in _TIME_FIELDS:
            if o.iv[1] == INF or o.iv[1] > INT32[1]:
                program_finding(
                    f"time field `{field}` exceeds int32 under the "
                    f"budget ({o.iv})")
        elif ((field in _MONO_FIELDS or field.startswith("counters."))
                and o.kind == 'i'):
            rate = mono_rates.get(field, 1)
            if rate * N_CHUNKS_BUDGET > INT32[1]:
                program_finding(
                    f"monotone counter `{field}` (rate {rate}/chunk) "
                    "overflows int32 under the budget")
        else:
            ind = _inductive_fields(cfg.n_pages, nd)
            if field in ind and not _contains(ind[field], o.iv):
                program_finding(
                    f"carry field `{field}` not inductive: declared "
                    f"{ind[field]}, one chunk reaches {o.iv}")
    return findings, bounds


#: Fixture inputs: non-table ints are declared in [0, 2^20].
_FIXTURE_INT_HI = 1 << 20


def run_paths(paths) -> list[Finding]:
    import jax

    from .common import fixture_case

    findings: list[Finding] = []
    for path in paths:
        case = fixture_case(path)
        if not case or case.get("kind") != "ranges":
            continue
        fn, args = case["make"]()
        jaxpr = jax.make_jaxpr(fn)(*args)
        findings += check_fixture(jaxpr, pathlib.Path(path).stem)
    return findings


def check_fixture(jaxpr, label):
    """Budget analysis for a fixture: argument 0 is the table (2-D
    (n, 8) or flat), other ints are bound to the fixture budget."""
    core = jaxpr.jaxpr
    findings: list = []

    def bind(time_hi, counter_hi=0):
        avs = []
        for i, v in enumerate(core.invars):
            kind, bits = _dtype_kind(v.aval.dtype)
            shape = tuple(v.aval.shape)
            if i == 0:
                n_pages = (shape[0] if len(shape) == 2
                           else shape[0] // 8)
                avs.append(_table_aval(v, n_pages, time_hi))
            elif kind == 'b':
                avs.append(AVal(shape, 'b', 1, (0, 1)))
            elif kind == 'i':
                avs.append(AVal(shape, kind, bits, (0, _FIXTURE_INT_HI)))
            else:
                avs.append(AVal(shape, kind, bits, (0.0, INF)))
        return avs

    tshape = tuple(core.invars[0].aval.shape)
    n_pages = tshape[0] if len(tshape) == 2 else tshape[0] // 8

    class _Cfg:
        pass

    cfg = _Cfg()
    cfg.n_pages = n_pages
    out_names = []
    for v in core.outvars:
        if tuple(v.aval.shape) in (tshape, (n_pages, 8), (n_pages * 8,)):
            out_names.append("table")
        else:
            out_names.append("y")
    f, _b = _check_core(label, core, bind, out_names, cfg, nd=2)
    return [Finding(x.path, x.line, PASS, x.message) for x in f]
