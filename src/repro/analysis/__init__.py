"""reprolint — repo-specific static analysis for the emulator's contracts.

The conventions this repo runs on (the one-true chunk schedule, lane
accessors on the packed table, donation aliasing, the traced/static
split, zero recompiles after warmup) have each been violated and
hand-fixed at least once. This package turns them into machine-checked
contracts:

    PYTHONPATH=src python -m repro.analysis --check

runs all passes against the repo and exits non-zero with
``path:line: [pass] message`` findings. Single passes run with
``--pass <name>``; fixture/file mode takes explicit paths. To exempt a
line, add ``# reprolint: allow[<pass>] <why>`` — the reason is part of
the contract.

Passes: schedule (jaxpr-level chunk schedule on the scan path AND the
Pallas kernel body), donation (lowered aliasing cross-check + AST
read-after-donate), lanes (AST lane-accessor discipline), staticness
(AST traced control flow + static_key completeness by perturbation),
tripwire (``assert_compile_flat`` + adoption check), docrefs (stale
legacy-entry-point references), ranges (interval abstract interpreter:
int32 overflow proofs for the packed-table accumulators under the
declared run budget + in-bounds proofs for every table gather/scatter,
on the scan path AND both step_ref paths), pallas_san (static Pallas
kernel sanitizer: VMEM footprint vs budget, init-before-read on
output/scratch refs, write-write grid hazards via index_map
evaluation).
"""
from __future__ import annotations

import pathlib

from . import (
    docrefs,
    donation,
    lanes,
    pallas_san,
    ranges,
    schedule,
    staticness,
    tripwire,
)
from .common import Finding, repo_root
from .tripwire import RecompileError, assert_compile_flat

__all__ = [
    "Finding",
    "PASSES",
    "RecompileError",
    "assert_compile_flat",
    "repo_root",
    "run_pass",
    "run_repo",
]

PASSES = {
    "schedule": schedule,
    "donation": donation,
    "lanes": lanes,
    "staticness": staticness,
    "tripwire": tripwire,
    "docrefs": docrefs,
    "ranges": ranges,
    "pallas_san": pallas_san,
}


def run_pass(name: str, paths=None, root=None) -> list[Finding]:
    """One pass, repo mode (``paths`` None) or file/fixture mode."""
    mod = PASSES[name]
    if paths:
        return mod.run_paths([pathlib.Path(p) for p in paths])
    return mod.run_repo(pathlib.Path(root) if root else repo_root())


def run_repo(passes=None, root=None) -> list[Finding]:
    """All (or the named) passes against the repo."""
    findings: list[Finding] = []
    for name in passes or PASSES:
        findings += run_pass(name, root=root)
    return findings
