"""CLI: ``python -m repro.analysis [--check] [--pass NAME] [paths...]``.

Repo mode (no paths) runs the selected passes — all six by default —
against the repository and exits 1 when any finding survives the
pragmas. File/fixture mode (explicit paths) runs the selected passes
against those files only: AST passes lint them, dynamic passes execute
their ``reprolint_case()`` if present. ``--report FILE`` additionally
writes the findings as JSON (the CI job uploads it as an artifact).
"""
from __future__ import annotations

import argparse
import json
import sys

from . import PASSES, run_pass


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint — the emulator's contract checkers")
    ap.add_argument("paths", nargs="*",
                    help="files to check (fixture/file mode); none = "
                         "whole repo")
    ap.add_argument("--check", action="store_true",
                    help="CI mode: run everything, exit 1 on findings "
                         "(the default behavior, spelled explicitly)")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=sorted(PASSES), metavar="NAME",
                    help="run only this pass (repeatable); default all")
    ap.add_argument("--report", metavar="FILE",
                    help="also write findings as JSON")
    ap.add_argument("--list-passes", action="store_true")
    args = ap.parse_args(argv)

    if args.list_passes:
        for name, mod in PASSES.items():
            doc = (mod.__doc__ or "").strip().splitlines()[0]
            print(f"{name:12s} {doc}")
        return 0

    names = args.passes or list(PASSES)
    findings = []
    for name in names:
        findings += run_pass(name, paths=args.paths or None)

    for f in findings:
        print(f.format())
    if args.report:
        with open(args.report, "w") as fh:
            json.dump([f.as_dict() for f in findings], fh, indent=2)
    n = len(findings)
    scope = "repo" if not args.paths else f"{len(args.paths)} file(s)"
    print(f"reprolint: {n} finding(s) [{', '.join(names)}] on {scope}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
