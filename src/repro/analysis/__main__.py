"""CLI: ``python -m repro.analysis [--check] [--pass NAME] [paths...]``.

Repo mode (no paths) runs the selected passes — all eight by default —
against the repository and exits 1 when any finding survives the
pragmas. File/fixture mode (explicit paths) runs the selected passes
against those files only: AST passes lint them, dynamic passes execute
their ``reprolint_case()`` if present.

``--report FILE`` writes a JSON report::

    {"findings": [{path, line, pass_name, message}, ...],
     "proved_bounds": [...],   # per-program budget proofs (ranges pass)
     "stats": {"<pass>": seconds, ..., "total": seconds}}

``--baseline FILE`` loads a previous report and exits 1 only on
findings NOT present in it (keyed on (path, pass_name, message) — line
numbers drift with unrelated edits). ``--stats`` prints per-pass wall
time; the CI job gates the total under its time budget.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from . import PASSES, run_pass


def _load_baseline(path) -> set:
    """Known-finding keys from a previous ``--report`` JSON (either the
    current ``{"findings": [...]}`` shape or the legacy flat list)."""
    with open(path) as fh:
        data = json.load(fh)
    rows = data["findings"] if isinstance(data, dict) else data
    return {(r["path"], r["pass_name"], r["message"]) for r in rows}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint — the emulator's contract checkers")
    ap.add_argument("paths", nargs="*",
                    help="files to check (fixture/file mode); none = "
                         "whole repo")
    ap.add_argument("--check", action="store_true",
                    help="CI mode: run everything, exit 1 on findings "
                         "(the default behavior, spelled explicitly)")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=sorted(PASSES), metavar="NAME",
                    help="run only this pass (repeatable); default all")
    ap.add_argument("--report", metavar="FILE",
                    help="also write findings + proved bounds as JSON")
    ap.add_argument("--baseline", metavar="FILE",
                    help="previous --report JSON; exit 1 only on "
                         "findings not already present in it")
    ap.add_argument("--stats", action="store_true",
                    help="print per-pass analyzer wall time")
    ap.add_argument("--list-passes", action="store_true")
    args = ap.parse_args(argv)

    if args.list_passes:
        for name, mod in PASSES.items():
            doc = (mod.__doc__ or "").strip().splitlines()[0]
            print(f"{name:12s} {doc}")
        return 0

    names = args.passes or list(PASSES)
    findings = []
    stats: dict[str, float] = {}
    for name in names:
        t0 = time.perf_counter()
        findings += run_pass(name, paths=args.paths or None)
        stats[name] = round(time.perf_counter() - t0, 3)
    stats["total"] = round(sum(stats.values()), 3)

    new = findings
    if args.baseline:
        known = _load_baseline(args.baseline)
        new = [f for f in findings
               if (f.path, f.pass_name, f.message) not in known]

    for f in findings:
        mark = "" if f in new else " (baseline)"
        print(f.format() + mark)
    if args.stats:
        for name in names:
            print(f"reprolint: pass {name} took {stats[name]:.3f}s")
        print(f"reprolint: total analyzer time {stats['total']:.3f}s")
    if args.report:
        from . import ranges
        report = {
            "findings": [f.as_dict() for f in findings],
            "proved_bounds": list(ranges.LAST_BOUNDS),
            "stats": stats,
        }
        with open(args.report, "w") as fh:
            json.dump(report, fh, indent=2, default=str)
    n = len(findings)
    scope = "repo" if not args.paths else f"{len(args.paths)} file(s)"
    tail = f", {len(new)} new vs baseline" if args.baseline else ""
    print(f"reprolint: {n} finding(s){tail} "
          f"[{', '.join(names)}] on {scope}")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
