"""Pass ``tripwire`` — ``assert_compile_flat``, the recompile tripwire.

The unified entry-point cache (core/emulator.py) makes
``entry_cache_count`` the exact compile count, so "zero recompiles after
warmup" is a checkable contract instead of a bench footnote. This module
provides the context manager the serving warmup test and the benches
use:

    with assert_compile_flat(engine) as cc:
        ... steady-state work ...
    # raises RecompileError listing the new cache entries if any
    # compilation happened; cc.count / cc.new_entries for reporting

and the pass itself verifies (AST) that the adoption sites still use it
— a dropped tripwire is how recompile regressions return.

Fixture protocol: ``reprolint_case()`` returning
``{"kind": "tripwire", "run": callable}`` where ``run`` performs work
under ``assert_compile_flat`` that compiles a fresh entry; the pass
reports the resulting ``RecompileError`` as a finding.
"""
from __future__ import annotations

import ast
import contextlib
import pathlib

from .common import Finding, rel

PASS = "tripwire"

# Files that must keep using assert_compile_flat (the zero-recompile
# contract holders).
ADOPTION_SITES = (
    "tests/test_serve.py",
    "benchmarks/bench_serve.py",
    "benchmarks/bench_engine.py",
    "benchmarks/bench_sweep.py",
)


class RecompileError(AssertionError):
    """Raised when compilation happened under ``assert_compile_flat``."""


class _CacheDelta:
    def __init__(self):
        self.count = 0
        self.new_entries: list[tuple] = []


def _cache_keys(skey):
    from repro.core import emulator

    # The private _ENTRY_CACHE is deliberately inspected here (same
    # repo, and the keys make the error actionable: they carry the
    # shape_sig that forced the new executable).
    return {k for k in emulator._ENTRY_CACHE
            if skey is None or k[0] == skey}


@contextlib.contextmanager
def assert_compile_flat(engine=None, *, allow: int = 0, msg: str = ""):
    """Assert no new emulation entry points compile inside the block.

    ``engine`` scopes the check to that engine's static geometry (its
    ``static_key``); None watches the whole cache. ``allow`` permits a
    known number of compilations (e.g. ``allow=1`` for a first-call
    bench that then asserts exactly one). Yields a handle whose
    ``count``/``new_entries`` are filled on exit, so benches can report
    the number they tolerated."""
    skey = None if engine is None else engine._skey
    before = _cache_keys(skey)
    delta = _CacheDelta()
    yield delta
    # no sort: cache keys carry a PolicyRegistry and don't order
    new = list(_cache_keys(skey) - before)
    delta.count = len(new)
    delta.new_entries = new
    if delta.count > allow:
        detail = "; ".join(
            f"batch={k[2]} donate={k[3]} shape_sig={k[4]}" for k in new)
        prefix = f"{msg}: " if msg else ""
        raise RecompileError(
            f"{prefix}{delta.count} new emulation entry point(s) "
            f"compiled under assert_compile_flat (allow={allow}): "
            f"{detail}")


def _uses_tripwire(tree: ast.AST) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and node.id == "assert_compile_flat":
            return True
        if (isinstance(node, ast.Attribute)
                and node.attr == "assert_compile_flat"):
            return True
    return False


def run_repo(root: pathlib.Path) -> list[Finding]:
    findings: list[Finding] = []
    for site in ADOPTION_SITES:
        path = root / site
        if not path.exists():
            findings.append(Finding(site, 1, PASS,
                                    "adoption site vanished — update "
                                    "analysis.tripwire.ADOPTION_SITES"))
            continue
        if not _uses_tripwire(ast.parse(path.read_text())):
            findings.append(Finding(
                site, 1, PASS,
                "no assert_compile_flat use — the zero-recompile "
                "contract lost its tripwire here"))
    return findings


def run_paths(paths) -> list[Finding]:
    from .common import fixture_case

    findings: list[Finding] = []
    for path in paths:
        path = pathlib.Path(path)
        case = fixture_case(path)
        if not case or case.get("kind") != "tripwire":
            continue
        try:
            case["run"]()
        except RecompileError as e:
            findings.append(Finding(rel(path), case.get("line", 1), PASS,
                                    str(e)))
    return findings
