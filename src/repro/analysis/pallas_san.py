"""Pass ``pallas_san`` — static sanitizer for the chunk-step Pallas
kernel (and any ``pallas_call`` in a fixture).

Three checks, all on the traced ``pallas_call`` equation (so they see
exactly what Mosaic compiles, not what the Python source suggests):

  1. **VMEM footprint** — the per-grid-iteration resident set (every
     block-spec block plus every scratch operand) must fit the kernel's
     declared budget ``chunk_step.VMEM_TABLE_BUDGET``. The dispatch gate
     (`use_chunk_step_kernel`) only sizes the table; this check covers
     the whole operand set of the geometry actually traced.
  2. **Init-before-read** — every output/scratch ref must be stored
     (``swap``) before it is loaded (``get``). Output blocks are
     uninitialized VMEM; a ``get`` first reads garbage. A ref escaping
     into an opaque sub-jaxpr counts as a read.
  3. **Write-write hazard** — for every output block spec, the
     ``index_map`` is evaluated at two points along each grid axis; if
     two distinct grid iterations map to the SAME output block, they
     overwrite each other's result (grid iterations are unordered on
     TPU, so the survivor is undefined).

Fixture protocol: ``reprolint_case()`` returning
``{"kind": "pallas_san", "make": lambda: (fn, args)}``; ``fn(*args)``
is traced and every ``pallas_call`` found is checked.
"""
from __future__ import annotations

import pathlib

import numpy as np

from .common import Finding, fixture_case, rel

PASS = "pallas_san"

#: Ref-touching primitives: loads vs stores. Anything else consuming a
#: ref is treated as a read (conservative).
_LOADS = ("get",)
_STORES = ("swap", "masked_swap", "addupdate")


def _walk_pallas_calls(jaxpr, out):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(eqn)
        for v in eqn.params.values():
            inner = getattr(v, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                _walk_pallas_calls(inner, out)
            elif hasattr(v, "eqns"):
                _walk_pallas_calls(v, out)
            elif isinstance(v, (tuple, list)):
                for w in v:
                    inner = getattr(w, "jaxpr", None)
                    if inner is not None and hasattr(inner, "eqns"):
                        _walk_pallas_calls(inner, out)
    return out


def _nbytes(shape, dtype):
    n = 1
    for d in shape:
        # pl.Blocked/None dims ("mapped") contribute a single element
        d = getattr(d, "block_size", d)
        n *= int(d) if d is not None else 1
    return n * np.dtype(dtype).itemsize


def _eval_index_map(bm, point):
    """Concretely evaluate one block mapping's index_map at a grid
    point; non-grid operands (scalar-prefetch refs) are bound to
    zeros."""
    from jax import core as jcore
    cj = bm.index_map_jaxpr
    ngrid = len(point)
    args = [np.int32(p) for p in point]
    for v in cj.jaxpr.invars[ngrid:]:
        aval = v.aval
        args.append(np.zeros(aval.shape, getattr(aval, "dtype", np.int32)))
    outs = jcore.eval_jaxpr(cj.jaxpr, cj.consts, *args)
    return tuple(int(o) for o in outs)


def check_pallas_eqn(eqn, budget, label) -> list[Finding]:
    findings: list[Finding] = []
    gm = eqn.params["grid_mapping"]
    body = eqn.params["jaxpr"]
    name = eqn.params.get("name_and_src_info", None)
    where = str(name) if name is not None else label

    def bad(msg):
        findings.append(Finding(f"<{label}>", 0, PASS,
                                f"[{where}] {msg}"))

    nidx = gm.num_index_operands
    nin = gm.num_inputs
    nout = gm.num_outputs
    nscratch = gm.num_scratch_operands
    bms = tuple(gm.block_mappings)

    # 1. VMEM footprint: all blocks + scratch per grid iteration.
    total = 0
    for bm in bms:
        aval = bm.transformed_block_aval
        inner = getattr(aval, "inner_aval", aval)
        total += _nbytes(inner.shape, getattr(inner, "dtype", np.int32))
    scratch_vars = body.invars[nidx + nin + nout:]
    for v in scratch_vars:
        aval = getattr(v.aval, "inner_aval", v.aval)
        total += _nbytes(aval.shape, getattr(aval, "dtype", np.int32))
    if total > budget:
        bad(f"VMEM footprint {total} bytes (blocks + scratch) exceeds "
            f"the kernel budget {budget} — shrink the block specs or "
            "raise VMEM_TABLE_BUDGET deliberately")

    # 2. init-before-read on output/scratch refs.
    out_refs = {id(v): i for i, v in enumerate(
        body.invars[nidx + nin:nidx + nin + nout])}
    scr_refs = {id(v): i for i, v in enumerate(scratch_vars)}
    seen_store: set = set()
    flagged: set = set()

    def scan_body(jx):
        for e in jx.eqns:
            prim = e.primitive.name
            for v in e.invars:
                vid = id(v)
                kind = ("output" if vid in out_refs
                        else "scratch" if vid in scr_refs else None)
                if kind is None or vid in seen_store or vid in flagged:
                    continue
                if prim in _STORES and v is e.invars[0]:
                    seen_store.add(vid)
                elif prim in _LOADS or prim not in _STORES:
                    slot = (out_refs.get(vid) if kind == "output"
                            else scr_refs.get(vid))
                    bad(f"{kind} ref #{slot} is read (`{prim}`) before "
                        "any store — uninitialized VMEM")
                    flagged.add(vid)

    scan_body(body)

    # 3. write-write hazard: two grid iterations targeting one block.
    grid = tuple(int(g) for g in gm.grid)
    for j, bm in enumerate(bms[nin:nin + nout]):
        base = (0,) * len(grid)
        try:
            b0 = _eval_index_map(bm, base)
        except Exception:
            continue  # dynamic index map — out of static scope
        for ax, g in enumerate(grid):
            if g < 2:
                continue
            p = list(base)
            p[ax] = 1
            try:
                b1 = _eval_index_map(bm, tuple(p))
            except Exception:
                continue
            if b1 == b0:
                bad(f"output block spec #{j}: grid points {base} and "
                    f"{tuple(p)} both map to block {b0} — write-write "
                    "hazard across grid iterations (iteration order is "
                    "undefined)")
    return findings


def check_traced(jaxpr, budget, label) -> list[Finding]:
    calls = _walk_pallas_calls(
        jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, [])
    findings: list[Finding] = []
    for eqn in calls:
        findings += check_pallas_eqn(eqn, budget, label)
    if not calls:
        findings.append(Finding(f"<{label}>", 0, PASS,
                                f"[{label}] no pallas_call found in the "
                                "trace — the sanitizer has nothing to "
                                "check"))
    return findings


def _trace_step_kernel(cfg, registry):
    """Trace the real batched chunk-step kernel at grid size 2 (two
    design points — enough for the two-point hazard evaluation)."""
    import jax
    import jax.numpy as jnp

    from repro.core.config import RuntimeParams
    from repro.kernels import chunk_step as cs

    step = cs._pallas_step_fn(cfg, registry, True)
    b, chunk = 2, cfg.chunk
    n_pages, w = cfg.n_pages, 8
    nb = 2 * cfg.n_banks
    n_int_params = sum(1 for f in RuntimeParams._fields
                      if f not in cs._FLOAT_PARAM_FIELDS)
    ni = cs._N_SC + n_int_params
    nf = len(cs._FLOAT_PARAM_FIELDS)
    i32 = jnp.int32
    args = (
        jnp.zeros((b, n_pages, w), i32), jnp.zeros((b, chunk), i32),
        jnp.zeros((b, chunk), i32), jnp.zeros((b, chunk), i32),
        jnp.ones((b, chunk), i32), jnp.ones((b, chunk), i32),
        jnp.zeros((b, ni), i32), jnp.zeros((b, nf), jnp.float32),
        jnp.zeros((b, nb), i32), jnp.zeros((b, 4, 2), i32),
        jnp.zeros((b, 4, 2), i32),
    )
    return jax.make_jaxpr(step)(*args)


def run_repo(root: pathlib.Path) -> list[Finding]:
    from repro.core.config import small_platform
    from repro.core.emulator import as_registry
    from repro.kernels import chunk_step as cs

    cfg = small_platform()
    registry = as_registry(None)
    jaxpr = _trace_step_kernel(cfg, registry)
    return check_traced(jaxpr, cs.VMEM_TABLE_BUDGET, "chunk-step-kernel")


def run_paths(paths) -> list[Finding]:
    import jax

    from repro.kernels import chunk_step as cs

    findings: list[Finding] = []
    for path in paths:
        case = fixture_case(path)
        if not case or case.get("kind") != "pallas_san":
            continue
        fn, args = case["make"]()
        jaxpr = jax.make_jaxpr(fn)(*args)
        stem = pathlib.Path(path).stem
        for f in check_traced(
                jaxpr, case.get("budget", cs.VMEM_TABLE_BUDGET), stem):
            # Kernel-geometry findings carry no jaxpr source loc; anchor
            # them at the fixture file so CI output stays clickable.
            if f.path == f"<{stem}>":
                f = Finding(rel(path), 1, f.pass_name, f.message)
            findings.append(f)
    return findings
