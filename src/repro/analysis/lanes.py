"""Pass ``lanes`` — lane-accessor discipline on the packed table.

``core/table.py`` is the single source of truth for the packed
``int32[n_pages, ROW_W]`` redirection-table layout (PR 2). Raw lane
indexing — ``table[..., HOTNESS]``, ``rows[:, 2]`` — anywhere else
couples callers to the physical layout, which is exactly how the
pre-PR-2 five-array scatter bugs happened. The contract:

  * the lane index constants (``DEVICE``/``FRAME``/``HOTNESS``/``WEAR``/
    ``OWNER``/``EPOCH``/``FLAGS``/``_PAD``) may be *referenced* only
    inside the allowlist (``core/table.py`` itself and the fused
    ``kernels/chunk_step.py`` Pallas body);
  * subscripting a table-like value with a bare integer lane is banned
    everywhere outside the allowlist;
  * everyone else goes through the accessors (``device_at``,
    ``hotness_at``, ``add_hotness``, ``store_flags``, ...).

FLAGS *bit* constants (``PIN_FAST``, ``POISONED``, ...) are public
vocabulary and stay legal everywhere.

Purely an AST pass — fixture files are linted directly by path.
"""
from __future__ import annotations

import ast
import pathlib

from .common import Finding, apply_pragmas, iter_py_files, rel

PASS = "lanes"

LANE_NAMES = {"DEVICE", "FRAME", "HOTNESS", "WEAR", "OWNER", "EPOCH",
              "FLAGS", "_PAD"}

# Files where raw lane indexing is the point.
ALLOWLIST = {
    "src/repro/core/table.py",
    "src/repro/kernels/chunk_step.py",
}

_ROW_W = 8


def _table_aliases(tree: ast.AST) -> tuple[set[str], set[str]]:
    """(module aliases of repro.core.table, directly imported lane
    constant names) in this file."""
    mod_aliases: set[str] = set()
    lane_names: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro.core.table":
                    mod_aliases.add(a.asname or "repro")
        elif isinstance(node, ast.ImportFrom):
            if node.module == "repro.core":
                for a in node.names:
                    if a.name == "table":
                        mod_aliases.add(a.asname or "table")
            elif node.module == "repro.core.table":
                for a in node.names:
                    if a.name in LANE_NAMES:
                        lane_names.add(a.asname or a.name)
    return mod_aliases, lane_names


def _mentions_table(node: ast.AST) -> bool:
    """Heuristic: does this expression look like the packed table?"""
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and "table" in n.id.lower():
            return True
        if isinstance(n, ast.Attribute) and "table" in n.attr.lower():
            return True
    return False


def check_source(source: str, path: str) -> list[Finding]:
    tree = ast.parse(source)
    mod_aliases, lane_names = _table_aliases(tree)
    findings: list[Finding] = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id in mod_aliases
                and node.attr in LANE_NAMES):
            findings.append(Finding(
                path, node.lineno, PASS,
                f"raw lane constant `{node.value.id}.{node.attr}` outside "
                "core/table.py — use the lane accessors "
                "(device_at/hotness_at/store_flags/...)"))
        elif (isinstance(node, ast.Name)
              and isinstance(node.ctx, ast.Load)
              and node.id in lane_names):
            findings.append(Finding(
                path, node.lineno, PASS,
                f"lane constant `{node.id}` imported and used outside "
                "core/table.py — use the lane accessors"))
        elif isinstance(node, ast.Subscript) and _mentions_table(node.value):
            sl = node.slice
            elems = sl.elts if isinstance(sl, ast.Tuple) else [sl]
            last = elems[-1]
            if (len(elems) >= 2 and isinstance(last, ast.Constant)
                    and isinstance(last.value, int)
                    and 0 <= last.value < _ROW_W):
                findings.append(Finding(
                    path, node.lineno, PASS,
                    f"bare integer lane index `[..., {last.value}]` on a "
                    "table-like value — use the lane accessors"))
    return apply_pragmas(findings, source)


def check_file(path: pathlib.Path) -> list[Finding]:
    return check_source(path.read_text(), rel(path))


def run_repo(root: pathlib.Path) -> list[Finding]:
    findings: list[Finding] = []
    for path in iter_py_files(root):
        if rel(path, root) in ALLOWLIST or "analysis" in path.parts:
            continue
        findings += check_file(path)
    return findings


def run_paths(paths) -> list[Finding]:
    findings: list[Finding] = []
    for path in paths:
        findings += check_file(pathlib.Path(path))
    return findings
