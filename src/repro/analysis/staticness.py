"""Pass ``staticness`` — the traced/static split that makes the sweep
engine work.

Everything in ``RuntimeParams`` is a traced scalar; everything in
``static_key`` shapes the compiled program. Three contracts, each of
which has been violated and hand-fixed before (stale ``policy_id``,
knobs missing from ``static_key``):

  * **no Python control flow on traced params** (AST): a
    ``RuntimeParams`` field reaching ``if``/``while``/``assert`` or a
    ternary test is a concretization error waiting for the first real
    trace — use ``jnp.where``/``lax.cond``.
  * **static_key completeness** (runtime, perturbation-based): perturb
    every ``EmulatorConfig`` field (descending into the two
    ``TechnologyParams``); each perturbation must change ``static_key``
    or a ``RuntimeParams.from_config`` leaf, or the knob silently
    misses both the cache key and the traced computation.
  * **canonical_config discipline** (runtime): static fields survive
    canonicalization (``static_key(canonical(c)) == static_key(c)``)
    and runtime-only fields are neutralized (equal canonical configs),
    because the canonical config is a jit *static* argument — a leaked
    runtime field fragments the entry cache.

Plus a dtype cross-check: ``kernels/chunk_step._FLOAT_PARAM_FIELDS``
must agree exactly with the float32 leaves of
``RuntimeParams.from_config`` (the Pallas scalar packing depends on it).

Fixture protocol: AST violations are linted directly by path.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib

from .common import Finding, apply_pragmas, iter_py_files, rel

PASS = "staticness"

# Unannotated names conventionally bound to RuntimeParams in this repo.
PARAM_NAME_HINTS = {"params", "p", "rp"}

# TechnologyParams subfields that are *documentation only* (config.py
# declares them behaviorally inert: `name` labels the row, and
# endurance_log10 is a datasheet number surfaced in reports, never read
# by the pipeline). Everything else must reach static_key or
# RuntimeParams.
INERT_SUBFIELDS = {"fast.name", "slow.name",
                   "fast.endurance_log10", "slow.endurance_log10"}


def _runtime_fields() -> frozenset[str]:
    from repro.core.config import RuntimeParams

    return frozenset(RuntimeParams._fields)


# --- AST: control flow on traced params -----------------------------------


def _annotated_param_names(fn) -> set[str]:
    names = set()
    args = list(fn.args.posonlyargs) + list(fn.args.args) + \
        list(fn.args.kwonlyargs)
    for a in args:
        ann = a.annotation
        if ann is None:
            continue
        txt = ast.unparse(ann)
        if "RuntimeParams" in txt:
            names.add(a.arg)
    return names


def check_source(source: str, path: str) -> list[Finding]:
    tree = ast.parse(source)
    fields = _runtime_fields()
    findings: list[Finding] = []

    def flag_tests(fn, names):
        tests = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                tests.append(node.test)
            elif isinstance(node, ast.Assert):
                tests.append(node.test)
        for test in tests:
            for n in ast.walk(test):
                if (isinstance(n, ast.Attribute)
                        and isinstance(n.value, ast.Name)
                        and n.value.id in names and n.attr in fields):
                    findings.append(Finding(
                        path, n.lineno, PASS,
                        f"traced RuntimeParams field `{n.value.id}."
                        f"{n.attr}` reaches Python control flow — use "
                        "jnp.where / lax.cond on traced values"))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names = _annotated_param_names(node) | PARAM_NAME_HINTS
            flag_tests(node, names)
    return apply_pragmas(findings, source)


def check_file(path: pathlib.Path) -> list[Finding]:
    return check_source(path.read_text(), rel(path))


# --- runtime: static_key completeness -------------------------------------


def _field_linenos(config_path: pathlib.Path) -> dict[str, int]:
    tree = ast.parse(config_path.read_text())
    out: dict[str, int] = {}
    for node in ast.walk(tree):
        if (isinstance(node, ast.ClassDef)
                and node.name == "EmulatorConfig"):
            for stmt in node.body:
                if (isinstance(stmt, ast.AnnAssign)
                        and isinstance(stmt.target, ast.Name)):
                    out[stmt.target.id] = stmt.lineno
    return out


def _perturb(name: str, value):
    """A changed-but-valid value for one config field, or None when the
    checker does not know how (itself a finding: teach it)."""
    if isinstance(value, bool):
        return not value
    if isinstance(value, int):
        return value + 1
    if isinstance(value, float):
        return value * 2 + 0.25
    if isinstance(value, str):
        from repro.core import policies

        menus = {
            "policy": sorted(policies.POLICIES),
            "bank_resolver": ["dense", "segmented", "auto"],
            "chunk_step_kernel": ["on", "off", "auto"],
        }
        menu = menus.get(name)
        if menu:
            return next(v for v in menu if v != value)
    return None


def _runtime_leaves(cfg):
    import numpy as np

    from repro.core.config import RuntimeParams

    rp = RuntimeParams.from_config(cfg)
    return {f: np.asarray(v) for f, v in zip(rp._fields, rp)}


def _check_one_field(findings, root, linenos, base, label, line, make):
    """Perturb one (sub)field via ``make(base) -> perturbed_cfg`` and
    enforce the completeness + canonicalization contracts."""
    import numpy as np

    from repro.core.config import canonical_config, static_key

    cfg_path = "src/repro/core/config.py"
    pert = make(base)
    static_changed = static_key(base) != static_key(pert)
    rb, rp = _runtime_leaves(base), _runtime_leaves(pert)
    runtime_changed = any(not np.array_equal(rb[f], rp[f]) for f in rb)
    if not static_changed and not runtime_changed:
        findings.append(Finding(
            cfg_path, line, PASS,
            f"config field `{label}` reaches NEITHER static_key nor "
            "RuntimeParams.from_config — a sweep/jit over this knob is "
            "silently inert"))
        return
    if static_changed:
        if static_key(canonical_config(pert)) != static_key(pert):
            findings.append(Finding(
                cfg_path, line, PASS,
                f"static field `{label}` does not survive "
                "canonical_config — geometry-equal sessions will not "
                "share executables"))
    elif canonical_config(pert) != canonical_config(base):
        findings.append(Finding(
            cfg_path, line, PASS,
            f"runtime field `{label}` leaks into canonical_config — the "
            "canonical config is a jit static argument, so this "
            "fragments the entry cache"))


def check_static_key_completeness(root: pathlib.Path) -> list[Finding]:
    from repro.core.config import EmulatorConfig, TechnologyParams

    findings: list[Finding] = []
    linenos = _field_linenos(root / "src" / "repro" / "core" / "config.py")
    base = EmulatorConfig()
    for f in dataclasses.fields(EmulatorConfig):
        value = getattr(base, f.name)
        line = linenos.get(f.name, 1)
        if isinstance(value, TechnologyParams):
            for sub in dataclasses.fields(TechnologyParams):
                label = f"{f.name}.{sub.name}"
                if label in INERT_SUBFIELDS:
                    continue
                new = _perturb(sub.name, getattr(value, sub.name))
                if new is None:
                    findings.append(Finding(
                        "src/repro/core/config.py", line, PASS,
                        f"don't know how to perturb `{label}` — teach "
                        "analysis.staticness._perturb about it"))
                    continue
                _check_one_field(
                    findings, root, linenos, base, label, line,
                    lambda c, f=f, sub=sub, new=new: c.with_(
                        **{f.name: dataclasses.replace(
                            getattr(c, f.name), **{sub.name: new})}))
            continue
        new = _perturb(f.name, value)
        if new is None:
            findings.append(Finding(
                "src/repro/core/config.py", line, PASS,
                f"don't know how to perturb `{f.name}` — teach "
                "analysis.staticness._perturb about it"))
            continue
        _check_one_field(findings, root, linenos, base, f.name, line,
                         lambda c, f=f, new=new: c.with_(**{f.name: new}))
    return findings


def check_float_fields(root: pathlib.Path) -> list[Finding]:
    """``_FLOAT_PARAM_FIELDS`` must be exactly the float32 leaves of
    ``RuntimeParams.from_config`` — the Pallas scalar packing splits on
    it."""
    import numpy as np

    from repro.core.config import EmulatorConfig, RuntimeParams
    from repro.kernels import chunk_step

    rp = RuntimeParams.from_config(EmulatorConfig())
    floats = {f for f, v in zip(rp._fields, rp)
              if np.asarray(v).dtype.kind == "f"}
    declared = set(chunk_step._FLOAT_PARAM_FIELDS)
    if floats == declared:
        return []
    path = root / "src" / "repro" / "kernels" / "chunk_step.py"
    line = 1
    for i, text in enumerate(path.read_text().splitlines(), start=1):
        if "_FLOAT_PARAM_FIELDS" in text:
            line = i
            break
    missing = sorted(floats - declared)
    extra = sorted(declared - floats)
    return [Finding(
        rel(path), line, PASS,
        "chunk_step._FLOAT_PARAM_FIELDS disagrees with the float32 "
        f"leaves of RuntimeParams.from_config (missing={missing}, "
        f"extra={extra}) — the Pallas scalar packing will mis-slot")]


def run_repo(root: pathlib.Path) -> list[Finding]:
    findings: list[Finding] = []
    for path in iter_py_files(root):
        if "analysis" in path.parts:
            continue
        # config.py builds RuntimeParams FROM the config on the host;
        # its `p`/`params` names are not traced values.
        if rel(path, root) == "src/repro/core/config.py":
            continue
        findings += check_file(path)
    findings += check_static_key_completeness(root)
    findings += check_float_fields(root)
    return findings


def run_paths(paths) -> list[Finding]:
    findings: list[Finding] = []
    for path in paths:
        findings += check_file(pathlib.Path(path))
    return findings
