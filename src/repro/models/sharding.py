"""Sharding context threaded through model code.

Models never hardcode mesh shapes: a ``ShardCtx`` carries the mesh axis
names/sizes and answers "how do I shard this tensor here?". With no mesh
(CPU smoke tests) every constraint is a no-op.

Conventions (DESIGN.md §4):
    batch  -> ("pod", "data")   (all DP axes)
    heads / ffn hidden / experts / vocab -> "model"  (TP/EP)
    residual seq -> "model"     (sequence parallelism between blocks)
    decode KV cache seq -> ("data","model") or "model" (flash-decode psum)

Head sharding is per-arch: only if the head count divides the model-axis
size (gemma3's 8 q heads don't split 16 ways — those archs run attention
batch-parallel with replicated attention weights).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    axis_sizes: tuple = ()       # ((name, size), ...) in mesh order; () = no mesh
    seq_shard: bool = True       # sequence-parallel residual stream
    mesh: object = None          # jax Mesh (needed by shard_map decode)

    @staticmethod
    def from_mesh(mesh, seq_shard: bool = True) -> "ShardCtx":
        return ShardCtx(tuple(zip(mesh.axis_names, mesh.devices.shape)),
                        seq_shard=seq_shard, mesh=mesh)

    @property
    def names(self) -> tuple:
        return tuple(n for n, _ in self.axis_sizes)

    def size(self, name: str) -> int:
        for n, s in self.axis_sizes:
            if n == name:
                return s
        return 1

    @property
    def batch_axes(self):
        ax = tuple(n for n in ("pod", "data") if n in self.names)
        return ax if ax else None

    def batch_axes_for(self, n: int):
        """DP axes only when the batch divides them (long_500k has B=1:
        the batch stays unsharded and the seq axis carries parallelism)."""
        ax = self.batch_axes
        if ax is None:
            return None
        prod = 1
        for a in ax:
            prod *= self.size(a)
        return ax if n % prod == 0 else None

    @property
    def model_axis(self):
        return "model" if "model" in self.names else None

    @property
    def all_axes(self):
        """Every mesh axis (for sharding one huge dim, e.g. 500k decode KV)."""
        return self.names if self.names else None

    def divides(self, n: int, axis: str = "model") -> bool:
        s = self.size(axis)
        return s > 1 and n % s == 0

    def constrain(self, x, *spec):
        """with_sharding_constraint if a mesh is active, else identity.
        spec entries: None, axis name, or tuple of axis names."""
        if not self.axis_sizes:
            return x
        clean = tuple(s if (s is None or isinstance(s, tuple)) else s
                      for s in spec)
        return jax.lax.with_sharding_constraint(x, P(*clean))

    # --- common activation constraints ----------------------------------------
    def act_btd(self, x):
        """Residual stream [B, S, D]: batch over DP axes, seq over model (SP)."""
        seq = self.model_axis if self.seq_shard else None
        return self.constrain(x, self.batch_axes, seq, None)

    def act_bhsd(self, x, n_heads: int):
        """Attention activations [B, H, S, D]: heads over model if divisible."""
        h = self.model_axis if self.divides(n_heads) else None
        return self.constrain(x, self.batch_axes, h, None, None)

    def head_axis(self, n_heads: int):
        return self.model_axis if self.divides(n_heads) else None
