"""Shared transformer layers: norms, RoPE, SwiGLU, GQA attention.

All functions are pure; parameters are dict pytrees created by
``transformer.init_params``. Activation dtype follows cfg.adtype with
fp32 accumulation where it matters (norms, softmax, losses).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .chunked_attention import chunked_attention, naive_attention
from .config import ModelConfig
from .sharding import ShardCtx


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)
            ).astype(x.dtype)


def rope_tables(positions: jax.Array, dim: int, theta: float
                ) -> tuple[jax.Array, jax.Array]:
    """positions [...,] -> (cos, sin) of shape [..., dim/2]."""
    freqs = theta ** (-jnp.arange(0, dim, 2, jnp.float32) / dim)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., S, D]; cos/sin [S, D/2] (broadcastable)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, p: dict, sh: ShardCtx, adtype) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(adtype))
    g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(adtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(adtype) * h
    h = sh.constrain(h, sh.batch_axes, None, sh.model_axis)
    return jnp.einsum("...f,fd->...d", h, p["w_out"].astype(adtype))


def gqa_project(cfg: ModelConfig, p: dict, x: jax.Array, adtype):
    """x [B,S,D] -> q [B,Hq,S,Dh], k/v [B,Hkv,S,Dh]."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(adtype))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(adtype))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(adtype))
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    return q, k, v


def use_context_parallel(cfg: ModelConfig, sh: ShardCtx, b: int, s: int,
                         budget_bytes: float = 4e9) -> bool:
    """Context-parallel attention for head counts that don't divide the
    model axis (musicgen 24H, gemma3 8H, hymba 25H): shard *queries* on
    the sequence axis instead — attention compute parallelizes dp x tp
    ways and the S x S logits become S x S/tp transients, at the price of
    all-gathering K/V over the model axis (EXPERIMENTS.md §Perf M2).

    Only when the per-device logit transient fits ``budget_bytes``
    (prefill_32k falls back to the chunked q-block path)."""
    if not (sh.model_axis is not None and not sh.divides(cfg.n_heads)
            and s % sh.size("model") == 0 and s > 1):
        return False
    dp = 1
    for a in (sh.batch_axes or ()):
        dp *= sh.size(a)
    b_loc = b / dp if b % dp == 0 else b
    logits = b_loc * cfg.n_heads * (s / sh.size("model")) * s * 4.0
    return logits <= budget_bytes


def attention_seq_sharded(cfg: ModelConfig, sh: ShardCtx, q, k, v, window,
                          scale=None):
    """q seq-sharded over 'model'; k/v replicated (pjit inserts the
    gathers). Single-shot logits: [B/dp, H, S/tp, S] per device."""
    b = sh.batch_axes
    m = sh.model_axis
    q = sh.constrain(q, b, None, m, None)
    k = sh.constrain(k, b, None, None, None)
    v = sh.constrain(v, b, None, None, None)
    o = naive_attention(q, k, v, causal=True, window=window, scale=scale)
    return sh.constrain(o, b, None, m, None)


def gqa_attention(cfg: ModelConfig, p: dict, x: jax.Array, sh: ShardCtx,
                  positions: jax.Array, window) -> tuple[jax.Array, dict]:
    """Full-sequence GQA attention (train / prefill). Returns (out, kv)."""
    adtype = cfg.adtype
    b, s, d = x.shape
    hd = cfg.head_dim_
    q, k, v = gqa_project(cfg, p, x, adtype)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if use_context_parallel(cfg, sh, b, s):
        o = attention_seq_sharded(cfg, sh, q, k, v, window)
    else:
        q = sh.act_bhsd(q, cfg.n_heads)
        k = sh.act_bhsd(k, cfg.n_kv_heads)
        v = sh.act_bhsd(v, cfg.n_kv_heads)
        attn_fn = (naive_attention if cfg.attention_impl == "naive"
                   else chunked_attention)
        o = attn_fn(q, k, v, causal=True, window=window)
        o = sh.act_bhsd(o, cfg.n_heads)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * hd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(adtype))
    return out, {"k": k, "v": v}


def embed_tokens(cfg: ModelConfig, p: dict, tokens: jax.Array, sh: ShardCtx
                 ) -> jax.Array:
    """Token ids [B,S] -> [B,S,D] (vocab-sharded one-hot matmul keeps the
    gather local to each vocab shard)."""
    emb = p["tokens"].astype(cfg.adtype)
    out = emb[tokens]
    return sh.act_btd(out)


def embed_frames(cfg: ModelConfig, p: dict, frames: jax.Array, sh: ShardCtx
                 ) -> jax.Array:
    """Precomputed modality embeddings [B,S,frame_dim] -> [B,S,D].
    (The modality frontend itself is a stub per the assignment; this is
    the learned adapter projection.)"""
    out = jnp.einsum("bsf,fd->bsd", frames.astype(cfg.adtype),
                     p["frames"].astype(cfg.adtype))
    return sh.act_btd(out)


def lm_logits(cfg: ModelConfig, params: dict, x: jax.Array, sh: ShardCtx
              ) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"]["tokens"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cfg.adtype))
    return sh.constrain(logits, sh.batch_axes, None, sh.model_axis)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE. logits [B,S,V] (any dtype), labels int32 [B,S]."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
