"""RWKV6 "Finch" (arXiv:2404.05892): attention-free linear recurrence with
data-dependent per-channel decay.

Time-mix recurrence per head (state S in R^{Dk x Dv}):

    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T ,   w_t = exp(-exp(dd(x_t)))

Training/prefill uses the *chunked parallel* formulation so compute lands
on the MXU as matmuls instead of a length-S sequential scan: within a
chunk of C tokens, cumulative log-decays turn the recurrence into masked
(q' k'^T) V products; a short lax.scan over S/C chunks carries the state.
Decode is the O(1) single-step update — the reason rwkv6 runs the
long_500k shape that full-attention archs must skip.

Simplifications vs the reference implementation (documented per DESIGN.md):
static token-shift mixing coefficients (RWKV6's ddlerp -> learned lerp),
and RMS-style per-head group norm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import ShardCtx


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x [B,S,D], prev [B,D] (last token of previous segment) -> shifted x."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu  # lerp(x, shifted, mu)


def _decay(cfg: ModelConfig, p: dict, xw: jax.Array) -> jax.Array:
    """Data-dependent decay (the Finch contribution): w in (0,1), [B,S,D]."""
    dd = jnp.einsum("bsd,dr->bsr", xw, p["decay_a"].astype(xw.dtype))
    dd = jnp.einsum("bsr,rd->bsd", jnp.tanh(dd.astype(jnp.float32)).astype(xw.dtype),
                    p["decay_b"].astype(xw.dtype))
    logw = -jnp.exp(jnp.clip(p["decay_base"].astype(jnp.float32)
                             + dd.astype(jnp.float32), -8.0, 6.0))
    return logw  # log w_t (negative)


def _heads(x, h):
    b, s, d = x.shape
    return x.reshape(b, s, h, d // h).transpose(0, 2, 1, 3)  # [B,H,S,Dh]


def rwkv_chunk_scan(r, k, v, logw, u, chunk: int, unroll: bool = False):
    """Chunked linear attention. r/k/v [B,H,S,Dh], logw [B,H,S,Dh] (log decay
    per key channel), u [H,Dh] bonus. Returns out [B,H,S,Dh]."""
    b, h, s, dk = r.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    if s % c:
        c = s
    n = s // c

    rc = r.reshape(b, h, n, c, dk)
    kc = k.reshape(b, h, n, c, dk)
    vc = v.reshape(b, h, n, c, dv)
    lwc = logw.reshape(b, h, n, c, dk).astype(jnp.float32)

    lw_cum = jnp.cumsum(lwc, axis=3)                      # inclusive
    lw_tot = lw_cum[:, :, :, -1]                          # [B,H,N,Dk]
    lw_excl = lw_cum - lwc                                # exclusive

    # q'_t = r_t * A_{t-1};  k'_s = k_s / A_s  (stable in log space).
    qp = rc.astype(jnp.float32) * jnp.exp(lw_excl)
    kp = kc.astype(jnp.float32) * jnp.exp(-lw_cum)
    # inter-chunk key weight: k_s * A_T / A_s
    kT = kc.astype(jnp.float32) * jnp.exp(lw_tot[:, :, :, None] - lw_cum)

    # Intra-chunk: strictly-lower-triangular (s < t) plus diag u bonus.
    att = jnp.einsum("bhntk,bhnsk->bhnts", qp, kp)
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
    att = jnp.where(mask[None, None, None], att, 0.0)
    diag = jnp.einsum("bhntk,hk->bhnt",
                      rc.astype(jnp.float32) * kc.astype(jnp.float32),
                      u.astype(jnp.float32))
    intra = jnp.einsum("bhnts,bhnsv->bhntv", att, vc.astype(jnp.float32))
    intra = intra + diag[..., None] * vc.astype(jnp.float32)

    def step(state, xs):
        qp_n, kT_n, v_n, lw_tot_n, intra_n = xs
        carry_out = jnp.einsum("bhtk,bhkv->bhtv", qp_n, state)
        new_state = state * jnp.exp(lw_tot_n)[..., None] + \
            jnp.einsum("bhsk,bhsv->bhkv", kT_n, v_n.astype(jnp.float32))
        return new_state, intra_n + carry_out

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (qp, kT, vc, lw_tot, intra))
    state0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    state, out = jax.lax.scan(step, state0, xs,
                              unroll=n if unroll else 1)
    out = jnp.moveaxis(out, 0, 2).reshape(b, h, s, dv)
    return out, state


def rwkv_time_mix(cfg: ModelConfig, p: dict, x: jax.Array, sh: ShardCtx,
                  prev: jax.Array):
    """x [B,S,D]; prev [B,D]. Returns (out [B,S,D], new_prev, new_state
    [B,H,Dk,Dv]) — the state seeds subsequent decode steps."""
    adtype = cfg.adtype
    b, s, d = x.shape
    h = cfg.n_heads
    xs = _token_shift(x, prev)

    r = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mu_r"].astype(adtype)),
                   p["w_r"].astype(adtype))
    k = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mu_k"].astype(adtype)),
                   p["w_k"].astype(adtype))
    v = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mu_v"].astype(adtype)),
                   p["w_v"].astype(adtype))
    g = jnp.einsum("bsd,de->bse", _mix(x, xs, p["mu_g"].astype(adtype)),
                   p["w_g"].astype(adtype))
    logw = _decay(cfg, p, _mix(x, xs, p["mu_w"].astype(adtype)))

    rh, kh, vh = _heads(r, h), _heads(k, h), _heads(v, h)
    lwh = _heads(logw.astype(adtype), h)
    rh = sh.act_bhsd(rh, h)
    kh = sh.act_bhsd(kh, h)
    vh = sh.act_bhsd(vh, h)

    out, new_state = rwkv_chunk_scan(rh, kh, vh, lwh, p["u"], cfg.rwkv_chunk,
                                     unroll=cfg.rwkv_unroll)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)

    # per-head group norm (RMS form) + output gate
    gn = out.reshape(b, s, h, d // h)
    gn = gn * jax.lax.rsqrt(jnp.mean(jnp.square(gn), -1, keepdims=True)
                            + cfg.norm_eps)
    out = (gn.reshape(b, s, d) * p["gn_w"].astype(jnp.float32))
    out = out.astype(adtype) * jax.nn.silu(g.astype(jnp.float32)).astype(adtype)
    out = jnp.einsum("bsd,de->bse", out, p["w_o"].astype(adtype))
    return out, x[:, -1], new_state


def rwkv_decode_step(cfg: ModelConfig, p: dict, x: jax.Array, sh: ShardCtx,
                     prev: jax.Array, state: jax.Array):
    """Single-token step. x [B,1,D]; state [B,H,Dk,Dv] fp32."""
    adtype = cfg.adtype
    b = x.shape[0]
    h = cfg.n_heads
    d = x.shape[-1]
    xs = prev[:, None]

    def proj(mu, w):
        return jnp.einsum("bsd,de->bse", _mix(x, xs, mu.astype(adtype)),
                          w.astype(adtype))

    r = proj(p["mu_r"], p["w_r"])[:, 0]
    k = proj(p["mu_k"], p["w_k"])[:, 0]
    v = proj(p["mu_v"], p["w_v"])[:, 0]
    g = proj(p["mu_g"], p["w_g"])[:, 0]
    logw = _decay(cfg, p, _mix(x, xs, p["mu_w"].astype(adtype)))[:, 0]

    dh = d // h
    rh = r.reshape(b, h, dh).astype(jnp.float32)
    kh = k.reshape(b, h, dh).astype(jnp.float32)
    vh = v.reshape(b, h, dh).astype(jnp.float32)
    w = jnp.exp(logw.reshape(b, h, dh))

    kv = kh[..., :, None] * vh[..., None, :]            # [B,H,Dk,Dv]
    out = jnp.einsum("bhk,bhkv->bhv",
                     rh, state + p["u"].astype(jnp.float32)[None, :, :, None] * kv)
    new_state = state * w[..., None] + kv

    gn = out.reshape(b, h, dh)
    gn = gn * jax.lax.rsqrt(jnp.mean(jnp.square(gn), -1, keepdims=True)
                            + cfg.norm_eps)
    o = (gn.reshape(b, d) * p["gn_w"].astype(jnp.float32)).astype(adtype)
    o = o * jax.nn.silu(g.astype(jnp.float32)).astype(adtype)
    o = jnp.einsum("bd,de->be", o, p["w_o"].astype(adtype))
    return o[:, None], x[:, 0], new_state


def rwkv_channel_mix(cfg: ModelConfig, p: dict, x: jax.Array, sh: ShardCtx,
                     prev: jax.Array):
    """RWKV channel-mix FFN (relu^2) with token shift.
    x [B,S,D] -> (out, new_prev)."""
    adtype = cfg.adtype
    xs = _token_shift(x, prev)
    xk = _mix(x, xs, p["mu_k"].astype(adtype))
    xr = _mix(x, xs, p["mu_r"].astype(adtype))
    kk = jnp.einsum("bsd,df->bsf", xk, p["w_k"].astype(adtype))
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(adtype)
    kk = sh.constrain(kk, sh.batch_axes, None, sh.model_axis)
    vv = jnp.einsum("bsf,fd->bsd", kk, p["w_v"].astype(adtype))
    rr = jax.nn.sigmoid(jnp.einsum(
        "bsd,de->bse", xr, p["w_r"].astype(adtype)).astype(jnp.float32))
    return vv * rr.astype(adtype), x[:, -1]
