"""Distributed flash-decode: partial softmax + psum combine over a
sequence-sharded KV cache.

Decode caches are sharded on the *sequence* axis (uniform across archs —
it works for 4-kv-head GQA and headless MLA latents alike, where head
sharding cannot split a 16-way model axis). Each model shard computes a
partial (max, sum, weighted-acc) over its cache slice; the combine is two
small collectives:

    m* = pmax(m);  l* = psum(l * e^{m-m*});  acc* = psum(acc * e^{m-m*})

This is the flash-decode algorithm across chips instead of across SM
blocks — the TPU-native mapping of the GPU kernel structure. On-chip, each
shard's slice streams through repro.kernels.decode_attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map                      # jax >= 0.8
except ImportError:                                # pragma: no cover
    from jax.experimental.shard_map import shard_map

from .sharding import ShardCtx

NEG_INF = -1e30


def _partial(q, k, v, kv_len, offset, window, scale):
    """Local partial softmax. q:[B,H,Dk]; k:[B,Hkv,Sl,Dk]; v:[B,Hkv,Sl,Dv].
    Returns m:[B,H], l:[B,H], acc:[B,H,Dv].

    Grouped-GQA einsums: kv heads are never expanded to query heads — for
    MLA (Hkv=1, 128 q heads) the expansion would broadcast the whole cache
    shard x128 (4.8 GB/layer at decode_32k; EXPERIMENTS.md §Perf M1)."""
    b, hq, dk = q.shape
    hkv, sl = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, dk)
    logits = jnp.einsum("bkgd,bktd->bkgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    pos = offset[..., None, :] + jnp.arange(sl)[None, None, None, :]
    mask = pos < kv_len[:, None, None, None]
    if window is not None:
        mask &= pos >= kv_len[:, None, None, None] - window
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    dv = v.shape[-1]
    return (m.reshape(b, hq), l.reshape(b, hq), acc.reshape(b, hq, dv))


def dist_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                kv_len: jax.Array, *, sh: ShardCtx,
                window=None, scale: float | None = None) -> jax.Array:
    """q:[B,Hq,Dk]; k_cache:[B,Hkv,Smax,Dk]; v_cache:[B,Hkv,Smax,Dv];
    kv_len:int32[B] -> [B,Hq,Dv] (fp32, caller casts).

    With a mesh: cache seq axis sharded over all non-batch mesh axes;
    without: single-shard reference path.
    """
    b, hq, dk = q.shape
    dv = v_cache.shape[-1]
    scale = scale if scale is not None else dk ** -0.5
    if window is not None and not isinstance(window, int):
        window = jnp.asarray(window, jnp.int32)

    seq_axes = tuple(a for a in ("model",) if a in (sh.names or ()))
    if getattr(sh, "mesh", None) is None or not seq_axes:
        m, l, acc = _partial(q, k_cache, v_cache, kv_len,
                             jnp.zeros((1, 1, 1), jnp.int32), window, scale)
        return acc / jnp.where(l == 0., 1., l)[..., None]

    batch = sh.batch_axes_for(b)
    mesh = sh.mesh
    sl = k_cache.shape[2] // sh.size("model")

    def local(q, k, v, kv_len, window):
        off = jax.lax.axis_index("model") * sl
        off = jnp.full((1, 1, 1), off, jnp.int32)
        m, l, acc = _partial(q, k, v, kv_len, off, window, scale)
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, "model")
        acc_g = jax.lax.psum(acc * corr[..., None], "model")
        return acc_g / jnp.where(l_g == 0., 1., l_g)[..., None]

    win_arg = (jnp.asarray(window, jnp.int32) if window is not None
               else jnp.asarray(0, jnp.int32))
    has_window = window is not None

    def wrapped(q, k, v, kv_len, win):
        return local(q, k, v, kv_len, win if has_window else None)

    fn = shard_map(
        wrapped, mesh=mesh,
        in_specs=(P(batch, None, None), P(batch, None, "model", None),
                  P(batch, None, "model", None), P(batch), P()),
        out_specs=P(batch, None, None))
    return fn(q, k_cache, v_cache, kv_len.astype(jnp.int32), win_arg)
