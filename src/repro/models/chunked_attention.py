"""Memory-bounded attention in pure jnp with a flash-style custom VJP.

This is the attention path used for training and prefill on every backend
(the Pallas kernel accelerates the TPU forward; this module guarantees the
whole system — including 32k prefill and 4k training backward — never
materializes an S x S attention matrix).

GQA/MQA/MLA kv heads are handled in *grouped* form — q is viewed as
[B, Hkv, G, S, D] and every einsum contracts against the unexpanded
[B, Hkv, S, D] k/v. No ``jnp.repeat`` materialization: for deepseek's
decode-style Hkv=1 x 128 q-heads the expansion would be a 4.8 GB/layer
broadcast (found via dry-run HLO inspection; EXPERIMENTS.md §Perf M1).

Forward: scan over query blocks; each block computes logits against the
full K (peak memory B*H*bq*S) with a numerically-stable softmax.
Backward: recomputes P blockwise from the saved logsumexp and accumulates
dK/dV in fp32 carries — O(S) residuals instead of O(S^2).

``window`` may be a traced scalar (per-layer dynamic windows let a scanned
layer stack mix local and global attention in one HLO body — how gemma3's
5:1 interleave lowers without doubling the graph).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(qi, ki, causal, window):
    m = jnp.ones(jnp.broadcast_shapes(qi.shape, ki.shape), bool)
    if causal:
        m &= ki <= qi
    if window is not None:
        m &= qi - ki < window
    return m


def _fwd_blocks(q, k, v, causal, window, scale, block_q):
    """q: [B,Hkv,G,S,D]; k/v: [B,Hkv,Skv,D] -> (out [B,Hkv,G,S,Dv],
    lse [B,Hkv,G,S])."""
    b, hkv, g, s, d = q.shape
    skv = k.shape[2]
    nb = s // block_q
    q_off = skv - s

    qb = q.reshape(b, hkv, g, nb, block_q, d).transpose(3, 0, 1, 2, 4, 5)

    def one_block(carry, xs):
        qi_block, qblk = xs                       # [B,Hkv,G,bq,D]
        logits = jnp.einsum("bkgqd,bktd->bkgqt",
                            qblk.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        qi = qi_block[:, None] + q_off
        ki = jnp.arange(skv)[None, :]
        logits = jnp.where(_mask(qi, ki, causal, window)[None, None, None],
                           logits, NEG_INF)
        m = jnp.max(logits, axis=-1)
        p = jnp.exp(logits - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bkgqt,bktd->bkgqd", p, v.astype(jnp.float32))
        o = o / jnp.where(l == 0., 1., l)[..., None]
        lse = m + jnp.log(jnp.where(l == 0., 1., l))
        return carry, (o, lse)

    qi_blocks = jnp.arange(s).reshape(nb, block_q)
    _, (o, lse) = jax.lax.scan(one_block, None, (qi_blocks, qb))
    dv = v.shape[-1]
    out = o.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, s, dv)
    lse = lse.transpose(1, 2, 3, 0, 4).reshape(b, hkv, g, s)
    return out, lse


def _bwd_blocks(q, k, v, out, lse, gr, causal, window, scale, block_q):
    b, hkv, g, s, d = q.shape
    skv, dv = k.shape[2], v.shape[-1]
    nb = s // block_q
    q_off = skv - s

    delta = jnp.sum(gr.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    tr = lambda x: x.reshape(b, hkv, g, nb, block_q, *x.shape[4:]
                             ).transpose(3, 0, 1, 2, 4,
                                         *range(5, x.ndim + 1))
    qb = tr(q)
    gb = tr(gr)
    lseb = lse.reshape(b, hkv, g, nb, block_q).transpose(3, 0, 1, 2, 4)
    deltab = delta.reshape(b, hkv, g, nb, block_q).transpose(3, 0, 1, 2, 4)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def one_block(carry, xs):
        dk, dvv = carry
        qi_block, qblk, gblk, lseblk, dblk = xs
        logits = jnp.einsum("bkgqd,bktd->bkgqt", qblk.astype(jnp.float32),
                            kf) * scale
        qi = qi_block[:, None] + q_off
        ki = jnp.arange(skv)[None, :]
        logits = jnp.where(_mask(qi, ki, causal, window)[None, None, None],
                           logits, NEG_INF)
        p = jnp.exp(logits - lseblk[..., None])
        gf = gblk.astype(jnp.float32)
        dp = jnp.einsum("bkgqd,bktd->bkgqt", gf, vf)
        ds = p * (dp - dblk[..., None]) * scale
        dq = jnp.einsum("bkgqt,bktd->bkgqd", ds, kf)
        dk = dk + jnp.einsum("bkgqt,bkgqd->bktd", ds,
                             qblk.astype(jnp.float32))
        dvv = dvv + jnp.einsum("bkgqt,bkgqd->bktd", p, gf)
        return (dk, dvv), dq

    qi_blocks = jnp.arange(s).reshape(nb, block_q)
    zero_k = jnp.zeros((b, hkv, skv, d), jnp.float32)
    zero_v = jnp.zeros((b, hkv, skv, dv), jnp.float32)
    (dkacc, dvacc), dqb = jax.lax.scan(one_block, (zero_k, zero_v),
                                       (qi_blocks, qb, gb, lseb, deltab))
    dq = dqb.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, s, d)
    return dq.astype(q.dtype), dkacc.astype(k.dtype), dvacc.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 6))
def _chunked(q, k, v, window, causal, scale, block_q):
    out, _ = _fwd_blocks(q, k, v, causal, window, scale, block_q)
    return out.astype(q.dtype)


def _chunked_fwd(q, k, v, window, causal, scale, block_q):
    out, lse = _fwd_blocks(q, k, v, causal, window, scale, block_q)
    return out.astype(q.dtype), (q, k, v, out, lse, window, scale)


def _chunked_bwd(causal, block_q, res, g):
    q, k, v, out, lse, window, scale = res
    dq, dk, dv = _bwd_blocks(q, k, v, out, lse, g, causal, window, scale,
                             block_q)
    return dq, dk, dv, None, None


_chunked.defvjp(_chunked_fwd, _chunked_bwd)


def naive_attention(q, k, v, *, causal=True, window=None, scale=None):
    """Single-shot attention (identical math, S x S logits materialized).
    Used by the dry-run cost-extraction variants where while-loops would
    be undercounted by XLA's cost analysis; never on the training path."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    qg = q.reshape(b, hkv, g, sq, d)
    logits = jnp.einsum("bkgqd,bktd->bkgqt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(sq)[:, None] + (skv - sq)
    ki = jnp.arange(skv)[None, :]
    if window is not None and not isinstance(window, int):
        window = jnp.asarray(window, jnp.int32)
    logits = jnp.where(_mask(qi, ki, causal, window)[None, None, None],
                       logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqt,bktd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, v.shape[-1]).astype(q.dtype)


def chunked_attention(q, k, v, *, causal=True, window=None, scale=None,
                      block_q=1024):
    """q:[B,Hq,Sq,D]; k,v:[B,Hkv,Skv,D] -> [B,Hq,Sq,Dv].

    ``window`` may be None, a Python int, or a traced int32 scalar.
    GQA kv heads are contracted in grouped form (never expanded).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    block_q = min(block_q, sq)
    if sq % block_q:                     # ragged tail: fall back to one block
        block_q = sq
    if window is not None and not isinstance(window, (int,)):
        window = jnp.asarray(window, jnp.int32)
    qg = q.reshape(b, hkv, g, sq, d)
    out = _chunked(qg, k, v, window, causal, scale, block_q)
    return out.reshape(b, hq, sq, v.shape[-1])
