"""Model configuration covering all assigned architecture families.

One dataclass describes dense GQA transformers, MoE (standard and
MLA/DeepSeek-style), RWKV6, hybrid attention+SSM (Hymba), sliding-window
interleaves (Gemma3), and modality-stub backbones (Phi-3-vision, MusicGen).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # always-on shared experts (DeepSeek-V2)
    d_ff_shared: int = 0         # width of the shared expert(s)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0             # 0 -> ceil(d_model / 16)
    expand: int = 1              # d_inner = expand * attn-width


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int                 # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # Block type: "gqa" | "mla" | "rwkv6" | "hymba"
    attn_type: str = "gqa"
    # Sliding-window interleave: None -> all global. Otherwise layers are
    # local (windowed) except every ``global_every``-th (gemma3: 5:1).
    window: Optional[int] = None
    global_every: int = 6
    # Hymba: indices of global-attention layers (first/middle/last).
    hymba_global_layers: tuple = ()

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # Modality frontend: "tokens" (LM) or "frames" (precomputed patch/frame
    # embeddings via input_specs() stub — paper-assigned vlm/audio entries).
    frontend: str = "tokens"
    frame_dim: int = 0           # embedding dim of precomputed frames

    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    # RWKV6 chunked-scan length (parallel linear-attention formulation).
    rwkv_chunk: int = 128

    # --- roofline-extraction knobs (launch.dryrun cost variants) -----------
    # XLA's cost_analysis counts while-loop bodies once; the dry-run
    # compiles unrolled 1-/2-layer "naive attention" variants and linearly
    # extrapolates exact totals (EXPERIMENTS.md §Roofline methodology).
    attention_impl: str = "chunked"   # "chunked" | "naive"
    unroll_layers: bool = False       # unroll the layer scan
    rwkv_unroll: bool = False         # unroll the rwkv chunk scan

    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        assert self.n_heads > 0
        return self.d_model // self.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.activation_dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.attn_type == "rwkv6"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence scaling: SSM / hybrid-window archs."""
        return self.attn_type in ("rwkv6", "hymba")

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attn_type == "gqa":
            hd = self.head_dim_
            per_layer += d * hd * (self.n_heads + 2 * self.n_kv_heads) + \
                self.n_heads * hd * d
        elif self.attn_type == "mla":
            m = self.mla
            qk = m.nope_head_dim + m.rope_head_dim
            per_layer += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
            per_layer += d * (m.kv_lora_rank + m.rope_head_dim)
            per_layer += m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * d
        elif self.attn_type == "rwkv6":
            per_layer += 4 * d * d + d * d  # r,k,v,g,o (approx; + small loras)
        elif self.attn_type == "hymba":
            hd = self.head_dim_
            att = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            di = self.n_heads * hd
            ssm = d * 2 * di + di * d + di * (self.ssm.d_state * 2 + 8)
            per_layer += att + ssm
        if self.moe:
            e = self.moe
            per_layer += d * e.n_experts  # router
            per_layer += e.n_experts * 3 * d * e.d_ff_expert
            per_layer += e.n_shared * 3 * d * e.d_ff_shared
        else:
            per_layer += 3 * d * f
        return emb + L * per_layer

    def n_active_params(self) -> int:
        """Activated parameters per token (MoE: only routed-to experts)."""
        if not self.moe:
            return self.n_params()
        e = self.moe
        d, L = self.d_model, self.n_layers
        dense = self.n_params() - L * e.n_experts * 3 * d * e.d_ff_expert
        return dense + L * e.top_k * 3 * d * e.d_ff_expert
