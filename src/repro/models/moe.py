"""Mixture-of-Experts FFN: capacity-based top-k routing with expert
parallelism over the "model" mesh axis.

Two execution paths (EXPERIMENTS.md §Perf M3):

* **shard_map EP** (meshes, full sequences): every device routes its own
  (batch x seq)-shard of tokens, scatters them into a local per-expert
  capacity buffer, and two *tiled all-to-alls* over the model axis move
  token blocks to their expert owners and back. Collective cost is the
  token payload itself (~2 x k x cf x T_dev x D bytes/layer) — measured
  16x less collective traffic than what the XLA partitioner derives from
  the textbook global-capacity formulation (which materializes and
  all-reduces the whole [E, C, D] buffer per layer: ~26 GB/layer/device
  on phi3.5-moe train_4k).
* **dense fallback** (no mesh / single-token decode): the classic global
  capacity buffer — exact, simple, and fine at those scales.

DeepSeek-V2 style shared experts run as a dense SwiGLU alongside. Returns
the combine output plus the switch-style load-balance auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:
    from jax import shard_map
except ImportError:                                # pragma: no cover
    from jax.experimental.shard_map import shard_map

from .config import ModelConfig
from .sharding import ShardCtx
from . import layers


def _top_k_dispatch(probs: jax.Array, k: int, capacity: int):
    """probs [T, E] -> (expert_idx [T,k], gates [T,k], pos [T,k], keep [T,k])."""
    vals, idx = jax.lax.top_k(probs, k)
    gates = vals / jnp.maximum(jnp.sum(vals, -1, keepdims=True), 1e-9)

    t, e = probs.shape
    counts = jnp.zeros((e,), jnp.int32)
    pos_slots = []
    keep_slots = []
    for j in range(k):
        onehot = jax.nn.one_hot(idx[:, j], e, dtype=jnp.int32)
        pos = counts[None, :] + jnp.cumsum(onehot, axis=0) - onehot
        pos_j = jnp.sum(pos * onehot, axis=-1)
        keep_slots.append(pos_j < capacity)
        pos_slots.append(jnp.minimum(pos_j, capacity - 1))
        counts = counts + jnp.sum(onehot, axis=0)
    pos = jnp.stack(pos_slots, axis=1)
    keep = jnp.stack(keep_slots, axis=1)
    return idx, gates, pos, keep


def _route_scatter(cfg: ModelConfig, router_w, xt, capacity):
    """xt [T,D] -> (buf [E,C,D], idx, gates, pos, keep, me, ce).
    me/ce are the switch-loss statistics (mean router prob / mean dispatch
    fraction per expert) — combined into the aux loss by the caller so the
    sharded path can average them *globally* first."""
    e = cfg.moe
    adtype = cfg.adtype
    t, d = xt.shape
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    idx, gates, pos, keep = _top_k_dispatch(probs, e.top_k, capacity)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(idx, e.n_experts), axis=1)
                  / e.top_k, axis=0)

    buf = jnp.zeros((e.n_experts, capacity, d), adtype)
    src = jnp.where(keep[..., None], xt[:, None, :], 0).astype(adtype)
    buf = buf.at[idx, pos].add(src)
    return buf, idx, gates, pos, keep, me, ce


def _aux_loss(cfg: ModelConfig, me, ce):
    return cfg.moe.n_experts * jnp.sum(me * ce)


def _expert_ffn(p, buf, adtype):
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(adtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(adtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(adtype) * h
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(adtype))


def _combine(eo, idx, gates, pos, keep, t, d, adtype):
    out_slots = eo[idx, pos]                              # [T,k,D]
    w = (gates * keep).astype(jnp.float32)
    return jnp.einsum("tkd,tk->td", out_slots.astype(jnp.float32), w
                      ).astype(adtype)


def _moe_dense(cfg: ModelConfig, p: dict, x: jax.Array, sh: ShardCtx):
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    capacity = max(4, int(t * e.top_k / e.n_experts * e.capacity_factor))
    buf, idx, gates, pos, keep, me, ce = _route_scatter(cfg, p["router"], xt,
                                                        capacity)
    aux = _aux_loss(cfg, me, ce)
    buf = sh.constrain(buf, sh.model_axis, None, None)
    eo = _expert_ffn(p, buf, cfg.adtype)
    eo = sh.constrain(eo, sh.model_axis, None, None)
    out = _combine(eo, idx, gates, pos, keep, t, d, cfg.adtype)
    return out.reshape(b, s, d), aux


def _moe_shard_map(cfg: ModelConfig, p: dict, x: jax.Array, sh: ShardCtx):
    e = cfg.moe
    adtype = cfg.adtype
    b, s, d = x.shape
    msz = sh.size("model")
    e_loc = e.n_experts // msz
    batch = sh.batch_axes_for(b)
    dp = 1
    for a in (batch or ()):
        dp *= sh.size(a)
    t_dev = (b // dp) * (s // msz)
    c_dev = max(4, int(t_dev * e.top_k / e.n_experts * e.capacity_factor))
    all_axes = tuple(a for a in ("pod", "data", "model") if a in sh.names)

    def local(xloc, router_w, w_in, w_gate, w_out):
        bl, sl, _ = xloc.shape
        xt = xloc.reshape(bl * sl, d)
        buf, idx, gates, pos, keep, me, ce = _route_scatter(
            cfg, router_w, xt, c_dev)
        # deliver token blocks to their expert owners (tiled all-to-all
        # over the model axis), compute, and send back.
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)        # [E_loc, msz*C_dev, D]
        eo = _expert_ffn({"w_in": w_in, "w_gate": w_gate, "w_out": w_out},
                         buf, adtype)
        eo = jax.lax.all_to_all(eo, "model", split_axis=1, concat_axis=0,
                                tiled=True)         # [E, C_dev, D]
        out = _combine(eo, idx, gates, pos, keep, xt.shape[0], d, adtype)
        # global load-balance statistics (identical to the dense formula)
        aux = _aux_loss(cfg, jax.lax.pmean(me, all_axes),
                        jax.lax.pmean(ce, all_axes))
        return out.reshape(bl, sl, d), aux

    fn = shard_map(
        local, mesh=sh.mesh,
        in_specs=(P(batch, "model", None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(batch, "model", None), P()))
    out, aux = fn(x, p["router"], p["w_in"], p["w_gate"], p["w_out"])

    if e.n_shared:
        out = out + layers.swiglu(x, p["shared"], sh, adtype)
    return out, aux


def moe_block(cfg: ModelConfig, p: dict, x: jax.Array, sh: ShardCtx
              ) -> tuple[jax.Array, jax.Array]:
    """x [B,S,D] -> (out [B,S,D], aux_loss scalar)."""
    e = cfg.moe
    b, s, d = x.shape
    msz = sh.size("model")
    if (sh.mesh is not None and msz > 1 and e.n_experts % msz == 0
            and s % msz == 0):
        return _moe_shard_map(cfg, p, x, sh)
    out, aux = _moe_dense(cfg, p, x, sh)
    if e.n_shared:
        out = out + layers.swiglu(x, p["shared"], sh, cfg.adtype)
    return out, aux
