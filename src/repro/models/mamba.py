"""Selective SSM (Mamba) head and the Hymba parallel attention+SSM block
(arXiv:2411.13676).

Hymba runs attention heads and Mamba heads *in parallel* on the same
normed input; per-path RMS-normalized outputs are averaged and projected
once. Most layers use sliding-window attention; layers
``cfg.hymba_global_layers`` (first / middle / last) stay global — the mix
that makes the arch viable at long context (long_500k runs for this arch).

The SSM recurrence h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t is evaluated
with a sequential lax.scan (state [B, d_inner, N]); its FLOP share is tiny
next to attention/FFN, so the scan is not on the roofline-critical path
(chunked parallelization noted as future work in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import ShardCtx
from . import layers
from .chunked_attention import chunked_attention, naive_attention
from .decode import dist_decode


def _dt_rank(cfg: ModelConfig) -> int:
    return cfg.ssm.dt_rank or max(1, -(-cfg.d_model // 16))


def _ssm_params(cfg: ModelConfig, p: dict, x_in: jax.Array):
    """x_in [B,S,di] (post conv+silu) -> dt [B,S,di], B/C [B,S,N]."""
    n = cfg.ssm.d_state
    r = _dt_rank(cfg)
    proj = jnp.einsum("bsd,dk->bsk", x_in, p["x_proj"].astype(x_in.dtype))
    dt, bc = proj[..., :r], proj[..., r:]
    bmat, cmat = bc[..., :n], bc[..., n:]
    dt = jnp.einsum("bsr,rd->bsd", dt, p["dt_proj"].astype(x_in.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return dt, bmat.astype(jnp.float32), cmat.astype(jnp.float32)


def _conv1d(x: jax.Array, w: jax.Array, state: jax.Array | None):
    """Causal depthwise conv. x [B,S,di], w [di,K]. state [B,K-1,di] carries
    the last K-1 inputs for decode; None -> zero history (train/prefill)."""
    b, s, di = x.shape
    k = w.shape[1]
    if state is None:
        state = jnp.zeros((b, k - 1, di), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + s].astype(jnp.float32) * \
            w[:, i].astype(jnp.float32)
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return out.astype(x.dtype), new_state


def mamba_mix(cfg: ModelConfig, p: dict, xn: jax.Array, sh: ShardCtx,
              conv_state=None, ssm_state=None):
    """Mamba path. xn [B,S,D] (normed input) -> (y [B,S,di], new_conv_state,
    new_ssm_state [B,di,N] fp32)."""
    adtype = cfg.adtype
    b, s, d = xn.shape
    n = cfg.ssm.d_state

    xz = jnp.einsum("bsd,de->bse", xn, p["in_proj"].astype(adtype))
    di = xz.shape[-1] // 2
    x, z = xz[..., :di], xz[..., di:]
    x, new_conv = _conv1d(x, p["conv_w"], conv_state)
    x = jax.nn.silu(x.astype(jnp.float32)).astype(adtype)
    x = sh.constrain(x, sh.batch_axes, None, sh.model_axis)

    dt, bmat, cmat = _ssm_params(cfg, p, x)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))        # [di,N], negative
    xf = x.astype(jnp.float32)

    def step(h, xs):
        dt_t, b_t, c_t, x_t = xs                         # [B,di],[B,N],[B,N],[B,di]
        decay = jnp.exp(dt_t[..., None] * a[None])       # [B,di,N]
        h = h * decay + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    if ssm_state is None:
        ssm_state = jnp.zeros((b, di, n), jnp.float32)
    xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(bmat, 1, 0),
          jnp.moveaxis(cmat, 1, 0), jnp.moveaxis(xf, 1, 0))
    new_ssm, ys = jax.lax.scan(step, ssm_state, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * p["d_skip"].astype(jnp.float32)[None, None]
    y = y.astype(adtype) * jax.nn.silu(z.astype(jnp.float32)).astype(adtype)
    return y, new_conv, new_ssm


def _path_norm(y: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + eps)
    return (yf * w.astype(jnp.float32)).astype(y.dtype)


def hymba_block(cfg: ModelConfig, p: dict, xn: jax.Array, sh: ShardCtx,
                positions: jax.Array, window) -> tuple[jax.Array, dict]:
    """Parallel attention + mamba on normed input xn [B,S,D].
    Returns (out [B,S,D], cache {k, v, conv, ssm})."""
    adtype = cfg.adtype
    b, s, d = xn.shape
    hd = cfg.head_dim_

    q, k, v = layers.gqa_project(cfg, p, xn, adtype)
    cos, sin = layers.rope_tables(positions, hd, cfg.rope_theta)
    q = layers.apply_rope(q, cos, sin)
    k = layers.apply_rope(k, cos, sin)
    if layers.use_context_parallel(cfg, sh, b, s):
        attn = layers.attention_seq_sharded(cfg, sh, q, k, v, window)
    else:
        attn_fn = (naive_attention if cfg.attention_impl == "naive"
                   else chunked_attention)
        attn = attn_fn(q, k, v, causal=True, window=window)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * hd)

    ssm_y, conv_state, ssm_state = mamba_mix(cfg, p["mamba"], xn, sh)

    fused = (_path_norm(attn, p["attn_out_norm"], cfg.norm_eps)
             + _path_norm(ssm_y, p["ssm_out_norm"], cfg.norm_eps)) * 0.5
    out = jnp.einsum("bse,ed->bsd", fused, p["wo"].astype(adtype))
    cache = {"k": k, "v": v, "conv": conv_state, "ssm": ssm_state}
    return out, cache


def hymba_decode(cfg: ModelConfig, p: dict, xn: jax.Array, sh: ShardCtx,
                 cache: dict, kv_len: jax.Array, eff_len=None
                 ) -> tuple[jax.Array, dict]:
    """Single-token Hymba step. xn [B,1,D]; cache holds k/v ring buffers
    [B,Hkv,size,Dh] (new token already written at slot (kv_len-1) % size),
    conv [B,K-1,di], ssm [B,di,N]. ``eff_len`` = number of valid ring
    slots (min(kv_len, size)); ring contents ARE the window, so no
    further window masking applies (keys carry absolute-position RoPE —
    attention is slot-order agnostic)."""
    adtype = cfg.adtype
    b = xn.shape[0]
    hd = cfg.head_dim_

    q = jnp.einsum("bsd,dh->bsh", xn, p["wq"].astype(adtype))
    q = q.reshape(b, cfg.n_heads, hd)
    pos = (kv_len - 1).astype(jnp.float32)
    cos, sin = layers.rope_tables(pos[:, None], hd, cfg.rope_theta)
    q = layers.apply_rope(q[:, :, None], cos[:, None], sin[:, None])[:, :, 0]

    if eff_len is None:
        eff_len = kv_len
    attn = dist_decode(q, cache["k"], cache["v"], eff_len, sh=sh)
    attn = attn.astype(adtype).reshape(b, 1, cfg.n_heads * hd)

    ssm_y, new_conv, new_ssm = mamba_mix(
        cfg, p["mamba"], xn, sh, conv_state=cache["conv"],
        ssm_state=cache["ssm"])

    fused = (_path_norm(attn, p["attn_out_norm"], cfg.norm_eps)
             + _path_norm(ssm_y, p["ssm_out_norm"], cfg.norm_eps)) * 0.5
    out = jnp.einsum("bse,ed->bsd", fused, p["wo"].astype(adtype))
    new_cache = dict(cache, conv=new_conv, ssm=new_ssm)
    return out, new_cache


def hymba_write_kv(cfg: ModelConfig, p: dict, xn: jax.Array, cache: dict,
                   kv_len: jax.Array, slot: jax.Array | None = None) -> dict:
    """Project and write the new token's k/v (RoPE'd at its absolute
    position kv_len-1) into ring slot ``slot`` (default: kv_len-1, i.e.
    a non-wrapping cache)."""
    adtype = cfg.adtype
    b = xn.shape[0]
    hd = cfg.head_dim_
    k = jnp.einsum("bsd,dh->bsh", xn, p["wk"].astype(adtype))
    v = jnp.einsum("bsd,dh->bsh", xn, p["wv"].astype(adtype))
    k = k.reshape(b, cfg.n_kv_heads, hd)
    v = v.reshape(b, cfg.n_kv_heads, hd)
    pos = (kv_len - 1).astype(jnp.float32)
    cos, sin = layers.rope_tables(pos[:, None], hd, cfg.rope_theta)
    k = layers.apply_rope(k[:, :, None], cos[:, None], sin[:, None])[:, :, 0]
    if slot is None:
        slot = kv_len - 1
    bidx = jnp.arange(b)
    return dict(cache,
                k=cache["k"].at[bidx, :, slot].set(k),
                v=cache["v"].at[bidx, :, slot].set(v))
