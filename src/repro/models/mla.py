"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV state is compressed into a small latent (kv_lora_rank=512 + 64 shared
RoPE dims) — the 236B model's decode cache is ~1/16 of an equivalent GQA
cache. Training materializes per-head K/V from the latent (standard
attention path); decode uses the *absorbed* formulation: queries are
mapped into latent space (q @ W_uk) so attention runs directly over the
cached latents with a single headless "kv head" — which drops straight
into the sequence-sharded distributed flash-decode (decode.dist_decode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import ShardCtx
from .chunked_attention import chunked_attention, naive_attention
from .decode import dist_decode
from . import layers


def _project_q(cfg: ModelConfig, p: dict, x: jax.Array):
    """x [B,S,D] -> q_nope [B,H,S,nope], q_rope [B,H,S,rope]."""
    m = cfg.mla
    adtype = cfg.adtype
    b, s, _ = x.shape
    cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(adtype))
    cq = layers.rms_norm(cq, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rh->bsh", cq, p["wq_b"].astype(adtype))
    q = q.reshape(b, s, cfg.n_heads, m.nope_head_dim + m.rope_head_dim)
    q = q.transpose(0, 2, 1, 3)
    return q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]


def _project_kv_latent(cfg: ModelConfig, p: dict, x: jax.Array):
    """x [B,S,D] -> c_kv [B,S,R] (normed), k_rope [B,1,S,rope] (unroped)."""
    m = cfg.mla
    adtype = cfg.adtype
    ckr = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(adtype))
    c_kv, k_rope = ckr[..., :m.kv_lora_rank], ckr[..., m.kv_lora_rank:]
    c_kv = layers.rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    return c_kv, k_rope[:, None]


def mla_attention(cfg: ModelConfig, p: dict, x: jax.Array, sh: ShardCtx,
                  positions: jax.Array, window) -> tuple[jax.Array, dict]:
    """Training / prefill path (materialized per-head K/V)."""
    m = cfg.mla
    adtype = cfg.adtype
    b, s, d = x.shape
    h = cfg.n_heads

    q_nope, q_rope = _project_q(cfg, p, x)
    c_kv, k_rope = _project_kv_latent(cfg, p, x)

    cos, sin = layers.rope_tables(positions, m.rope_head_dim, cfg.rope_theta)
    q_rope = layers.apply_rope(q_rope, cos, sin)
    k_rope = layers.apply_rope(k_rope, cos, sin)

    k_nope = jnp.einsum("bsr,rhn->bhsn", c_kv,
                        p["wk_b"].astype(adtype).reshape(
                            m.kv_lora_rank, h, m.nope_head_dim))
    v = jnp.einsum("bsr,rhn->bhsn", c_kv,
                   p["wv_b"].astype(adtype).reshape(
                       m.kv_lora_rank, h, m.v_head_dim))

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (b, h, s, m.rope_head_dim))], axis=-1)
    q = sh.act_bhsd(q, h)
    k = sh.act_bhsd(k, h)
    v = sh.act_bhsd(v, h)

    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    attn_fn = (naive_attention if cfg.attention_impl == "naive"
               else chunked_attention)
    o = attn_fn(q, k, v, causal=True, window=window, scale=scale)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(adtype))
    cache = {"c_kv": c_kv, "k_rope": k_rope[:, 0]}
    return out, cache


def mla_decode(cfg: ModelConfig, p: dict, x: jax.Array, sh: ShardCtx,
               cache: dict, kv_len: jax.Array) -> tuple[jax.Array, dict]:
    """Absorbed decode. x [B,1,D]; cache {c_kv [B,Smax,R],
    k_rope [B,Smax,rope]}; the new token is already written at kv_len-1."""
    m = cfg.mla
    adtype = cfg.adtype
    b = x.shape[0]
    h = cfg.n_heads

    q_nope, q_rope = _project_q(cfg, p, x)          # [B,H,1,*]
    pos = (kv_len - 1).astype(jnp.float32)
    cos, sin = layers.rope_tables(pos[:, None], m.rope_head_dim,
                                  cfg.rope_theta)
    q_rope = layers.apply_rope(q_rope, cos[:, None], sin[:, None])

    # Absorb W_uk into the query: q_lat = q_nope @ W_uk^T per head.
    wk = p["wk_b"].astype(adtype).reshape(m.kv_lora_rank, h, m.nope_head_dim)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, :, 0], wk)
    q_cat = jnp.concatenate([q_lat, q_rope[:, :, 0]], axis=-1)  # [B,H,R+rope]

    k_cat = jnp.concatenate([cache["c_kv"], cache["k_rope"]], axis=-1)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    ctx = dist_decode(q_cat, k_cat[:, None], cache["c_kv"][:, None],
                      kv_len, sh=sh, scale=scale)   # [B,H,R] fp32

    wv = p["wv_b"].astype(adtype).reshape(m.kv_lora_rank, h, m.v_head_dim)
    o = jnp.einsum("bhr,rhn->bhn", ctx.astype(adtype), wv)
    o = o.reshape(b, 1, h * m.v_head_dim)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(adtype))
    return out, cache


def mla_write_cache(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
                    kv_len: jax.Array) -> dict:
    """Project the new token's latent and write it at position kv_len-1."""
    m = cfg.mla
    c_kv, k_rope = _project_kv_latent(cfg, p, x)     # [B,1,R], [B,1,1,rope]
    pos = (kv_len - 1).astype(jnp.float32)
    cos, sin = layers.rope_tables(pos[:, None], m.rope_head_dim,
                                  cfg.rope_theta)
    k_rope = layers.apply_rope(k_rope[:, 0], cos, sin)

    bidx = jnp.arange(x.shape[0])
    new_c = cache["c_kv"].at[bidx, kv_len - 1].set(c_kv[:, 0])
    new_r = cache["k_rope"].at[bidx, kv_len - 1].set(k_rope[:, 0])
    return {"c_kv": new_c, "k_rope": new_r}
