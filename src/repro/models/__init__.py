"""Model zoo: one backbone, four block families (GQA / MLA / RWKV6 /
Hymba), dense or MoE FFN, token or frame frontends."""
from .config import ModelConfig, MoEConfig, MLAConfig, SSMConfig
from .sharding import ShardCtx
from .transformer import (init_params, loss_fn, forward_seq, prefill,
                          decode_step, init_cache, layer_windows)

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "ShardCtx",
           "init_params", "loss_fn", "forward_seq", "prefill", "decode_step",
           "init_cache", "layer_windows"]
