"""Model assembly: init, train forward, prefill, and decode for every
assigned architecture family.

Layers are scanned with stacked parameters (one compact HLO body even for
60-layer models) and rematerialized (jax.checkpoint) so training memory is
O(residual stream). Per-layer heterogeneity (gemma3's 5:1 local:global
interleave, hymba's global-attention islands) rides through the scan as a
traced per-layer window scalar, so a single HLO body serves both layer
kinds.

Cache convention: ``pos`` = number of tokens already in the cache. A
decode step writes the new token's state at index ``pos`` and attends over
``pos + 1`` entries.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import layers, mla as mla_lib, moe as moe_lib, rwkv as rwkv_lib
from . import mamba as mamba_lib
from .config import ModelConfig
from .decode import dist_decode
from .sharding import ShardCtx

NO_WINDOW = jnp.int32(2 ** 30)   # dynamic-window sentinel: "global"


def _unroll(cfg: "ModelConfig") -> int:
    return cfg.n_layers if cfg.unroll_layers else 1


# --------------------------------------------------------------------------- #
# parameter init
# --------------------------------------------------------------------------- #

def _dense(key, shape, dtype, scale=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _init_attn(cfg: ModelConfig, key, L) -> dict:
    dt = cfg.pdtype
    d, hd = cfg.d_model, cfg.head_dim_
    ks = jax.random.split(key, 5)
    return {
        "norm": jnp.ones((L, d), dt),
        "wq": _dense(ks[0], (L, d, cfg.n_heads * hd), dt),
        "wk": _dense(ks[1], (L, d, cfg.n_kv_heads * hd), dt),
        "wv": _dense(ks[2], (L, d, cfg.n_kv_heads * hd), dt),
        "wo": _dense(ks[3], (L, cfg.n_heads * hd, d), dt),
    }


def _init_mla(cfg: ModelConfig, key, L) -> dict:
    dt = cfg.pdtype
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.nope_head_dim + m.rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "norm": jnp.ones((L, d), dt),
        "wq_a": _dense(ks[0], (L, d, m.q_lora_rank), dt),
        "q_norm": jnp.ones((L, m.q_lora_rank), dt),
        "wq_b": _dense(ks[1], (L, m.q_lora_rank, h * qk), dt),
        "wkv_a": _dense(ks[2], (L, d, m.kv_lora_rank + m.rope_head_dim), dt),
        "kv_norm": jnp.ones((L, m.kv_lora_rank), dt),
        "wk_b": _dense(ks[3], (L, m.kv_lora_rank, h * m.nope_head_dim), dt),
        "wv_b": _dense(ks[4], (L, m.kv_lora_rank, h * m.v_head_dim), dt),
        "wo": _dense(ks[5], (L, h * m.v_head_dim, d), dt),
    }


def _init_rwkv(cfg: ModelConfig, key, L) -> dict:
    dt = cfg.pdtype
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 8)
    base = jnp.linspace(-6.0, -1.0, d, dtype=jnp.float32)
    return {
        "norm": jnp.ones((L, d), dt),
        "mu_r": jnp.full((L, d), 0.5, dt), "mu_k": jnp.full((L, d), 0.5, dt),
        "mu_v": jnp.full((L, d), 0.5, dt), "mu_w": jnp.full((L, d), 0.5, dt),
        "mu_g": jnp.full((L, d), 0.5, dt),
        "w_r": _dense(ks[0], (L, d, d), dt),
        "w_k": _dense(ks[1], (L, d, d), dt),
        "w_v": _dense(ks[2], (L, d, d), dt),
        "w_g": _dense(ks[3], (L, d, d), dt),
        "w_o": _dense(ks[4], (L, d, d), dt),
        "decay_a": _dense(ks[5], (L, d, 64), dt),
        "decay_b": _dense(ks[6], (L, 64, d), dt),
        "decay_base": jnp.tile(base, (L, 1)),
        "u": _dense(ks[7], (L, h, dh), dt, scale=0.1),
        "gn_w": jnp.ones((L, d), dt),
    }


def _init_mamba(cfg: ModelConfig, key) -> dict:
    """Sub-dict for the Hymba SSM path (leading L dim added by caller)."""
    dt = cfg.pdtype
    d = cfg.d_model
    di = cfg.n_heads * cfg.head_dim_
    n = cfg.ssm.d_state
    r = cfg.ssm.dt_rank or max(1, -(-d // 16))
    k = cfg.ssm.d_conv
    ks = jax.random.split(key, 4)
    a = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))[None, :]
    return {
        "in_proj": _dense(ks[0], (d, 2 * di), dt),
        "conv_w": _dense(ks[1], (di, k), dt, scale=0.2),
        "x_proj": _dense(ks[2], (di, r + 2 * n), dt),
        "dt_proj": _dense(ks[3], (r, di), dt),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),   # softplus^-1(0.01)
        "a_log": jnp.broadcast_to(a, (di, n)).astype(jnp.float32),
        "d_skip": jnp.ones((di,), jnp.float32),
    }


def _init_hymba(cfg: ModelConfig, key, L) -> dict:
    dt = cfg.pdtype
    d = cfg.d_model
    di = cfg.n_heads * cfg.head_dim_
    k1, k2, k3 = jax.random.split(key, 3)
    att = _init_attn(cfg, k1, L)
    del att["wo"]
    mam = jax.vmap(lambda k: _init_mamba(cfg, k))(jax.random.split(k2, L))
    return {
        **att,
        "mamba": mam,
        "attn_out_norm": jnp.ones((L, di), dt),
        "ssm_out_norm": jnp.ones((L, di), dt),
        "wo": _dense(k3, (L, di, d), dt),
    }


def _init_mlp(cfg: ModelConfig, key, L) -> dict:
    dt = cfg.pdtype
    d = cfg.d_model
    if cfg.attn_type == "rwkv6":   # rwkv channel mix
        ks = jax.random.split(key, 3)
        return {
            "norm": jnp.ones((L, d), dt),
            "mu_k": jnp.full((L, d), 0.5, dt),
            "mu_r": jnp.full((L, d), 0.5, dt),
            "w_k": _dense(ks[0], (L, d, cfg.d_ff), dt),
            "w_v": _dense(ks[1], (L, cfg.d_ff, d), dt),
            "w_r": _dense(ks[2], (L, d, d), dt),
        }
    if cfg.moe:
        e = cfg.moe
        ks = jax.random.split(key, 7)
        p = {
            "norm": jnp.ones((L, d), dt),
            "router": _dense(ks[0], (L, d, e.n_experts), dt),
            "w_in": _dense(ks[1], (L, e.n_experts, d, e.d_ff_expert), dt),
            "w_gate": _dense(ks[2], (L, e.n_experts, d, e.d_ff_expert), dt),
            "w_out": _dense(ks[3], (L, e.n_experts, e.d_ff_expert, d), dt),
        }
        if e.n_shared:
            p["shared"] = {
                "w_in": _dense(ks[4], (L, d, e.d_ff_shared), dt),
                "w_gate": _dense(ks[5], (L, d, e.d_ff_shared), dt),
                "w_out": _dense(ks[6], (L, e.d_ff_shared, d), dt),
            }
        return p
    ks = jax.random.split(key, 3)
    return {
        "norm": jnp.ones((L, d), dt),
        "w_in": _dense(ks[0], (L, d, cfg.d_ff), dt),
        "w_gate": _dense(ks[1], (L, d, cfg.d_ff), dt),
        "w_out": _dense(ks[2], (L, cfg.d_ff, d), dt),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dt = cfg.pdtype
    k_embed, k_attn, k_mlp, k_head = jax.random.split(key, 4)
    L = cfg.n_layers
    if cfg.frontend == "frames":
        embed = {"frames": _dense(k_embed, (cfg.frame_dim, cfg.d_model), dt),
                 "tokens": _dense(jax.random.fold_in(k_embed, 1),
                                  (cfg.vocab, cfg.d_model), dt)}
    else:
        embed = {"tokens": _dense(k_embed, (cfg.vocab, cfg.d_model), dt)}

    attn_init = {"gqa": _init_attn, "mla": _init_mla, "rwkv6": _init_rwkv,
                 "hymba": _init_hymba}[cfg.attn_type]
    params = {
        "embed": embed,
        "layers": {"attn": attn_init(cfg, k_attn, L),
                   "mlp": _init_mlp(cfg, k_mlp, L)},
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(k_head, (cfg.d_model, cfg.vocab), dt)
    return params


# --------------------------------------------------------------------------- #
# per-layer windows (dynamic local/global interleave)
# --------------------------------------------------------------------------- #

def layer_windows(cfg: ModelConfig) -> Optional[jax.Array]:
    """None -> all layers global (no window logic lowered). Otherwise an
    int32[L] of per-layer window sizes (NO_WINDOW sentinel = global)."""
    if cfg.window is None:
        return None
    L = cfg.n_layers
    idx = jnp.arange(L)
    if cfg.attn_type == "hymba":
        glb = jnp.zeros((L,), bool)
        for g in cfg.hymba_global_layers:
            glb = glb | (idx == g)
    else:
        glb = (idx % cfg.global_every) == (cfg.global_every - 1)
    return jnp.where(glb, NO_WINDOW, jnp.int32(cfg.window))


# --------------------------------------------------------------------------- #
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------------- #

def _seq_block(cfg: ModelConfig, sh: ShardCtx, positions, p, x, window):
    """One layer over the full sequence. Returns (x, cache_entry, aux)."""
    h = layers.rms_norm(x, p["attn"]["norm"], cfg.norm_eps)
    if cfg.attn_type == "gqa":
        a, cache = layers.gqa_attention(cfg, p["attn"], h, sh, positions,
                                        window)
    elif cfg.attn_type == "mla":
        a, cache = mla_lib.mla_attention(cfg, p["attn"], h, sh, positions,
                                         window)
    elif cfg.attn_type == "hymba":
        a, cache = mamba_lib.hymba_block(cfg, p["attn"], h, sh, positions,
                                         window)
    elif cfg.attn_type == "rwkv6":
        prev = jnp.zeros((x.shape[0], x.shape[2]), x.dtype)
        a, prev_att, state = rwkv_lib.rwkv_time_mix(cfg, p["attn"], h, sh,
                                                    prev)
        cache = {"state": state, "prev_att": prev_att}
    else:
        raise ValueError(cfg.attn_type)
    x = sh.act_btd(x + a)

    h2 = layers.rms_norm(x, p["mlp"]["norm"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    if cfg.attn_type == "rwkv6":
        prev2 = jnp.zeros((x.shape[0], x.shape[2]), x.dtype)
        m, prev_ffn = rwkv_lib.rwkv_channel_mix(cfg, p["mlp"], h2, sh, prev2)
        cache["prev_ffn"] = prev_ffn
    elif cfg.moe:
        m, aux = moe_lib.moe_block(cfg, p["mlp"], h2, sh)
    else:
        m = layers.swiglu(h2, p["mlp"], sh, cfg.adtype)
    x = sh.act_btd(x + m)
    return x, cache, aux


def forward_seq(cfg: ModelConfig, params: dict, inputs: jax.Array,
                sh: ShardCtx, *, collect_cache: bool):
    """inputs: int32 tokens [B,S] or frames [B,S,frame_dim].
    Returns (x_final [B,S,D], stacked cache | None, aux_mean)."""
    if cfg.frontend == "frames" and inputs.ndim == 3:
        x = layers.embed_frames(cfg, params["embed"], inputs, sh)
    else:
        x = layers.embed_tokens(cfg, params["embed"], inputs, sh)
    b, s = x.shape[:2]
    positions = jnp.arange(s, dtype=jnp.float32)
    windows = layer_windows(cfg)

    def body(x, xs):
        p, window = xs
        x, cache, aux = _seq_block(cfg, sh, positions, p, x, window)
        ys = (cache, aux) if collect_cache else (None, aux)
        return x, ys

    body = jax.checkpoint(body, prevent_cse=False)
    xs = (params["layers"],
          windows if windows is not None else jnp.zeros((cfg.n_layers,),
                                                        jnp.int32))
    if windows is None:
        def body_nw(x, p):
            x, cache, aux = _seq_block(cfg, sh, positions, p, x, None)
            return x, ((cache, aux) if collect_cache else (None, aux))
        body_nw = jax.checkpoint(body_nw, prevent_cse=False)
        x, (cache, aux) = jax.lax.scan(body_nw, x, params["layers"],
                                       unroll=_unroll(cfg))
    else:
        x, (cache, aux) = jax.lax.scan(body, x, xs, unroll=_unroll(cfg))
    return x, cache, jnp.mean(aux)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, sh: ShardCtx
            ) -> tuple[jax.Array, dict]:
    """Next-token CE (+ MoE aux). batch: {"inputs", "labels"}."""
    x, _, aux = forward_seq(cfg, params, batch["inputs"], sh,
                            collect_cache=False)
    logits = layers.lm_logits(cfg, params, x, sh)
    ce = layers.cross_entropy(logits, batch["labels"])
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


def prefill(cfg: ModelConfig, params: dict, inputs: jax.Array, sh: ShardCtx,
            smax: int):
    """Build a decode cache of capacity ``smax`` from a full prompt.
    Returns (last_logits [B,V], cache, pos [B])."""
    x, cache, _ = forward_seq(cfg, params, inputs, sh, collect_cache=True)
    b, s = x.shape[:2]
    if cfg.attn_type == "gqa":
        pad = [(0, 0)] * 5
        pad[3] = (0, smax - s)
        cache["k"] = jnp.pad(cache["k"], pad)
        cache["v"] = jnp.pad(cache["v"], pad)
    elif cfg.attn_type == "hymba":
        # restack into per-layer ring buffers (slot = position % size)
        layers_cache = []
        for l, size in enumerate(hymba_cache_sizes(cfg, smax)):
            ck, cv = cache["k"][l], cache["v"][l]       # [B,Hkv,S,hd]
            if size >= s:
                ck = jnp.pad(ck, [(0, 0), (0, 0), (0, size - s), (0, 0)])
                cv = jnp.pad(cv, [(0, 0), (0, 0), (0, size - s), (0, 0)])
            else:
                ps = jnp.arange(s - size, s)
                slots = ps % size                        # permutation of size
                ck = jnp.zeros((b, cfg.n_kv_heads, size, cfg.head_dim_),
                               ck.dtype).at[:, :, slots].set(ck[:, :, ps])
                cv = jnp.zeros((b, cfg.n_kv_heads, size, cfg.head_dim_),
                               cv.dtype).at[:, :, slots].set(cv[:, :, ps])
            layers_cache.append({"k": ck, "v": cv,
                                 "conv": cache["conv"][l],
                                 "ssm": cache["ssm"][l]})
        cache = tuple(layers_cache)
    elif cfg.attn_type == "mla":
        cache["c_kv"] = jnp.pad(cache["c_kv"], [(0, 0), (0, 0),
                                                (0, smax - s), (0, 0)])
        cache["k_rope"] = jnp.pad(cache["k_rope"], [(0, 0), (0, 0),
                                                    (0, smax - s), (0, 0)])
    logits = layers.lm_logits(cfg, params, x[:, -1:], sh)[:, 0]
    pos = jnp.full((b,), s, jnp.int32)
    return logits, cache, pos


# --------------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------------- #

def hymba_cache_sizes(cfg: ModelConfig, smax: int) -> tuple:
    """Per-layer KV capacities: ring buffers of the sliding window for
    local layers, full smax for the global-attention layers. At long_500k
    this is 21 MB vs 1.9 GB/device of mostly-dead full cache (29 of 32
    layers only ever attend the last 1024 positions)."""
    w = cfg.window or smax
    return tuple(smax if l in cfg.hymba_global_layers else min(w, smax)
                 for l in range(cfg.n_layers))


def init_cache(cfg: ModelConfig, batch: int, smax: int):
    """Empty decode cache (capacity smax). Stacked over layers, except
    hymba: a per-layer tuple (ring buffers have heterogeneous sizes)."""
    L, b = cfg.n_layers, batch
    dt = cfg.adtype
    hd = cfg.head_dim_
    if cfg.attn_type == "gqa":
        kv = (L, b, cfg.n_kv_heads, smax, hd)
        return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt)}
    if cfg.attn_type == "mla":
        m = cfg.mla
        return {"c_kv": jnp.zeros((L, b, smax, m.kv_lora_rank), dt),
                "k_rope": jnp.zeros((L, b, smax, m.rope_head_dim), dt)}
    if cfg.attn_type == "rwkv6":
        h = cfg.n_heads
        dh = cfg.d_model // h
        return {"state": jnp.zeros((L, b, h, dh, dh), jnp.float32),
                "prev_att": jnp.zeros((L, b, cfg.d_model), dt),
                "prev_ffn": jnp.zeros((L, b, cfg.d_model), dt)}
    if cfg.attn_type == "hymba":
        di = cfg.n_heads * hd
        return tuple(
            {"k": jnp.zeros((b, cfg.n_kv_heads, size, hd), dt),
             "v": jnp.zeros((b, cfg.n_kv_heads, size, hd), dt),
             "conv": jnp.zeros((b, cfg.ssm.d_conv - 1, di), dt),
             "ssm": jnp.zeros((b, di, cfg.ssm.d_state), jnp.float32)}
            for size in hymba_cache_sizes(cfg, smax))
    raise ValueError(cfg.attn_type)


def _decode_block(cfg: ModelConfig, sh: ShardCtx, p, x, cache, pos, window):
    """One layer, one token. x [B,1,D]. Returns (x, new_cache)."""
    new_len = pos + 1
    h = layers.rms_norm(x, p["attn"]["norm"], cfg.norm_eps)
    if cfg.attn_type == "gqa":
        b = x.shape[0]
        hd = cfg.head_dim_
        adtype = cfg.adtype
        k = jnp.einsum("bsd,dh->bsh", h, p["attn"]["wk"].astype(adtype))
        v = jnp.einsum("bsd,dh->bsh", h, p["attn"]["wv"].astype(adtype))
        k = k.reshape(b, cfg.n_kv_heads, hd)
        v = v.reshape(b, cfg.n_kv_heads, hd)
        posf = pos.astype(jnp.float32)
        cos, sin = layers.rope_tables(posf[:, None], hd, cfg.rope_theta)
        k = layers.apply_rope(k[:, :, None], cos[:, None], sin[:, None])[:, :, 0]
        bidx = jnp.arange(b)
        cache = dict(cache,
                     k=cache["k"].at[bidx, :, pos].set(k),
                     v=cache["v"].at[bidx, :, pos].set(v))
        q = jnp.einsum("bsd,dh->bsh", h, p["attn"]["wq"].astype(adtype))
        q = q.reshape(b, cfg.n_heads, hd)
        q = layers.apply_rope(q[:, :, None], cos[:, None], sin[:, None])[:, :, 0]
        o = dist_decode(q, cache["k"], cache["v"], new_len, sh=sh,
                        window=window)
        o = o.astype(adtype).reshape(b, 1, cfg.n_heads * hd)
        a = jnp.einsum("bsh,hd->bsd", o, p["attn"]["wo"].astype(adtype))
    elif cfg.attn_type == "mla":
        cache = mla_lib.mla_write_cache(cfg, p["attn"], h, cache, new_len)
        a, cache = mla_lib.mla_decode(cfg, p["attn"], h, sh, cache, new_len)
    elif cfg.attn_type == "hymba":
        # ring-buffer write: slot = pos % capacity; attention then covers
        # min(new_len, capacity) slots with no further window mask (the
        # ring *is* the window for local layers).
        size = cache["k"].shape[2]
        slot = pos % size
        cache = mamba_lib.hymba_write_kv(cfg, p["attn"], h, cache, new_len,
                                         slot=slot)
        eff_len = jnp.minimum(new_len, size)
        a, cache = mamba_lib.hymba_decode(cfg, p["attn"], h, sh, cache,
                                          new_len, eff_len)
    elif cfg.attn_type == "rwkv6":
        a, prev_att, state = rwkv_lib.rwkv_decode_step(
            cfg, p["attn"], h, sh, cache["prev_att"], cache["state"])
        cache = dict(cache, prev_att=prev_att, state=state)
    x = x + a

    h2 = layers.rms_norm(x, p["mlp"]["norm"], cfg.norm_eps)
    if cfg.attn_type == "rwkv6":
        xs = cache["prev_ffn"][:, None]
        adtype = cfg.adtype
        mp = p["mlp"]
        xk = h2 + (xs - h2) * mp["mu_k"].astype(adtype)
        xr = h2 + (xs - h2) * mp["mu_r"].astype(adtype)
        kk = jnp.einsum("bsd,df->bsf", xk, mp["w_k"].astype(adtype))
        kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(adtype)
        vv = jnp.einsum("bsf,fd->bsd", kk, mp["w_v"].astype(adtype))
        rr = jax.nn.sigmoid(jnp.einsum(
            "bsd,de->bse", xr, mp["w_r"].astype(adtype)).astype(jnp.float32))
        m = vv * rr.astype(adtype)
        cache = dict(cache, prev_ffn=h2[:, 0])
    elif cfg.moe:
        m, _ = moe_lib.moe_block(cfg, p["mlp"], h2, sh)
    else:
        m = layers.swiglu(h2, p["mlp"], sh, cfg.adtype)
    x = x + m
    return x, cache


def decode_step(cfg: ModelConfig, params: dict, inputs: jax.Array,
                cache: dict, pos: jax.Array, sh: ShardCtx):
    """One new token for every sequence in the batch.

    inputs: int32 [B] token ids (or [B, frame_dim] frames); cache: stacked
    pytree from init_cache/prefill; pos: int32[B] tokens already cached.
    Returns (logits [B,V], new_cache, pos+1).
    """
    if cfg.frontend == "frames" and inputs.ndim == 2:
        x = layers.embed_frames(cfg, params["embed"], inputs[:, None], sh)
    else:
        x = layers.embed_tokens(cfg, params["embed"], inputs[:, None], sh)
    windows = layer_windows(cfg)

    def body(x, xs):
        p, cache, window = xs
        x, new_cache = _decode_block(cfg, sh, p, x, cache, pos, window)
        return x, new_cache

    if cfg.attn_type == "hymba":
        # heterogeneous ring-buffer capacities -> unrolled per-layer loop
        # (decode blocks are small; 32 unrolled bodies compile fine)
        new_cache = []
        for l in range(cfg.n_layers):
            p_l = jax.tree.map(lambda a, l=l: a[l], params["layers"])
            x, nc = _decode_block(cfg, sh, p_l, x, cache[l], pos, None)
            new_cache.append(nc)
        new_cache = tuple(new_cache)
    elif windows is None:
        def body_nw(x, xs):
            p, cache = xs
            x, new_cache = _decode_block(cfg, sh, p, x, cache, pos, None)
            return x, new_cache
        x, new_cache = jax.lax.scan(body_nw, x, (params["layers"], cache),
                                    unroll=_unroll(cfg))
    else:
        x, new_cache = jax.lax.scan(body, x,
                                    (params["layers"], cache, windows),
                                    unroll=_unroll(cfg))
    logits = layers.lm_logits(cfg, params, x, sh)[:, 0]
    return logits, new_cache, pos + 1
