"""AdamW in plain JAX (no optax dependency), pytree-native.

Moments are fp32 regardless of param dtype (bf16 params + fp32 moments is
the standard large-scale recipe); the dry-run memory analysis accounts
them. ZeRO-1 sharding happens at the pjit level: moment pytrees get the
same PartitionSpecs as their params *plus* the "data" axis on the largest
dim where divisible (launch.shardings.zero1_specs).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    mu: dict
    nu: dict
    step: jax.Array


def init_opt_state(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params),
                    step=jnp.zeros((), jnp.int32))


def warmup_cosine(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / max(1, cfg.warmup_steps)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr_peak * jnp.where(s < cfg.warmup_steps, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = warmup_cosine(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * gf
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(gf)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu,
                        is_leaf=lambda x: isinstance(x, jax.Array))
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(new_mu, new_nu, step), \
        {"lr": lr, "grad_norm": gnorm}
