"""Int8 gradient compression for the cross-pod gradient reduction.

ICI links inside a pod are fast (~50 GB/s/link); the pod<->pod hop is the
scarce resource at 512+ chips. The standard distributed-optimization trick:
all-reduce *within* the pod in bf16, then quantize to int8 with per-block
scales for the cross-pod exchange — 2x less DCN traffic at <0.5% relative
error (stochastic rounding keeps it unbiased in expectation).

Used by launch.train when the mesh has a "pod" axis and
``--grad-compression`` is on; the compression error is benchmarked in
tests/test_optim.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def compress_int8(x: jax.Array, key: jax.Array | None = None):
    """x (any shape, float) -> (q int8 [N], scale f32 [N/BLOCK], meta)."""
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    y = blocks / scale
    if key is not None:  # stochastic rounding (unbiased)
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    q = jnp.clip(y, -127, 127).astype(jnp.int8)
    return q, scale[:, 0], (shape, n)


def decompress_int8(q: jax.Array, scale: jax.Array, meta) -> jax.Array:
    shape, n = meta
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)


def compressed_psum_spec(grads, axis_name: str, key: jax.Array):
    """psum grads over ``axis_name`` with int8 wire format (for use inside
    shard_map): quantize -> psum int32 -> dequantize. Scales are reduced
    with pmax so the shared scale bounds every participant's values."""
    def one(g, k):
        q, scale, meta = compress_int8(g, k)
        # int8 (+ per-block f32 scales) on the wire: with P pods an
        # all-gather moves (P-1)/P bytes/elem vs ~2x4 bytes/elem for a ring
        # all-reduce in f32 — ~8x less DCN traffic at P=2.
        qs = jax.lax.all_gather(q, axis_name)
        ss = jax.lax.all_gather(scale, axis_name)
        shape, n = meta
        summed = jnp.sum(qs.astype(jnp.float32) * ss[..., None], axis=0)
        return summed.reshape(-1)[:n].reshape(shape)

    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = [one(g, k) for g, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)
