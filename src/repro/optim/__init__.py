"""Optimizer substrate: AdamW with ZeRO-1-sharded moments, global-norm
clipping, warmup-cosine schedules, and optional int8 gradient compression
for the slow cross-pod all-reduce."""
from .adamw import (AdamWConfig, init_opt_state, adamw_update,
                    warmup_cosine, clip_by_global_norm)
from .compress import compress_int8, decompress_int8, compressed_psum_spec

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "warmup_cosine",
           "clip_by_global_norm", "compress_int8", "decompress_int8",
           "compressed_psum_spec"]
