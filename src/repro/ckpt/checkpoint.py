"""Atomic, async, mesh-agnostic checkpointing.

Fault-tolerance contract (DESIGN.md §4):
  * **Atomic**: writes go to ``step_K.tmp/`` then os.rename — a crash
    mid-write never corrupts the latest checkpoint.
  * **Async**: the host thread snapshots device arrays (device_get) and a
    background thread serializes, so the train loop overlaps I/O with the
    next steps (bounded queue of 1 — backpressure instead of OOM).
  * **Mesh-agnostic / elastic**: arrays are stored as full (unsharded)
    host arrays keyed by pytree path, so a restart may use a *different*
    mesh shape or device count (elastic rescale) — pjit reshards on the
    first step after restore.
  * **Auto-resume**: ``latest_step`` scans the directory; the train driver
    restarts from the newest complete checkpoint after any failure
    (simulated-failure integration test: tests/test_checkpoint.py).

Format: one .npz per checkpoint (flattened path->array) + a json manifest
with step, config fingerprint, and data-pipeline cursor.
"""
from __future__ import annotations

import json
import os
import queue
import threading

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz can't store ml_dtypes;
            arr = arr.astype(np.float32)   # load_checkpoint casts back
        flat[key] = arr
    return flat


def _unflatten(template, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, tree, extra: dict | None = None
                    ) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step:08d}.tmp")
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, **(extra or {})}, f)
    if os.path.exists(final):
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    return max(steps) if steps else None


def load_checkpoint(directory: str, template, step: int | None = None):
    """Returns (tree_like_template, manifest). ``template`` provides the
    pytree structure and target dtypes (arrays may reshard afterwards)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    return _unflatten(template, flat), manifest


class CheckpointManager:
    """Async writer with a depth-1 queue + retention policy."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._error: BaseException | None = None

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree, extra = item
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:   # surfaced on next save()/close()
                self._error = e

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        import shutil
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def save(self, step: int, tree, extra: dict | None = None):
        if self._error:
            raise self._error
        host_tree = jax.device_get(tree)   # snapshot before enqueue
        self._q.put((step, host_tree, extra))

    def close(self):
        self._q.put(None)
        self._worker.join(timeout=60)
        if self._error:
            raise self._error
