"""Checkpointing + fault tolerance."""
from .checkpoint import (CheckpointManager, save_checkpoint, load_checkpoint,
                         latest_step)

__all__ = ["CheckpointManager", "save_checkpoint", "load_checkpoint",
           "latest_step"]
