"""Continuous-batching serving front-end over the HMMU session API.

The paper's §III-G placement hints exist so the *system software* above
the hybrid memory can express latency-critical pages. This package is
that system software at serving scale: a request scheduler that drives
``repro.Engine`` with the page-access streams of 100k+ concurrent
decoding sequences, under the disciplines real serving stacks impose —

* **admission control** (``max_live_seqs`` live-sequence cap plus a
  ``max_live_batches`` cap on in-flight device dispatches),
* **bucketed batch sizes with padded dispatch** (``BucketSpec`` —
  ``sorted_batch_sizes`` / ``get_padded_batch_size`` selection, so every
  dispatch hits a pre-compiled shape in the Engine's entry cache),
* **per-sequence pin contracts** stamped at admission and released at
  completion (``contracts`` — the FLAGS-lane lifecycle, batched and
  traced so a 100k-sequence session never syncs the host per page),
* **eviction of cold KV pages under memory pressure** (``PagedKVMap`` —
  vectorized page bookkeeping with LRU eviction watermarks).

The dispatch path overlaps host-side batch assembly with the in-flight
device step: dispatches are asynchronous, results are harvested lazily
(at most ``max_live_batches`` outstanding), and scheduling decisions
never depend on device results — so the host assembles batch ``k+1``
while the device emulates batch ``k``, and a scheduled run is bitwise
identical to the same request stream replayed serially
(tests/test_serve.py).
"""
from .buckets import BucketSpec
from .contracts import release_pin_pages, stamp_pin_pages
from .kv import PagedKVMap
from .scheduler import ContinuousBatchingScheduler, ServeConfig, ServeReport

__all__ = [
    "BucketSpec",
    "ContinuousBatchingScheduler",
    "PagedKVMap",
    "ServeConfig",
    "ServeReport",
    "release_pin_pages",
    "stamp_pin_pages",
]
