"""§III-G pin-contract lifecycle, batched and traced.

A pin contract nails a latency-critical KV page to the tier it actually
occupies: ``PIN_FAST`` below the tier boundary, ``PIN_SLOW`` where the
allocation spilled. The bit must agree with the page's *current* DEVICE
lane — not its id-boundary tier (migration may have moved a recycled
page since init) — and, when the page is a member of the DMA engine's
in-flight swap, with the tier that swap commits it to (``page_a``
promotes to FAST, ``page_b`` demotes to SLOW; ``dma.maybe_complete``
commits unconditionally, so pinning the pre-swap tier would break the
pin<->DEVICE invariant one chunk later).

The stamp and release here are **traced, batched device ops**: they read
the DEVICE lane and the swap membership inside the program, so stamping
a whole admission batch costs one queued table update — no host sync per
page — and composes with the scheduler's async dispatch pipeline (the
FLAGS writes are ordered against the dispatches by the carried state).
Padding lanes use an out-of-range sentinel page and are dropped by the
scatter, so one compiled program serves every admission-batch size up to
the pad width.

``repro.memtier.TieredKVAccounting`` stamps through the same helpers
(width-1 batches), so the serving scheduler and the model-coupled
serving engine share one pin-semantics implementation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import FAST, SLOW
from repro.core import table as table_lib
from repro.core.emulator import EmulatorState


@functools.partial(jax.jit, static_argnames=("n_pages",), donate_argnums=(0,))
def _stamp(table, active, page_a, page_b, pages, live, *, n_pages):
    dev = table_lib.device_at(table, jnp.clip(pages, 0, n_pages - 1))
    in_swap_a = (active != 0) & (pages == page_a)
    in_swap_b = (active != 0) & (pages == page_b)
    dev = jnp.where(in_swap_a, FAST, jnp.where(in_swap_b, SLOW, dev))
    bit = jnp.where(dev == FAST, table_lib.PIN_FAST, table_lib.PIN_SLOW)
    cur = table_lib.flags_at(table, jnp.clip(pages, 0, n_pages - 1))
    # Never pin a page whose frame is dying or dead: a pin on a POISONED
    # page would both violate the table invariant and veto its own
    # rescue. The scheduler re-places such contracts on healthy pages.
    healthy = (cur & (table_lib.POISONED | table_lib.RETIRED)) == 0
    bit = jnp.where(live & healthy, bit, 0).astype(jnp.int32)
    idx = jnp.where(live & healthy, pages, n_pages)  # sentinel rows drop
    return table_lib.store_flags(table, idx, cur | bit)


@functools.partial(jax.jit, static_argnames=("n_pages",), donate_argnums=(0,))
def _release(table, pages, live, *, n_pages):
    idx = jnp.where(live, pages, n_pages)
    cur = table_lib.flags_at(table, jnp.clip(pages, 0, n_pages - 1))
    return table_lib.store_flags(table, idx,
                                 cur & ~jnp.int32(table_lib.PINNED))


def _pad(pages, width: int):
    pages = jnp.asarray(pages, jnp.int32).reshape(-1)
    n = pages.shape[0]
    if width < n:
        raise ValueError(f"{n} contract pages exceed the pad width {width}")
    live = jnp.arange(width) < n
    return jnp.pad(pages, (0, width - n)), live


def stamp_pin_pages(state: EmulatorState, pages, *,
                    width: int | None = None) -> EmulatorState:
    """Stamp pin contracts on ``pages`` (device-accurate, swap-aware).

    ``width`` pads the batch to a fixed shape so a scheduler admitting a
    variable number of sequences per step reuses one compiled stamp
    program; None traces at the batch's own length. The carried table is
    donated — the passed-in state is consumed, like ``Engine.run``.
    """
    n_pages = state.table.shape[0]
    pages, live = _pad(pages, width if width is not None else len(pages))
    table = _stamp(state.table, state.dma.active, state.dma.page_a,
                   state.dma.page_b, pages, live, n_pages=n_pages)
    return state._replace(table=table)


def release_pin_pages(state: EmulatorState, pages, *,
                      width: int | None = None) -> EmulatorState:
    """Clear the pin contracts of ``pages`` (both pin bits — release is
    tier-agnostic). Same padding/donation contract as
    :func:`stamp_pin_pages`."""
    n_pages = state.table.shape[0]
    pages, live = _pad(pages, width if width is not None else len(pages))
    table = _release(state.table, pages, live, n_pages=n_pages)
    return state._replace(table=table)
