"""Bucketed batch sizes for padded dispatch.

A serving scheduler must never present the compiler with a novel shape:
every dispatched trace length comes from a small, sorted bucket list so
each (length, carried-state) pair hits a pre-compiled executable in the
Engine's unified entry cache. This is the saxml servable-model shape
discipline (``sorted_batch_sizes`` / ``get_padded_batch_size``) applied
to request-stream dispatch: steady-state dispatches take the largest
bucket that is already full (no padding, the remainder carries to the
next step, exactly like ``Engine.run_stream``'s sub-chunk carry), and
drain dispatches pad the tail up to the smallest covering bucket with an
invalid-lane mask — the mask is a traced argument, so a padded dispatch
reuses the same executable as a full one.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """A validated, ascending list of allowed dispatch sizes (requests).

    Every bucket must be a positive multiple of ``chunk`` so a dispatch
    is always a whole number of pipeline chunks (an all-valid
    chunk-multiple dispatch is bitwise-equivalent to the same requests
    flowing through ``Engine.run_stream``, regardless of where the
    dispatch boundaries fall).
    """

    sorted_batch_sizes: tuple[int, ...]
    chunk: int

    def __post_init__(self):
        sizes = tuple(int(s) for s in self.sorted_batch_sizes)
        if not sizes:
            raise ValueError("need at least one batch size")
        if list(sizes) != sorted(set(sizes)):
            raise ValueError(
                f"batch sizes must be strictly ascending: {sizes}")
        for s in sizes:
            if s <= 0 or s % self.chunk:
                raise ValueError(
                    f"batch size {s} is not a positive multiple of the "
                    f"pipeline chunk ({self.chunk})")
        object.__setattr__(self, "sorted_batch_sizes", sizes)

    @property
    def min_size(self) -> int:
        return self.sorted_batch_sizes[0]

    @property
    def max_size(self) -> int:
        return self.sorted_batch_sizes[-1]

    def get_padded_batch_size(self, n: int) -> int:
        """The smallest bucket that fits ``n`` requests (pad-up
        selection, for drain/flush dispatches). ``n`` above the largest
        bucket is a caller bug — split first, then pad the tail."""
        for s in self.sorted_batch_sizes:
            if n <= s:
                return s
        raise ValueError(
            f"{n} requests exceed the largest bucket {self.max_size}; "
            "dispatch full buckets first and pad only the tail")

    def get_dispatch_size(self, n: int) -> int | None:
        """The largest bucket already filled by ``n`` pending requests
        (floor selection, for steady-state no-padding dispatches), or
        None while the backlog is still smaller than every bucket."""
        best = None
        for s in self.sorted_batch_sizes:
            if s <= n:
                best = s
        return best
