"""Vectorized KV-page bookkeeping for serving-scale sequence counts.

``repro.memtier.TieredKVAccounting`` keeps per-page Python dicts — fine
for a handful of model-coupled sequences, hopeless for 100k concurrent
ones. ``PagedKVMap`` is the same middleware role (the paper's
driver+jemalloc analogue over the flat hybrid space) rebuilt on numpy
arrays: free lists are stacks with a top pointer, the page->owner map and
the LRU clock are flat arrays, and every operation — allocation,
assignment, release, eviction — is a batched array op, so the host-side
cost of a scheduler step is O(pages touched), not O(python objects).

Eviction models the serving stack swapping cold KV pages out to host
memory under pressure: when the free pool drops below the low watermark,
the coldest unpinned pages (oldest ``last_access`` stamp, never a page
touched this step, never a contracted page) are released back to the
allocator until the high watermark is restored. A sequence whose evicted
page is needed again re-allocates it (a *refetch*, counted by the
scheduler) — with windowed attention the candidates are precisely the
pages the attention pass will never stream again, so refetches indicate
an undersized window or an overcommitted tier.
"""
from __future__ import annotations

import numpy as np

from repro.core import FAST, SLOW, EmulatorConfig

_NEVER = np.iinfo(np.int64).max


class _Stack:
    """A fixed-capacity LIFO of page numbers (vector push/pop)."""

    def __init__(self, pages: np.ndarray):
        self.buf = np.asarray(pages, np.int32).copy()
        self.top = len(self.buf)

    def __len__(self) -> int:
        return self.top

    def pop(self, k: int) -> np.ndarray:
        take = self.buf[self.top - k:self.top][::-1].copy()
        self.top -= k
        return take

    def push(self, pages: np.ndarray) -> None:
        k = len(pages)
        self.buf[self.top:self.top + k] = pages
        self.top += k


class PagedKVMap:
    """Flat-space page allocator + per-sequence page table + LRU clock."""

    def __init__(self, cfg: EmulatorConfig, max_live_seqs: int,
                 max_pages_per_seq: int, pin_pages_per_seq: int = 1,
                 free_low_frac: float = 0.02, free_high_frac: float = 0.04):
        n, nf = cfg.n_pages, cfg.n_fast_pages
        self.cfg = cfg
        self.pin_pages = pin_pages_per_seq
        # Initial-placement pools, allocation order matching
        # core.table.HybridAllocator (page 0 first).
        self._stacks = {FAST: _Stack(np.arange(nf - 1, -1, -1)),
                        SLOW: _Stack(np.arange(n - 1, nf - 1, -1))}
        self.page_of = np.full((max_live_seqs, max_pages_per_seq), -1,
                               np.int32)
        self.owner = np.full(n, -1, np.int32)      # slot owning each page
        self.owner_idx = np.full(n, -1, np.int32)  # page index within seq
        self.pinned = np.zeros(n, bool)
        self.last_access = np.full(n, _NEVER, np.int64)  # free = _NEVER
        self.low_mark = int(free_low_frac * n)
        self.high_mark = max(int(free_high_frac * n), self.low_mark + 1)
        self.evictions = 0

    @property
    def free_total(self) -> int:
        return len(self._stacks[FAST]) + len(self._stacks[SLOW])

    @property
    def free_pages(self) -> dict[int, int]:
        return {d: len(s) for d, s in self._stacks.items()}

    def alloc(self, k: int, hint: int = FAST) -> np.ndarray:
        """Allocate ``k`` pages preferring the hinted tier's initial
        placement, spilling to the other (§III-G best-effort hints)."""
        if k == 0:
            return np.empty(0, np.int32)
        other = SLOW if hint == FAST else FAST
        a = min(k, len(self._stacks[hint]))
        if k - a > len(self._stacks[other]):
            raise MemoryError(
                f"out of hybrid memory: want {k} pages, "
                f"free {self.free_total} (eviction exhausted?)")
        pages = self._stacks[hint].pop(a)
        if k > a:
            pages = np.concatenate([pages, self._stacks[other].pop(k - a)])
        return pages

    def assign(self, slots: np.ndarray, idx: np.ndarray,
               pages: np.ndarray, step: int) -> None:
        """Record ``pages`` as page ``idx`` of sequence slot ``slots``."""
        self.page_of[slots, idx] = pages
        self.owner[pages] = slots
        self.owner_idx[pages] = idx
        self.pinned[pages] = idx < self.pin_pages
        self.last_access[pages] = step

    def touch(self, pages: np.ndarray, step: int) -> None:
        self.last_access[pages] = step

    def release_slots(self, slots: np.ndarray
                      ) -> tuple[np.ndarray, np.ndarray]:
        """Free every page of the given sequence slots. Returns
        ``(all_pages, contracted_pages)`` — the latter still carry pin
        bits in the emulated table and must be released there too."""
        rows = self.page_of[slots]                       # [k, max_pages]
        pages = rows[rows >= 0]
        pinned = pages[self.pinned[pages]]
        self.page_of[slots] = -1
        self._free(pages)
        return pages, pinned

    def _free(self, pages: np.ndarray) -> None:
        if len(pages) == 0:
            return
        self.owner[pages] = -1
        self.owner_idx[pages] = -1
        self.pinned[pages] = False
        self.last_access[pages] = _NEVER
        nf = self.cfg.n_fast_pages
        fast = pages[pages < nf]
        if len(fast):
            self._stacks[FAST].push(fast)
        slow = pages[pages >= nf]
        if len(slow):
            self._stacks[SLOW].push(slow)

    def evictable(self, step: int) -> int:
        """Pages eviction could reclaim right now: allocated, unpinned,
        and not touched this step."""
        return int(((self.owner >= 0) & ~self.pinned
                    & (self.last_access < step)).sum())

    def maybe_evict(self, step: int, extra_needed: int = 0) -> np.ndarray:
        """Evict cold pages when free pages dip under the low watermark
        (plus any immediately-needed allocation). Victims are the oldest
        unpinned allocated pages not touched this step; eviction stops at
        the high watermark or when candidates run out. Returns the
        evicted pages (their owners' ``page_of`` entries become -1)."""
        want_free = self.low_mark + extra_needed
        if self.free_total >= want_free:
            return np.empty(0, np.int32)
        target = max(self.high_mark + extra_needed - self.free_total, 0)
        cand = (self.owner >= 0) & ~self.pinned & (self.last_access < step)
        n_cand = int(cand.sum())
        k = min(target, n_cand)
        if k == 0:
            return np.empty(0, np.int32)
        age = np.where(cand, self.last_access, _NEVER)
        victims = np.argpartition(age, k - 1)[:k].astype(np.int32)
        self.page_of[self.owner[victims], self.owner_idx[victims]] = -1
        self._free(victims)
        self.evictions += k
        return victims
