"""Vectorized KV-page bookkeeping for serving-scale sequence counts.

``repro.memtier.TieredKVAccounting`` keeps per-page Python dicts — fine
for a handful of model-coupled sequences, hopeless for 100k concurrent
ones. ``PagedKVMap`` is the same middleware role (the paper's
driver+jemalloc analogue over the flat hybrid space) rebuilt on numpy
arrays: free lists are stacks with a top pointer, the page->owner map and
the LRU clock are flat arrays, and every operation — allocation,
assignment, release, eviction — is a batched array op, so the host-side
cost of a scheduler step is O(pages touched), not O(python objects).

Eviction models the serving stack swapping cold KV pages out to host
memory under pressure: when the free pool drops below the low watermark,
the coldest unpinned pages (oldest ``last_access`` stamp, never a page
touched this step, never a contracted page, never a page referenced by a
built-but-undispatched request — the ``protected`` set) are released
back to the allocator until the high watermark is restored. A sequence
whose evicted page is needed again re-allocates it (a *refetch*, counted
by the scheduler) — with windowed attention the candidates are precisely
the pages the attention pass will never stream again, so refetches
indicate an undersized window or an overcommitted tier.

Endurance retirement: :meth:`PagedKVMap.retire_pages` takes pages the
emulator reported dead (a retired frame's tombstone and its rescued
counterpart — the serving layer conservatively kills both) permanently
out of circulation. Dead pages are compacted out of the free stacks
eagerly and ``_free`` silently drops them, so a retired page id is never
handed out again; live owners are detached so the next access refetches
onto a healthy page.
"""
from __future__ import annotations

import numpy as np

from repro.core import FAST, SLOW, EmulatorConfig

_NEVER = np.iinfo(np.int64).max


class _Stack:
    """A fixed-capacity LIFO of page numbers (vector push/pop)."""

    def __init__(self, pages: np.ndarray):
        self.buf = np.asarray(pages, np.int32).copy()
        self.top = len(self.buf)

    def __len__(self) -> int:
        return self.top

    def pop(self, k: int) -> np.ndarray:
        take = self.buf[self.top - k:self.top][::-1].copy()
        self.top -= k
        return take

    def push(self, pages: np.ndarray) -> None:
        k = len(pages)
        self.buf[self.top:self.top + k] = pages
        self.top += k


class PagedKVMap:
    """Flat-space page allocator + per-sequence page table + LRU clock."""

    def __init__(self, cfg: EmulatorConfig, max_live_seqs: int,
                 max_pages_per_seq: int, pin_pages_per_seq: int = 1,
                 free_low_frac: float = 0.02, free_high_frac: float = 0.04):
        n, nf = cfg.n_pages, cfg.n_fast_pages
        self.cfg = cfg
        self.pin_pages = pin_pages_per_seq
        # Initial-placement pools, allocation order matching
        # core.table.HybridAllocator (page 0 first).
        self._stacks = {FAST: _Stack(np.arange(nf - 1, -1, -1)),
                        SLOW: _Stack(np.arange(n - 1, nf - 1, -1))}
        self.page_of = np.full((max_live_seqs, max_pages_per_seq), -1,
                               np.int32)
        self.owner = np.full(n, -1, np.int32)      # slot owning each page
        self.owner_idx = np.full(n, -1, np.int32)  # page index within seq
        self.pinned = np.zeros(n, bool)
        self.dead = np.zeros(n, bool)                    # retired frames
        self.last_access = np.full(n, _NEVER, np.int64)  # free = _NEVER
        self.low_mark = int(free_low_frac * n)
        self.high_mark = max(int(free_high_frac * n), self.low_mark + 1)
        self.evictions = 0
        self.retired = 0

    @property
    def free_total(self) -> int:
        return len(self._stacks[FAST]) + len(self._stacks[SLOW])

    @property
    def free_pages(self) -> dict[int, int]:
        return {d: len(s) for d, s in self._stacks.items()}

    def alloc(self, k: int, hint: int = FAST) -> np.ndarray:
        """Allocate ``k`` pages preferring the hinted tier's initial
        placement, spilling to the other (§III-G best-effort hints)."""
        if k == 0:
            return np.empty(0, np.int32)
        other = SLOW if hint == FAST else FAST
        a = min(k, len(self._stacks[hint]))
        if k - a > len(self._stacks[other]):
            raise MemoryError(
                f"out of hybrid memory: want {k} pages, "
                f"free {self.free_total} (eviction exhausted?)")
        pages = self._stacks[hint].pop(a)
        if k > a:
            pages = np.concatenate([pages, self._stacks[other].pop(k - a)])
        return pages

    def assign(self, slots: np.ndarray, idx: np.ndarray,
               pages: np.ndarray, step: int) -> None:
        """Record ``pages`` as page ``idx`` of sequence slot ``slots``."""
        self.page_of[slots, idx] = pages
        self.owner[pages] = slots
        self.owner_idx[pages] = idx
        self.pinned[pages] = idx < self.pin_pages
        self.last_access[pages] = step

    def touch(self, pages: np.ndarray, step: int) -> None:
        self.last_access[pages] = step

    def release_slots(self, slots: np.ndarray
                      ) -> tuple[np.ndarray, np.ndarray]:
        """Free every page of the given sequence slots. Returns
        ``(all_pages, contracted_pages)`` — the latter still carry pin
        bits in the emulated table and must be released there too."""
        rows = self.page_of[slots]                       # [k, max_pages]
        pages = rows[rows >= 0]
        pinned = pages[self.pinned[pages]]
        self.page_of[slots] = -1
        self._free(pages)
        return pages, pinned

    def retire_pages(self, pages: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Take ``pages`` permanently out of circulation (their emulated
        frames died). Free-stack copies are compacted away; live owners
        are detached (their ``page_of`` entry becomes -1, triggering a
        refetch on next access). Returns ``(live, slots, idxs)`` — the
        subset that was owned when it died, with each page's owning slot
        and page index, so the scheduler can re-place contract pages."""
        pages = np.asarray(pages, np.int32).reshape(-1)
        pages = np.unique(pages[pages >= 0])
        pages = pages[~self.dead[pages]]
        if len(pages) == 0:
            e = np.empty(0, np.int32)
            return e, e, e
        self.dead[pages] = True
        self.retired += len(pages)
        for s in self._stacks.values():
            keep = s.buf[:s.top][~self.dead[s.buf[:s.top]]]
            s.buf[:len(keep)] = keep
            s.top = len(keep)
        live = pages[self.owner[pages] >= 0]
        slots = self.owner[live].copy()
        idxs = self.owner_idx[live].copy()
        self.page_of[slots, idxs] = -1
        self.owner[live] = -1
        self.owner_idx[live] = -1
        self.pinned[live] = False
        self.last_access[pages] = _NEVER
        return live, slots, idxs

    def _free(self, pages: np.ndarray) -> None:
        pages = pages[~self.dead[pages]]   # retired frames never return
        if len(pages) == 0:
            return
        self.owner[pages] = -1
        self.owner_idx[pages] = -1
        self.pinned[pages] = False
        self.last_access[pages] = _NEVER
        nf = self.cfg.n_fast_pages
        fast = pages[pages < nf]
        if len(fast):
            self._stacks[FAST].push(fast)
        slow = pages[pages >= nf]
        if len(slow):
            self._stacks[SLOW].push(slow)

    def _evict_cand(self, step: int,
                    protected: np.ndarray | None) -> np.ndarray:
        cand = (self.owner >= 0) & ~self.pinned & (self.last_access < step)
        if protected is not None and len(protected):
            cand[protected] = False
        return cand

    def evictable(self, step: int,
                  protected: np.ndarray | None = None) -> int:
        """Pages eviction could reclaim right now: allocated, unpinned,
        not touched this step, and not in the ``protected`` set."""
        return int(self._evict_cand(step, protected).sum())

    def maybe_evict(self, step: int, extra_needed: int = 0,
                    protected: np.ndarray | None = None) -> np.ndarray:
        """Evict cold pages when free pages dip under the low watermark
        (plus any immediately-needed allocation). Victims are the oldest
        unpinned allocated pages not touched this step and not in
        ``protected`` (pages referenced by built-but-undispatched
        requests — evicting one would recycle a page id an already-built
        trace still names); eviction stops at the high watermark or when
        candidates run out. Returns the evicted pages (their owners'
        ``page_of`` entries become -1)."""
        want_free = self.low_mark + extra_needed
        if self.free_total >= want_free:
            return np.empty(0, np.int32)
        target = max(self.high_mark + extra_needed - self.free_total, 0)
        cand = self._evict_cand(step, protected)
        n_cand = int(cand.sum())
        k = min(target, n_cand)
        if k == 0:
            return np.empty(0, np.int32)
        age = np.where(cand, self.last_access, _NEVER)
        victims = np.argpartition(age, k - 1)[:k].astype(np.int32)
        self.page_of[self.owner[victims], self.owner_idx[victims]] = -1
        self._free(victims)
        self.evictions += k
        return victims
