"""Continuous-batching scheduler over the HMMU session API.

The scheduler plays the role the serving stack plays above the paper's
platform: it turns a population of concurrent sequence requests (each a
prompt prefill followed by windowed decode steps over its KV pages) into
the page-access stream the hybrid-memory emulator consumes, under the
disciplines real serving systems impose — admission control, bucketed
batch shapes, pin contracts, and eviction under memory pressure.

Design rules that make it scale to 100k+ live sequences on one host:

* **Host state is flat numpy** — slot tables, the page map
  (``PagedKVMap``), and the request buffer are arrays; a scheduling step
  is a handful of vectorized ops, never a Python loop over sequences.
* **Every dispatch shape is pre-compiled** — trace lengths come from
  ``BucketSpec`` (steady-state floor selection carries the remainder;
  drain pads the tail up to the smallest covering bucket with an
  invalid-lane mask), and :meth:`ContinuousBatchingScheduler.warmup`
  compiles every bucket up front, so ``Engine.compile_count`` stays flat
  for the whole serving run.
* **Scheduling never reads device results** — completion is decided by
  host-side decode counters, so dispatches stay asynchronous: at most
  ``max_live_batches`` un-harvested dispatches are in flight, and the
  host assembles batch ``k+1`` while the device emulates batch ``k``.
  Because the emulation is one pure scan over chunks, the scheduled run
  is bitwise identical to the same request stream replayed serially
  through ``Engine.run_stream`` — overlap depth changes wall-clock only.
* **Pin contracts are batched device ops** — stamped at admission and
  released at completion through ``serve.contracts`` at fixed pad
  widths, so the FLAGS lifecycle of a variable-size admission batch
  reuses one compiled program and never syncs the host.

Graceful degradation under faults: when a :class:`~repro.core.faults.
FaultPlan` rides along (``ServeConfig.faults``, threaded into every
dispatch — event chunk indices are absolute, so one plan spans the whole
run), harvest feeds recovery. Pages the emulator retired (the tombstone
parked on the dead frame and its rescued swap partner — both
conservatively dropped) leave circulation via ``PagedKVMap.
retire_pages``; dead *contract* pages are re-placed and re-stamped
immediately; transiently-faulted KV pages are invalidated so their
owners refetch. Contracts stranded off the fast tier (admission spills
or post-death re-placements) sit in a renegotiation queue and re-pin to
DRAM as fast pages free, so a retirement burst dents the pinned
fast-hit rate only transiently.

Latency accounting: each sequence's end-to-end latency is the emulated
span from its first prefill request issuing to its last decode request
returning (``returns - latency`` of the first request vs ``returns`` of
the last, folded per sequence with ``np.minimum.at`` / ``np.maximum.at``
at harvest). Cycles are reported as microseconds at the paper's 1 GHz
fabric clock (1 cycle = 1 ns).
"""
from __future__ import annotations

import collections
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import FAST, SLOW
from repro.core.emulator import Trace
from repro.engine import Engine

from .buckets import BucketSpec
from .contracts import release_pin_pages, stamp_pin_pages
from .kv import PagedKVMap

_FIELDS = ("page", "offset", "is_write", "size", "rid", "pinned")
_LINE = 64
_LINES_PER_PAGE = 64


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of the serving front-end (see README "Serving")."""

    sorted_batch_sizes: tuple[int, ...]   # allowed dispatch sizes (requests)
    max_live_seqs: int                    # admission cap on live sequences
    max_live_batches: int = 2             # un-harvested dispatches in flight
    max_admit_per_step: int = 1024        # admissions per scheduling step
    pin_pages_per_seq: int = 1            # leading pages pinned per sequence
    max_pages_per_seq: int = 8            # KV growth cap per sequence
    positions_per_page: int = 64          # decode tokens per KV page
    window_pages: int = 2                 # attention window (pages read/token)
    prefill_writes_per_page: int = 4      # prefill burst per prompt page
    free_low_frac: float = 0.02           # eviction low watermark (of pages)
    free_high_frac: float = 0.04          # eviction high watermark
    slo_latency_us: float = 100_000.0     # per-sequence latency SLO
    pinned_slo: float = 0.90              # pinned fast-hit-rate SLO
    record_traces: bool = False           # keep host copies for replay tests
    faults: object = None                 # FaultPlan injected every dispatch


@dataclasses.dataclass(frozen=True)
class ServeReport:
    """SLO-facing summary of one serving run."""

    n_sequences: int
    n_mem_requests: int
    n_dispatches: int
    n_steps: int
    p50_latency_us: float
    p99_latency_us: float
    mean_latency_us: float
    slo_latency_us: float
    slo_attainment: float        # fraction of sequences within the SLO
    pinned_accesses: int
    pinned_fast_hit_rate: float  # 0.0 when nothing was pinned
    pinned_slo: float
    pinned_slo_met: bool
    evictions: int
    refetches: int
    inflight_high_water: int
    live_seqs_high_water: int
    compile_count: int
    per_bucket: dict             # size -> dispatches/requests/service stats
    frames_retired: int = 0      # pages killed by endurance retirement
    fault_refetches: int = 0     # refetches forced by faults/retirement
    renegotiations: int = 0      # contracts re-pinned to the fast tier

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class _ReqBuf:
    """FIFO of pending memory requests (struct-of-arrays, chunked)."""

    def __init__(self):
        self._parts: collections.deque[dict] = collections.deque()
        self.n = 0

    def append(self, part: dict) -> None:
        if len(part["page"]):
            self._parts.append(part)
            self.n += len(part["page"])

    def pop(self, d: int) -> dict:
        take: dict[str, list] = {f: [] for f in _FIELDS}
        got = 0
        while got < d:
            p = self._parts[0]
            k = len(p["page"])
            if k <= d - got:
                self._parts.popleft()
                for f in _FIELDS:
                    take[f].append(p[f])
                got += k
            else:
                need = d - got
                for f in _FIELDS:
                    take[f].append(p[f][:need])
                    p[f] = p[f][need:]
                got = d
        self.n -= d
        return {f: np.concatenate(v) if len(v) > 1 else v[0]
                for f, v in take.items()}


class _SlotStack:
    """LIFO of free sequence slots (slot 0 handed out first)."""

    def __init__(self, n: int):
        self.buf = np.arange(n - 1, -1, -1, dtype=np.int64)
        self.top = n

    def __len__(self):
        return self.top

    def pop(self, k: int) -> np.ndarray:
        take = self.buf[self.top - k:self.top][::-1].copy()
        self.top -= k
        return take

    def push(self, slots: np.ndarray) -> None:
        k = len(slots)
        self.buf[self.top:self.top + k] = slots
        self.top += k


class _Inflight:
    __slots__ = ("outs", "rid", "pinned", "pages", "n_valid", "size")

    def __init__(self, outs, rid, pinned, pages, n_valid, size):
        self.outs, self.rid, self.pinned = outs, rid, pinned
        self.pages, self.n_valid, self.size = pages, n_valid, size


class ContinuousBatchingScheduler:
    """Drive an :class:`~repro.Engine` with a continuous-batching
    request stream. ``submit`` sequences, then ``run()`` to completion
    (or ``step()``/``flush()`` manually), then ``report()``."""

    def __init__(self, engine: Engine, cfg: ServeConfig):
        self.engine = engine
        self.cfg = cfg
        self.buckets = BucketSpec(cfg.sorted_batch_sizes, engine.cfg.chunk)
        self.kv = PagedKVMap(engine.cfg, cfg.max_live_seqs,
                             cfg.max_pages_per_seq, cfg.pin_pages_per_seq,
                             cfg.free_low_frac, cfg.free_high_frac)
        self.carry = engine.init_state()
        n = cfg.max_live_seqs
        self._free_slots = _SlotStack(n)
        self._slot_rid = np.full(n, -1, np.int64)
        self._slot_pages = np.zeros(n, np.int32)
        self._slot_tokens = np.zeros(n, np.int32)
        self._slot_left = np.zeros(n, np.int32)
        # FIFO arrival queue (rid == index into the per-sequence arrays).
        self._q_prompt = np.empty(0, np.int32)
        self._q_decode = np.empty(0, np.int32)
        self._q_head = 0
        self._first_issue = np.empty(0, np.int64)
        self._last_return = np.empty(0, np.int64)
        self._pending = _ReqBuf()
        self._inflight: collections.deque[_Inflight] = collections.deque()
        self._release_q: collections.deque = collections.deque()
        # Contracts pinned off the fast tier (spilled at admission, or
        # re-placed after a frame death landed them slow): (slot, idx,
        # rid), re-pinned to DRAM as fast pages free up.
        self._reneg: collections.deque = collections.deque()
        self._stamp_width = cfg.max_admit_per_step * cfg.pin_pages_per_seq
        self._rr = 0                  # round-robin service pointer
        self._step_no = 0
        self._built = 0               # requests appended to pending, ever
        self._dispatched = 0          # valid requests dispatched, ever
        self._n_decoding = 0          # live slots with decode work left
        self._n_occupied = 0
        self.refetches = 0
        self.fault_refetches = 0
        self.renegotiations = 0
        self._buckets_stats: dict[int, dict] = {}
        self.dispatch_log: list[tuple[int, int]] = []
        self.inflight_high_water = 0
        self.live_seqs_high_water = 0
        self.trace_log: list[Trace] = []    # valid requests only (record)
        self.outs_log: list[dict] = []      # harvested outs (record)

    # ------------------------------------------------------------------
    # request intake
    # ------------------------------------------------------------------
    def submit(self, prompt_pages, decode_tokens) -> np.ndarray:
        """Enqueue sequences (FIFO). ``prompt_pages[i]`` KV pages are
        prefilled at admission; ``decode_tokens[i]`` decode steps follow.
        Returns the assigned request ids."""
        pp = np.asarray(prompt_pages, np.int32).reshape(-1)
        dt = np.asarray(decode_tokens, np.int32).reshape(-1)
        if pp.shape != dt.shape:
            raise ValueError("prompt_pages and decode_tokens must match")
        floor = max(1, self.cfg.pin_pages_per_seq)
        if len(pp) and (int(pp.min()) < floor or int(dt.min()) < 1):
            raise ValueError(
                f"need prompt_pages >= {floor} (the pinned prefix) and "
                "decode_tokens >= 1 per sequence")
        if len(pp) and int(pp.max()) > self.cfg.max_pages_per_seq:
            raise ValueError("prompt exceeds max_pages_per_seq")
        rid0 = len(self._first_issue)
        self._q_prompt = np.concatenate([self._q_prompt, pp])
        self._q_decode = np.concatenate([self._q_decode, dt])
        k = len(pp)
        self._first_issue = np.concatenate(
            [self._first_issue, np.full(k, np.iinfo(np.int64).max)])
        self._last_return = np.concatenate(
            [self._last_return, np.full(k, -1, np.int64)])
        return np.arange(rid0, rid0 + k, dtype=np.int64)

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------
    def warmup(self) -> None:
        """Compile every bucket entry (and the contract programs) against
        a throwaway state, so ``Engine.compile_count`` is flat across the
        real run. The serving state is untouched."""
        st = self.engine.init_state()
        for s in self.buckets.sorted_batch_sizes:
            z = jnp.zeros(s, jnp.int32)
            tr = Trace(page=z, offset=z, is_write=jnp.zeros(s, bool),
                       size=jnp.full(s, _LINE, jnp.int32))
            st = self.engine.run(tr, state=st,
                                 faults=self.cfg.faults).state
        if self.cfg.pin_pages_per_seq:
            w = self._stamp_width
            st = stamp_pin_pages(st, np.zeros(0, np.int32), width=w)
            st = release_pin_pages(st, np.zeros(0, np.int32), width=w)
        jnp.asarray(st.clock).block_until_ready()

    def step(self) -> int:
        """One scheduling step: decode service, admission, dispatch.
        Returns the number of memory requests built."""
        self._step_no += 1
        self._renegotiate_contracts()
        parts: list[dict] = []
        done = self._decode(parts)
        self._admit(parts)
        built = 0
        for p in parts:
            built += len(p["page"])
            self._pending.append(p)
        self._built += built
        if len(done):
            self._release_q.append((self._built, done))
        self._dispatch_ready()
        if built == 0 and (self._q_len() or self._release_q):
            # All slots are occupied by finished-but-unflushed sequences
            # (or nothing new fit): flush the sub-bucket tail so their
            # final requests dispatch and the slots recycle.
            self._flush_pending()
        return built

    def run(self) -> None:
        """Drive every submitted sequence to completion and harvest."""
        while self._q_len() or self._n_decoding:
            self.step()
        self.flush()

    def flush(self) -> None:
        """Dispatch the padded tail, harvest everything in flight, and
        process every completion."""
        self._flush_pending()
        while self._inflight:
            self._harvest_one()
        self._process_releases()

    # -- live status ----------------------------------------------------
    @property
    def pending(self) -> bool:
        """Work remains: queued arrivals or live decoding sequences."""
        return bool(self._q_len() or self._n_decoding)

    @property
    def queued(self) -> int:
        """Sequences submitted but not yet admitted."""
        return self._q_len()

    @property
    def live_seqs(self) -> int:
        """Slots currently occupied by admitted sequences."""
        return self._n_occupied

    @property
    def dispatches(self) -> int:
        """Batches dispatched to the engine so far."""
        return len(self.dispatch_log)

    @property
    def requests_dispatched(self) -> int:
        """Valid memory requests dispatched so far."""
        return self._dispatched

    # -- fault recovery -------------------------------------------------
    def _protected_pages(self) -> np.ndarray:
        """Pages referenced by built-but-undispatched requests. They must
        not be evicted, freed, or renegotiated away: the pending trace
        already names them, and recycling a named page would hand another
        sequence's data the same address."""
        parts = [p["page"] for p in self._pending._parts]
        if not parts:
            return np.empty(0, np.int32)
        return np.concatenate(parts)

    def _renegotiate_contracts(self) -> None:
        """Re-pin contracts stranded off the fast tier (§III-G
        renegotiation): whenever fast pages free up, the oldest stranded
        contract migrates onto one — old page released and freed, new
        page stamped — so a burst of spills or frame deaths degrades the
        pinned fast-hit rate only transiently."""
        if not self._reneg:
            return
        kv = self.kv
        nf = self.engine.cfg.n_fast_pages
        w = self._stamp_width
        prot = self._protected_pages()
        deferred = []
        while self._reneg and len(kv._stacks[FAST]):
            slot, idx, rid = self._reneg.popleft()
            if self._slot_rid[slot] != rid:
                continue                 # sequence finished; moot
            old = int(kv.page_of[slot, idx])
            if old < 0 or old < nf:
                continue                 # refetch pending, or already fast
            if len(prot) and old in prot:
                deferred.append((slot, idx, rid))
                continue                 # a pending request names it
            fresh = kv.alloc(1, hint=FAST)
            if self.cfg.pin_pages_per_seq:
                self.carry = release_pin_pages(
                    self.carry, np.array([old], np.int32), width=w)
            kv.page_of[slot, idx] = -1
            kv._free(np.array([old], np.int32))
            kv.assign(np.array([slot]), np.array([idx], np.int32), fresh,
                      self._step_no)
            if self.cfg.pin_pages_per_seq:
                self.carry = stamp_pin_pages(self.carry, fresh, width=w)
            self.renegotiations += 1
        self._reneg.extendleft(reversed(deferred))

    def _replace_contracts(self, slots: np.ndarray,
                           idxs: np.ndarray) -> None:
        """Re-place contract pages whose frames died: allocate fresh
        pages (fast-tier hint), stamp new pins, and queue any slow
        spills for renegotiation. The refetched contents count as
        fault refetches."""
        k = len(slots)
        if k == 0:
            return
        self.kv.maybe_evict(self._step_no, k,
                            protected=self._protected_pages())
        fresh = self.kv.alloc(k, hint=FAST)
        self.kv.assign(slots, idxs, fresh, self._step_no)
        if self.cfg.pin_pages_per_seq:
            self.carry = stamp_pin_pages(self.carry, fresh,
                                         width=self._stamp_width)
        nf = self.engine.cfg.n_fast_pages
        for s, i in zip(slots[fresh >= nf], idxs[fresh >= nf]):
            self._reneg.append((int(s), int(i), int(self._slot_rid[s])))
        self.fault_refetches += k

    def _recover_faults(self, rec: _Inflight) -> None:
        """Serving-level graceful degradation: retire pages the emulator
        killed this dispatch (the tombstone parked on the dead frame and
        its rescued swap partner — both conservatively dropped, ~2 pages
        per death), re-place dead contract pages immediately, and
        invalidate transiently-faulted KV pages so their owners refetch.
        """
        rp = np.asarray(rec.outs["retired_page"]).reshape(-1)
        tb = np.asarray(rec.outs["tombstone"]).reshape(-1)
        dead = np.concatenate([rp[rp >= 0], tb[tb >= 0]])
        if len(dead):
            live, slots, idxs = self.kv.retire_pages(dead)
            contract = idxs < self.cfg.pin_pages_per_seq
            self._replace_contracts(slots[contract], idxs[contract])
            # Non-contract pages refetch lazily on their next access.
        faulted = np.asarray(rec.outs["faulted"]).reshape(-1)[:rec.n_valid]
        if faulted.any():
            fp = np.unique(rec.pages[faulted])
            fp = fp[fp >= 0]
            # Contract pages refill in place (they are pinned to stay
            # put); dead/unowned pages are already handled above.
            fp = fp[~self.kv.dead[fp] & (self.kv.owner[fp] >= 0)
                    & ~self.kv.pinned[fp]]
            prot = self._protected_pages()
            if len(prot):
                fp = fp[~np.isin(fp, prot)]
            if len(fp):
                self.kv.page_of[self.kv.owner[fp],
                                self.kv.owner_idx[fp]] = -1
                self.kv._free(fp)
                self.fault_refetches += len(fp)

    # -- decode service -------------------------------------------------
    def _decode(self, parts: list[dict]) -> np.ndarray:
        cfg = self.cfg
        live = np.flatnonzero((self._slot_rid >= 0) & (self._slot_left > 0))
        if not len(live):
            return np.empty(0, np.int64)
        pos = int(np.searchsorted(live, self._rr))
        order = np.roll(live, -pos)
        W = cfg.window_pages
        cost = np.minimum(self._slot_pages[order], W) + 1
        cum = np.cumsum(cost)
        B = min(int(np.searchsorted(cum, self.buckets.max_size)) + 1,
                len(order))
        sv = order[:B]
        self._rr = int(order[B - 1] + 1) % cfg.max_live_seqs

        pages_sv = self._slot_pages[sv]
        w = np.minimum(pages_sv, W)
        col = np.arange(W, dtype=np.int32)
        idx = (pages_sv - w)[:, None] + col[None, :]
        colmask = col[None, :] < w[:, None]
        P = self.kv.page_of[sv[:, None], np.clip(idx, 0, cfg.max_pages_per_seq - 1)]
        P = np.where(colmask, P, -1)
        missing = (P < 0) & colmask
        self.kv.touch(P[colmask & ~missing], self._step_no)

        # New tail page when the current token starts a fresh page.
        need_new = (self._slot_tokens[sv] % cfg.positions_per_page == 0) \
            & (pages_sv < cfg.max_pages_per_seq)
        n_missing, n_new = int(missing.sum()), int(need_new.sum())
        self.kv.maybe_evict(self._step_no, n_missing + n_new,
                            protected=self._protected_pages())
        if n_missing:                       # refetch evicted window pages
            r, c = np.nonzero(missing)
            fresh = self.kv.alloc(n_missing, hint=SLOW)
            self.kv.assign(sv[r], idx[r, c], fresh, self._step_no)
            P[r, c] = fresh
            self.refetches += n_missing
        if n_new:
            t = sv[need_new]
            fresh = self.kv.alloc(n_new, hint=SLOW)
            self.kv.assign(t, self._slot_pages[t], fresh, self._step_no)
            self._slot_pages[t] += 1
        tail = self.kv.page_of[sv, self._slot_pages[sv] - 1]
        self.kv.touch(tail, self._step_no)

        # Row-major flatten: each slot's window reads then its token write.
        M = np.concatenate([P, tail[:, None]], axis=1)
        mask = np.concatenate([colmask, np.ones((B, 1), bool)], axis=1)
        flat_pages = M[mask].astype(np.int32)
        row_tok = self._slot_tokens[sv]
        off = ((row_tok % _LINES_PER_PAGE) * _LINE).astype(np.int32)
        offs = np.broadcast_to(off[:, None], mask.shape)[mask]
        is_w = np.broadcast_to(
            np.arange(W + 1)[None, :] == W, mask.shape)[mask]
        rid = np.repeat(self._slot_rid[sv], w + 1)
        parts.append({
            "page": flat_pages, "offset": offs, "is_write": is_w,
            "size": np.full(len(flat_pages), _LINE, np.int32),
            "rid": rid, "pinned": self.kv.pinned[flat_pages].copy()})

        self._slot_tokens[sv] += 1
        self._slot_left[sv] -= 1
        done = sv[self._slot_left[sv] == 0]
        self._n_decoding -= len(done)
        return done.astype(np.int64)

    # -- admission ------------------------------------------------------
    def _admit(self, parts: list[dict]) -> None:
        cfg = self.cfg
        k = min(len(self._free_slots), self._q_len(), cfg.max_admit_per_step)
        if k == 0:
            return
        h = self._q_head
        plen = self._q_prompt[h:h + k]
        # Memory-aware admission: a prompt is admitted only if it fits in
        # free-plus-evictable pages, with one decode page of headroom, so
        # eviction pressure comes from decode churn rather than a
        # pathological admission burst.
        protected = self._protected_pages()
        budget = self.kv.free_total + self.kv.evictable(self._step_no,
                                                        protected)
        k = int(np.searchsorted(np.cumsum(plen + 1), budget, side="right"))
        if k == 0:
            if self._n_occupied == 0:
                raise MemoryError(
                    f"prompt of {int(plen[0])} pages can never be "
                    "admitted: even an empty platform lacks the pages")
            return
        slots = self._free_slots.pop(k)
        plen = plen[:k]
        dec = self._q_decode[h:h + k]
        rids = np.arange(h, h + k, dtype=np.int64)
        self._q_head += k

        total = int(plen.sum())
        self.kv.maybe_evict(self._step_no, total, protected=protected)
        slot_rep = np.repeat(slots, plen)
        starts = np.cumsum(plen) - plen
        idx = np.arange(total, dtype=np.int32) - np.repeat(starts, plen)
        # §III-G hint discipline: only the contracted prefix carries the
        # fast-tier hint — the rest of the prompt starts slow and earns
        # promotion from the placement policy like any other page.
        pin_mask = idx < cfg.pin_pages_per_seq
        pages = np.empty(total, np.int32)
        pages[pin_mask] = self.kv.alloc(int(pin_mask.sum()), hint=FAST)
        pages[~pin_mask] = self.kv.alloc(int((~pin_mask).sum()), hint=SLOW)
        self.kv.assign(slot_rep, idx, pages, self._step_no)

        if cfg.pin_pages_per_seq:
            pin_pages = pages[pin_mask]
            self.carry = stamp_pin_pages(self.carry, pin_pages,
                                         width=self._stamp_width)
            # Contracts whose fast-tier hint spilled slow renegotiate
            # back onto DRAM as fast pages free up.
            nf = self.engine.cfg.n_fast_pages
            spill = pin_pages >= nf
            if spill.any():
                s_sp = slot_rep[pin_mask][spill]
                i_sp = idx[pin_mask][spill]
                r_sp = np.repeat(rids, plen)[pin_mask][spill]
                self._reneg.extend(
                    (int(s), int(i), int(r))
                    for s, i, r in zip(s_sp, i_sp, r_sp))

        ppw = cfg.prefill_writes_per_page
        pref_pages = np.repeat(pages, ppw)
        j = np.tile(np.arange(ppw, dtype=np.int32), total)
        parts.append({
            "page": pref_pages,
            "offset": ((j % _LINES_PER_PAGE) * _LINE).astype(np.int32),
            "is_write": np.ones(len(pref_pages), bool),
            "size": np.full(len(pref_pages), _LINE, np.int32),
            "rid": np.repeat(np.repeat(rids, plen), ppw),
            "pinned": self.kv.pinned[pref_pages].copy()})

        self._slot_rid[slots] = rids
        self._slot_pages[slots] = plen
        self._slot_tokens[slots] = 0
        self._slot_left[slots] = dec
        self._n_decoding += k
        self._n_occupied += k
        self.live_seqs_high_water = max(self.live_seqs_high_water,
                                        self._n_occupied)

    # -- dispatch & harvest ---------------------------------------------
    def _dispatch_ready(self) -> None:
        while True:
            d = self.buckets.get_dispatch_size(self._pending.n)
            if d is None:
                return
            self._dispatch(self._pending.pop(d), d, d)

    def _flush_pending(self) -> None:
        n = self._pending.n
        if n == 0:
            self._process_releases()
            return
        while True:          # full buckets first, then pad only the tail
            d = self.buckets.get_dispatch_size(self._pending.n)
            if d is None:
                break
            self._dispatch(self._pending.pop(d), d, d)
        n = self._pending.n
        if n:
            size = self.buckets.get_padded_batch_size(n)
            batch = self._pending.pop(n)
            pad = size - n
            for f in _FIELDS:
                z = np.zeros(pad, batch[f].dtype)
                batch[f] = np.concatenate([batch[f], z])
            self._dispatch(batch, size, n)

    def _dispatch(self, batch: dict, size: int, n_valid: int) -> None:
        if len(self._inflight) >= self.cfg.max_live_batches:
            self._harvest_one()
        trace = Trace(page=jnp.asarray(batch["page"]),
                      offset=jnp.asarray(batch["offset"]),
                      is_write=jnp.asarray(batch["is_write"]),
                      size=jnp.asarray(batch["size"]))
        valid = None if n_valid == size else jnp.arange(size) < n_valid
        state, outs = self.engine.run(trace, state=self.carry, valid=valid,
                                      faults=self.cfg.faults)
        self.carry = state
        self._inflight.append(_Inflight(outs, batch["rid"][:n_valid],
                                        batch["pinned"][:n_valid],
                                        batch["page"][:n_valid],
                                        n_valid, size))
        self.inflight_high_water = max(self.inflight_high_water,
                                       len(self._inflight))
        self.dispatch_log.append((size, n_valid))
        self._dispatched += n_valid
        if self.cfg.record_traces:
            self.trace_log.append(Trace(
                *(jnp.asarray(batch[f][:n_valid])
                  for f in ("page", "offset", "is_write", "size"))))
        self._process_releases()

    def _process_releases(self) -> None:
        while self._release_q and self._release_q[0][0] <= self._dispatched:
            _, slots = self._release_q.popleft()
            _, contracted = self.kv.release_slots(slots)
            if self.cfg.pin_pages_per_seq and len(contracted):
                w = self._stamp_width
                for i in range(0, len(contracted), w):
                    self.carry = release_pin_pages(
                        self.carry, contracted[i:i + w], width=w)
            self._slot_rid[slots] = -1
            self._slot_pages[slots] = 0
            self._free_slots.push(slots)
            self._n_occupied -= len(slots)

    def _harvest_one(self) -> None:
        rec = self._inflight.popleft()
        n = rec.n_valid
        returns = np.asarray(rec.outs["returns"])[:n].astype(np.int64)
        lat = np.asarray(rec.outs["latency"])[:n].astype(np.int64)
        dev = np.asarray(rec.outs["device"])[:n]
        np.minimum.at(self._first_issue, rec.rid, returns - lat)
        np.maximum.at(self._last_return, rec.rid, returns)
        pin = rec.pinned
        b = self._buckets_stats.setdefault(
            rec.size, {"dispatches": 0, "requests": 0, "padded": 0,
                       "service_lat_sum": 0.0, "service_lat_max": 0.0,
                       "pinned_accesses": 0, "pinned_fast_hits": 0})
        b["dispatches"] += 1
        b["requests"] += n
        b["padded"] += rec.size - n
        b["service_lat_sum"] += float(lat.sum())
        b["service_lat_max"] = max(b["service_lat_max"], float(lat.max()))
        b["pinned_accesses"] += int(pin.sum())
        b["pinned_fast_hits"] += int((pin & (dev == FAST)).sum())
        self._recover_faults(rec)
        if self.cfg.record_traces:
            self.outs_log.append(
                {k: np.asarray(v)[:n] for k, v in rec.outs.items()})

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------
    def _q_len(self) -> int:
        return len(self._q_prompt) - self._q_head

    def report(self) -> ServeReport:
        cfg = self.cfg
        done = self._last_return >= 0
        lat_us = (self._last_return[done]
                  - self._first_issue[done]) / 1e3
        if len(lat_us):
            p50 = float(np.percentile(lat_us, 50))
            p99 = float(np.percentile(lat_us, 99))
            mean = float(lat_us.mean())
            slo = float((lat_us <= cfg.slo_latency_us).mean())
        else:
            p50 = p99 = mean = 0.0
            slo = 1.0
        pa = sum(b["pinned_accesses"] for b in self._buckets_stats.values())
        ph = sum(b["pinned_fast_hits"] for b in self._buckets_stats.values())
        rate = ph / pa if pa else 0.0
        per_bucket = {}
        for size, b in sorted(self._buckets_stats.items()):
            per_bucket[size] = dict(b)
            per_bucket[size]["service_lat_mean_us"] = (
                b["service_lat_sum"] / b["requests"] / 1e3
                if b["requests"] else 0.0)
        return ServeReport(
            n_sequences=int(done.sum()),
            n_mem_requests=self._dispatched,
            n_dispatches=len(self.dispatch_log),
            n_steps=self._step_no,
            p50_latency_us=p50, p99_latency_us=p99, mean_latency_us=mean,
            slo_latency_us=cfg.slo_latency_us, slo_attainment=slo,
            pinned_accesses=pa, pinned_fast_hit_rate=rate,
            pinned_slo=cfg.pinned_slo, pinned_slo_met=rate >= cfg.pinned_slo
            if pa else True,
            evictions=self.kv.evictions, refetches=self.refetches,
            inflight_high_water=self.inflight_high_water,
            live_seqs_high_water=self.live_seqs_high_water,
            compile_count=self.engine.compile_count,
            per_bucket=per_bucket,
            frames_retired=self.kv.retired,
            fault_refetches=self.fault_refetches,
            renegotiations=self.renegotiations)
