import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, print memory/cost analysis, and emit roofline
terms.

The XLA_FLAGS line above MUST run before any other import — jax locks the
device count at first init. 512 host devices stand in for 2 pods x 256
chips; everything below is ShapeDtypeStruct-driven, so nothing is
allocated at model scale.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
        --out results/dryrun.jsonl
"""

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.configs as configs                      # noqa: E402
from repro.configs.shapes import SHAPES, shape_applicable  # noqa: E402
from repro.data import DataConfig, batch_specs as data_specs  # noqa: E402
from repro.launch import shardings as shd            # noqa: E402
from repro.launch.mesh import make_production_mesh   # noqa: E402
from repro.launch.steps import (make_prefill_step, make_serve_step,  # noqa: E402
                                make_train_step)
from repro.models import ModelConfig, ShardCtx, init_cache, init_params  # noqa: E402
from repro.optim import AdamWConfig                  # noqa: E402
from repro.optim.adamw import init_opt_state         # noqa: E402

# bytes per element for HLO shape parsing
_DT = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
       "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
       "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DT:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes (per-device) of every collective op."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s*(\w[\w\-]*)\(", s)
        if not m:
            continue
        op = m.group(2)
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-"):
                out[c] += _shape_bytes(m.group(1))
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def micro_batches_for(arch: str, shape_name: str) -> int:
    """Gradient-accumulation depth per cell (activation-memory lever)."""
    if shape_name != "train_4k":
        return 1
    return {"deepseek-v2-236b": 8, "phi3.5-moe-42b-a6.6b": 4,
            "minitron-8b": 2, "rwkv6-7b": 2}.get(arch, 1)


def build_cell(arch: str, shape_name: str, mesh, cfg: ModelConfig | None = None,
               micro_batches: int | None = None):
    """Returns (jitted_fn, arg_specs) for one (arch x shape) cell."""
    cfg = cfg or configs.get(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, why
    sh = ShardCtx.from_mesh(mesh)

    pspecs = shd.param_specs(cfg, sh)
    params_shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pshapes_tree = jax.tree.map(lambda x: x.shape, params_shapes)

    if shape.kind == "train":
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=shape.seq_len,
                          global_batch=shape.global_batch,
                          frontend=cfg.frontend, frame_dim=cfg.frame_dim)
        bspecs = shd.batch_specs(cfg, sh)
        opt_shapes = jax.eval_shape(init_opt_state, params_shapes)
        ospecs_inner = shd.zero1_specs(pspecs, pshapes_tree, sh)
        ospecs = type(opt_shapes)(mu=ospecs_inner, nu=ospecs_inner,
                                  step=jax.sharding.PartitionSpec())
        mb = (micro_batches if micro_batches is not None
              else micro_batches_for(arch, shape_name))
        gspecs = shd.to_named(ospecs_inner, mesh)   # ZeRO-2 grad layout
        step = make_train_step(cfg, AdamWConfig(), sh, micro_batches=mb,
                               grad_specs=gspecs)
        # reprolint: allow[donation] model-training params/opt-state, not
        # emulator session state; aliasing is exercised by the dryrun CLI
        fn = jax.jit(step,
                     in_shardings=(shd.to_named(pspecs, mesh),
                                   shd.to_named(ospecs, mesh),
                                   shd.to_named(bspecs, mesh)),
                     out_shardings=(shd.to_named(pspecs, mesh),
                                    shd.to_named(ospecs, mesh), None),
                     donate_argnums=(0, 1))
        args = (params_shapes, opt_shapes, data_specs(dcfg))
        return (fn, args), None

    if shape.kind == "prefill":
        step = make_prefill_step(cfg, sh, smax=shape.seq_len)
        if cfg.frontend == "frames":
            inputs = jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len, cfg.frame_dim),
                jnp.float32)
            ispec = jax.sharding.PartitionSpec(sh.batch_axes, None, None)
        else:
            inputs = jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len), jnp.int32)
            ispec = jax.sharding.PartitionSpec(sh.batch_axes, None)
        cspecs = shd.cache_specs(cfg, sh)
        out_sh = (None, shd.to_named(cspecs, mesh), None)
        fn = jax.jit(step,
                     in_shardings=(shd.to_named(pspecs, mesh),
                                   shd.to_named(ispec, mesh)),
                     out_shardings=out_sh)
        return (fn, (params_shapes, inputs)), None

    # decode
    step = make_serve_step(cfg, sh)
    cache_shapes = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    cspecs = shd.cache_specs(cfg, sh, batch=shape.global_batch)
    tokens = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    pos = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    bspec = jax.sharding.PartitionSpec(
        sh.batch_axes_for(shape.global_batch))
    # reprolint: allow[donation] decode KV cache of the model-serving
    # dry-run, not emulator session state
    fn = jax.jit(step,
                 in_shardings=(shd.to_named(pspecs, mesh),
                               shd.to_named(bspec, mesh),
                               shd.to_named(cspecs, mesh),
                               shd.to_named(bspec, mesh)),
                 out_shardings=(None, shd.to_named(cspecs, mesh), None),
                 donate_argnums=(2,))
    return (fn, (params_shapes, tokens, cache_shapes, pos)), None


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on current jax, a one-element
    list of dicts on older releases — normalize."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _compile_metrics(fn, args, mesh) -> dict:
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        cost = _cost_dict(compiled)
        coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll["total"], "coll_by_op": coll}


def roofline_costs(arch: str, shape_name: str, mesh) -> dict:
    """Exact per-device FLOPs/bytes/collective totals via the unrolled
    1-/2-layer variant diff (XLA counts while bodies once; total(L) =
    (2*V1 - V2) + L*(V2 - V1); EXPERIMENTS.md §Roofline methodology)."""
    base_cfg = configs.get(arch)
    L = base_cfg.n_layers
    out = {}
    vs = []
    for lvar in (1, 2):
        cfg = base_cfg.with_(n_layers=lvar, unroll_layers=True,
                             attention_impl="naive", rwkv_unroll=True)
        built, why = build_cell(arch, shape_name, mesh, cfg=cfg,
                                micro_batches=1)
        if built is None:
            return {"status": "skipped", "reason": why}
        fn, args = built
        vs.append(_compile_metrics(fn, args, mesh))
    v1, v2 = vs
    for key in ("flops", "bytes", "coll"):
        body = v2[key] - v1[key]
        out[key] = max(0.0, (2 * v1[key] - v2[key]) + L * body)
    out["coll_by_op"] = {
        k: max(0, (2 * v1["coll_by_op"][k] - v2["coll_by_op"][k])
               + L * (v2["coll_by_op"][k] - v1["coll_by_op"][k]))
        for k in v1["coll_by_op"]}
    out["per_layer"] = {k: v2[k] - v1[k] for k in ("flops", "bytes", "coll")}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             roofline: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    built, why = build_cell(arch, shape_name, mesh)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if built is None:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec
    fn, args = built
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled)
        coll = collective_bytes(compiled.as_text())
    rec.update(
        status="ok",
        compile_s=round(time.time() - t0, 1),
        flops=float(cost.get("flops", 0.0)),
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        collective_bytes=coll,
        memory={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    )
    if roofline:
        rec["roofline_raw"] = roofline_costs(arch, shape_name, mesh)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--roofline", action="store_true",
                    help="also extract exact roofline costs (slower)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.all:
        cells = [(a, s) for a in configs.ALIASES for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    out = open(args.out, "a") if args.out else None
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            try:
                rec = run_cell(arch, shape, mp, roofline=args.roofline)
            except Exception as e:           # a failure here is a system bug
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "FAILED", "error": repr(e)[:500]}
                failures += 1
            line = json.dumps(rec)
            print(line, flush=True)
            if out:
                out.write(line + "\n")
                out.flush()
    if out:
        out.close()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
