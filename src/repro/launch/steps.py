"""Step builders: the exact jit-able functions the launcher lowers/runs.

``make_train_step`` supports gradient accumulation (micro-batches) — the
activation-memory lever for the biggest train cells — and returns
(params, opt_state, metrics) with params/opt donated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, ShardCtx, decode_step, loss_fn, prefill
from repro.optim import AdamWConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, sh: ShardCtx,
                    micro_batches: int = 1, grad_specs=None):
    """``grad_specs`` (optional PartitionSpec pytree, normally the ZeRO-1
    moment specs): constrains gradients — and the fp32 accumulation
    buffers — to the data-sharded layout. XLA then reduce-scatters each
    microbatch's gradients instead of all-reducing, and the accumulator
    shrinks by the data-axis size (ZeRO-2; EXPERIMENTS.md §Perf M5)."""

    def _constrain(grads):
        if grad_specs is None or not sh.axis_sizes:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, grad_specs,
            is_leaf=lambda x: isinstance(x, jax.Array))

    def compute_grads(params, batch):
        grad_fn = jax.value_and_grad(
            lambda p, b: loss_fn(cfg, p, b, sh), has_aux=True)
        if micro_batches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, _constrain(grads)

        def split(x):
            return x.reshape(micro_batches, x.shape[0] // micro_batches,
                             *x.shape[1:])
        micro = jax.tree.map(split, batch)

        def acc(carry, mb):
            loss_a, grads_a = carry
            (loss, metrics), grads = grad_fn(params, mb)
            grads = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32),
                grads_a, _constrain(grads))
            return (loss_a + loss, _constrain(grads)), metrics

        zero = _constrain(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss_sum, grads), metrics = jax.lax.scan(acc, (0.0, zero), micro)
        inv = 1.0 / micro_batches
        grads = jax.tree.map(lambda g: g * inv, grads)
        last_metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum * inv, last_metrics, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, sh: ShardCtx, smax: int):
    def prefill_step(params, inputs):
        return prefill(cfg, params, inputs, sh, smax)
    return prefill_step


def make_serve_step(cfg: ModelConfig, sh: ShardCtx):
    def serve_step(params, tokens, cache, pos):
        return decode_step(cfg, params, tokens, cache, pos, sh)
    return serve_step
