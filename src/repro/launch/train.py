"""Training driver: mesh setup, sharded init, checkpoint/auto-resume,
failure injection, straggler watchdog.

Fault-tolerance behaviours (exercised by tests/test_train_loop.py):
  * auto-resume: restarts continue from the newest complete checkpoint
    with bit-identical data batches (deterministic pipeline keyed by step);
  * --simulate-failure-at N: hard-crash mid-run to prove the above;
  * straggler watchdog: logs any step slower than ``straggler_factor`` x
    the running median — the hook a cluster controller uses to evict/
    replace slow hosts (on a single host it observes, not migrates);
  * elastic restart: checkpoints are mesh-agnostic; pass a different
    --mesh-model/--mesh-data on resume and pjit reshards.

Usage (CPU example run, ~100M-param smoke-family model):
    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --smoke --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax

import repro.configs as configs
from repro.ckpt import CheckpointManager, latest_step, load_checkpoint
from repro.data import DataConfig, make_batch_iterator
from repro.launch import shardings as shd
from repro.launch.mesh import make_dev_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import ShardCtx, init_params
from repro.optim import AdamWConfig
from repro.optim.adamw import init_opt_state


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="width multiplier on the smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--total-steps", type=int, default=None,
                    help="schedule horizon (pin across restarts; default "
                         "--steps)")
    ap.add_argument("--mesh", choices=["none", "dev", "pod", "multipod"],
                    default="none")
    ap.add_argument("--mesh-model", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--simulate-failure-at", type=int, default=None)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    if args.smoke and args.scale != 1.0:
        s = args.scale
        cfg = cfg.with_(d_model=int(cfg.d_model * s) // 8 * 8,
                        d_ff=int(cfg.d_ff * s) // 8 * 8)

    if args.mesh == "none":
        mesh = None
        sh = ShardCtx()
    else:
        mesh = (make_dev_mesh(model=args.mesh_model) if args.mesh == "dev"
                else make_production_mesh(multi_pod=args.mesh == "multipod"))
        sh = ShardCtx.from_mesh(mesh)

    horizon = args.total_steps or args.steps
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=min(20, horizon // 5),
                          total_steps=horizon)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed,
                      frontend=cfg.frontend, frame_dim=cfg.frame_dim)

    step_fn = make_train_step(cfg, opt_cfg, sh,
                              micro_batches=args.micro_batches)

    def init_all():
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        return params, init_opt_state(params)

    if mesh is not None:
        pspecs = shd.param_specs(cfg, sh)
        shapes = jax.eval_shape(init_all)
        pshapes = jax.tree.map(lambda x: x.shape, shapes[0])
        ospecs_inner = shd.zero1_specs(pspecs, pshapes, sh)
        ospecs = type(shapes[1])(mu=ospecs_inner, nu=ospecs_inner,
                                 step=jax.sharding.PartitionSpec())
        step_fn = make_train_step(
            cfg, opt_cfg, sh, micro_batches=args.micro_batches,
            grad_specs=shd.to_named(ospecs_inner, mesh))   # ZeRO-2 grads
        with mesh:
            params, opt_state = jax.jit(
                init_all, out_shardings=(shd.to_named(pspecs, mesh),
                                         shd.to_named(ospecs, mesh)))()
            # reprolint: allow[donation] training params/opt-state loop,
            # not emulator session state (rebound every step below)
            step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        params, opt_state = jax.jit(init_all)()
        # reprolint: allow[donation] training params/opt-state loop, not
        # emulator session state (rebound every step below)
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    # --- auto-resume --------------------------------------------------------
    start_step = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), manifest = load_checkpoint(
            args.ckpt_dir, (params, opt_state))
        start_step = manifest["step"]
        print(f"[resume] restored step {start_step} from {args.ckpt_dir}")

    it = make_batch_iterator(dcfg, start_step=start_step)
    durations: list[float] = []
    ctx = mesh if mesh is not None else _null()
    with ctx:
        for step, batch in it:
            if step >= args.steps:
                break
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0

            # straggler watchdog
            if len(durations) >= 8:
                med = statistics.median(durations[-32:])
                if dt > args.straggler_factor * med:
                    print(f"[straggler] step {step}: {dt:.3f}s vs median "
                          f"{med:.3f}s — flagging for controller eviction")
            durations.append(dt)

            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(metrics['loss']):8.4f} "
                      f"grad_norm {float(metrics['grad_norm']):8.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:7.1f} ms")

            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, (params, opt_state))

            if args.simulate_failure_at is not None and \
                    step + 1 == args.simulate_failure_at:
                if mgr:
                    mgr.save(step + 1, (params, opt_state))
                    mgr.close()
                raise SystemExit(f"[failure-injection] crash at step {step+1}")

    if mgr:
        mgr.save(args.steps, (params, opt_state))
        mgr.close()
    return params, float(metrics["loss"])


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    run()
