"""PartitionSpec assignment for every pytree in the system.

Param specs are derived from the init_params structure by path rules
(weights stacked over layers: specs gain a leading None). ZeRO-1 moment
specs additionally shard one replicated dim over "data". Head-sharding is
conditional on divisibility (ShardCtx.divides) — gemma3 (8 heads) and
hymba (25 heads) run attention batch-parallel.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import ModelConfig, ShardCtx


def _attn_specs(cfg: ModelConfig, sh: ShardCtx) -> dict:
    m = sh.model_axis
    heads_ok = sh.divides(cfg.n_heads * cfg.head_dim_) and \
        sh.divides(cfg.n_heads)
    kv_ok = sh.divides(cfg.n_kv_heads * cfg.head_dim_) and \
        sh.divides(cfg.n_kv_heads)
    h = m if heads_ok else None
    k = m if kv_ok else None
    if cfg.attn_type == "gqa" or cfg.attn_type == "hymba":
        base = {
            "norm": P(), "wq": P(None, None, h), "wk": P(None, None, k),
            "wv": P(None, None, k),
        }
        if cfg.attn_type == "gqa":
            base["wo"] = P(None, h, None)
            return base
        di_ok = sh.divides(cfg.n_heads * cfg.head_dim_)
        dm = m if di_ok else None
        base.update({
            "wo": P(None, dm, None),
            "attn_out_norm": P(), "ssm_out_norm": P(),
            "mamba": {
                "in_proj": P(None, None, dm),
                "conv_w": P(None, dm, None),
                "x_proj": P(None, dm, None),
                "dt_proj": P(None, None, dm),
                "dt_bias": P(None, dm),
                "a_log": P(None, dm, None),
                "d_skip": P(None, dm),
            },
        })
        return base
    if cfg.attn_type == "mla":
        hd_ok = sh.divides(cfg.n_heads)
        h = m if hd_ok else None
        return {
            "norm": P(), "wq_a": P(None, None, None), "q_norm": P(),
            "wq_b": P(None, None, h),
            "wkv_a": P(None, None, None), "kv_norm": P(),
            "wk_b": P(None, None, h), "wv_b": P(None, None, h),
            "wo": P(None, h, None),
        }
    if cfg.attn_type == "rwkv6":
        d_ok = sh.divides(cfg.d_model) and sh.divides(cfg.n_heads)
        h = m if d_ok else None
        return {
            "norm": P(), "mu_r": P(), "mu_k": P(), "mu_v": P(), "mu_w": P(),
            "mu_g": P(),
            "w_r": P(None, None, h), "w_k": P(None, None, h),
            "w_v": P(None, None, h), "w_g": P(None, None, h),
            "w_o": P(None, h, None),
            "decay_a": P(), "decay_b": P(None, None, h),
            "decay_base": P(None, h) if h else P(),
            "u": P(None, h, None), "gn_w": P(None, h) if h else P(),
        }
    raise ValueError(cfg.attn_type)


def _mlp_specs(cfg: ModelConfig, sh: ShardCtx) -> dict:
    m = sh.model_axis
    if cfg.attn_type == "rwkv6":
        f = m if sh.divides(cfg.d_ff) else None
        return {"norm": P(), "mu_k": P(), "mu_r": P(),
                "w_k": P(None, None, f), "w_v": P(None, f, None),
                "w_r": P(None, None, None)}
    if cfg.moe:
        e_ok = sh.divides(cfg.moe.n_experts)
        e = m if e_ok else None
        p = {"norm": P(), "router": P(None, None, None),
             "w_in": P(None, e, None, None), "w_gate": P(None, e, None, None),
             "w_out": P(None, e, None, None)}
        if cfg.moe.n_shared:
            f = m if sh.divides(cfg.moe.d_ff_shared) else None
            p["shared"] = {"w_in": P(None, None, f),
                           "w_gate": P(None, None, f),
                           "w_out": P(None, f, None)}
        return p
    f = m if sh.divides(cfg.d_ff) else None
    return {"norm": P(), "w_in": P(None, None, f), "w_gate": P(None, None, f),
            "w_out": P(None, f, None)}


def needs_fsdp(cfg: ModelConfig, sh: ShardCtx,
               hbm_budget: float = 8e9) -> bool:
    """Model-axis TP alone leaves params replicated across the data axis;
    when that replica exceeds the budget (deepseek-v2: 29.5 GB on a 16-way
    model axis), shard params over 'data' too (FSDP / ZeRO-3)."""
    msz = max(1, sh.size("model"))
    return cfg.n_params() * 2 / msz > hbm_budget


def param_specs(cfg: ModelConfig, sh: ShardCtx,
                fsdp: bool | None = None) -> dict:
    m = sh.model_axis
    v = m if sh.divides(cfg.vocab) else None
    embed = {"tokens": P(v, None)}
    if cfg.frontend == "frames":
        embed["frames"] = P(None, None)
    specs = {
        "embed": embed,
        "layers": {"attn": _attn_specs(cfg, sh), "mlp": _mlp_specs(cfg, sh)},
        "final_norm": P(),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, v)
    if fsdp is None:
        fsdp = needs_fsdp(cfg, sh)
    if fsdp and "data" in sh.names:
        shapes = _param_shapes(cfg)
        specs = zero1_specs(specs, shapes, sh)   # adds 'data' on a free dim
    return specs


def _param_shapes(cfg: ModelConfig):
    import jax as _jax
    from repro.models import init_params as _init
    shapes = _jax.eval_shape(lambda: _init(cfg, _jax.random.PRNGKey(0)))
    return _jax.tree.map(lambda x: x.shape, shapes)


def zero1_specs(param_specs_tree, params_shapes, sh: ShardCtx):
    """Optimizer-moment specs: param spec + shard one free dim over 'data'
    (ZeRO-1). Picks the largest divisible unsharded dim."""
    data = "data" if "data" in sh.names else None
    if data is None:
        return param_specs_tree
    dsz = sh.size("data")

    def one(spec: P, shape):
        if len(shape) == 0:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        if any(e == data or (isinstance(e, tuple) and data in e)
               for e in entries):
            return P(*entries)        # already data-sharded (FSDP params)
        best, best_dim = None, 0
        for i, (e, dim) in enumerate(zip(entries, shape)):
            if e is None and dim % dsz == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best is not None:
            entries[best] = data
        return P(*entries)

    return jax.tree.map(one, param_specs_tree, params_shapes,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg: ModelConfig, sh: ShardCtx) -> dict:
    b = sh.batch_axes
    if cfg.frontend == "frames":
        return {"inputs": P(b, None, None), "labels": P(b, None)}
    return {"inputs": P(b, None), "labels": P(b, None)}


def cache_specs(cfg: ModelConfig, sh: ShardCtx,
                batch: int | None = None) -> dict:
    """Decode-cache specs: batch over DP axes (when divisible), seq over
    'model' (sequence-sharded flash-decode; DESIGN.md §4)."""
    b = sh.batch_axes if batch is None else sh.batch_axes_for(batch)
    m = sh.model_axis
    if cfg.attn_type == "gqa":
        kv = P(None, b, None, m, None)
        return {"k": kv, "v": kv}
    if cfg.attn_type == "mla":
        return {"c_kv": P(None, b, m, None), "k_rope": P(None, b, m, None)}
    if cfg.attn_type == "rwkv6":
        h = m if sh.divides(cfg.n_heads) else None
        return {"state": P(None, b, h, None, None),
                "prev_att": P(None, b, None), "prev_ffn": P(None, b, None)}
    if cfg.attn_type == "hymba":
        di = m if sh.divides(cfg.n_heads * cfg.head_dim_) else None
        kv = P(b, None, m, None)     # per-layer ring buffers (tuple cache)
        return tuple({"k": kv, "v": kv, "conv": P(b, None, di),
                      "ssm": P(b, di, None)}
                     for _ in range(cfg.n_layers))
    raise ValueError(cfg.attn_type)


def to_named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
