"""Launcher: production mesh, sharding specs, step builders, dry-run."""
