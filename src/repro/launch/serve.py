"""Serving driver: batched requests through the ServeEngine with the
HMMU-managed tiered KV cache (the paper's platform evaluating a cache
tier-management policy under a real decoding workload).

Usage (CPU):
    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --smoke --requests 8 --policy hotness
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

import repro.configs as configs
from repro.core import EmulatorConfig
from repro.memtier import ServeEngine
from repro.memtier.engine import Request
from repro.models import init_params


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--smax", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--policy", default="hotness",
                    choices=["static", "hotness", "write_bias"])
    ap.add_argument("--fast-pages", type=int, default=64,
                    help="DRAM-tier size of the emulated hybrid memory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    emu_cfg = EmulatorConfig(n_fast_pages=args.fast_pages,
                             n_slow_pages=4096, chunk=64,
                             policy=args.policy, hot_threshold=4)
    eng = ServeEngine(cfg, params, batch_size=args.batch, smax=args.smax,
                      emu_cfg=emu_cfg, policy=args.policy)

    rng = np.random.default_rng(args.seed)
    for r in range(args.requests):
        if cfg.frontend == "frames":
            prompt = rng.standard_normal(
                (args.prompt_len, cfg.frame_dim)).astype(np.float32)
        else:
            prompt = rng.integers(0, cfg.vocab,
                                  args.prompt_len).astype(np.int32)
        eng.submit(Request(rid=r, prompt=prompt, max_new_tokens=args.max_new))

    t0 = time.time()
    steps = eng.run()
    wall = time.time() - t0
    rep = eng.report()
    print(f"served {args.requests} requests in {steps} decode steps "
          f"({wall:.2f}s wall)")
    print(f"policy={args.policy} est_cycles={rep['est_total_cycles']} "
          f"migrations={rep['migrations']} "
          f"mean_read_latency={rep['mean_read_latency_cyc']:.1f}cyc "
          f"fast_traffic={rep['reads_fast']+rep['writes_fast']} "
          f"slow_traffic={rep['reads_slow']+rep['writes_slow']}")
    return rep


if __name__ == "__main__":
    run()
