"""Production mesh definitions.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(model: int = 2, data: int | None = None):
    """Whatever this host has, as a (data, model) mesh — for integration
    tests with xla_force_host_platform_device_count."""
    n = len(jax.devices())
    model = min(model, n)
    data = data or n // model
    return jax.make_mesh((data, model), ("data", "model"))
