"""The SPEC CPU 2017 workload suite of paper Table III, as trace recipes.

Footprints are the paper's measured values (Table III). Per-workload
request *volumes* follow the paper's Fig 8 ordering (505.mcf most traffic —
2.83 TB read / 2.82 TB write; 538.imagick least — 4.47/4.49 GB), with
intermediate workloads ranked by their published cache-miss intensity
[Limaye & Adegbija, ISPASS'18], the same source the paper cites to confirm
its Fig 8 observations. Access patterns encode each benchmark's well-known
behaviour (mcf pointer-heavy zipfian, lbm streaming, namd strided, ...).

``scale`` shrinks absolute request counts for laptop-scale runs while
preserving ratios; the benchmark harness reports volumes re-expanded to
paper scale.
"""
from __future__ import annotations

import dataclasses

from repro.core.emulator import Trace
from .generators import TraceSpec, generate

_MB = 1 << 20
_GB = 1 << 30
_TB = 1 << 40


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    footprint_bytes: int
    total_traffic_bytes: float   # read + write volume at paper scale (Fig 8)
    write_frac: float
    pattern: str
    zipf_alpha: float = 1.1
    stride_pages: int = 2
    seq_frac: float = 0.5


WORKLOADS: dict[str, Workload] = {w.name: w for w in [
    # --- integer ---------------------------------------------------------------
    Workload("500.perlbench", 202 * _MB, 120 * _GB, 0.45, "zipfian", 1.2),
    Workload("505.mcf", 602 * _MB, 5.65 * _TB, 0.50, "zipfian", 0.9),
    Workload("508.namd", 172 * _MB, 40 * _GB, 0.35, "strided", stride_pages=3),
    Workload("520.omnetpp", 241 * _MB, 800 * _GB, 0.45, "zipfian", 1.0),
    Workload("523.xalancbmk", 481 * _MB, 600 * _GB, 0.40, "pointer"),
    Workload("525.x264", 165 * _MB, 60 * _GB, 0.40, "mixed", seq_frac=0.8),
    Workload("531.deepsjeng", 700 * _MB, 50 * _GB, 0.45, "zipfian", 1.3),
    Workload("541.leela", 22 * _MB, 10 * _GB, 0.45, "zipfian", 1.3),
    Workload("557.xz", 727 * _MB, 500 * _GB, 0.50, "mixed", seq_frac=0.6),
    # --- floating point ---------------------------------------------------------
    Workload("519.lbm", 410 * _MB, 1.5 * _TB, 0.50, "sequential"),
    Workload("538.imagick", 287 * _MB, 8.96 * _GB, 0.50, "mixed", seq_frac=0.8),
    Workload("544.nab", 147 * _MB, 30 * _GB, 0.35, "strided", stride_pages=5),
]}


def workload_trace(name: str, scale: float = 1e-6, page_size: int = 4096,
                   seed: int = 0, max_requests: int = 4_000_000,
                   min_requests: int = 2048) -> tuple[Trace, Workload, int]:
    """Build the trace for one workload at the given volume scale.

    Returns (trace, workload, n_requests). ``n_requests`` is clamped to
    [min_requests, max_requests] to keep laptop runs bounded; the scale
    factor actually applied is recoverable as n_requests*64/total_traffic.
    """
    w = WORKLOADS[name]
    n = int(w.total_traffic_bytes * scale / 64)
    n = max(min_requests, min(max_requests, n))
    spec = TraceSpec(
        n_requests=n,
        footprint_pages=max(1, w.footprint_bytes // page_size),
        write_frac=w.write_frac,
        pattern=w.pattern,
        zipf_alpha=w.zipf_alpha,
        stride_pages=w.stride_pages,
        seq_frac=w.seq_frac,
        page_size=page_size,
        seed=seed,
    )
    return generate(spec), w, n
