"""Workload substrate: trace generators + the SPEC-2017-like suite."""
from .generators import (zipfian, sequential, strided, pointer_chase, mixed,
                         serve_mixed, TraceSpec, generate)
from .workloads import WORKLOADS, workload_trace

__all__ = ["zipfian", "sequential", "strided", "pointer_chase", "mixed",
           "serve_mixed", "TraceSpec", "generate", "WORKLOADS",
           "workload_trace"]
