"""Synthetic memory-trace generators.

The paper drives its platform with real SPEC CPU 2017 binaries on the hard
ARM cores. Without a host CPU, we synthesize post-cache-filter request
streams with the access-pattern families that dominate those benchmarks:
zipfian reuse (pointer-heavy codes like mcf/omnetpp), sequential streaming
(lbm, x264), strided (namd), and pointer-chasing (xalancbmk). ``mixed``
composes them with per-workload ratios (see workloads.py).

Generators are jit-compiled JAX so trace production runs at "native"
speed — the role the real application plays on the paper's platform.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.emulator import Trace


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Recipe for a synthetic request stream."""
    n_requests: int
    footprint_pages: int         # working-set size in pages
    write_frac: float = 0.3
    pattern: str = "zipfian"     # zipfian | sequential | strided | pointer
    #                            # | mixed | serve_mixed
    zipf_alpha: float = 1.1
    stride_pages: int = 2
    seq_frac: float = 0.5        # for `mixed`: fraction of sequential traffic
    n_tenants: int = 4           # for `serve_mixed`: concurrent tenants
    prefill_frac: float = 0.2    # for `serve_mixed`: prefill share of traffic
    decode_window: int = 8       # for `serve_mixed`: decode reuse window, pages
    line: int = 64
    page_size: int = 4096
    seed: int = 0


def _writes(key, spec) -> jax.Array:
    return jax.random.uniform(key, (spec.n_requests,)) < spec.write_frac


def _offsets(key, spec) -> jax.Array:
    lines = spec.page_size // spec.line
    return (jax.random.randint(key, (spec.n_requests,), 0, lines)
            * spec.line).astype(jnp.int32)


def _zipf_pages(key, n, footprint, alpha) -> jax.Array:
    """Zipfian page popularity via inverse-CDF sampling on ranks."""
    ranks = jnp.arange(1, footprint + 1, dtype=jnp.float32)
    w = ranks ** -alpha
    cdf = jnp.cumsum(w) / jnp.sum(w)
    u = jax.random.uniform(key, (n,))
    pages = jnp.searchsorted(cdf, u).astype(jnp.int32)
    # Scatter ranks over the footprint so hot pages aren't contiguous.
    perm_key = jax.random.fold_in(key, 7)
    perm = jax.random.permutation(perm_key, footprint)
    return perm[jnp.clip(pages, 0, footprint - 1)].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("spec",))
def zipfian(spec: TraceSpec) -> Trace:
    k = jax.random.PRNGKey(spec.seed)
    k1, k2, k3 = jax.random.split(k, 3)
    return Trace(
        page=_zipf_pages(k1, spec.n_requests, spec.footprint_pages, spec.zipf_alpha),
        offset=_offsets(k2, spec),
        is_write=_writes(k3, spec),
        size=jnp.full(spec.n_requests, spec.line, jnp.int32))


@functools.partial(jax.jit, static_argnames=("spec",))
def sequential(spec: TraceSpec) -> Trace:
    k = jax.random.PRNGKey(spec.seed)
    k2, k3 = jax.random.split(k)
    lines = spec.page_size // spec.line
    idx = jnp.arange(spec.n_requests)
    page = ((idx // lines) % spec.footprint_pages).astype(jnp.int32)
    return Trace(page=page,
                 offset=((idx % lines) * spec.line).astype(jnp.int32),
                 is_write=_writes(k3, spec),
                 size=jnp.full(spec.n_requests, spec.line, jnp.int32))


@functools.partial(jax.jit, static_argnames=("spec",))
def strided(spec: TraceSpec) -> Trace:
    k = jax.random.PRNGKey(spec.seed)
    k2, k3 = jax.random.split(k)
    idx = jnp.arange(spec.n_requests)
    page = ((idx * spec.stride_pages) % spec.footprint_pages).astype(jnp.int32)
    return Trace(page=page, offset=_offsets(k2, spec),
                 is_write=_writes(k3, spec),
                 size=jnp.full(spec.n_requests, spec.line, jnp.int32))


@functools.partial(jax.jit, static_argnames=("spec",))
def pointer_chase(spec: TraceSpec) -> Trace:
    """Random-walk page chain: each access determined by a hash of the
    previous page — no locality, worst case for any placement policy."""
    k = jax.random.PRNGKey(spec.seed)
    k2, k3 = jax.random.split(k)

    def step(p, i):
        nxt = (p * 1103515245 + 12345 + i) % spec.footprint_pages
        return nxt, nxt

    _, page = jax.lax.scan(step, jnp.int32(1),
                           jnp.arange(spec.n_requests, dtype=jnp.int32))
    return Trace(page=page.astype(jnp.int32), offset=_offsets(k2, spec),
                 is_write=_writes(k3, spec),
                 size=jnp.full(spec.n_requests, spec.line, jnp.int32))


@functools.partial(jax.jit, static_argnames=("spec",))
def mixed(spec: TraceSpec) -> Trace:
    """Interleave sequential streaming with zipfian reuse traffic."""
    z = zipfian(spec)
    s = sequential(spec)
    k = jax.random.fold_in(jax.random.PRNGKey(spec.seed), 99)
    pick_seq = jax.random.uniform(k, (spec.n_requests,)) < spec.seq_frac
    return Trace(*(jnp.where(pick_seq, a, b) for a, b in zip(s, z)))


@functools.partial(jax.jit, static_argnames=("spec",))
def serve_mixed(spec: TraceSpec) -> Trace:
    """Multi-tenant mixed prefill/decode serving traffic.

    The page-access shape continuous-batching KV serving presents to the
    memory system, without needing the full ``repro.serve`` scheduler:
    ``n_tenants`` tenants share the footprint in equal slices; a
    ``prefill_frac`` share of requests are prefill — sequential *writes*
    marching each tenant's slice forward (prompt ingestion) — and the
    rest are decode — reads spread over the last ``decode_window`` pages
    behind that tenant's prefill frontier (windowed attention reuse)
    plus token writes at the frontier at the usual ``write_frac``.
    Interleaving across tenants is uniform, so the stream mixes hot
    decode reuse with cold streaming writes the way a busy multi-tenant
    serving box does.
    """
    T, W = spec.n_tenants, spec.decode_window
    per = max(spec.footprint_pages // T, 1)
    n = spec.n_requests
    k = jax.random.PRNGKey(spec.seed)
    k1, k2, k3, k4, k5 = jax.random.split(k, 5)
    tenant = jax.random.randint(k1, (n,), 0, T)
    is_prefill = jax.random.uniform(k2, (n,)) < spec.prefill_frac
    # Each tenant's prefill frontier: running count of its prefill
    # requests (vectorized per-tenant cumsum via one-hot columns).
    onehot = (tenant[:, None] == jnp.arange(T)[None, :]) & is_prefill[:, None]
    frontier = jnp.take_along_axis(jnp.cumsum(onehot, axis=0),
                                   tenant[:, None], axis=1)[:, 0]
    page_prefill = frontier % per
    delta = jax.random.randint(k3, (n,), 0, W)
    page_decode = jnp.clip(frontier - 1 - delta, 0) % per
    page = tenant * per + jnp.where(is_prefill, page_prefill, page_decode)
    is_write = jnp.where(is_prefill, True,
                         (jax.random.uniform(k4, (n,)) < spec.write_frac)
                         & (delta == 0))
    return Trace(page=page.astype(jnp.int32),
                 offset=_offsets(k5, spec),
                 is_write=is_write,
                 size=jnp.full(n, spec.line, jnp.int32))


_PATTERNS = {"zipfian": zipfian, "sequential": sequential, "strided": strided,
             "pointer": pointer_chase, "mixed": mixed,
             "serve_mixed": serve_mixed}


def generate(spec: TraceSpec) -> Trace:
    return _PATTERNS[spec.pattern](spec)
