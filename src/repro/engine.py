"""The session API: one stateful, mesh-aware entry point for the platform.

The paper's value proposition is *fast iterative design exploration* —
and an exploration session is stateful: you compile a geometry once, run
a workload, look at the counters, tweak a knob or a policy, continue
from warm state, fan a grid out over devices, and keep going.
:class:`Engine` is that session as an object:

    from repro import Engine
    from repro.core import paper_platform

    engine = Engine(paper_platform().with_(chunk=512))
    state, outs = engine.run(trace)                 # one design point
    state, outs = engine.run(trace2, state=state)   # continue, in place
    res = engine.sweep(spec, trace, mesh="auto")    # grid, sharded
    res = engine.continue_sweep(res, trace2, mesh="auto")   # warm grid

An ``Engine`` owns three things:

* the **static geometry** (``config.static_key`` of its config) — the
  only thing that forces recompilation;
* a **frozen** :class:`~repro.core.policies.PolicyRegistry` — an
  immutable snapshot of the policy table taken at construction, so a
  session's compiled programs can never be invalidated (or silently
  changed) by later ``policies.register`` calls;
* the **unified jit entry-point cache** (module-level in
  ``core.emulator``, shared by every Engine): one cache keyed by
  (static geometry, registry, batch, donate, shape signature) subsumes
  the four hand-rolled jit variants the free-function API used to carry,
  so constructing a second same-geometry Engine reuses every cached
  executable and :attr:`Engine.compile_count` reports real compilations
  without poking jit internals.

States passed into ``run``/``run_stream``/``continue_sweep`` are
**donated by default**: the session contract is that carried state moves
forward in place (the packed table updates without an O(n_pages) copy)
and the passed-in object is CONSUMED — reading it afterwards raises.
Pass ``donate=False`` to keep your copy.
"""
from __future__ import annotations

from typing import Iterable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import counters as counters_lib
from repro.core.config import (EmulatorConfig, RuntimeParams,
                               canonical_config, static_key)
from repro.core.emulator import (EmulatorState, Trace, as_registry,
                                 entry_cache_count, entry_point, init_state,
                                 pad_trace)
from repro.core.faults import FaultPlan
from repro.core.policies import PolicyRegistry
from repro.sweep.results import SweepResult
from repro.sweep.spec import DesignPoint, SweepSpec, build_points


class RunResult(NamedTuple):
    """Outcome of one :meth:`Engine.run` / :meth:`Engine.run_stream`:
    unpacks as ``(state, outs)``; ``outs`` maps ``returns`` / ``device``
    / ``latency`` to per-request arrays (trimmed to the trace length)."""

    state: EmulatorState
    outs: dict

    def summary(self) -> dict:
        """Host-side counter summary (per-tier traffic, latency, energy)."""
        return counters_lib.summary(self.state.counters)


def stack_params(points: list[DesignPoint]) -> RuntimeParams:
    """Stack per-point RuntimeParams into one pytree with a leading
    point axis (the vmap axis)."""
    ps = [p.params for p in points]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def sweep_mesh():
    """A 1-D device mesh over every local device, for sharded sweeps."""
    from repro.launch.mesh import make_dev_mesh

    return make_dev_mesh(model=1)


def _prefetched(segments, depth: int):
    """Keep ``depth`` upcoming segments transferred to device ahead of
    consumption, so the host->device copy of segment ``k+1`` overlaps
    the in-flight emulation of segment ``k`` (JAX dispatch is async; the
    transfer is enqueued, not waited on). Bitwise-neutral: values are
    unchanged, only their placement time moves."""
    from collections import deque

    it = iter(segments)
    buf: deque = deque()

    def pull():
        try:
            buf.append(jax.tree.map(jax.device_put, next(it)))
        except StopIteration:
            pass

    for _ in range(max(depth, 1)):
        pull()
    while buf:
        yield buf.popleft()
        pull()


def _pad_to_multiple(tree, n: int, mult: int):
    """Pad the leading (point) axis of every leaf to a multiple of
    ``mult`` by repeating the last point. Works on stacked params and on
    stacked states alike."""
    pad = (-n) % mult
    if pad == 0:
        return tree, 0
    padded = jax.tree.map(
        lambda x: jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)]),
        tree,
    )
    return padded, pad


class Engine:
    """A compiled, stateful session over one static platform geometry.

    ``cfg`` supplies the static geometry (and the default runtime design
    point); ``registry`` optionally restricts/overrides the policy table
    — a ``PolicyRegistry``, a tuple of registered names, or None for a
    snapshot of everything registered so far. All methods accept an
    optional ``params`` (a ``RuntimeParams`` whose ``policy_id`` indexes
    *this engine's registry*) defaulting to the config's runtime point.
    """

    def __init__(self, cfg: EmulatorConfig, *, registry=None):
        self.cfg = cfg
        self.registry: PolicyRegistry = as_registry(registry)
        # Compiled programs are keyed on static geometry only; runtime
        # knobs travel in params, so geometry-equal sessions share every
        # executable.
        self._static = canonical_config(cfg)
        self._skey = static_key(cfg)
        self._valid_cache: dict[int, jax.Array] = {}
        if cfg.policy in self.registry:
            self._default_params = RuntimeParams.from_config(cfg)._replace(
                policy_id=jnp.int32(self.registry.index(cfg.policy)))
        else:
            # A restricted registry without the config's policy has no
            # well-defined default design point — defaulting to the
            # *global* policy_id would silently run a different policy
            # (the lax.switch clamps out-of-range ids). Defer the error
            # to default-params use; explicit params= always works.
            self._default_params = None

    @property
    def params(self) -> RuntimeParams:
        """The config's runtime design point, with ``policy_id`` indexing
        this engine's registry. Raises when the registry was restricted
        past ``cfg.policy`` — pass ``params=`` explicitly then."""
        if self._default_params is None:
            raise ValueError(
                f"config policy {self.cfg.policy!r} is not in this "
                f"engine's registry {self.registry.names}: there is no "
                "default design point — pass params= with a policy_id "
                "indexing the engine's registry")
        return self._default_params

    # ------------------------------------------------------------------
    # plumbing
    # ------------------------------------------------------------------
    @property
    def compile_count(self) -> int:
        """Compiled emulation programs held for this geometry (all
        sessions; backed by the unified entry-point cache)."""
        return entry_cache_count(self._skey)

    def init_state(self, params: RuntimeParams | None = None) -> EmulatorState:
        """Fresh platform state for this geometry (tier boundary and
        pre-pinned fraction read from ``params``). Every leaf gets its
        own buffer, so the state is safe to pass back with the default
        donation (a raw ``core.init_state`` shares one zero scalar
        across leaves, which XLA refuses to donate twice)."""
        state = init_state(self._static,
                           self.params if params is None else params)
        return jax.tree.map(jnp.array, state)

    def _entry_for(self, n: int, *, carried: bool, donate: bool,
                   fsig=None):
        """The compiled single-run entry point for an ``n``-request
        padded trace — the single source of truth for the run-path
        shape-sig layout (``benchmarks/bench_engine.py`` uses it for its
        raw-jit baseline). ``carried`` selects the continued-state
        program (fresh state is otherwise built inside the program, and
        donation only ever applies to a carried state). ``fsig`` is the
        :class:`FaultPlan` shape signature (None = no plan) — a plan's
        event-array shapes are executable determinants like everything
        else in the sig."""
        return entry_point(self._static, self.registry,
                           donate=donate and carried,
                           shape_sig=(n, False, not carried, fsig))

    @staticmethod
    def _fault_sig(faults):
        return None if faults is None else (faults.shape_sig,
                                            faults.is_batched)

    def _dispatch(self, trace, valid, state, params, donate, faults=None):
        fn = self._entry_for(len(trace), carried=state is not None,
                             donate=donate, fsig=self._fault_sig(faults))
        return fn(self._static, self.registry, trace, valid, state, params,
                  faults)

    @staticmethod
    def _resolve_donate(donate: bool | None, state) -> bool:
        """Tri-state donate: None (the default) means donate whatever
        carried state there is; an EXPLICIT True with no state to donate
        raises instead of being silently dropped."""
        if donate and state is None:
            raise ValueError(
                "donate=True requires state=...: a fresh run builds its "
                "state inside the program and has nothing of yours to "
                "donate (the default donate=None already donates a "
                "passed-in state)")
        return True if donate is None else donate

    def _ones_valid(self, n: int) -> jax.Array:
        """All-valid mask, cached per length: a chunk-aligned trace needs
        no padding, and rebuilding the mask every call is pure dispatch
        overhead on the continued/serving hot path."""
        v = self._valid_cache.get(n)
        if v is None:
            v = jnp.ones(n, bool)
            self._valid_cache[n] = v
        return v

    # ------------------------------------------------------------------
    # single design point
    # ------------------------------------------------------------------
    def run(self, trace: Trace, *, params: RuntimeParams | None = None,
            state: EmulatorState | None = None,
            valid: jax.Array | None = None,
            donate: bool | None = None,
            faults: FaultPlan | None = None) -> RunResult:
        """Run one trace through the platform at one design point.

        The trace is padded to a chunk multiple automatically (outputs
        come back trimmed to the original length); pass ``valid`` only
        with an already-padded trace. ``state`` continues a previous run
        and is **donated (consumed) by default** — the packed table
        updates in place; pass ``donate=False`` to keep it readable.
        ``faults`` injects a :class:`~repro.core.faults.FaultPlan`
        (events keyed on the carried state's absolute ``chunk_idx``);
        None is bitwise-identical to the empty plan.
        """
        params = self.params if params is None else params
        donate = self._resolve_donate(donate, state)
        n = len(trace)
        if valid is None:
            if n % self.cfg.chunk:
                trace, valid = pad_trace(self.cfg, trace)
            else:
                valid = self._ones_valid(n)
        elif n % self.cfg.chunk:
            raise ValueError("explicit valid= requires a chunk-multiple "
                             "trace (use pad_trace, or drop valid=)")
        state, outs = self._dispatch(trace, valid, state, params, donate,
                                     faults)
        if len(trace) != n:
            outs = jax.tree.map(lambda x: x[:n], outs)
        return RunResult(state, outs)

    def run_stream(self, segments: Iterable[Trace], *,
                   params: RuntimeParams | None = None,
                   state: EmulatorState | None = None,
                   donate: bool | None = None,
                   prefetch: int = 0,
                   faults: FaultPlan | None = None) -> RunResult:
        """Emulate a trace delivered as segments — the serving-scale path
        for streams larger than device memory.

        Segments may have arbitrary lengths: requests are re-chunked
        across segment boundaries (a sub-chunk remainder is carried into
        the next segment), so the result is **bitwise identical** to one
        :meth:`run` over the concatenated trace — same outputs, same
        final state. Segments of equal, chunk-multiple length share a
        single compiled executable; ragged lengths compile per distinct
        length. Intermediate states are engine-owned and always donated;
        ``donate`` governs only a caller-passed ``state`` (consumed by
        default, like :meth:`run`).

        ``prefetch`` > 0 keeps that many upcoming segments transferred
        to device ahead of consumption, overlapping the host->device
        copy of segment ``k+1`` (often a lazily *generated* segment)
        with the in-flight emulation of segment ``k``. Results are
        bitwise identical at any depth.

        One ``faults`` plan spans the whole stream: its events are keyed
        on the carried state's absolute ``chunk_idx``, so the same plan
        is threaded into every segment dispatch and each event fires in
        whichever segment reaches its stamp (the serving scheduler
        relies on this across dispatch boundaries).
        """
        params = self.params if params is None else params
        donate = self._resolve_donate(donate, state)
        if prefetch:
            segments = _prefetched(segments, prefetch)
        chunk = self.cfg.chunk
        carry: Trace | None = None
        parts: list[dict] = []
        first = True
        for seg in segments:
            buf = seg if carry is None else Trace(
                *(jnp.concatenate([a, b]) for a, b in zip(carry, seg)))
            m = len(buf) - len(buf) % chunk
            if m == 0:
                carry = buf
                continue
            head = Trace(*(x[:m] for x in buf))
            carry = Trace(*(x[m:] for x in buf)) if m < len(buf) else None
            state, outs = self._dispatch(
                head, self._ones_valid(m), state, params,
                donate if first else True, faults)
            parts.append(outs)
            first = False
        if carry is not None and len(carry):
            n = len(carry)
            padded, valid = pad_trace(self.cfg, carry)
            state, outs = self._dispatch(padded, valid, state, params,
                                         donate if first else True, faults)
            parts.append(jax.tree.map(lambda x: x[:n], outs))
        if not parts:
            z = jnp.zeros(0, jnp.int32)
            if state is None:
                state = self.init_state(params)
            return RunResult(state, {"returns": z, "device": z, "latency": z})
        outs = {k: jnp.concatenate([p[k] for p in parts]) for k in parts[0]}
        return RunResult(state, outs)

    def run_channels(self, traces: Trace, *,
                     params: RuntimeParams | None = None,
                     faults: FaultPlan | None = None):
        """FPGA-style spatial parallelism: emulate independent trace
        channels at once (``traces`` has a leading channel axis; each
        channel's length must be a chunk multiple). Returns
        ``(states, outs)`` with the channel axis leading. ``params`` —
        and the optional shared ``faults`` plan — apply to every
        channel."""
        params = self.params if params is None else params
        fn = entry_point(self._static, self.registry,
                         shape_sig=("channels", tuple(traces.page.shape),
                                    self._fault_sig(faults)))
        batched = jax.vmap(
            lambda t: fn(self._static, self.registry, t, None, None, params,
                         faults))
        return batched(traces)

    # ------------------------------------------------------------------
    # design-space sweeps
    # ------------------------------------------------------------------
    def _sweep_batch(self, spec):
        """Normalize spec/points/params into (points, registry, params)."""
        if isinstance(spec, RuntimeParams):
            # A pre-stacked params batch: policy_id already indexes this
            # engine's registry; synthesize index-only points for rows().
            n = int(jnp.shape(spec.policy_id)[0])
            points = [DesignPoint(index=i, coords=(("point", i),),
                                  cfg=self.cfg) for i in range(n)]
            return points, self.registry, spec
        points = list(spec) if isinstance(spec, (list, tuple)) \
            else build_points(spec)
        if not points:
            raise ValueError("empty sweep")
        keys = {static_key(p.cfg) for p in points}
        if keys != {self._skey}:
            raise ValueError(
                f"points disagree on this engine's static geometry: {keys}")
        # Compile the policy switch only over policies actually present;
        # remap each point's policy_id into that restricted registry.
        names: list[str] = []
        for p in points:
            if p.cfg.policy not in names:
                names.append(p.cfg.policy)
        registry = self.registry.subset(names)
        ids = jnp.asarray([registry.index(p.cfg.policy) for p in points],
                          jnp.int32)
        params = stack_params(points)._replace(policy_id=ids)
        return points, registry, params

    def sweep(self, spec: SweepSpec | list[DesignPoint] | RuntimeParams,
              trace: Trace, *, mesh=None, states=None,
              donate: bool | None = None,
              faults: FaultPlan | None = None) -> SweepResult:
        """Evaluate every design point of ``spec`` on ``trace`` in ONE
        compiled, vmapped emulation.

        ``spec``: a :class:`SweepSpec` grid, a ``DesignPoint`` list, or a
        pre-stacked ``RuntimeParams`` batch (``policy_id`` indexing this
        engine's registry). All points must share this engine's static
        geometry.

        ``mesh``: None runs on the default device; ``"auto"`` builds a
        1-D mesh over all local devices; an explicit ``jax.sharding.Mesh``
        shards the point axis over its first axis (the point count is
        padded to a mesh multiple by replicating the last point; padding
        is dropped from the results).

        ``states``: stacked per-point ``EmulatorState`` (a previous
        sweep's ``SweepResult.states``) to continue from. Continued
        sweeps **compose with mesh sharding**: the stacked states are
        padded and placed with the same ``NamedSharding`` as the params,
        so an incremental sweep fans out across devices exactly like a
        fresh one. ``donate`` defaults to True when ``states`` is given
        (the session contract — the passed-in states are CONSUMED where
        their sharding already matches; resharded states donate the
        transferred copy).

        ``faults``: one shared :class:`FaultPlan` applied to every
        point, or a stacked per-point batch (``faults.stack_plans`` —
        pad with ``pad_plan`` first so shapes agree) making the failure
        rate itself a swept design axis. A stacked batch is padded and
        sharded alongside the params.
        """
        points, registry, params = self._sweep_batch(spec)
        return self._sweep_exec(points, registry, params, trace,
                                mesh=mesh, states=states, donate=donate,
                                faults=faults)

    def _sweep_exec(self, points, registry, params, trace, *,
                    mesh, states, donate, faults=None) -> SweepResult:
        """Run an already-normalized (points, registry, stacked params)
        batch — shared by :meth:`sweep` and :meth:`continue_sweep`."""
        n = len(points)
        if donate is None:
            donate = states is not None
        if donate and states is None:
            raise ValueError(
                "donate=True requires states=... (a previous "
                "SweepResult.states): donation aliases the carried "
                "per-point states into the outputs, and a fresh-state "
                "sweep has nothing to donate — without states= the flag "
                "used to be silently ignored")
        stacked = params     # pre-padding batch, recorded for continuation
        padded, valid = pad_trace(self.cfg, trace)
        if mesh == "auto":
            mesh = sweep_mesh()
        n_padded = 0
        if mesh is not None:
            size = mesh.devices.shape[0]
            sharding = NamedSharding(mesh, PartitionSpec(mesh.axis_names[0]))
            params, n_padded = _pad_to_multiple(params, n, size)
            params = jax.device_put(params, sharding)
            if states is not None:
                states, _ = _pad_to_multiple(states, n, size)
                states = jax.device_put(states, sharding)
            if faults is not None and faults.is_batched:
                faults, _ = _pad_to_multiple(faults, n, size)
                faults = jax.device_put(faults, sharding)
        fn = entry_point(self._static, registry, batch=True, donate=donate,
                         shape_sig=(len(padded), n + n_padded,
                                    states is None, mesh,
                                    self._fault_sig(faults)))
        states, outs = fn(self._static, registry, padded, valid, states,
                          params, faults)
        if n_padded:
            states, outs = jax.tree.map(lambda x: x[:n], (states, outs))
        return SweepResult(points=points, states=states, outs=outs,
                           params=stacked, registry=registry)

    def continue_sweep(self, result: SweepResult, trace: Trace, *,
                       mesh=None, donate: bool = True,
                       faults: FaultPlan | None = None) -> SweepResult:
        """Continue a previous sweep on a further trace segment — every
        point resumes from its own warm state, donated (consumed) by
        default, optionally fanned out over ``mesh`` (the stacked states
        are sharded alongside the params). A mesh-sharded continued
        sweep is bitwise-equal to the single long unsharded sweep.

        The continuation replays the *recorded* stacked params/registry
        of ``result`` when present (exact for every sweep flavour,
        including pre-stacked ``RuntimeParams`` batches whose knobs are
        not recoverable from ``result.points``); results from older
        pickles without the record fall back to rebuilding from points.
        """
        if result.params is not None:
            return self._sweep_exec(result.points, result.registry,
                                    result.params, trace, mesh=mesh,
                                    states=result.states, donate=donate,
                                    faults=faults)
        return self.sweep(result.points, trace, mesh=mesh,
                          states=result.states, donate=donate, faults=faults)


__all__ = ["Engine", "RunResult", "PolicyRegistry", "stack_params",
           "sweep_mesh"]
