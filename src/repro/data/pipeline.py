"""Deterministic, restart-safe synthetic LM data pipeline.

Every batch is a pure function of (seed, step, shard) — the property that
makes checkpoint/restart exact and elastic resharding trivial: a restarted
job at step k on a different data-parallel layout regenerates byte-identical
global batches. Sequences are Markov-chain token streams (non-uniform
unigram + bigram structure) so losses actually *decrease* during the
example training runs, plus next-token labels.

For frame-frontend archs the pipeline emits deterministic pseudo-frames
(the modality stub mandated by the assignment).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend: str = "tokens"
    frame_dim: int = 0


def _markov_batch(cfg: DataConfig, step: int) -> dict:
    """Tokens follow x_{t+1} = (a*x_t + noise) mod V — cheap structure a
    model can learn (the example training driver shows decreasing loss)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2 = jax.random.split(key)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab
    x0 = jax.random.randint(k1, (b, 1), 0, v)
    noise = jax.random.randint(k2, (b, s), 0, max(2, v // 64))

    def stepfn(x, n):
        nxt = (x * 31 + 7 + n) % v
        return nxt, nxt

    _, seq = jax.lax.scan(stepfn, x0[:, 0], noise.T)
    tokens = jnp.concatenate([x0, seq.T], axis=1)  # [B, S+1]
    return {"inputs": tokens[:, :-1].astype(jnp.int32),
            "labels": tokens[:, 1:].astype(jnp.int32)}


def _frame_batch(cfg: DataConfig, step: int) -> dict:
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 77), step)
    k1, k2 = jax.random.split(key)
    b, s = cfg.global_batch, cfg.seq_len
    frames = jax.random.normal(k1, (b, s, cfg.frame_dim), jnp.float32)
    labels = jax.random.randint(k2, (b, s), 0, cfg.vocab).astype(jnp.int32)
    return {"inputs": frames, "labels": labels}


def make_batch_iterator(cfg: DataConfig, start_step: int = 0):
    """Yields (step, batch) forever, deterministically, resumable at any
    step."""
    fn = jax.jit(lambda s: (_frame_batch(cfg, s) if cfg.frontend == "frames"
                            else _markov_batch(cfg, s)),
                 static_argnums=())
    step = start_step
    while True:
        yield step, fn(jnp.int32(step))
        step += 1


def batch_specs(cfg: DataConfig):
    """ShapeDtypeStructs for one global batch (dry-run input stand-ins)."""
    if cfg.frontend == "frames":
        inputs = jax.ShapeDtypeStruct(
            (cfg.global_batch, cfg.seq_len, cfg.frame_dim), jnp.float32)
    else:
        inputs = jax.ShapeDtypeStruct(
            (cfg.global_batch, cfg.seq_len), jnp.int32)
    labels = jax.ShapeDtypeStruct((cfg.global_batch, cfg.seq_len), jnp.int32)
    return {"inputs": inputs, "labels": labels}
