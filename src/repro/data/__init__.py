"""Deterministic sharded synthetic token pipeline."""
from .pipeline import DataConfig, make_batch_iterator, batch_specs

__all__ = ["DataConfig", "make_batch_iterator", "batch_specs"]
