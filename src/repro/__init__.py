"""HMES — a hybrid memory emulation system as a JAX platform.

Public surface:

* :class:`repro.Engine` — the stateful session API (runs, streams,
  channels, incremental mesh-sharded sweeps); the durable entry point.
* ``repro.core`` — the emulation pipeline itself (config, packed
  redirection table, DMA, latency scans, policies, counters).
* ``repro.sweep`` — design-space grids (``SweepSpec``) and the results
  table; execution happens through ``Engine.sweep``.

Exports resolve lazily (PEP 562): ``import repro`` must stay free of
jax side effects so entry points that configure ``XLA_FLAGS`` before
first jax init (``repro.launch.dryrun``) keep working under
``python -m``.
"""
__all__ = ["Engine", "RunResult", "PolicyRegistry"]


def __getattr__(name):
    if name in ("Engine", "RunResult"):
        from repro import engine

        return getattr(engine, name)
    if name == "PolicyRegistry":
        from repro.core.policies import PolicyRegistry

        return PolicyRegistry
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
