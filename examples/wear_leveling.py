"""Endurance study — the paper's core asymmetry (Table I) as a design
space: NVM absorbs a limited number of writes per cell, so placement
policies are judged not only on AMAT/hit-rate but on how evenly they
spread writes over slow frames (the packed table's WEAR lane, charged by
demand writes AND by the DMA engine's full-page migration writes).

The study sweeps pin fraction x policy x write_weight as ONE compiled,
vmapped emulation over a churn-heavy write trace (rotating hot window
wider than the fast tier, so migration never settles), then derives a
device-lifetime estimate from each point's peak frame wear:

    lifetime ~ endurance_per_cell / (peak_wear / emulated_time)

``wear_level`` must beat plain ``hotness`` on peak wear at (near-)equal
hit rate — asserted by ``--check`` (the CI smoke job runs
``--quick --check``).

    PYTHONPATH=src python examples/wear_leveling.py \
        [--quick] [--check] [--out wear_leveling.csv] [--requests N]
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np                                     # noqa: E402

from repro import Engine                               # noqa: E402
from repro.core import EmulatorConfig, Trace           # noqa: E402
from repro.core import table as table_lib              # noqa: E402
from repro.sweep import SweepSpec                      # noqa: E402


def churn_trace(cfg: EmulatorConfig, n: int, hot_w: int, period: int,
                write_frac: float, seed: int = 0) -> Trace:
    """Rotating write-hot window over the slow tier, wider than the fast
    tier: promotions churn continuously, so both demand writes and
    migration writes keep landing on NVM frames. (The wear_level tests
    load this exact function via tests/conftest.py ``make_churn_trace``.)"""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    nf, ns = cfg.n_fast_pages, cfg.n_slow_pages
    idx = np.arange(n)
    base = (idx // period) * (hot_w // 2)   # rotate by half a window
    page = (nf + (base + rng.integers(0, hot_w, n)) % ns).astype(np.int32)
    off = (rng.integers(0, cfg.page_size // 64, n) * 64).astype(np.int32)
    wr = rng.random(n) < write_frac
    sz = np.full(n, 64, np.int32)
    return Trace(jnp.asarray(page), jnp.asarray(off), jnp.asarray(wr),
                 jnp.asarray(sz))


def lifetime_days(cfg: EmulatorConfig, peak_wear: int,
                  emulated_cycles: int) -> float:
    """Crude lifetime projection: cycles are ns, each WEAR unit is one
    line-sized write to the most-worn frame, endurance is per-cell write
    cycles (config technology table)."""
    if peak_wear <= 0:
        return float("inf")
    endurance = 10.0 ** cfg.slow.endurance_log10
    writes_per_s = peak_wear / (emulated_cycles * 1e-9)
    return endurance / writes_per_s / (24 * 3600)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (fewer requests)")
    ap.add_argument("--check", action="store_true",
                    help="assert wear_level beats hotness on peak wear "
                         "at (near-)equal hit rate")
    ap.add_argument("--out", default=None,
                    help="CSV path for the sweep rows (+lifetime column)")
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args()

    base = EmulatorConfig(n_fast_pages=64, n_slow_pages=448, chunk=256,
                          hot_threshold=4, decay_every=8, wear_slack=16)
    n = args.requests or (40_000 if args.quick else 120_000)
    trace = churn_trace(base, n, hot_w=96, period=2048, write_frac=0.7)

    # pin fraction x policy x write_weight: one compiled, vmapped sweep.
    res = Engine(base).sweep(SweepSpec(
        base=base,
        policies=("static", "hotness", "write_bias", "wear_level"),
        extra_axes=(("pin_fast_fraction", (0.0, 0.25)),
                    ("write_weight", (1, 4))),
    ), trace)

    rows = res.rows()
    clock = np.asarray(res.states.clock)
    for r, c in zip(rows, clock):
        r["lifetime_days"] = round(lifetime_days(base, r["nvm_peak_wear"],
                                                 int(c)), 3)

    keys = ("label", "amat_cyc", "fast_hit_rate", "swaps", "nvm_peak_wear",
            "nvm_total_writes", "lifetime_days")
    widths = [max(len(k), *(len(f"{r[k]:.3f}" if isinstance(r[k], float)
                                else str(r[k])) for r in rows)) for k in keys]
    print("endurance study — pin fraction x policy x write_weight "
          f"({len(rows)} design points, one compilation):")
    print("  ".join(k.ljust(w) for k, w in zip(keys, widths)))
    for r in rows:
        cells = [f"{r[k]:.3f}" if isinstance(r[k], float) else str(r[k])
                 for k in keys]
        print("  ".join(v.rjust(w) for v, w in zip(cells, widths)))

    def row(policy, pin=0.0, ww=1):
        return next(r for r in rows if r["policy"] == policy
                    and r["pin_fast_fraction"] == pin
                    and r["write_weight"] == ww)

    hot, wl = row("hotness"), row("wear_level")
    print(f"\nwear_level vs hotness (pin=0, write_weight=1): peak wear "
          f"{wl['nvm_peak_wear']} vs {hot['nvm_peak_wear']}, hit rate "
          f"{wl['fast_hit_rate']:.3f} vs {hot['fast_hit_rate']:.3f}, "
          f"lifetime {wl['lifetime_days']}d vs {hot['lifetime_days']}d")

    if args.out:
        import csv
        with open(args.out, "w", newline="") as fh:
            w = csv.DictWriter(fh, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
        print(f"rows written to {args.out}")

    # pinning sanity: every pinned point kept its pinned pages in DRAM
    dev = np.asarray(table_lib.device(res.states.table))
    flg = np.asarray(table_lib.flags(res.states.table))
    for i, r in enumerate(rows):
        pinned = (flg[i] & table_lib.PIN_FAST) != 0
        assert (dev[i][pinned] == 0).all(), f"pinned page migrated at {i}"

    if args.check:
        assert wl["nvm_peak_wear"] < hot["nvm_peak_wear"], \
            f"wear_level peak {wl['nvm_peak_wear']} !< hotness " \
            f"{hot['nvm_peak_wear']}"
        assert wl["fast_hit_rate"] >= hot["fast_hit_rate"] - 0.02, \
            f"wear_level hit {wl['fast_hit_rate']} << {hot['fast_hit_rate']}"
        assert wl["lifetime_days"] > hot["lifetime_days"]
        print("--check passed: wear_level flattens peak NVM wear at "
              "(near-)equal hit rate")


if __name__ == "__main__":
    main()
