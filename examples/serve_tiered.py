"""Serve a small model with batched requests over the HMMU-managed tiered
KV cache, comparing tier-management policies with and without §III-G
placement contracts (the paper's platform doing its job inside a serving
stack).

Each sequence's first KV page is latency-critical — the attention pass
streams it on every decode step — and on this 4-page fast tier the
migration policies' churn can *demote* exactly those pages (watch the
unpinned hotness/write_bias rows lose fast-tier hit rate to the static
baseline). ``pin=1`` allocates that page under a placement contract
(``HybridAllocator.alloc(pin=True)``): pinned to the tier it lands on,
un-evictable by any policy. The **pinned-page fast hit rate** column —
the fraction of accesses to contracted pages served from DRAM — is the
contract-quality metric: contracts that spill to NVM (more live
sequences than fast pages) are pinned where they landed and drag it
below 100%.

    PYTHONPATH=src python examples/serve_tiered.py
"""
import numpy as np
import jax

import sys
sys.path.insert(0, "src")
import repro.configs as C                       # noqa: E402
from repro.core import EmulatorConfig           # noqa: E402
from repro.memtier import ServeEngine           # noqa: E402
from repro.memtier.engine import Request        # noqa: E402
from repro.models import init_params            # noqa: E402

cfg = C.get_smoke("phi3_mini_3p8b")
params = init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

for policy, pin in (("static", 0), ("hotness", 0), ("hotness", 1),
                    ("write_bias", 0), ("write_bias", 1)):
    emu = EmulatorConfig(n_fast_pages=4, n_slow_pages=128, chunk=32,
                         policy=policy, hot_threshold=3, write_weight=4)
    eng = ServeEngine(cfg, params, batch_size=4, smax=160, emu_cfg=emu,
                      policy=policy, pin_pages_per_seq=pin)
    for r in range(10):
        eng.submit(Request(rid=r,
                           prompt=rng.integers(0, cfg.vocab, 96).astype(np.int32),
                           max_new_tokens=32))
    steps = eng.run()
    rep = eng.report()
    fast = rep["reads_fast"] + rep["writes_fast"]
    slow = rep["reads_slow"] + rep["writes_slow"]
    pinned = (f"pinned-hit={rep['pinned_fast_hit_rate']*100:5.1f}% "
              f"({rep['pinned_accesses']} contracted accesses)"
              if pin else "no contracts")
    print(f"{policy:11s} pin={pin} steps={steps:3d} "
          f"est_time={rep['est_total_cycles']/1e3:8.1f}us "
          f"fast-hit={fast/(fast+slow)*100:5.1f}% "
          f"migrations={rep['migrations']:3d} "
          f"mean_lat={rep['mean_read_latency_cyc']:7.1f}cyc {pinned}")
