"""Serve a small model with batched requests over the HMMU-managed tiered
KV cache, comparing tier-management policies (the paper's platform doing
its job inside a serving stack).

    PYTHONPATH=src python examples/serve_tiered.py
"""
import numpy as np
import jax

import sys
sys.path.insert(0, "src")
import repro.configs as C                       # noqa: E402
from repro.core import EmulatorConfig           # noqa: E402
from repro.memtier import ServeEngine           # noqa: E402
from repro.memtier.engine import Request        # noqa: E402
from repro.models import init_params            # noqa: E402

cfg = C.get_smoke("phi3_mini_3p8b")
params = init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)

for policy in ("static", "hotness", "write_bias"):
    emu = EmulatorConfig(n_fast_pages=4, n_slow_pages=128, chunk=32,
                         policy=policy, hot_threshold=3, write_weight=4)
    eng = ServeEngine(cfg, params, batch_size=4, smax=160, emu_cfg=emu,
                      policy=policy)
    for r in range(10):
        eng.submit(Request(rid=r,
                           prompt=rng.integers(0, cfg.vocab, 96).astype(np.int32),
                           max_new_tokens=32))
    steps = eng.run()
    rep = eng.report()
    fast = rep["reads_fast"] + rep["writes_fast"]
    slow = rep["reads_slow"] + rep["writes_slow"]
    print(f"{policy:11s} steps={steps:3d} est_time={rep['est_total_cycles']/1e3:9.1f}us "
          f"fast-hit={fast/(fast+slow)*100:5.1f}% migrations={rep['migrations']:3d} "
          f"mean_lat={rep['mean_read_latency_cyc']:7.1f}cyc")
