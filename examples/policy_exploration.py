"""Design-space exploration — what the paper built its platform for:
sweep placement policies, NVM technologies and policy knobs, compare
outcomes quickly, and persist the results for cross-run comparison.

Both studies below run through ``repro.sweep``: every grid is ONE
compiled, vmapped emulation (the packed redirection-table rows of all
design points are gathered by one batched kernel launch per chunk).

    PYTHONPATH=src python examples/policy_exploration.py \
        [--out policy_heatmap.csv] [--requests 40000]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro import Engine                              # noqa: E402
from repro.core import paper_platform                 # noqa: E402
from repro.sweep import SweepSpec                     # noqa: E402
from repro.trace import TraceSpec, generate           # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="policy_heatmap.csv",
                    help="CSV path for the hot_threshold x decay_every "
                         "heatmap rows (repro.sweep.load_rows reads it back)")
    ap.add_argument("--requests", type=int, default=40_000)
    args = ap.parse_args()

    trace = generate(TraceSpec(n_requests=args.requests,
                               footprint_pages=100_000, write_frac=0.4,
                               pattern="zipfian", zipf_alpha=1.05))
    base = paper_platform().with_(chunk=512, hot_threshold=4,
                                  write_weight=4, decay_every=32)
    # One session serves both studies: the grids below share the static
    # geometry, so every sweep reuses the session's compiled executables.
    engine = Engine(base)

    # --- study 1: policy x NVM technology (paper Fig 8-style comparison)
    res = engine.sweep(SweepSpec(
        base=base,
        technologies=("3dxpoint", "stt-ram"),
        policies=("static", "hotness", "write_bias", "stream"),
    ), trace)
    print("policy x technology (one compiled sweep):")
    print(res.table())
    print()

    # --- study 2: hotness-policy knob heatmap, persisted to CSV
    # Zipfian hot pages accumulate hotness fast (write_weight is policy-
    # scoped and only biases write_bias, so this hotness grid counts all
    # accesses equally), and the interesting threshold range spans orders
    # of magnitude: the top end effectively disables migration and
    # converges to the static baseline.
    thresholds = (2, 32, 512, 8192)
    decays = (8, 32, 128)
    res2 = engine.sweep(SweepSpec(
        base=base.with_(policy="hotness"),
        extra_axes=(("hot_threshold", thresholds),
                    ("decay_every", decays)),
    ), trace)
    rows = {(r["hot_threshold"], r["decay_every"]): r for r in res2.rows()}

    print("AMAT (cycles) heatmap — hot_threshold (rows) x decay_every (cols):")
    label_w = max(len(f"hot_threshold={th}") for th in thresholds)
    print(" " * label_w + "".join(f"{d:>10d}" for d in decays))
    for th in thresholds:
        cells = "".join(f"{rows[(th, d)]['amat_cyc']:10.1f}" for d in decays)
        print(f"hot_threshold={th}".ljust(label_w) + cells)

    path = res2.to_csv(args.out)
    print(f"\nheatmap rows written to {path} "
          "(load with repro.sweep.load_rows for cross-run comparison)")


if __name__ == "__main__":
    main()
