"""Design-space exploration — what the paper built its platform for:
sweep (placement policy x NVM technology) and compare outcomes quickly.

    PYTHONPATH=src python examples/policy_exploration.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import TECHNOLOGIES, paper_platform, run_trace  # noqa: E402
from repro.trace import TraceSpec, generate                      # noqa: E402

trace = generate(TraceSpec(n_requests=40_000, footprint_pages=100_000,
                           write_frac=0.4, pattern="zipfian",
                           zipf_alpha=1.05))

print(f"{'policy':12s} {'NVM':10s} {'read lat (cyc)':>14s} "
      f"{'fast hit %':>10s} {'migrations':>10s} {'energy mJ':>10s}")
for tech in ("3dxpoint", "stt-ram"):
    for policy in ("static", "hotness", "write_bias", "stream"):
        cfg = paper_platform().with_(
            policy=policy, slow=TECHNOLOGIES[tech], chunk=512,
            hot_threshold=4, write_weight=4, decay_every=32)
        state, _, s = run_trace(cfg, trace)
        fast = s["reads_fast"] + s["writes_fast"]
        slow = s["reads_slow"] + s["writes_slow"]
        print(f"{policy:12s} {tech:10s} {s['mean_read_latency_cyc']:14.1f} "
              f"{fast/(fast+slow)*100:10.1f} {int(state.dma.swaps_done):10d} "
              f"{s['energy_mJ']:10.2f}")
