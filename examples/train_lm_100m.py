"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with checkpointing + auto-resume (CPU-runnable; pass --steps 300 for the
full run, default is shorter so the example finishes quickly).

    PYTHONPATH=src python examples/train_lm_100m.py --steps 300
"""
import argparse
import sys

sys.path.insert(0, "src")
from repro.launch import train as train_mod  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
args = ap.parse_args()

# internlm2 family scaled to ~100M params: the launcher's --scale knob
# multiplies width on the reduced config; scale 12 -> d_model 768 d_ff 1536.
params, final_loss = train_mod.run([
    "--arch", "internlm2-1.8b", "--smoke", "--scale", "12",
    "--steps", str(args.steps), "--batch", "4", "--seq", "256",
    "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
    "--log-every", "10",
])
print(f"final loss: {final_loss:.4f} (checkpoints in {args.ckpt_dir})")
