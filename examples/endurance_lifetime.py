"""Lifetime-vs-AMAT Pareto study: endurance budget x policy x fault rate.

The retirement subsystem turns endurance into a *closed-loop* design
axis: a traced ``endurance_budget`` caps per-frame writes — frames that
cross it are poisoned and their pages rescued to healthy frames — and a
seeded :class:`~repro.core.faults.FaultPlan` injects early frame deaths
on top. This study sweeps

    endurance_budget x policy   (one vmapped ``Engine.sweep`` grid)
    x fault rate                (stacked per-point ``FaultPlan`` batches)

and reads out the paper-facing trade-off: aggressive budgets flatten
peak wear (longer projected lifetime) but burn DMA bandwidth on rescue
migrations (higher AMAT); fault pressure shifts every point. All fault
rates reuse ONE compiled program — plans are padded to a common event
shape, so ``Engine.compile_count`` is flat after the first rate
(asserted by ``--check``).

    PYTHONPATH=src python examples/endurance_lifetime.py \
        [--quick] [--check] [--out endurance_lifetime.csv] [--requests N]
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np                                         # noqa: E402

from repro import Engine                                   # noqa: E402
from repro.core import EmulatorConfig, check_table         # noqa: E402
from repro.core import faults as faults_lib                # noqa: E402
from repro.sweep import SweepSpec                          # noqa: E402
from wear_leveling import churn_trace, lifetime_days       # noqa: E402

BUDGETS = (0, 120, 400)             # 0 = retirement off
POLICIES = ("hotness", "wear_level")
FAULT_RATES = (0.0, 0.01, 0.03)     # fraction of slow frames dying early


def stacked_plans(base: EmulatorConfig, rate: float, n_points: int,
                  n_chunks: int, max_deaths: int) -> faults_lib.FaultPlan:
    """One seeded plan per design point (distinct seeds — independent
    death draws), padded to a shared event shape so every fault rate
    reuses the compiled sweep entry."""
    n_deaths = int(round(rate * base.n_slow_pages))
    slow = np.arange(base.n_fast_pages, base.n_pages)
    plans = [
        faults_lib.pad_plan(
            faults_lib.seeded_plan(1000 + i, pages=slow, n_chunks=n_chunks,
                                   n_deaths=n_deaths,
                                   n_transient=8 * n_deaths),
            max(8 * max_deaths, 1), max(max_deaths, 1))
        for i in range(n_points)
    ]
    return faults_lib.stack_plans(plans)


def pareto(rows: list[dict]) -> set[int]:
    """Indices of rows not dominated on (AMAT min, lifetime max)."""
    front = set()
    for i, r in enumerate(rows):
        dominated = any(
            o["amat_cyc"] <= r["amat_cyc"]
            and o["lifetime_days"] >= r["lifetime_days"]
            and (o["amat_cyc"] < r["amat_cyc"]
                 or o["lifetime_days"] > r["lifetime_days"])
            for o in rows)
        if not dominated:
            front.add(i)
    return front


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (fewer requests)")
    ap.add_argument("--check", action="store_true",
                    help="assert compile flatness, table invariants, and "
                         "fault-pressure monotonicity")
    ap.add_argument("--out", default=None,
                    help="CSV path for all rows (+lifetime/fault columns)")
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args()

    base = EmulatorConfig(n_fast_pages=64, n_slow_pages=448, chunk=256,
                          hot_threshold=4, decay_every=8, wear_slack=16)
    n = args.requests or (40_000 if args.quick else 120_000)
    trace = churn_trace(base, n, hot_w=96, period=2048, write_frac=0.7)
    n_chunks = n // base.chunk

    spec = SweepSpec(base=base, policies=POLICIES,
                     extra_axes=(("endurance_budget", BUDGETS),))
    n_points = len(spec.build())
    max_deaths = int(round(max(FAULT_RATES) * base.n_slow_pages))

    engine = Engine(base)
    all_rows: list[dict] = []
    compiles = []
    for rate in FAULT_RATES:
        faults = stacked_plans(base, rate, n_points, n_chunks, max_deaths)
        res = engine.sweep(spec, trace, faults=faults)
        compiles.append(engine.compile_count)
        rows = res.rows()
        clock = np.asarray(res.states.clock)
        for i, (r, c) in enumerate(zip(rows, clock)):
            r["fault_rate"] = rate
            r["lifetime_days"] = round(
                lifetime_days(base, r["nvm_peak_wear"], int(c)), 3)
            if args.check:
                check_table(res.points[i].cfg,
                            np.asarray(res.states.table[i]))
        all_rows.extend(rows)

    front = pareto(all_rows)
    keys = ("policy", "endurance_budget", "fault_rate", "amat_cyc",
            "fast_hit_rate", "nvm_peak_wear", "frames_retired",
            "transient_faults", "lifetime_days")

    def fmt(r, k):
        v = r[k]
        return f"{v:.3f}" if isinstance(v, float) else str(v)

    widths = [max(len(k), *(len(fmt(r, k)) for r in all_rows)) for k in keys]
    print(f"endurance budget x policy x fault rate ({len(all_rows)} design "
          "points, one compiled sweep reused across fault rates):")
    print("  ".join(k.ljust(w) for k, w in zip(keys, widths)) + "  pareto")
    for i, r in enumerate(all_rows):
        mark = "  *" if i in front else ""
        print("  ".join(fmt(r, k).rjust(w)
                        for k, w in zip(keys, widths)) + mark)

    if args.out:
        import csv
        with open(args.out, "w", newline="") as fh:
            w = csv.DictWriter(fh, fieldnames=list(all_rows[0]) + ["pareto"])
            w.writeheader()
            for i, r in enumerate(all_rows):
                w.writerow({**r, "pareto": int(i in front)})
        print(f"rows written to {args.out}")

    if args.check:
        assert len(set(compiles)) == 1, \
            f"fault-rate sweeps recompiled: compile counts {compiles}"
        by = {(r["policy"], r["endurance_budget"], r["fault_rate"]): r
              for r in all_rows}
        for pol in POLICIES:
            # budget=0, rate=0 is the frozen baseline: nothing retires
            clean = by[(pol, 0, 0.0)]
            assert clean["frames_retired"] == 0
            assert clean["transient_faults"] == 0
            # a finite budget under this churn retires frames
            assert by[(pol, BUDGETS[1], 0.0)]["frames_retired"] > 0, \
                f"budget={BUDGETS[1]} never fired for {pol}"
            # fault pressure is monotone in the injected death count
            r0 = by[(pol, 0, FAULT_RATES[1])]["frames_retired"]
            r1 = by[(pol, 0, FAULT_RATES[2])]["frames_retired"]
            assert 0 < r0 <= r1, f"deaths not monotone for {pol}: {r0},{r1}"
        assert front, "empty Pareto front"
        print("--check passed: one compilation across fault rates, "
              "tables valid, retirement fires and scales with fault rate")


if __name__ == "__main__":
    main()
