"""Continuous-batching serving over the emulated hybrid memory.

A compact tour of ``repro.serve``: a few thousand mixed prefill/decode
sequences flow through the ``ContinuousBatchingScheduler`` on top of one
``Engine`` session — sequences are admitted as slots free up, their
pinned-prefix pages get §III-G placement contracts on the fast tier,
decode windows keep hot KV pages touched, and cold pages are evicted
(and transparently refetched) when the free-page watermark is crossed.

Every dispatched batch is one of the pre-declared bucket sizes, so after
``warmup()`` the run performs **zero** recompilations — watch the
``compile_count`` column stay flat while thousands of sequences of
different lengths drain.

    PYTHONPATH=src python examples/serve_continuous.py
"""
import numpy as np

import sys
sys.path.insert(0, "src")
from repro import Engine                                   # noqa: E402
from repro.core import small_platform                      # noqa: E402
from repro.serve import (ContinuousBatchingScheduler,      # noqa: E402
                         ServeConfig)

cfg = small_platform(n_fast_pages=2048, n_slow_pages=4096, chunk=128)
serve = ServeConfig(
    sorted_batch_sizes=(512, 1024, 2048),  # every dispatch shape, up front
    max_live_seqs=1_500,                   # admission cap (slots)
    max_live_batches=2,                    # async dispatch overlap depth
    pin_pages_per_seq=1,                   # §III-G contract on the prefix
    max_pages_per_seq=6,
    positions_per_page=16,
    window_pages=2,                        # decode attention window
    free_low_frac=0.25, free_high_frac=0.30,  # eviction watermarks
    slo_latency_us=5_000.0, pinned_slo=0.90)

engine = Engine(cfg)
sched = ContinuousBatchingScheduler(engine, serve)
sched.warmup()                             # compile every bucket once
warm = engine.compile_count

rng = np.random.default_rng(0)
n = 2_000
sched.submit(prompt_pages=rng.choice([1, 2, 3, 4], size=n,
                                     p=[0.6, 0.2, 0.1, 0.1]),
             decode_tokens=rng.integers(8, 25, size=n))

print(f"{'step':>5} {'live':>6} {'queued':>7} {'dispatched':>11} "
      f"{'evictions':>10} {'compiles':>9}")
while sched.pending:
    sched.step()
    if sched.dispatches % 8 == 0:
        print(f"{sched.dispatches:>5} {sched.live_seqs:>6} "
              f"{sched.queued:>7} {sched.requests_dispatched:>11} "
              f"{sched.kv.evictions:>10} {engine.compile_count:>9}")
sched.flush()

rep = sched.report()
print(f"\n{rep.n_sequences} sequences, {rep.n_mem_requests} memory "
      f"requests in {rep.n_dispatches} dispatches "
      f"(peak {rep.live_seqs_high_water} live)")
print(f"latency p50 {rep.p50_latency_us:.0f} us, p99 "
      f"{rep.p99_latency_us:.0f} us -> SLO({rep.slo_latency_us:.0f} us) "
      f"attainment {rep.slo_attainment:.3f}")
print(f"pinned fast-hit rate {rep.pinned_fast_hit_rate:.3f} "
      f"(target {rep.pinned_slo:.2f}: "
      f"{'met' if rep.pinned_slo_met else 'MISSED'})")
print(f"evictions {rep.evictions}, refetches {rep.refetches}, "
      f"recompiles after warmup {engine.compile_count - warm}")
assert engine.compile_count == warm, "a dispatch shape escaped the buckets"
