"""Quickstart: emulate a hybrid-memory workload and read the counters.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import paper_platform, run_trace, TECHNOLOGIES
from repro.trace import workload_trace

# The paper's platform: 128MB DRAM + 1GB 3D-XPoint behind a PCIe link.
cfg = paper_platform().with_(chunk=512, policy="hotness", hot_threshold=4)

# One SPEC-2017-like workload from Table III (scaled for a laptop run).
trace, workload, n = workload_trace("520.omnetpp", scale=1e-8)
print(f"workload {workload.name}: {n} post-cache memory requests, "
      f"footprint {workload.footprint_bytes >> 20} MB")

state, outs, summary = run_trace(cfg, trace)
print(f"emulated time: {int(state.clock)/1e6:.2f} ms "
      f"| migrations: {int(state.dma.swaps_done)}")
for k, v in summary.items():
    print(f"  {k:24s} {v}")

# Swap the NVM technology (paper §III-F: arbitrary stall cycles).
for tech in ("3dxpoint", "stt-ram", "flash"):
    cfg2 = cfg.with_(slow=TECHNOLOGIES[tech])
    _, _, s = run_trace(cfg2, trace)
    print(f"NVM={tech:9s} mean read latency "
          f"{s['mean_read_latency_cyc']:8.1f} cycles")
