"""Quickstart: open an emulation session, run a workload, read the
counters, and sweep the NVM technology — all through ``repro.Engine``.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro import Engine                           # noqa: E402
from repro.core import paper_platform              # noqa: E402
from repro.sweep import SweepSpec                  # noqa: E402
from repro.trace import workload_trace             # noqa: E402

# The paper's platform: 128MB DRAM + 1GB 3D-XPoint behind a PCIe link.
cfg = paper_platform().with_(chunk=512, policy="hotness", hot_threshold=4)
engine = Engine(cfg)    # compiled session: one geometry, many runs/sweeps

# One SPEC-2017-like workload from Table III (scaled for a laptop run).
trace, workload, n = workload_trace("520.omnetpp", scale=1e-8)
print(f"workload {workload.name}: {n} post-cache memory requests, "
      f"footprint {workload.footprint_bytes >> 20} MB")

result = engine.run(trace)
state = result.state
print(f"emulated time: {int(state.clock)/1e6:.2f} ms "
      f"| migrations: {int(state.dma.swaps_done)}")
for k, v in result.summary().items():
    print(f"  {k:24s} {v}")

# Swap the NVM technology (paper §III-F: arbitrary stall cycles). All
# three design points run in ONE compiled, vmapped emulation — the same
# session, so the geometry's executables are shared with the run above.
res = engine.sweep(SweepSpec(base=cfg, technologies=("3dxpoint", "stt-ram",
                                                     "flash")), trace)
for row in res.rows():
    print(f"NVM={row['tech']:9s} mean read latency "
          f"{row['amat_cyc']:10.1f} cycles | migrations {row['swaps']}")
