"""Quickstart: emulate a hybrid-memory workload and read the counters.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import paper_platform, run_trace   # noqa: E402
from repro.sweep import SweepSpec, run_sweep       # noqa: E402
from repro.trace import workload_trace             # noqa: E402

# The paper's platform: 128MB DRAM + 1GB 3D-XPoint behind a PCIe link.
cfg = paper_platform().with_(chunk=512, policy="hotness", hot_threshold=4)

# One SPEC-2017-like workload from Table III (scaled for a laptop run).
trace, workload, n = workload_trace("520.omnetpp", scale=1e-8)
print(f"workload {workload.name}: {n} post-cache memory requests, "
      f"footprint {workload.footprint_bytes >> 20} MB")

state, outs, summary = run_trace(cfg, trace)
print(f"emulated time: {int(state.clock)/1e6:.2f} ms "
      f"| migrations: {int(state.dma.swaps_done)}")
for k, v in summary.items():
    print(f"  {k:24s} {v}")

# Swap the NVM technology (paper §III-F: arbitrary stall cycles). All
# three design points run in ONE compiled, vmapped emulation (repro.sweep).
res = run_sweep(SweepSpec(base=cfg, technologies=("3dxpoint", "stt-ram",
                                                  "flash")), trace)
for row in res.rows():
    print(f"NVM={row['tech']:9s} mean read latency "
          f"{row['amat_cyc']:10.1f} cycles | migrations {row['swaps']}")
