"""Design-space exploration throughput: the paper's Fig 8 / Table III
study — technologies x tier ratios x policies x link latencies — as ONE
compiled, vmapped emulation (repro.sweep).

Reports per-point summaries (AMAT, fast-tier hit rate, migrations, NVM
wear, held responses, energy) plus the executor's compile count: the
entire grid shares a single compiled emulation program, which is what makes
sweeping cheap enough to be the default workflow.

Runnable standalone for the perf trajectory::

    PYTHONPATH=src python -m benchmarks.bench_sweep --quick \
        --out sweep.csv --out sweep.jsonl --summary-out BENCH_sweep.json

``--out`` persists the per-point rows (format keyed by extension, see
``repro.sweep.load_rows``); ``--summary-out`` writes the standardized
``BENCH_sweep.json`` payload (benchmarks.schema envelope: timings,
compile count, best point, per-point rows) — committed at the repo root
when a PR moves the numbers, regenerated as a CI artifact every run.
"""

from __future__ import annotations

import argparse
import time

import jax

from benchmarks.schema import (add_check_args, bench_payload, run_check,
                               write_bench_json)
from repro import Engine
from repro.analysis import assert_compile_flat
from repro.core import paper_platform
from repro.sweep import SweepSpec, build_points
from repro.trace import TraceSpec, generate


def make_spec(base=None) -> SweepSpec:
    """2 technologies x 2 tier ratios x 2 policies x 2 link latencies =
    16 design points, all sharing one static geometry."""
    if base is None:
        # paper Table II geometry scaled to a laptop-size page table:
        # 72 K pages total; the tier split itself is a sweep axis.
        base = paper_platform().with_(
            n_fast_pages=8192,
            n_slow_pages=65536,
            chunk=512,
            hot_threshold=4,
            decay_every=32,
            write_weight=4,
        )
    return SweepSpec(
        base=base,
        technologies=("3dxpoint", "stt-ram"),
        fast_fractions=(1 / 9, 2 / 9),
        policies=("hotness", "static"),
        link_lats=(600, 1200),
    )


def run(verbose=True, n_requests=100_000, sharded=None, out=None):
    spec = make_spec()
    points = build_points(spec)
    trace = generate(
        TraceSpec(
            n_requests=n_requests,
            footprint_pages=60_000,
            write_frac=0.4,
            pattern="zipfian",
            zipf_alpha=1.05,
        )
    )

    mesh = "auto" if sharded or len(jax.devices()) > 1 else None
    engine = Engine(points[0].cfg)
    t0 = time.time()
    # allow=1: the grid's ONE compilation; a second entry raises.
    with assert_compile_flat(engine, allow=1,
                             msg="design-space sweep") as cc:
        res = engine.sweep(points, trace, mesh=mesh)
        jax.block_until_ready(res.states.clock)
    first_s = time.time() - t0
    compiles = cc.count
    assert compiles == 1, f"sweep must compile once, got {compiles}"

    t0 = time.time()
    res = engine.sweep(points, trace, mesh=mesh)
    jax.block_until_ready(res.states.clock)
    steady_s = time.time() - t0

    rows = res.rows()
    best = res.best()
    written = []
    for path in [out] if isinstance(out, str) else (out or []):
        write = res.to_jsonl if str(path).endswith(".jsonl") else res.to_csv
        written.append(write(path))
    summary = {
        "n_points": len(points),
        "compiles": compiles,
        "first_call_s": first_s,
        "steady_s": steady_s,
        "us_per_point_req": steady_s / (len(points) * n_requests) * 1e6,
        "best_label": best["label"],
        "best_amat": best["amat_cyc"],
        "rows": rows,
        "out": written,
    }
    if verbose:
        print(res.table())
        msg = (
            f"  {len(points)} design points, {compiles} compilation(s); "
            f"first call {first_s:.2f}s, steady {steady_s:.2f}s "
            f"({summary['us_per_point_req']:.3f} us/point/request)"
        )
        print(msg)
        print(f"  best AMAT: {best['label']} ({best['amat_cyc']:.1f} cyc)")
        for path in written:
            print(f"  rows written to {path}")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--quick", action="store_true", help="20k requests instead of 100k")
    ap.add_argument(
        "--requests",
        type=int,
        default=None,
        help="explicit request count (overrides --quick)",
    )
    ap.add_argument(
        "--out",
        action="append",
        default=[],
        help="persist per-point rows (.jsonl -> JSONL, else CSV); repeatable",
    )
    ap.add_argument("--summary-out", default=None, help="write the run summary dict as JSON")
    add_check_args(ap)
    args = ap.parse_args()
    n = args.requests or (20_000 if args.quick else 100_000)
    summary = run(n_requests=n, out=args.out)
    payload = bench_payload(
        "sweep",
        metrics={
            "n_requests": n,
            "n_points": summary["n_points"],
            "compiles": summary["compiles"],
            "first_call_s": summary["first_call_s"],
            "steady_s": summary["steady_s"],
            "us_per_point_req": summary["us_per_point_req"],
            "best_amat": summary["best_amat"],
        },
        cases=summary["rows"],
        best_label=summary["best_label"],
    )
    if args.summary_out:
        write_bench_json(args.summary_out, payload)
        print(f"  summary written to {args.summary_out}")
    run_check(payload, args, ["us_per_point_req", "compiles"])


if __name__ == "__main__":
    main()
