"""Paper Fig 8: memory-request volume per workload, measured by the
platform's performance counters and re-expanded to paper scale."""
from __future__ import annotations

from repro import Engine
from repro.core import paper_platform
from repro.trace import WORKLOADS, workload_trace


def run(scale=4e-9, verbose=True):
    engine = Engine(paper_platform().with_(chunk=512))
    rows = []
    for name, w in WORKLOADS.items():
        t, _, n = workload_trace(name, scale=scale)
        summ = engine.run(t).summary()
        applied_scale = n * 64 / w.total_traffic_bytes
        rows.append({
            "workload": name,
            "measured_GB_read": summ["GB_read"],
            "measured_GB_written": summ["GB_written"],
            "paper_scale_TB_read": summ["GB_read"] / applied_scale / 1e3,
            "paper_scale_TB_written": summ["GB_written"] / applied_scale / 1e3,
            "energy_mJ": summ["energy_mJ"],
        })
        if verbose:
            r = rows[-1]
            print(f"  {name:15s} R {r['paper_scale_TB_read']:8.3f} TB | "
                  f"W {r['paper_scale_TB_written']:8.3f} TB (paper scale)")
    order = sorted(rows, key=lambda r: -(r["paper_scale_TB_read"]
                                         + r["paper_scale_TB_written"]))
    if verbose:
        print(f"  max: {order[0]['workload']}  min: {order[-1]['workload']} "
              f"(paper: 505.mcf max 5.65TB, 538.imagick min 8.96GB)")
    return rows
