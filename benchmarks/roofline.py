"""Roofline-term derivation from dry-run records (EXPERIMENTS.md §Roofline).

Hardware constants (TPU v5e class, per chip):
    peak bf16 compute 197 TFLOP/s, HBM 819 GB/s, ICI ~50 GB/s/link.

Terms (seconds, per step, per chip — dry-run costs are already per-device):
    compute    = corrected_HLO_FLOPs / 197e12
    memory     = corrected_HLO_bytes / 819e9     (upper bound: pre-fusion
                 operand traffic; `memory_flash_adj` additionally removes
                 the S x S attention-logit traffic that the flash kernels
                 keep in VMEM)
    collective = corrected_collective_bytes / 50e9

MODEL_FLOPS (the "useful" yardstick): 6*N_active*T for training,
2*N_active*T for prefill, 2*N_active*B for decode, plus causal-optimal
attention score/value FLOPs; divided by total chips for the per-chip ratio.
"""
from __future__ import annotations

import json

import repro.configs as configs
from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _attention_flops(cfg, shape) -> float:
    """Causal-optimal attention score+value FLOPs for the whole step."""
    L, H = cfg.n_layers, max(cfg.n_heads, 1)
    if cfg.attn_type == "rwkv6":
        # linear recurrence: ~4 flops per (token, channel) state update
        return 8.0 * shape.global_batch * shape.seq_len * cfg.d_model * L
    if cfg.attn_type == "mla":
        hd = cfg.mla.nope_head_dim + cfg.mla.rope_head_dim + cfg.mla.v_head_dim
    else:
        hd = 2 * cfg.head_dim_
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        per_layer = 2.0 * B * H * S * hd
        win = cfg.window
        if win is not None:
            n_glob = (L // cfg.global_every if cfg.attn_type != "hymba"
                      else len(cfg.hymba_global_layers))
            loc = L - n_glob
            per_layer_loc = 2.0 * B * H * min(win, S) * hd
            return n_glob * per_layer + loc * per_layer_loc
        return L * per_layer
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd+bwd vs fwd
    return mult * L * B * H * S * S * hd / 2.0     # /2: causal triangle


def model_flops(arch: str, shape_name: str) -> float:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    n = cfg.n_active_params()
    if shape.kind == "train":
        base = 6.0 * n * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        base = 2.0 * n * shape.global_batch * shape.seq_len
    else:
        base = 2.0 * n * shape.global_batch       # one token per sequence
    return base + _attention_flops(cfg, shape)


def logit_traffic_adjustment(arch: str, shape_name: str, chips: int,
                             dp: int = 16, tp: int = 16) -> float:
    """Per-device bytes of S x S attention-logit traffic in the naive cost
    variant that flash attention keeps in VMEM (estimate, ~10 passes of
    the fp32 logit tensor fwd+bwd, ~5 fwd-only).

    Sharding-aware: logits shard over batch (dp) always, over heads (tp)
    only when the head count divides the model axis — musicgen (24H),
    gemma3 (8H) and hymba (25H) attention is batch-parallel only."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "decode" or cfg.attn_type == "rwkv6":
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    H = max(cfg.n_heads, 1)
    b_loc = B / dp if B % dp == 0 else B
    if H % tp == 0:
        h_loc, s_loc = H / tp, S            # head-sharded logits
    elif b_loc * H * (S / tp) * S * 4.0 <= 4e9 and S % tp == 0:
        h_loc, s_loc = H, S / tp            # context-parallel (M2) logits
    else:
        h_loc, s_loc = H, S                 # replicated-head fallback
    passes = 10.0 if shape.kind == "train" else 5.0
    return passes * 4.0 * b_loc * h_loc * s_loc * S * cfg.n_layers


def terms(rec: dict, chips: int = 256) -> dict | None:
    """rec: one dryrun.jsonl record with roofline_raw."""
    rr = rec.get("roofline_raw")
    if not rr or rr.get("status") == "skipped":
        return None
    arch, shape = rec["arch"], rec["shape"]
    t_c = rr["flops"] / PEAK_FLOPS
    t_m = rr["bytes"] / HBM_BW
    adj = max(0.0, rr["bytes"]
              - logit_traffic_adjustment(arch, shape, chips)) / HBM_BW
    t_x = rr["coll"] / ICI_BW
    dom = max((("compute", t_c), ("memory", adj), ("collective", t_x)),
              key=lambda kv: kv[1])
    mf = model_flops(arch, shape)
    useful = mf / (rr["flops"] * chips) if rr["flops"] else 0.0
    bound = max(t_c, adj, t_x)
    return {
        "compute_s": t_c, "memory_s": t_m, "memory_flash_adj_s": adj,
        "collective_s": t_x, "dominant": dom[0],
        "model_flops": mf, "useful_ratio": useful,
        "step_lower_bound_s": bound,
        "roofline_fraction": (mf / chips / PEAK_FLOPS) / bound if bound else 0,
    }


def markdown_table(jsonl_path: str, chips: int = 256) -> str:
    lines = ["| arch | shape | compute (ms) | memory^ (ms) | collective (ms) "
             "| dominant | useful | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    with open(jsonl_path) as f:
        for raw in f:
            rec = json.loads(raw)
            if rec.get("status") == "skipped":
                lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                             f"skipped: {rec.get('reason','')[:40]} | — | — |")
                continue
            t = terms(rec, chips)
            if t is None:
                continue
            lines.append(
                f"| {rec['arch']} | {rec['shape']} "
                f"| {t['compute_s']*1e3:.2f} | {t['memory_flash_adj_s']*1e3:.2f} "
                f"| {t['collective_s']*1e3:.2f} | **{t['dominant']}** "
                f"| {t['useful_ratio']*100:.0f}% "
                f"| {t['roofline_fraction']*100:.0f}% |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    print(markdown_table(sys.argv[1] if len(sys.argv) > 1
                         else "results/dryrun.jsonl"))
