"""Design-space exploration — the platform's purpose: compare hybrid-memory
management policies on the same workload (paper §II-B/III-A). Reports mean
access latency, fast-tier hit rate, migrations and energy per policy."""
from __future__ import annotations

from repro import Engine
from repro.core import paper_platform
from repro.trace import TraceSpec, generate


def run(verbose=True, n_requests=120_000):
    spec = TraceSpec(n_requests=n_requests, footprint_pages=120_000,
                     write_frac=0.4, pattern="zipfian", zipf_alpha=1.05)
    trace = generate(spec)
    rows = []
    for policy in ("static", "hotness", "write_bias", "stream"):
        cfg = paper_platform().with_(policy=policy, chunk=512,
                                     hot_threshold=4, write_weight=4,
                                     decay_every=32)
        result = Engine(cfg).run(trace)
        state, summ = result.state, result.summary()
        fast = summ["reads_fast"] + summ["writes_fast"]
        slow = summ["reads_slow"] + summ["writes_slow"]
        rows.append({
            "policy": policy,
            "mean_read_latency": summ["mean_read_latency_cyc"],
            "fast_hit_rate": fast / (fast + slow),
            "migrations": int(state.dma.swaps_done),
            "energy_mJ": summ["energy_mJ"],
            "emulated_ms": int(state.clock) / 1e6,
        })
        if verbose:
            r = rows[-1]
            print(f"  {policy:11s} lat {r['mean_read_latency']:8.1f}cyc  "
                  f"fast-hit {r['fast_hit_rate']*100:5.1f}%  "
                  f"migr {r['migrations']:5d}  "
                  f"energy {r['energy_mJ']:8.2f}mJ  "
                  f"time {r['emulated_ms']:7.2f}ms")
    return rows
