"""Paper Fig 7: simulation time normalized against native execution.

Native execution time = the *emulated* wall-clock of the platform (the
final HMMU cycle counter in ns) — i.e. how long the application's memory
phase takes on the real hardware the emulator models. Each simulator's
slowdown = host wall time / native time. The paper reports FPGA 3.17x,
ChampSim 7241x, gem5 29398x (speedups 2286x / 9280x vs the FPGA).

Our analogue: the jit-compiled HMES emulation pipeline vs the sequential
trace-driven simulator (ChampSim-class) vs the event-driven cycle-level
simulator with CPU model (gem5-class), on the SPEC-2017-like suite.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import Engine
from repro.core import paper_platform
from repro.sims import cycle_sim, trace_sim
from repro.trace import workload_trace

WORKLOADS_SMALL = ["505.mcf", "519.lbm", "538.imagick", "520.omnetpp",
                   "508.namd", "541.leela"]


def _time(fn, reps=1):
    fn()                       # warmup/compile
    t0 = time.time()
    for _ in range(reps):
        out = fn()
    return (time.time() - t0) / reps, out


def run(scale=6e-9, chunk=4096, workloads=None, verbose=True,
        min_requests=16_384):
    cfg = paper_platform().with_(chunk=chunk)
    engine = Engine(cfg)
    rows = []
    for name in workloads or WORKLOADS_SMALL:
        t, w, n = workload_trace(name, scale=scale,
                                 min_requests=min_requests)
        page, off, wr, sz = (np.asarray(x) for x in t)

        def run_emu():
            state = engine.run(t).state
            jax.block_until_ready(state.clock)
            return state

        emu_s, state = _time(run_emu, reps=3)
        native_s = int(state.clock) * 1e-9          # 1 cycle == 1 ns

        ts_s, _ = _time(lambda: trace_sim.simulate(cfg, page, off, wr, sz))
        cs_s, _ = _time(lambda: cycle_sim.simulate(
            cfg, page, off, wr, sz, refresh=True, cpu_model=True))

        row = {
            "workload": name, "requests": n,
            "native_s": native_s,
            "emu_slowdown": emu_s / native_s,
            "tracesim_slowdown": ts_s / native_s,
            "cyclesim_slowdown": cs_s / native_s,
            "speedup_vs_tracesim": ts_s / emu_s,
            "speedup_vs_cyclesim": cs_s / emu_s,
        }
        rows.append(row)
        if verbose:
            print(f"  {name:15s} n={n:6d} emu {row['emu_slowdown']:9.1f}x | "
                  f"trace {row['tracesim_slowdown']:9.1f}x | "
                  f"cycle {row['cyclesim_slowdown']:9.1f}x | "
                  f"speedup {row['speedup_vs_tracesim']:6.1f}x /"
                  f" {row['speedup_vs_cyclesim']:6.1f}x")

    def geomean(key):
        v = np.array([r[key] for r in rows])
        return float(np.exp(np.mean(np.log(v))))

    summary = {k: geomean(k) for k in
               ("emu_slowdown", "tracesim_slowdown", "cyclesim_slowdown",
                "speedup_vs_tracesim", "speedup_vs_cyclesim")}
    if verbose:
        print(f"  geomean: emu {summary['emu_slowdown']:.1f}x, "
              f"trace_sim {summary['tracesim_slowdown']:.1f}x, "
              f"cycle_sim {summary['cyclesim_slowdown']:.1f}x -> "
              f"speedups {summary['speedup_vs_tracesim']:.1f}x / "
              f"{summary['speedup_vs_cyclesim']:.1f}x")
    return rows, summary
