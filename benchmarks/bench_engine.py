"""Session-API dispatch overhead: ``Engine.run`` vs the raw jit call.

The session API must be free at runtime: ``Engine.run`` adds a cache
lookup, trace padding, and result wrapping around the same compiled
executable the raw entry point runs. This microbench measures that
wrapper cost per call on a deliberately tiny workload (so fixed per-call
overhead is not drowned by emulation work), for both the fresh-state and
the donated continued-state paths, plus the cost of *constructing* an
Engine against warm caches (must not recompile).

Runnable standalone::

    PYTHONPATH=src python -m benchmarks.bench_engine --quick \
        --out BENCH_engine.json

Emits the standardized ``BENCH_engine.json`` payload (benchmarks.schema
envelope) — regenerated as a CI artifact every run.
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks.schema import (add_check_args, bench_payload, run_check,
                               write_bench_json)
from repro import Engine
from repro.analysis import assert_compile_flat
from repro.core import paper_platform
from repro.trace import TraceSpec, generate


def _per_call(fn, reps):
    fn()  # warm (compile)
    t0 = time.time()
    for _ in range(reps):
        fn()
    return (time.time() - t0) / reps


def run(verbose=True, n=4_096, reps=50):
    cfg = paper_platform().with_(chunk=256)
    trace = generate(TraceSpec(n_requests=n, footprint_pages=60_000,
                               write_frac=0.4, pattern="zipfian",
                               zipf_alpha=1.05))
    engine = Engine(cfg)

    # --- fresh-state path: Engine.run vs the raw cached entry point.
    sec_engine = _per_call(
        lambda: jax.block_until_ready(engine.run(trace).state.clock), reps)

    from repro.core.emulator import pad_trace
    padded, valid = pad_trace(cfg, trace)
    static, registry = engine._static, engine.registry
    # _entry_for is Engine.run's own entry lookup, so the raw baseline is
    # guaranteed to hit the very executable the wrapped path runs.
    raw = engine._entry_for(len(padded), carried=False, donate=False)
    sec_raw = _per_call(
        lambda: jax.block_until_ready(
            raw(static, registry, padded, valid, None, engine.params)[0].clock),
        reps)

    # --- continued donated path (the serving access pattern).
    def continued_engine():
        s = engine.run(trace).state
        for _ in range(4):
            s = engine.run(trace, state=s).state
        jax.block_until_ready(s.clock)

    sec_engine_cont = _per_call(continued_engine, max(2, reps // 10)) / 5

    raw_don = engine._entry_for(len(padded), carried=True, donate=True)

    def continued_raw():
        s = raw(static, registry, padded, valid, None, engine.params)[0]
        for _ in range(4):
            s = raw_don(static, registry, padded, valid, s, engine.params)[0]
        jax.block_until_ready(s.clock)

    sec_raw_cont = _per_call(continued_raw, max(2, reps // 10)) / 5

    # --- session construction against warm caches: no recompilation.
    t0 = time.time()
    k = 20
    with assert_compile_flat(
            engine, msg="same-geometry Engine construction") as cc:
        for _ in range(k):
            e2 = Engine(cfg.with_(hot_threshold=9))  # same geometry
            jax.block_until_ready(e2.run(trace).state.clock)
    construct_s = (time.time() - t0) / k
    recompiles = cc.count

    metrics = {
        "n_requests": n,
        "us_per_call_engine": sec_engine * 1e6,
        "us_per_call_raw_jit": sec_raw * 1e6,
        "dispatch_overhead_us": (sec_engine - sec_raw) * 1e6,
        "dispatch_overhead_frac": (sec_engine - sec_raw) / sec_raw,
        "us_per_call_engine_continued": sec_engine_cont * 1e6,
        "us_per_call_raw_continued": sec_raw_cont * 1e6,
        "continued_overhead_us": (sec_engine_cont - sec_raw_cont) * 1e6,
        "warm_construct_plus_run_us": construct_s * 1e6,
        "warm_construct_recompiles": recompiles,
    }
    if verbose:
        print(f"  Engine.run (fresh)      {sec_engine*1e6:9.1f} us/call")
        print(f"  raw jit call (fresh)    {sec_raw*1e6:9.1f} us/call "
              f"(overhead {metrics['dispatch_overhead_us']:+.1f} us, "
              f"{metrics['dispatch_overhead_frac']*100:+.1f}%)")
        print(f"  Engine.run (continued)  {sec_engine_cont*1e6:9.1f} us/call")
        print(f"  raw jit (continued)     {sec_raw_cont*1e6:9.1f} us/call "
              f"(overhead {metrics['continued_overhead_us']:+.1f} us)")
        print(f"  warm Engine() + run     {construct_s*1e6:9.1f} us "
              f"({recompiles} recompiles)")
    return bench_payload(
        "engine", metrics,
        config={"chunk": cfg.chunk, "n_pages": cfg.n_pages, "reps": reps})


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="fewer reps (CI smoke)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="write the standardized BENCH_engine.json")
    add_check_args(ap)
    args = ap.parse_args()
    summary = run(n=args.requests or 4_096, reps=10 if args.quick else 50)
    if args.out:
        print(f"  written to {write_bench_json(args.out, summary)}")
    run_check(summary, args,
              ["us_per_call_engine", "warm_construct_recompiles"])


if __name__ == "__main__":
    main()
