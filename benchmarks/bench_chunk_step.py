"""Chunk-step microbenchmark: the everything-path of the platform.

Every request of every design point of every sweep flows through
``_chunk_step``; this bench isolates the three perf levers this repo
tunes on it, on the default paper geometry (n_banks=16, chunk=512):

* ``resolver=dense`` vs ``resolver=segmented`` — O(n_banks*chunk) one-hot
  bank-queue resolution vs the O(chunk log chunk) sort-based segmented
  max-plus scan (bitwise identical; see core.latency);
* ``gather=unfused`` vs ``gather=fused`` — separate dynamic-slice reads
  of the DMA swap pair's table rows vs appending them to the chunk's
  lookup-kernel launch (chunk + 2 rows, one gather);
* ``donate=off`` vs ``donate=on`` — continued emulation with the carried
  state's buffers copied vs donated (the packed table updates in place);
* ``kernel=off`` vs ``kernel=on`` — the restructured scan path vs the
  one-kernel Pallas chunk step (``chunk_step_kernel``; interpret mode
  off-TPU, so its absolute number is only meaningful on real hardware —
  benched at a reduced request count).

It also reports a per-stage breakdown of the chunk step itself (RX link /
gather / bank resolve / in-order return / boundary commit / policy),
measured by timing stage-truncated scans (``kernels.chunk_step.step_until``)
and differencing successive stages.

Runnable standalone::

    PYTHONPATH=src python -m benchmarks.bench_chunk_step --quick \
        --out BENCH_chunk_step.json [--check-against BENCH_chunk_step.json]

``--check-against`` is the tiered CI perf-regression gate shared by
every bench (``benchmarks.schema.check_against``): a GitHub
``::warning::`` past the warn tolerance, a failing ``::error::`` past
the fail tolerance — CI runners are noisy, so the smoke job passes a
wide fail tolerance for this wall-clock metric.
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks.bench_throughput import _bench  # shared warm-then-average
from benchmarks.schema import (add_check_args, bench_payload, run_check,
                               write_bench_json)
import jax.numpy as jnp

from repro import Engine
from repro.core import init_state, pad_trace, paper_platform
from repro.kernels import chunk_step as chunk_step_lib
from repro.trace import TraceSpec, generate

# The default hot path: what plain paper_platform() users get.
_DEFAULT_CASE = "resolver=auto/gather=fused"

# step_until stages in pipeline order; each breakdown entry is the delta
# between a stage-truncated scan and its predecessor.
_STAGE_ORDER = ("rx", "gather", "resolve", "return", "commit", "full")
_STAGE_LABEL = {"rx": "rx_link", "gather": "gather", "resolve": "resolve",
                "return": "inorder_return", "commit": "boundary_commit",
                "full": "policy"}


def _stage_breakdown(base, trace, reps, n, verbose):
    """us/req per chunk-step stage: time a scan of ``step_until`` at each
    truncation point and difference successive stages. The truncated
    steps keep the full carry structure, so each timing is a real
    end-to-end scan, not an isolated microkernel."""
    engine = Engine(base)
    params, registry = engine.params, engine.registry
    padded, valid = pad_trace(base, trace)
    n_chunks = padded.page.shape[0] // base.chunk
    chunks = jax.tree.map(lambda x: x.reshape(n_chunks, base.chunk),
                          padded)
    vchunks = valid.reshape(n_chunks, base.chunk)
    state0 = init_state(base, params)
    sc0 = chunk_step_lib.StepScalars(
        clock=state0.clock, clock_ptr=state0.clock_ptr,
        chunk_idx=state0.chunk_idx, dma=state0.dma,
        link_free_rx=state0.link_free_rx, link_free_tx=state0.link_free_tx,
        last_return=state0.last_return)

    times = {}
    for stage in _STAGE_ORDER:
        @jax.jit
        def run(table, bank_free, _stage=stage):
            def body(carry, xs):
                table, sc, bank_free = carry
                (page, offset, is_write, size), v = xs
                table, sc, bank_free, outs = chunk_step_lib.step_until(
                    base, registry, table, params, sc, bank_free,
                    page, offset, is_write, size, v, upto=_stage)
                # keep every stage's products live (returns/device plus
                # the whole carry below), or XLA dead-code-eliminates the
                # truncated stages and the deltas read as zero
                return (table, sc, bank_free), (outs["returns"],
                                                outs["device"],
                                                outs["latency"])
            carry, ys = jax.lax.scan(
                body, (table, sc0, bank_free), (chunks, vchunks))
            return carry, ys
        fn = lambda: jax.block_until_ready(  # noqa: E731
            run(state0.table, state0.bank_free))
        times[stage] = _bench(fn, reps)

    breakdown, prev = {}, 0.0
    for stage in _STAGE_ORDER:
        us = max(times[stage] - prev, 0.0) / n * 1e6
        breakdown[f"us_per_req_stage_{_STAGE_LABEL[stage]}"] = us
        prev = times[stage]
        if verbose:
            print(f"  stage {_STAGE_LABEL[stage]:16s} {us:8.3f} us/req "
                  f"(cumulative {times[stage] / n * 1e6:8.3f})")
    return breakdown


def run(verbose=True, n=32_768, reps=5, out=None):
    base = paper_platform().with_(chunk=512)
    trace = generate(TraceSpec(n_requests=n, footprint_pages=60_000,
                               write_frac=0.4, pattern="zipfian",
                               zipf_alpha=1.05))
    rows = []

    def case(name, cfg, state=None, donate=False):
        engine = Engine(cfg)
        if state is None:
            fn = lambda: jax.block_until_ready(  # noqa: E731
                engine.run(trace).state.clock)
            sec = _bench(fn, reps)
        else:
            # Continued emulation: each call consumes the previous call's
            # state — exactly the serving/incremental-sweep access pattern
            # donation exists for. Warm with the same donate flag (the
            # donated entry point is its own compilation).
            s = engine.run(trace, state=state, donate=donate).state
            jax.block_until_ready(s.clock)
            t0 = time.time()
            for _ in range(reps):
                s = engine.run(trace, state=s, donate=donate).state
            jax.block_until_ready(s.clock)
            sec = (time.time() - t0) / reps
        rows.append({"case": name, "s_per_call": sec,
                     "us_per_req": sec / n * 1e6})
        if verbose:
            print(f"  {name:38s} {sec * 1e3:9.1f} ms/call "
                  f"{rows[-1]['us_per_req']:8.3f} us/req")
        return sec

    sec_pre = case("resolver=dense/gather=unfused (pre-PR path)",
                   base.with_(bank_resolver="dense", fuse_swap_gather=False))
    sec_dense = case("resolver=dense/gather=fused",
                     base.with_(bank_resolver="dense"))
    sec_seg = case("resolver=segmented/gather=fused",
                   base.with_(bank_resolver="segmented"))
    sec_unfused = case("resolver=auto/gather=unfused",
                       base.with_(fuse_swap_gather=False))
    sec_default = case(_DEFAULT_CASE, base)

    state0 = Engine(base).run(trace).state
    sec_nodon = case("continued/donate=off", base, state=state0)
    state0 = Engine(base).run(trace).state
    sec_don = case("continued/donate=on", base, state=state0, donate=True)

    # One-kernel chunk step. Off-TPU the kernel runs in interpret mode —
    # orders of magnitude slower than compiled — so bench it on a reduced
    # trace: the case exists to pin the path end-to-end and to carry a
    # trajectory for TPU runs, not to win on CPU.
    n_kernel = min(n, 2_048)
    ktrace = jax.tree.map(lambda x: x[:n_kernel], trace)
    kcfg = base.with_(chunk_step_kernel="on")
    engine_k = Engine(kcfg)
    fn_k = lambda: jax.block_until_ready(  # noqa: E731
        engine_k.run(ktrace).state.clock)
    sec_kernel = _bench(fn_k, max(2, reps // 2))
    rows.append({"case": "kernel=on (interpret off-TPU)",
                 "s_per_call": sec_kernel,
                 "us_per_req": sec_kernel / n_kernel * 1e6,
                 "n_requests": n_kernel})
    if verbose:
        print(f"  {'kernel=on (interpret off-TPU)':38s} "
              f"{sec_kernel * 1e3:9.1f} ms/call "
              f"{rows[-1]['us_per_req']:8.3f} us/req  (n={n_kernel})")

    if verbose:
        print("  per-stage breakdown (scan path, stage-truncated scans):")
    breakdown = _stage_breakdown(base, trace, reps, n, verbose)

    metrics = {
        "n_requests": n,
        "us_per_req_default": sec_default / n * 1e6,
        "us_per_req_pre_pr_path": sec_pre / n * 1e6,
        "us_per_req_dense": sec_dense / n * 1e6,
        "us_per_req_segmented": sec_seg / n * 1e6,
        "speedup_vs_pre_pr": sec_pre / sec_default,
        "speedup_segmented_vs_dense": sec_dense / sec_seg,
        "speedup_fused_vs_unfused": sec_unfused / sec_default,
        "speedup_donate": sec_nodon / sec_don,
        "us_per_req_kernel_interpret": sec_kernel / n_kernel * 1e6,
        **breakdown,
    }
    if verbose:
        print(f"  vs pre-PR path: {metrics['speedup_vs_pre_pr']:.2f}x, "
              f"segmented vs dense: {metrics['speedup_segmented_vs_dense']:.2f}x, "
              f"fused vs unfused: {metrics['speedup_fused_vs_unfused']:.2f}x, "
              f"donated continuation: {metrics['speedup_donate']:.2f}x")
    summary = bench_payload(
        "chunk_step", metrics,
        config={"chunk": base.chunk, "n_banks": base.n_banks,
                "n_pages": base.n_pages, "reps": reps,
                "n_kernel": n_kernel},
        cases=rows)
    if out:
        path = write_bench_json(out, summary)
        if verbose:
            print(f"  written to {path}")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="8k requests, 2 reps (CI smoke)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="write the standardized BENCH_chunk_step.json")
    add_check_args(ap)
    args = ap.parse_args()
    n = args.requests or (8_192 if args.quick else 32_768)
    summary = run(n=n, reps=2 if args.quick else 5, out=args.out)
    run_check(summary, args,
              ["us_per_req_default", "us_per_req_kernel_interpret"])


if __name__ == "__main__":
    main()
