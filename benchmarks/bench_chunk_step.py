"""Chunk-step microbenchmark: the everything-path of the platform.

Every request of every design point of every sweep flows through
``_chunk_step``; this bench isolates the three perf levers this repo
tunes on it, on the default paper geometry (n_banks=16, chunk=512):

* ``resolver=dense`` vs ``resolver=segmented`` — O(n_banks*chunk) one-hot
  bank-queue resolution vs the O(chunk log chunk) sort-based segmented
  max-plus scan (bitwise identical; see core.latency);
* ``gather=unfused`` vs ``gather=fused`` — separate dynamic-slice reads
  of the DMA swap pair's table rows vs appending them to the chunk's
  lookup-kernel launch (chunk + 2 rows, one gather);
* ``donate=off`` vs ``donate=on`` — continued emulation with the carried
  state's buffers copied vs donated (the packed table updates in place).

Runnable standalone::

    PYTHONPATH=src python -m benchmarks.bench_chunk_step --quick \
        --out BENCH_chunk_step.json [--check-against BENCH_chunk_step.json]

``--check-against`` is the tiered CI perf-regression gate shared by
every bench (``benchmarks.schema.check_against``): a GitHub
``::warning::`` past the warn tolerance, a failing ``::error::`` past
the fail tolerance — CI runners are noisy, so the smoke job passes a
wide fail tolerance for this wall-clock metric.
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks.bench_throughput import _bench  # shared warm-then-average
from benchmarks.schema import (add_check_args, bench_payload, run_check,
                               write_bench_json)
from repro import Engine
from repro.core import paper_platform
from repro.trace import TraceSpec, generate

# The default hot path: what plain paper_platform() users get.
_DEFAULT_CASE = "resolver=auto/gather=fused"


def run(verbose=True, n=32_768, reps=5, out=None):
    base = paper_platform().with_(chunk=512)
    trace = generate(TraceSpec(n_requests=n, footprint_pages=60_000,
                               write_frac=0.4, pattern="zipfian",
                               zipf_alpha=1.05))
    rows = []

    def case(name, cfg, state=None, donate=False):
        engine = Engine(cfg)
        if state is None:
            fn = lambda: jax.block_until_ready(  # noqa: E731
                engine.run(trace).state.clock)
            sec = _bench(fn, reps)
        else:
            # Continued emulation: each call consumes the previous call's
            # state — exactly the serving/incremental-sweep access pattern
            # donation exists for. Warm with the same donate flag (the
            # donated entry point is its own compilation).
            s = engine.run(trace, state=state, donate=donate).state
            jax.block_until_ready(s.clock)
            t0 = time.time()
            for _ in range(reps):
                s = engine.run(trace, state=s, donate=donate).state
            jax.block_until_ready(s.clock)
            sec = (time.time() - t0) / reps
        rows.append({"case": name, "s_per_call": sec,
                     "us_per_req": sec / n * 1e6})
        if verbose:
            print(f"  {name:38s} {sec * 1e3:9.1f} ms/call "
                  f"{rows[-1]['us_per_req']:8.3f} us/req")
        return sec

    sec_pre = case("resolver=dense/gather=unfused (pre-PR path)",
                   base.with_(bank_resolver="dense", fuse_swap_gather=False))
    sec_dense = case("resolver=dense/gather=fused",
                     base.with_(bank_resolver="dense"))
    sec_seg = case("resolver=segmented/gather=fused",
                   base.with_(bank_resolver="segmented"))
    sec_unfused = case("resolver=auto/gather=unfused",
                       base.with_(fuse_swap_gather=False))
    sec_default = case(_DEFAULT_CASE, base)

    state0 = Engine(base).run(trace).state
    sec_nodon = case("continued/donate=off", base, state=state0)
    state0 = Engine(base).run(trace).state
    sec_don = case("continued/donate=on", base, state=state0, donate=True)

    metrics = {
        "n_requests": n,
        "us_per_req_default": sec_default / n * 1e6,
        "us_per_req_pre_pr_path": sec_pre / n * 1e6,
        "us_per_req_dense": sec_dense / n * 1e6,
        "us_per_req_segmented": sec_seg / n * 1e6,
        "speedup_vs_pre_pr": sec_pre / sec_default,
        "speedup_segmented_vs_dense": sec_dense / sec_seg,
        "speedup_fused_vs_unfused": sec_unfused / sec_default,
        "speedup_donate": sec_nodon / sec_don,
    }
    if verbose:
        print(f"  vs pre-PR path: {metrics['speedup_vs_pre_pr']:.2f}x, "
              f"segmented vs dense: {metrics['speedup_segmented_vs_dense']:.2f}x, "
              f"fused vs unfused: {metrics['speedup_fused_vs_unfused']:.2f}x, "
              f"donated continuation: {metrics['speedup_donate']:.2f}x")
    summary = bench_payload(
        "chunk_step", metrics,
        config={"chunk": base.chunk, "n_banks": base.n_banks,
                "n_pages": base.n_pages, "reps": reps},
        cases=rows)
    if out:
        path = write_bench_json(out, summary)
        if verbose:
            print(f"  written to {path}")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="8k requests, 2 reps (CI smoke)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="write the standardized BENCH_chunk_step.json")
    add_check_args(ap)
    args = ap.parse_args()
    n = args.requests or (8_192 if args.quick else 32_768)
    summary = run(n=n, reps=2 if args.quick else 5, out=args.out)
    run_check(summary, args, ["us_per_req_default"])


if __name__ == "__main__":
    main()
