"""Standardized BENCH_*.json payloads for the perf trajectory.

Every benchmark that persists machine-readable results writes the same
envelope, so cross-PR tooling (and the CI soft-regression check) can diff
runs without per-bench parsing:

    {
      "bench": "<name>",            # e.g. "sweep", "chunk_step"
      "schema_version": 1,
      "created_unix": <int>,        # wall-clock of the run
      "jax": "<version>", "backend": "cpu" | "tpu" | ...,
      "config": {...},              # knobs the numbers depend on
      "metrics": {...},             # flat name -> number map (the data)
      "cases": [...],               # optional per-case rows
    }

Convention: files live at the repo root as ``BENCH_<name>.json`` and are
committed when a PR moves a number, giving each benchmark a trajectory in
git history; CI regenerates them as workflow artifacts on every run.
"""
from __future__ import annotations

import json
import time

SCHEMA_VERSION = 1


def bench_payload(name: str, metrics: dict, *, config: dict | None = None,
                  cases: list | None = None, **extra) -> dict:
    import jax

    payload = {
        "bench": name,
        "schema_version": SCHEMA_VERSION,
        "created_unix": int(time.time()),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "config": config or {},
        "metrics": metrics,
    }
    if cases is not None:
        payload["cases"] = cases
    payload.update(extra)
    return payload


def write_bench_json(path, payload: dict) -> str:
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    return str(path)


def load_bench_json(path) -> dict:
    with open(path) as fh:
        return json.load(fh)
