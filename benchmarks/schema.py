"""Standardized BENCH_*.json payloads for the perf trajectory.

Every benchmark that persists machine-readable results writes the same
envelope, so cross-PR tooling (and the CI soft-regression check) can diff
runs without per-bench parsing:

    {
      "bench": "<name>",            # e.g. "sweep", "chunk_step"
      "schema_version": 1,
      "created_unix": <int>,        # wall-clock of the run
      "jax": "<version>", "backend": "cpu" | "tpu" | ...,
      "config": {...},              # knobs the numbers depend on
      "metrics": {...},             # flat name -> number map (the data)
      "cases": [...],               # optional per-case rows
    }

Convention: files live at the repo root as ``BENCH_<name>.json`` and are
committed when a PR moves a number, giving each benchmark a trajectory in
git history; CI regenerates them as workflow artifacts on every run.

``check_against`` is the one perf-regression gate every bench shares
(bench_chunk_step, bench_sweep, bench_engine, bench_serve): it compares
selected metrics of a fresh payload against the committed baseline and
grades each on a **tiered** scale — OK within the warn tolerance, a
GitHub ``::warning::`` annotation above it, a ``::error::`` (and a
failing exit code via ``run_check``) above the fail tolerance. CI
runners are noisy, so wall-clock benches pass a wider fail tolerance;
deterministic metrics (compile counts, emulated latencies, SLO rates)
gate at the defaults.
"""
from __future__ import annotations

import json
import sys
import time

SCHEMA_VERSION = 1

DEFAULT_WARN_TOLERANCE = 1.10   # >10% regression: warn
DEFAULT_FAIL_TOLERANCE = 2.00   # >2x regression: fail


def bench_payload(name: str, metrics: dict, *, config: dict | None = None,
                  cases: list | None = None, **extra) -> dict:
    import jax

    payload = {
        "bench": name,
        "schema_version": SCHEMA_VERSION,
        "created_unix": int(time.time()),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "config": config or {},
        "metrics": metrics,
    }
    if cases is not None:
        payload["cases"] = cases
    payload.update(extra)
    return payload


def write_bench_json(path, payload: dict) -> str:
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    return str(path)


def load_bench_json(path) -> dict:
    with open(path) as fh:
        return json.load(fh)


def _ratio(got: float, want: float, higher_better: bool) -> float:
    """Regression ratio, 1.0 = parity, >1 = worse than baseline."""
    num, den = (want, got) if higher_better else (got, want)
    if den == 0:
        return 1.0 if num == 0 else float("inf")
    return num / den


def check_against(summary: dict, baseline_path: str, metrics: list[str], *,
                  warn_tolerance: float = DEFAULT_WARN_TOLERANCE,
                  fail_tolerance: float = DEFAULT_FAIL_TOLERANCE,
                  higher_better: tuple[str, ...] = (),
                  metrics_key: str = "metrics") -> bool:
    """Tiered perf-regression gate vs a committed baseline payload.

    Grades each named metric of ``summary[metrics_key]`` against the
    baseline's: within ``warn_tolerance`` is OK, beyond it prints a
    GitHub ``::warning::`` annotation, beyond ``fail_tolerance`` prints
    an ``::error::`` and fails the gate (returns False). Metrics in
    ``higher_better`` regress downward (hit rates, SLO attainment,
    speedups). A missing/unreadable baseline or metric soft-skips with a
    warning — a fresh checkout must not fail on its first run.
    ``metrics_key`` selects an alternate metrics map in both payloads
    (bench_serve's like-for-like ``--quick`` profile).
    """
    name = summary.get("bench", "bench")
    try:
        base = load_bench_json(baseline_path)[metrics_key]
    except (OSError, ValueError, KeyError, TypeError) as e:
        print(f"::warning title={name} perf baseline unusable::"
              f"{baseline_path}: {e!r} — skipping the perf gate")
        return True
    ok = True
    for m in metrics:
        got, want = summary[metrics_key].get(m), base.get(m)
        if got is None or want is None:
            print(f"::warning title={name} perf baseline incomplete::"
                  f"metric {m!r} absent from "
                  f"{'baseline' if got is not None else 'payload'} — skipped")
            continue
        r = _ratio(got, want, m in higher_better)
        detail = (f"{m} {got:.4g} vs baseline {want:.4g} "
                  f"(x{r:.2f} regression)")
        if r <= warn_tolerance:
            print(f"  perf gate OK: {detail}")
        elif r <= fail_tolerance:
            print(f"::warning title={name} perf regression::{detail} "
                  f"exceeds the x{warn_tolerance:.2f} warn tolerance")
        else:
            print(f"::error title={name} perf regression::{detail} "
                  f"exceeds the x{fail_tolerance:.2f} fail tolerance")
            ok = False
    return ok


def add_check_args(ap, *, fail_tolerance: float = DEFAULT_FAIL_TOLERANCE,
                   warn_tolerance: float = DEFAULT_WARN_TOLERANCE) -> None:
    """The shared ``--check-against`` CLI surface."""
    ap.add_argument("--check-against", default=None,
                    help="tiered perf-regression gate vs a committed "
                         "BENCH_*.json (warn > warn-tolerance, fail > "
                         "fail-tolerance)")
    ap.add_argument("--warn-tolerance", type=float, default=warn_tolerance,
                    help=f"warn threshold multiplier (default "
                         f"{warn_tolerance:g}x)")
    ap.add_argument("--fail-tolerance", type=float, default=fail_tolerance,
                    help=f"fail threshold multiplier (default "
                         f"{fail_tolerance:g}x)")


def run_check(summary: dict, args, metrics: list[str], *,
              higher_better: tuple[str, ...] = (),
              metrics_key: str = "metrics") -> None:
    """Apply the gate per the parsed ``add_check_args`` flags; exits 1
    on a fail-tier regression."""
    if not args.check_against:
        return
    ok = check_against(summary, args.check_against, metrics,
                       warn_tolerance=args.warn_tolerance,
                       fail_tolerance=args.fail_tolerance,
                       higher_better=higher_better,
                       metrics_key=metrics_key)
    if not ok:
        sys.exit(1)
