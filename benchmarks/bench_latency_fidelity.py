"""Paper Table I / §III-F: arbitrary-latency emulation fidelity.

For each NVM technology, run an all-slow-tier uniform trace at low load
and compare the measured per-request read latency against the analytic
expectation (link RTT + serialization + device latency + transfer).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro import Engine
from repro.core import TECHNOLOGIES, Trace, paper_platform


def expected_read_latency(cfg) -> float:
    """Analytic end-to-end read latency at zero load, measured from issue:
    RX serialization + link RTT + device latency + media transfer + TX
    serialization (no queueing at a large issue gap)."""
    t = cfg.slow
    rx = int(np.ceil(16 / cfg.link_bytes_per_cycle))
    tx = int(np.ceil(64 / cfg.link_bytes_per_cycle))
    xfer = int(np.ceil(64 / t.bytes_per_cycle))
    return rx + tx + cfg.link_lat + t.read_lat + xfer


def run(verbose=True):
    rows = []
    rng = np.random.default_rng(0)
    n = 2048
    for name, tech in TECHNOLOGIES.items():
        if name == "hdd":
            continue                      # not a memory-bus technology
        cfg = paper_platform().with_(slow=tech, policy="static", chunk=1,
                                     issue_gap=4096)  # low load: no queueing
        page = rng.integers(cfg.n_fast_pages, cfg.n_pages, n).astype(np.int32)
        t = Trace(jnp.asarray(page),
                  jnp.zeros(n, jnp.int32),
                  jnp.zeros(n, bool),
                  jnp.full(n, 64, jnp.int32))
        summ = Engine(cfg).run(t).summary()
        exp = expected_read_latency(cfg)
        rows.append({"technology": name,
                     "configured_read_ns": tech.read_lat,
                     "expected_e2e_ns": exp,
                     "measured_e2e_ns": summ["mean_read_latency_cyc"],
                     "rel_err": abs(summ["mean_read_latency_cyc"] - exp) / exp})
        if verbose:
            r = rows[-1]
            print(f"  {name:10s} device {r['configured_read_ns']:>7}ns  "
                  f"e2e expected {r['expected_e2e_ns']:>8.0f}  measured "
                  f"{r['measured_e2e_ns']:>9.1f}  err {r['rel_err']*100:.2f}%")
    return rows
