"""Emulation-platform throughput: requests/second of the HMES pipeline vs
chunk width and parallel channels (the FPGA-parallelism analogue). This is
the paper-technique perf surface tracked in EXPERIMENTS.md §Perf."""
from __future__ import annotations

import time

import jax

from repro import Engine
from repro.core import Trace, paper_platform
from repro.trace import TraceSpec, generate
import jax.numpy as jnp


def _bench(fn, reps=3):
    fn()
    t0 = time.time()
    for _ in range(reps):
        fn()
    return (time.time() - t0) / reps


def run(verbose=True, n=65_536):
    spec = TraceSpec(n_requests=n, footprint_pages=100_000, pattern="zipfian")
    trace = generate(spec)
    rows = []
    for chunk in (256, 1024, 4096):
        engine = Engine(paper_platform().with_(chunk=chunk))
        sec = _bench(lambda: jax.block_until_ready(
            engine.run(trace).state.clock))
        rows.append({"mode": f"chunk={chunk}", "us_per_req": sec / n * 1e6,
                     "req_per_s": n / sec})
        if verbose:
            print(f"  chunk={chunk:5d}              "
                  f"{rows[-1]['us_per_req']:7.3f} us/req  "
                  f"({rows[-1]['req_per_s']:,.0f} req/s)")

    # spatial parallelism: C independent channels (vmap)
    for channels in (4, 16):
        cfg = paper_platform().with_(chunk=1024)
        engine = Engine(cfg)
        per = n // channels
        per = per - per % cfg.chunk
        t = Trace(*(jnp.stack([x[i*per:(i+1)*per] for i in range(channels)])
                    for x in trace))
        sec = _bench(lambda: jax.block_until_ready(
            engine.run_channels(t)[0].clock))
        total = per * channels
        rows.append({"mode": f"channels={channels}",
                     "us_per_req": sec / total * 1e6,
                     "req_per_s": total / sec})
        if verbose:
            print(f"  channels={channels:3d} (chunk 1024) "
                  f"{rows[-1]['us_per_req']:7.3f} us/req  "
                  f"({rows[-1]['req_per_s']:,.0f} req/s)")
    return rows
