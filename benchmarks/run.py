"""Benchmark harness — one bench per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints one CSV line per bench: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    from benchmarks import (bench_chunk_step, bench_engine,
                            bench_latency_fidelity, bench_policies,
                            bench_request_volume, bench_serve, bench_speedup,
                            bench_sweep, bench_throughput)

    csv = []

    print("== Fig 7: simulation time vs native (slowdowns & speedups) ==")
    rows, summary = bench_speedup.run(
        scale=3e-9 if args.quick else 6e-9,
        workloads=["505.mcf", "538.imagick"] if args.quick else None)
    emu_us = 1e6 * sum(r["native_s"] * r["emu_slowdown"] for r in rows) / \
        sum(r["requests"] for r in rows)
    csv.append(("fig7_speedup", f"{emu_us:.3f}",
                f"geomean_speedup_vs_gem5class={summary['speedup_vs_cyclesim']:.1f}x;"
                f"vs_champsimclass={summary['speedup_vs_tracesim']:.1f}x;"
                f"emu_slowdown={summary['emu_slowdown']:.1f}x"))

    print("== Fig 8: memory request volumes ==")
    vol = bench_request_volume.run(scale=2e-9 if args.quick else 4e-9)
    mx = max(vol, key=lambda r: r["paper_scale_TB_read"])
    csv.append(("fig8_request_volume", "0",
                f"max_workload={mx['workload']};"
                f"max_TB={mx['paper_scale_TB_read']+mx['paper_scale_TB_written']:.2f}"))

    print("== Table I: arbitrary-latency emulation fidelity ==")
    fid = bench_latency_fidelity.run()
    worst = max(r["rel_err"] for r in fid)
    csv.append(("tableI_latency_fidelity", "0", f"worst_rel_err={worst:.4f}"))

    print("== Policy design-space exploration (platform use case) ==")
    pol = bench_policies.run(n_requests=30_000 if args.quick else 120_000)
    best = min(pol, key=lambda r: r["mean_read_latency"])
    static = [r for r in pol if r["policy"] == "static"][0]
    csv.append(("policy_exploration", "0",
                f"best={best['policy']};"
                f"latency_gain={static['mean_read_latency']/best['mean_read_latency']:.2f}x"))

    print("== Design-space sweep (one compiled vmapped emulation) ==")
    sw = bench_sweep.run(n_requests=20_000 if args.quick else 100_000)
    csv.append(("design_space_sweep", f"{sw['us_per_point_req']:.3f}",
                f"points={sw['n_points']};compiles={sw['compiles']};"
                f"best={sw['best_label']};best_amat={sw['best_amat']:.1f}"))

    print("== Chunk-step hot path (resolver / gather fusion / donation) ==")
    cs = bench_chunk_step.run(n=8_192 if args.quick else 32_768,
                              reps=2 if args.quick else 5)
    m = cs["metrics"]
    csv.append(("chunk_step", f"{m['us_per_req_default']:.3f}",
                f"seg_vs_dense={m['speedup_segmented_vs_dense']:.2f}x;"
                f"fused_vs_unfused={m['speedup_fused_vs_unfused']:.2f}x;"
                f"donate={m['speedup_donate']:.2f}x"))

    print("== Session API dispatch overhead (Engine vs raw jit) ==")
    ev = bench_engine.run(reps=10 if args.quick else 50)
    em = ev["metrics"]
    csv.append(("engine_dispatch", f"{em['us_per_call_engine']:.1f}",
                f"overhead={em['dispatch_overhead_us']:+.1f}us;"
                f"warm_recompiles={em['warm_construct_recompiles']}"))

    print("== Serving SLO (continuous batching over the tiered KV) ==")
    sv, _ = bench_serve.run_profile("quick" if args.quick else "full")
    csv.append(("serve_slo", f"{sv['p99_latency_us']:.0f}",
                f"slo_attainment={sv['slo_attainment']:.3f};"
                f"pinned_fast_hit={sv['pinned_fast_hit_rate']:.3f};"
                f"live_peak={sv['live_seqs_high_water']};"
                f"recompiles={sv['recompiles_after_warmup']}"))

    print("== Emulator throughput (chunk width / channels) ==")
    thr = bench_throughput.run(n=16_384 if args.quick else 65_536)
    best_thr = min(thr, key=lambda r: r["us_per_req"])
    csv.append(("emulator_throughput", f"{best_thr['us_per_req']:.3f}",
                f"best_mode={best_thr['mode']};req_per_s={best_thr['req_per_s']:.0f}"))

    print("\nname,us_per_call,derived")
    for name, us, derived in csv:
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
