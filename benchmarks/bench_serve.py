"""Serving-SLO benchmark: continuous batching at 100k+ live sequences.

Drives the ``repro.serve`` continuous-batching scheduler over the HMMU
session with mixed prefill/decode traffic — the workload the ROADMAP's
serving front-end item calls for: ≥100k concurrent sequences, bucketed
padded dispatch (every shape pre-compiled; zero recompiles after warmup,
asserted via ``Engine.compile_count``), per-sequence pin contracts, and
cold-KV eviction under real memory pressure (the watermarks are set so
the live page demand crosses them).

Three profiles, all in the committed ``BENCH_serve.json``:

* **full** (default): 110k sequences through a 100k-live-slot scheduler
  on a serving-size geometry — the headline ``metrics``;
* **quick** (``--quick``, CI): the same shape scaled to seconds — the
  ``quick_metrics`` map. The emulated numbers (p50/p99 latency, SLO
  attainment, pinned fast-hit rate, evictions) are **deterministic**,
  so CI gates ``--quick --check-against BENCH_serve.json`` like-for-like
  against the committed ``quick_metrics`` at the default tight
  tolerances (schema.check_against); wall-clock is reported, not gated.
* **degraded** (runs with ``--quick``): the quick profile under a seeded
  :func:`~repro.core.faults.seeded_plan` that kills ~5% of the fast
  tier's frames mid-run — the graceful-degradation gate. Hard floors
  are asserted in-process (SLO attainment >= 0.99, pinned fast-hit
  >= 0.95 despite the retirement burst), and the deterministic metrics
  gate like-for-like against the committed ``degraded_metrics``.

Runnable standalone::

    PYTHONPATH=src python -m benchmarks.bench_serve --quick \
        --out BENCH_serve.json --bucket-table serve_buckets.csv \
        [--check-against BENCH_serve.json] [--summary-md summary.md]

Per-sequence latency is the emulated span from first prefill issue to
last decode return, in us at the 1 GHz fabric clock.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.schema import (add_check_args, bench_payload, check_against,
                               run_check, write_bench_json)
from repro import Engine
from repro.analysis import assert_compile_flat
from repro.core import paper_platform, seeded_plan
from repro.serve import ContinuousBatchingScheduler, ServeConfig

# Deterministic emulated metrics, gated like-for-like against the
# committed baseline; rates regress downward.
GATED_METRICS = ["p50_latency_us", "p99_latency_us", "slo_attainment",
                 "pinned_fast_hit_rate", "recompiles_after_warmup"]
HIGHER_BETTER = ("slo_attainment", "pinned_fast_hit_rate")

# Fast tier sized to hold every pin contract; slow tier sized so the
# live KV demand crosses the eviction watermarks (pressure is real).
PROFILES = {
    "full": dict(
        geometry=dict(n_fast_pages=131072, n_slow_pages=163840, chunk=512),
        serve=dict(sorted_batch_sizes=(8192, 16384, 32768),
                   max_live_seqs=100_000, max_live_batches=2,
                   max_admit_per_step=4096, pin_pages_per_seq=1,
                   max_pages_per_seq=6, positions_per_page=64,
                   window_pages=2, prefill_writes_per_page=2,
                   free_low_frac=0.15, free_high_frac=0.18,
                   slo_latency_us=120_000.0, pinned_slo=0.90),
        n_seqs=110_000, decode_lo=8, decode_hi=41, min_live=100_000),
    "quick": dict(
        geometry=dict(n_fast_pages=8192, n_slow_pages=10240, chunk=256),
        serve=dict(sorted_batch_sizes=(1024, 2048, 4096),
                   max_live_seqs=5_000, max_live_batches=2,
                   max_admit_per_step=512, pin_pages_per_seq=1,
                   max_pages_per_seq=6, positions_per_page=16,
                   window_pages=2, prefill_writes_per_page=2,
                   free_low_frac=0.28, free_high_frac=0.32,
                   slo_latency_us=5_000.0, pinned_slo=0.90),
        n_seqs=6_000, decode_lo=8, decode_hi=25, min_live=5_000),
}

# Graceful-degradation profile: quick, plus a seeded fault plan whose
# deaths retire ~5% of the fast tier's frames spread across the run
# (~1100 emulated chunks). The recovery path (retire -> re-place ->
# renegotiate) must hold the hard floors below.
PROFILES["degraded"] = dict(
    PROFILES["quick"],
    faults=dict(seed=20, fast_frac=0.05, n_chunks=1100),
    floors=dict(slo_attainment=0.99, pinned_fast_hit_rate=0.95))


def _workload(n_seqs: int, lo: int, hi: int, seed: int = 0):
    """Mixed prompts: mostly short, a long tail of 4-page prompts whose
    cold middle pages become the eviction victims."""
    rng = np.random.default_rng(seed)
    prompt = rng.choice([1, 2, 3, 4], size=n_seqs, p=[0.6, 0.2, 0.1, 0.1])
    decode = rng.integers(lo, hi, size=n_seqs)
    return prompt.astype(np.int32), decode.astype(np.int32)


def run_profile(name: str, verbose: bool = True) -> tuple[dict, dict]:
    """Run one profile; returns (metrics, per_bucket table)."""
    prof = PROFILES[name]
    cfg = paper_platform().with_(**prof["geometry"])
    engine = Engine(cfg)
    serve_kwargs = dict(prof["serve"])
    if prof.get("faults"):
        f = prof["faults"]
        nf = prof["geometry"]["n_fast_pages"]
        serve_kwargs["faults"] = seeded_plan(
            f["seed"], pages=np.arange(nf), n_chunks=f["n_chunks"],
            n_deaths=int(f["fast_frac"] * nf))
    sched = ContinuousBatchingScheduler(engine, ServeConfig(**serve_kwargs))
    t0 = time.time()
    sched.warmup()
    warm_s = time.time() - t0

    prompt, decode = _workload(prof["n_seqs"], prof["decode_lo"],
                               prof["decode_hi"])
    t0 = time.time()
    with assert_compile_flat(
            engine, msg="a dispatch shape escaped the bucket list "
            f"{prof['serve']['sorted_batch_sizes']}") as cc:
        sched.submit(prompt, decode)
        sched.run()
    wall_s = time.time() - t0
    rep = sched.report()
    recompiles = cc.count
    assert rep.live_seqs_high_water >= prof["min_live"], \
        f"only {rep.live_seqs_high_water} concurrent sequences " \
        f"(wanted >= {prof['min_live']})"
    assert rep.n_sequences == prof["n_seqs"]

    metrics = {
        "n_sequences": rep.n_sequences,
        "n_mem_requests": rep.n_mem_requests,
        "n_dispatches": rep.n_dispatches,
        "live_seqs_high_water": rep.live_seqs_high_water,
        "inflight_high_water": rep.inflight_high_water,
        "p50_latency_us": rep.p50_latency_us,
        "p99_latency_us": rep.p99_latency_us,
        "mean_latency_us": rep.mean_latency_us,
        "slo_latency_us": rep.slo_latency_us,
        "slo_attainment": rep.slo_attainment,
        "pinned_accesses": rep.pinned_accesses,
        "pinned_fast_hit_rate": rep.pinned_fast_hit_rate,
        "evictions": rep.evictions,
        "refetches": rep.refetches,
        "frames_retired": rep.frames_retired,
        "fault_refetches": rep.fault_refetches,
        "renegotiations": rep.renegotiations,
        "recompiles_after_warmup": recompiles,
        "warmup_s": warm_s,
        "wall_s": wall_s,
        "req_per_s": rep.n_mem_requests / wall_s if wall_s else 0.0,
    }
    for metric, floor in prof.get("floors", {}).items():
        assert metrics[metric] >= floor, \
            f"degradation floor broken: {metric} {metrics[metric]:.4f} " \
            f"< {floor} with {rep.frames_retired} frames retired"
    if verbose:
        print(f"  [{name}] {rep.n_sequences} seqs "
              f"(peak {rep.live_seqs_high_water} live), "
              f"{rep.n_mem_requests} requests in {rep.n_dispatches} "
              f"dispatches, {wall_s:.1f}s wall "
              f"({metrics['req_per_s']:,.0f} req/s)")
        print(f"  [{name}] latency p50 {rep.p50_latency_us:.0f} us, "
              f"p99 {rep.p99_latency_us:.0f} us, SLO({rep.slo_latency_us:.0f} "
              f"us) attainment {rep.slo_attainment:.3f}")
        print(f"  [{name}] pinned fast-hit {rep.pinned_fast_hit_rate:.3f} "
              f"({rep.pinned_accesses} accesses), evictions {rep.evictions}, "
              f"refetches {rep.refetches}, recompiles {recompiles}")
        if rep.frames_retired:
            print(f"  [{name}] degradation: {rep.frames_retired} frames "
                  f"retired, {rep.fault_refetches} fault refetches, "
                  f"{rep.renegotiations} contract renegotiations")
    return metrics, rep.per_bucket


def write_bucket_table(path: str, per_bucket: dict) -> str:
    cols = ["dispatches", "requests", "padded", "service_lat_mean_us",
            "service_lat_max", "pinned_accesses", "pinned_fast_hits"]
    with open(path, "w") as fh:
        fh.write(",".join(["size"] + cols) + "\n")
        for size, row in sorted(per_bucket.items()):
            fh.write(",".join([str(size)] + [str(row.get(c, ""))
                                             for c in cols]) + "\n")
    return path


def write_summary_md(path: str, payloads: dict[str, dict]) -> None:
    """Append the SLO table to a markdown file ($GITHUB_STEP_SUMMARY)."""
    with open(path, "a") as fh:
        fh.write("## Serving SLO (bench_serve)\n\n")
        fh.write("| profile | seqs (peak live) | p50 us | p99 us | "
                 "SLO attainment | pinned fast-hit | evictions | "
                 "recompiles |\n|---|---|---|---|---|---|---|---|\n")
        for name, m in payloads.items():
            fh.write(f"| {name} | {m['n_sequences']} "
                     f"({m['live_seqs_high_water']}) "
                     f"| {m['p50_latency_us']:.0f} | {m['p99_latency_us']:.0f} "
                     f"| {m['slo_attainment']:.3f} "
                     f"| {m['pinned_fast_hit_rate']:.3f} | {m['evictions']} "
                     f"| {m['recompiles_after_warmup']} |\n")
        fh.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="quick profile only (CI smoke; deterministic "
                         "metrics gate like-for-like vs quick_metrics)")
    ap.add_argument("--out", default=None,
                    help="write the standardized BENCH_serve.json")
    ap.add_argument("--bucket-table", default=None,
                    help="write the per-bucket latency table CSV")
    ap.add_argument("--summary-md", default=None,
                    help="append the SLO table to a markdown file "
                         "($GITHUB_STEP_SUMMARY)")
    add_check_args(ap)
    args = ap.parse_args()

    quick_metrics, per_bucket = run_profile("quick")
    degraded_metrics, _ = run_profile("degraded")
    summaries = {"quick": quick_metrics, "degraded": degraded_metrics}
    if args.quick:
        metrics = quick_metrics
    else:
        metrics, per_bucket = run_profile("full")
        summaries["full"] = metrics

    payload = bench_payload(
        "serve", metrics,
        config={k: dict(geometry=p["geometry"], serve=p["serve"],
                        n_seqs=p["n_seqs"], faults=p.get("faults"),
                        floors=p.get("floors"))
                for k, p in PROFILES.items()},
        cases=[dict(size=s, **row) for s, row in sorted(per_bucket.items())],
        quick_metrics=quick_metrics, degraded_metrics=degraded_metrics)
    if args.out:
        print(f"  written to {write_bench_json(args.out, payload)}")
    if args.bucket_table:
        print(f"  bucket table written to "
              f"{write_bucket_table(args.bucket_table, per_bucket)}")
    if args.summary_md:
        write_summary_md(args.summary_md, summaries)
    run_check(payload, args, GATED_METRICS, higher_better=HIGHER_BETTER,
              metrics_key="quick_metrics" if args.quick else "metrics")
    if args.check_against:
        # The degradation gate rides the same tiered check, against the
        # committed degraded_metrics (frames_retired joins the gate so a
        # silently-inert fault plan fails loudly).
        ok = check_against(
            payload, args.check_against,
            GATED_METRICS + ["frames_retired"],
            warn_tolerance=args.warn_tolerance,
            fail_tolerance=args.fail_tolerance,
            higher_better=HIGHER_BETTER + ("frames_retired",),
            metrics_key="degraded_metrics")
        if not ok:
            sys.exit(1)


if __name__ == "__main__":
    main()
